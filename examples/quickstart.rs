//! Quickstart: build a small ICSML model through the public API and run
//! it on all three backends — the ST-interpreter PLC (generated ICSML
//! code), the native engine, and (when artifacts exist) the AOT/XLA
//! comparator — printing agreement and modeled PLC timing.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use icsml::api::{Backend, EngineBackend, Session as _, StBackend};
use icsml::engine::{Act, Layer, Model};
use icsml::plc::HwProfile;
use icsml::porting::{codegen::CodegenOptions, generate_st_program,
                     LayerSpec, ModelSpec};
use icsml::util::{binio, json::Json, rng::SplitMix64};

fn main() -> Result<()> {
    println!("== ICSML quickstart: a 8-16-4 MLP on three backends\n");

    // 1. Author a model (any trained weights would do; random here).
    let mut rng = SplitMix64::new(2024);
    let sizes = [8usize, 16, 4];
    let acts = ["relu", "linear"];
    let mut layers = Vec::new();
    let mut specs = Vec::new();
    let dir = std::env::temp_dir().join("icsml_quickstart");
    std::fs::create_dir_all(&dir)?;
    for i in 0..2 {
        let (n_in, n_out) = (sizes[i], sizes[i + 1]);
        let w: Vec<f32> =
            (0..n_in * n_out).map(|_| rng.uniform(-0.8, 0.8) as f32).collect();
        let b: Vec<f32> =
            (0..n_out).map(|_| rng.uniform(-0.2, 0.2) as f32).collect();
        // Export in ICSML binary format (what BINARR loads).
        binio::write_f32(&dir.join(format!("l{i}_w.bin")), &w)?;
        binio::write_f32(&dir.join(format!("l{i}_b.bin")), &b)?;
        layers.push(Layer::dense(w, b, n_in, Act::from_name(acts[i]).unwrap()));
        specs.push(LayerSpec {
            inputs: n_in,
            neurons: n_out,
            weights: format!("l{i}_w.bin"),
            biases: format!("l{i}_b.bin"),
        });
    }
    let spec = ModelSpec {
        name: "quickstart".into(),
        sizes: sizes.to_vec(),
        activations: acts.iter().map(|s| s.to_string()).collect(),
        weights_dir: ".".into(),
        layers: specs,
        report: Json::Null,
    };

    // 2. Port to ICSML ST (the paper's §4.3 flow, automated).
    let st_src = generate_st_program(&spec, &CodegenOptions::default());
    println!("generated {} lines of ICSML ST\n", st_src.lines().count());

    // 3. Run the same input everywhere.
    let x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).sin()).collect();

    // Backends are immutable, shareable handles; inference happens
    // through per-caller sessions (the Engine/Session split).
    let engine = EngineBackend::new(Model::new(layers));
    let y_engine = engine.session()?.infer(&x)?;

    let mut interp = icsml::icsml_st::load(&st_src)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    interp.io_dir = dir;
    let st = StBackend::new(interp, "MAIN")?;
    let mut st_session = st.session()?;
    let y_st = st_session.infer(&x)?;

    println!("engine : {y_engine:?}");
    println!("st/plc : {y_st:?}");
    let max_dev = y_engine
        .iter()
        .zip(&y_st)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max deviation: {max_dev:.2e}\n");
    assert!(max_dev < 1e-5);

    // 4. Modeled on-PLC cost of the ST inference (metered on the
    //    session that ran it).
    if let Some(m) = st_session.last_meter() {
        for p in [HwProfile::beaglebone(), HwProfile::wago_pfc100()] {
            println!("modeled CPU time on {:>18}: {:>8.1} µs", p.name,
                     p.time_us(&m));
        }
    }

    // 5. Optional: the AOT/XLA path on the real classifier artifacts.
    let root = icsml::artifacts_dir();
    if root.join("manifest.json").exists() {
        use icsml::porting::Manifest;
        use icsml::runtime::Runtime;
        let man = Manifest::load(&root)?;
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo(&man.hlo_path("classifier_b1")?)?;
        let win = binio::read_f32(
            &root.join(man.dataset.expect("eval_windows").as_str().unwrap()),
        )?;
        let logits = exe.run_f32(&win[..400], &[1, 400])?;
        println!(
            "\nAOT/XLA classifier on eval window 0: logits {logits:?} -> {}",
            if logits[1] > logits[0] { "ATTACK" } else { "normal" }
        );
    } else {
        println!("\n(run `make artifacts` to also exercise the AOT/XLA path)");
    }
    println!("\nquickstart OK");
    Ok(())
}
