//! Multipart inference (paper §6.3): run a reduced MobileNet-style
//! model (4x Conv2D, 7x BatchNorm+ReLU, 3x ConvDW — the paper's α=0.25
//! configuration class) on the BeagleBone profile at a 90 ms scan
//! cycle, splitting the computation across cycles and reporting the
//! output latency. Paper reference point: 1.17 s.
//!
//! Run: `cargo run --release --example multipart_inference`

use icsml::coordinator::MultipartSession;
use icsml::engine::{Act, Layer, Model};
use icsml::plc::HwProfile;
use icsml::util::rng::SplitMix64;

fn scale(rng: &mut SplitMix64, c: usize, dim: usize, act: Act) -> Layer {
    Layer::Scale {
        scales: (0..c).map(|_| 0.8 + 0.4 * rng.next_f64() as f32).collect(),
        shifts: (0..c).map(|_| rng.uniform(-0.1, 0.1) as f32).collect(),
        channels: c,
        dim,
        act,
        alpha: 0.0,
    }
}

fn randv(rng: &mut SplitMix64, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-s as f64, s as f64) as f32).collect()
}

/// Reduced MobileNet-style stack on 3x96x96 input:
/// 4 Conv2D + 7 BatchNorm(+ReLU) + 3 ConvDW + classifier head.
fn mobilenet_ish() -> Model {
    let mut r = SplitMix64::new(99);
    let conv = |r: &mut SplitMix64, ic: usize, oc: usize, ih: usize,
                iw: usize, k: usize, s: usize| Layer::Conv2D {
        w: randv(r, oc * ic * k * k, 0.2),
        b: randv(r, oc, 0.05),
        in_c: ic,
        in_h: ih,
        in_w: iw,
        out_c: oc,
        k_h: k,
        k_w: k,
        stride: s,
        act: Act::None,
        alpha: 0.0,
    };
    let dw = |r: &mut SplitMix64, c: usize, ih: usize, iw: usize,
              k: usize, s: usize| Layer::ConvDW {
        w: randv(r, c * k * k, 0.3),
        b: randv(r, c, 0.05),
        chans: c,
        in_h: ih,
        in_w: iw,
        k_h: k,
        k_w: k,
        stride: s,
        act: Act::None,
        alpha: 0.0,
    };
    Model::new(vec![
        conv(&mut r, 3, 16, 96, 96, 3, 2),        // -> 16x47x47
        scale(&mut r, 16, 16 * 47 * 47, Act::Relu),
        dw(&mut r, 16, 47, 47, 3, 1),             // -> 16x45x45
        scale(&mut r, 16, 16 * 45 * 45, Act::Relu),
        conv(&mut r, 16, 32, 45, 45, 1, 1),       // -> 32x45x45
        scale(&mut r, 32, 32 * 45 * 45, Act::Relu),
        dw(&mut r, 32, 45, 45, 3, 2),             // -> 32x22x22
        scale(&mut r, 32, 32 * 22 * 22, Act::Relu),
        conv(&mut r, 32, 64, 22, 22, 1, 1),       // -> 64x22x22
        scale(&mut r, 64, 64 * 22 * 22, Act::Relu),
        dw(&mut r, 64, 22, 22, 3, 1),             // -> 64x20x20
        scale(&mut r, 64, 64 * 20 * 20, Act::Relu),
        conv(&mut r, 64, 128, 20, 20, 3, 2),      // -> 128x9x9
        scale(&mut r, 128, 128 * 9 * 9, Act::Relu),
        Layer::dense(
            randv(&mut r, 128 * 81 * 10, 0.02),
            randv(&mut r, 10, 0.01),
            128 * 81,
            Act::None,
        ),
    ])
}

fn main() {
    let model = mobilenet_ish();
    println!(
        "== multipart inference: MobileNet-style model, {:.1} M MACs, \
         {} layers",
        model.macs() as f64 / 1e6,
        model.layers().len()
    );

    let profile = HwProfile::beaglebone();
    let scan_ms = 90.0;
    let control_us = 2_000.0; // other ICS tasks in the cycle
    let budget_us = scan_ms * 1e3 - control_us;

    // Single-shot modeled time (would blow the scan cycle).
    let single_ms = model.macs() as f64
        * icsml::coordinator::multipart::us_per_mac(&profile)
        / 1e3;
    println!(
        "single-shot modeled time on {}: {:.0} ms — {:.1}x the {scan_ms} ms \
         scan cycle (would starve the control task)",
        profile.name,
        single_ms,
        single_ms / scan_ms
    );

    let mut rng = SplitMix64::new(5);
    let x: Vec<f32> =
        (0..3 * 96 * 96).map(|_| rng.next_f64() as f32).collect();
    let mut session = MultipartSession::new(model, profile);
    let (out, cycles) = session
        .run_to_completion(&x, budget_us, 100_000)
        .expect("backend error")
        .expect("inference must finish");

    println!(
        "multipart: {} cycles x {scan_ms} ms -> output latency {:.2} s \
         (paper §6.3 reference: 1.17 s)",
        cycles,
        cycles as f64 * scan_ms / 1e3
    );
    println!(
        "max ML time in any cycle: {:.1} ms (budget {:.1} ms) — the control \
         task is never starved",
        session.stats.max_cycle_us / 1e3,
        budget_us / 1e3
    );
    println!("logits: {out:?}");

    // Correctness: multipart == single shot.
    let mut reference = mobilenet_ish();
    let want = reference.infer(&x);
    assert_eq!(out, want, "multipart must equal single-shot");
    println!("\nmultipart_inference OK");
}
