//! END-TO-END DRIVER (paper §7): the full MSF-desalination case study.
//!
//! Composes every layer of the stack on a real workload:
//!  * the Rust MSF plant twin + cascaded PID (HITL substitute),
//!  * the simulated PLC (scan cycle, ADC, BBB timing model),
//!  * the trained anomaly classifier (JAX-trained, §4.3-ported to
//!    ICSML ST, executed by the ST interpreter *inside* the scan
//!    cycle),
//!  * attack injection (Fig. 7 scenario) and detection,
//!  * the non-intrusiveness comparison (Fig. 8).
//!
//! Run after `make artifacts`:
//! `cargo run --release --example desalination_defense [--xla|--engine]`
//! Outputs Fig. 7 series to /tmp/icsml_fig7.csv.

use anyhow::Result;
use icsml::api::{Backend, EngineBackend, StBackend};
use icsml::defense::Detector;
use icsml::hitl::HitlRunner;
use icsml::msf::{Attack, AttackFamily};
use icsml::plc::HwProfile;
use icsml::porting::{self, codegen::CodegenOptions, Manifest};
use icsml::runtime::{Runtime, XlaBackend};

fn detector(man: &Manifest, backend: &str) -> Result<Detector> {
    let spec = man.model("classifier")?;
    // Each detector gets its own session; the backend handle is the
    // shared, immutable part.
    let b: Box<dyn icsml::api::Backend> = match backend {
        "engine" => Box::new(EngineBackend::new(porting::load_engine_model(
            &man.root, spec,
        )?)),
        "xla" => {
            let rt = Runtime::cpu()?;
            Box::new(XlaBackend::new(
                rt.load_hlo(&man.hlo_path("classifier_b1")?)?,
                spec.in_dim(),
                spec.out_dim(),
            ))
        }
        _ => {
            // The real thing: generated ICSML ST on the PLC simulator.
            let src = porting::generate_st_program(
                spec,
                &CodegenOptions::default(),
            );
            let mut it = icsml::icsml_st::load(&src)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            it.io_dir = man.root.join(&spec.weights_dir);
            Box::new(StBackend::new(it, "MAIN")?)
        }
    };
    Ok(Detector::new(b.session()?, 5))
}

fn main() -> Result<()> {
    let root = icsml::artifacts_dir();
    anyhow::ensure!(
        root.join("manifest.json").exists(),
        "run `make artifacts` first"
    );
    let man = Manifest::load(&root)?;
    let args: Vec<String> = std::env::args().collect();
    let backend = if args.iter().any(|a| a == "--xla") {
        "xla"
    } else if args.iter().any(|a| a == "--engine") {
        "engine"
    } else {
        "st"
    };
    println!("== §7 case study — defense backend: {backend}\n");

    // ---------------- Fig. 7: attack detection ------------------------
    // Combined actuator attack (recycle brine + steam + reject flows),
    // parameters unseen in training (magnitude 0.5 vs trained 0.30/0.55
    // jittered instances). Paper: injected @436, detected @486 (5 s).
    let inject_at = 4360u64; // let the plant + window warm up first
    let steps = 9000u64;
    let runner = HitlRunner::new(
        7,
        true,
        vec![Attack::new(AttackFamily::Combined, 0.5, inject_at, steps)],
        Some(detector(&man, backend)?),
        HwProfile::beaglebone(),
        100_000.0, // 100 ms scan cycle
    );
    let report = runner.run(steps)?;

    match report.detections.first() {
        Some((start, at)) => {
            println!(
                "attack injected @cycle {start}, detected @cycle {at} — \
                 {:.1} s latency (paper: injected @436, detected @486, 5 s)",
                (at - start) as f64 * 0.1
            );
        }
        None => println!("attack NOT detected — check the model"),
    }
    println!("false positives during normal operation: {}",
             report.false_positives);
    if report.scan.stats.ml_time_us > 0.0 {
        println!(
            "mean modeled ML time per evaluated cycle: {:.2} ms \
             (scan overruns: {})",
            report.scan.stats.ml_time_us
                / report.scan.stats.cycles.max(1) as f64
                / 1e3,
            report.scan.stats.overruns
        );
    }

    // Fig. 7 series dump.
    let csv = "/tmp/icsml_fig7.csv";
    let mut out = String::from("cycle,tb0_adc,wd_adc,attack,detected\n");
    for r in report.records.iter().step_by(5) {
        out.push_str(&format!(
            "{},{:.4},{:.5},{},{}\n",
            r.step, r.tb0_adc, r.wd_adc, r.attack_active as u8,
            r.detected as u8
        ));
    }
    std::fs::write(csv, out)?;
    println!("Fig. 7 series written to {csv}\n");

    // ---------------- Fig. 8: non-intrusiveness -----------------------
    // 6000 cycles of normal operation, defense OFF vs ON; identical
    // seed so the only difference is the defense task in the cycle.
    let off = HitlRunner::new(21, true, vec![], None,
                              HwProfile::beaglebone(), 100_000.0)
        .run(6000)?;
    let on = HitlRunner::new(21, true, vec![], Some(detector(&man, backend)?),
                             HwProfile::beaglebone(), 100_000.0)
        .run(6000)?;
    let (m_off, s_off) = off.wd_stats();
    let (m_on, s_on) = on.wd_stats();
    println!("Fig. 8 — Wd over 6000 cycles (paper: mean 19.18 both, σ \
              9.47e-4 / 9.18e-4):");
    println!("  defense OFF: mean {m_off:.2} t/min, σ {s_off:.2e}");
    println!("  defense ON : mean {m_on:.2} t/min, σ {s_on:.2e}");
    assert!((m_off - m_on).abs() < 0.01, "defense must not move the mean");
    assert_eq!(on.false_positives, 0, "no false alarms in normal operation");
    println!(
        "  -> identical process statistics: the defense is non-intrusive"
    );

    println!("\ndesalination_defense OK");
    Ok(())
}
