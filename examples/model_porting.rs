//! Model-porting walkthrough (paper §4.3 + §8.2): trained JAX model →
//! manifest → generated ICSML ST → execution on the simulated PLC,
//! with accuracy verified against labels and logits cross-checked
//! against the AOT/XLA path.
//!
//! Run after `make artifacts`:
//! `cargo run --release --example model_porting`

use anyhow::Result;
use icsml::api::{Backend, Session as _, StBackend};
use icsml::plc::HwProfile;
use icsml::porting::{self, codegen::CodegenOptions, Manifest};
use icsml::runtime::{Runtime, XlaBackend};
use icsml::util::binio;

fn main() -> Result<()> {
    let root = icsml::artifacts_dir();
    anyhow::ensure!(
        root.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let man = Manifest::load(&root)?;
    let spec = man.model("classifier")?;
    println!(
        "== porting model 'classifier' {:?} (trained: {})",
        spec.sizes,
        spec.report.to_string()
    );

    // 1. Generate the ICSML ST application (paper Fig. 2 flow).
    let src = porting::generate_st_program(spec, &CodegenOptions::default());
    println!("generated ST program: {} lines", src.lines().count());

    // 2. Compile it with the framework and attach the weight dir.
    let mut it =
        icsml::icsml_st::load(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
    it.io_dir = root.join(&spec.weights_dir);
    let st_backend = StBackend::new(it, "MAIN")?;
    let mut st = st_backend.session()?;

    // 3. XLA comparator (dims from the manifest spec, not hardcoded).
    let rt = Runtime::cpu()?;
    let xla_backend = XlaBackend::new(
        rt.load_hlo(&man.hlo_path("classifier_b1")?)?,
        spec.in_dim(),
        spec.out_dim(),
    );
    let mut xla = xla_backend.session()?;

    // 4. Evaluate a slice: accuracy + ST-vs-XLA agreement + modeled
    //    on-PLC cost of one inference.
    let ds = &man.dataset;
    let n = ds.expect("eval_n").as_usize().unwrap().min(200);
    let x = binio::read_f32(&man.dataset_path("eval_windows")?)?;
    let y = binio::read_i32(&man.dataset_path("eval_labels")?)?;

    let (mut correct, mut max_dev) = (0usize, 0.0f32);
    for i in 0..n {
        let xi = &x[i * 400..(i + 1) * 400];
        let a = st.infer(xi)?;
        let b = xla.infer(xi)?;
        max_dev = max_dev
            .max((a[0] - b[0]).abs())
            .max((a[1] - b[1]).abs());
        let pred = if a[1] > a[0] { 1 } else { 0 };
        if pred == y[i] {
            correct += 1;
        }
    }
    println!(
        "on-PLC (ST) accuracy over {n} eval windows: {:.2}% (paper: ~93.68%)",
        100.0 * correct as f64 / n as f64
    );
    println!("max |ST - XLA| logit deviation: {max_dev:.2e}");
    assert!(max_dev < 1e-3, "backends disagree");

    if let Some(m) = st.last_meter() {
        println!("\nmodeled per-inference cost of the ported model:");
        for p in [HwProfile::beaglebone(), HwProfile::wago_pfc100()] {
            println!(
                "  {:>18}: {:>8.2} ms (scan budget 100 ms)",
                p.name,
                p.time_us(&m) / 1e3
            );
        }
    }
    println!("\nmodel_porting OK");
    Ok(())
}
