"""Build-time training for the paper's two models (paper §4.3 step 2).

* MSF anomaly classifier (§7): 400-64-32-16-2 ReLU MLP over 20 s sliding
  windows of (TB0, Wd) PLC readings; dataset synthesized by the plant twin
  in :mod:`compile.plant` (paper: 22 h 45 min at 100 ms, ~48.8 %% attack
  time, 7 attack families; split 72.25/12.75/15).
* MNIST-style quantization-study model (§6.1): 784-512-512-10 on a
  procedural 7-segment digit dataset (substitution documented in
  DESIGN.md §2 — the study needs a trained 512x512 layer's weight
  distribution, not MNIST semantics).

Training uses plain-jnp forwards (identical math to the Pallas kernels,
which are reserved for the AOT/inference path and verified against the
same oracle). Optimizer: Adam. The paper trains with LR=1e-5 and 64-epoch
early-stopping patience; we use LR=1e-3 for build-time practicality
(documented substitution — same architecture/loss).
"""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import plant
from .model import (CLASSIFIER_LAYERS, CLASSIFIER_ACTS, MNIST_LAYERS,
                    MNIST_ACTS, init_mlp)

FAST = os.environ.get("ICSML_FAST", "0") == "1"

# Paper: 22h45m total, 11h06m under attack, 100 ms interval.
TOTAL_STEPS = 819_000 if not FAST else 60_000
WINDOW = 200           # readings per feature (20 s x 10 Hz)
N_FEATURES = 2
STRIDE = 5             # window subsampling stride for the training set
SPLIT = (0.7225, 0.1275, 0.15)


# ------------------------------------------------------------ MSF dataset
def attack_schedule(total_steps: int, rng: plant.SplitMix64):
    """Alternating normal/attack blocks covering all 7 families twice,
    with family-appropriate magnitudes (moderate + strong instance each).
    Attack time lands near the paper's ~48.8 %."""
    mags = {
        "steam_bias": (0.15, 0.35),
        "recycle_reduction": (0.12, 0.30),
        "reject_manipulation": (0.25, 0.50),
        "tb0_fdi": (1.5, 4.0),
        "wd_fdi": (0.04, 0.10),
        "setpoint_tamper": (1.0, 3.0),
        "combined": (0.30, 0.55),
    }
    families = list(plant.ATTACK_FAMILIES)
    n_blocks = 2 * len(families)
    attack_len = int(total_steps * 0.488) // n_blocks
    normal_len = (total_steps - n_blocks * attack_len) // (n_blocks + 1)
    attacks, cursor = [], normal_len
    order = families + families[::-1]
    for i, fam in enumerate(order):
        lo, hi = mags[fam]
        m = lo if i < len(families) else hi
        m *= 0.9 + 0.2 * rng.next_f64()   # jitter magnitudes
        attacks.append(plant.Attack(fam, m, cursor, cursor + attack_len))
        cursor += attack_len + normal_len
    return attacks


def simulate_series(total_steps: int = TOTAL_STEPS, seed: int = 11):
    """Run the closed-loop twin and return PLC-visible series + labels."""
    rng = plant.SplitMix64(seed ^ 0xA5A5)
    sim = plant.Simulator(seed=seed, noise=True,
                          attacks=attack_schedule(total_steps, rng))
    tb0 = np.empty(total_steps, np.float32)
    wd = np.empty(total_steps, np.float32)
    lab = np.empty(total_steps, np.int32)
    for i in range(total_steps):
        t, w, _, active = sim.step()
        tb0[i] = t
        wd[i] = w
        lab[i] = 1 if active else 0
    return tb0, wd, lab


def window_matrix(tb0, wd, lab, idx):
    """Gather feature windows ending at ``idx`` (inclusive): the paper's
    400 inputs = ordered TB0 readings then ordered Wd readings over the
    past 20 s. Label = attack state at the window end."""
    offs = np.arange(-(WINDOW - 1), 1)
    gather = idx[:, None] + offs[None, :]
    x = np.concatenate([tb0[gather], wd[gather]], axis=1)
    return x.astype(np.float32), lab[idx].astype(np.int32)


def make_dataset(seed: int = 11):
    tb0, wd, lab = simulate_series(seed=seed)
    idx = np.arange(WINDOW - 1, len(tb0), STRIDE)
    rng = np.random.default_rng(seed)
    rng.shuffle(idx)
    n = len(idx)
    n_tr = int(n * SPLIT[0])
    n_va = int(n * SPLIT[1])
    parts = {
        "train": idx[:n_tr],
        "val": idx[n_tr:n_tr + n_va],
        "test": idx[n_tr + n_va:],
    }
    # Per-channel normalization constants from the train split only.
    xtr, _ = window_matrix(tb0, wd, lab, parts["train"][:20000])
    mu = np.array([xtr[:, :WINDOW].mean(), xtr[:, WINDOW:].mean()], np.float32)
    sd = np.array([max(xtr[:, :WINDOW].std(), 1e-6),
                   max(xtr[:, WINDOW:].std(), 1e-6)], np.float32)
    return (tb0, wd, lab), parts, (mu, sd)


def normalize(x, mu, sd):
    out = x.copy()
    out[:, :WINDOW] = (out[:, :WINDOW] - mu[0]) / sd[0]
    out[:, WINDOW:] = (out[:, WINDOW:] - mu[1]) / sd[1]
    return out


# ------------------------------------------------------------ training
def _forward_jnp(params, x, acts):
    from .kernels.dense import apply_activation
    for (w, b), act in zip(params, acts):
        x = apply_activation(x @ w + b[None, :], act)
    return x


def _make_update(acts, lr):
    def loss_fn(params, x, y):
        logits = _forward_jnp(params, x, acts)
        logz = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logz, y[:, None], axis=1))

    @jax.jit
    def update(params, opt, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_params, new_opt = [], []
        for (p, g), (m, v, t) in zip(
                [(p, g) for lp, lg in zip(params, grads) for p, g in zip(lp, lg)],
                [s for ls in opt for s in ls]):
            t = t + 1
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * (g * g)
            mh = m / (1 - 0.9 ** t)
            vh = v / (1 - 0.999 ** t)
            new_params.append(p - lr * mh / (jnp.sqrt(vh) + 1e-8))
            new_opt.append((m, v, t))
        params = [tuple(new_params[i:i + 2]) for i in range(0, len(new_params), 2)]
        opt = [tuple(new_opt[i:i + 2]) for i in range(0, len(new_opt), 2)]
        return params, opt, loss

    return update


def _init_opt(params):
    return [tuple((jnp.zeros_like(w), jnp.zeros_like(w), jnp.int32(0))
                  for w in layer) for layer in params]


def _accuracy(params, acts, x, y, batch=4096):
    correct = 0
    for i in range(0, len(x), batch):
        logits = _forward_jnp(params, jnp.asarray(x[i:i + batch]), acts)
        correct += int((jnp.argmax(logits, axis=1) == y[i:i + batch]).sum())
    return correct / len(x)


def train_classifier(seed: int = 11, verbose: bool = True):
    """Train the §7 anomaly classifier. Returns (params, report, eval_pack).

    ``params`` has the normalization folded into layer 0 so the ported
    model consumes raw ADC readings (see aot.py).
    """
    (tb0, wd, lab), parts, (mu, sd) = make_dataset(seed)
    key = jax.random.PRNGKey(seed)
    params = init_mlp(key, CLASSIFIER_LAYERS)
    opt = _init_opt(params)
    update = _make_update(CLASSIFIER_ACTS, lr=1e-3)

    steps = 3000 if not FAST else 300
    batch = 256
    rng = np.random.default_rng(seed + 1)
    train_idx = parts["train"]
    best_val, best_params, patience = 0.0, params, 0
    xval, yval = window_matrix(tb0, wd, lab, parts["val"][:8000])
    xval = normalize(xval, mu, sd)

    for step in range(steps):
        take = rng.integers(0, len(train_idx), batch)
        xb, yb = window_matrix(tb0, wd, lab, train_idx[take])
        xb = normalize(xb, mu, sd)
        params, opt, loss = update(params, opt, jnp.asarray(xb), jnp.asarray(yb))
        if (step + 1) % 250 == 0:
            vacc = _accuracy(params, CLASSIFIER_ACTS, xval, yval)
            if verbose:
                print(f"[classifier] step {step+1} loss {float(loss):.4f} "
                      f"val_acc {vacc:.4f}")
            if vacc > best_val:
                best_val, best_params, patience = vacc, params, 0
            else:
                patience += 1
                if patience >= 4:   # early stopping (paper: patience 64 epochs)
                    break

    params = best_params
    xte, yte = window_matrix(tb0, wd, lab, parts["test"][:20000])
    xte_n = normalize(xte, mu, sd)
    test_acc = _accuracy(params, CLASSIFIER_ACTS, xte_n, yte)
    if verbose:
        print(f"[classifier] test_acc {test_acc:.4f} (paper: ~0.9368)")

    # Fold normalization into layer 0: y = W^T (x-mu)/sd + b
    w0, b0 = params[0]
    scale = np.ones((CLASSIFIER_LAYERS[0],), np.float32)
    shift = np.zeros((CLASSIFIER_LAYERS[0],), np.float32)
    scale[:WINDOW], scale[WINDOW:] = 1.0 / sd[0], 1.0 / sd[1]
    shift[:WINDOW], shift[WINDOW:] = mu[0] / sd[0], mu[1] / sd[1]
    w0f = w0 * jnp.asarray(scale)[:, None]
    b0f = b0 - jnp.asarray(shift) @ w0
    folded = [(w0f, b0f)] + params[1:]

    report = {
        "test_accuracy": float(test_acc),
        "val_accuracy": float(best_val),
        "paper_accuracy": 0.9368,
        "train_windows": int(len(parts["train"])),
        "total_steps_simulated": TOTAL_STEPS,
    }
    # Raw (unnormalized) eval slice for the Rust-side accuracy check.
    eval_pack = (xte[:2000], yte[:2000])
    return folded, report, eval_pack


# ------------------------------------------------ synthetic MNIST (§6.1)
_SEGS = {  # 7-segment truth table per digit
    0: "abcdef", 1: "bc", 2: "abdeg", 3: "abcdg", 4: "bcfg",
    5: "acdfg", 6: "acdefg", 7: "abc", 8: "abcdefg", 9: "abcdfg",
}
_SEG_BOXES = {  # (r0, r1, c0, c1) on a 28x28 canvas
    "a": (3, 6, 8, 20), "b": (6, 14, 18, 21), "c": (15, 23, 18, 21),
    "d": (22, 25, 8, 20), "e": (15, 23, 7, 10), "f": (6, 14, 7, 10),
    "g": (13, 16, 8, 20),
}


def synth_digits(n: int, seed: int):
    """Procedural 7-segment digit images (28x28), jittered + noised."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, 28, 28), np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    for i in range(n):
        img = np.zeros((28, 28), np.float32)
        amp = 0.7 + 0.3 * rng.random()
        for seg in _SEGS[int(y[i])]:
            r0, r1, c0, c1 = _SEG_BOXES[seg]
            img[r0:r1, c0:c1] = amp
        dr, dc = rng.integers(-3, 4, 2)
        img = np.roll(np.roll(img, dr, axis=0), dc, axis=1)
        img += 0.12 * rng.standard_normal((28, 28)).astype(np.float32)
        x[i] = np.clip(img, 0.0, 1.0)
    return x.reshape(n, 784), y


def train_mnist(seed: int = 5, verbose: bool = True):
    """Train the §6.1 quantization-study model on procedural digits."""
    n_train = 20000 if not FAST else 3000
    xtr, ytr = synth_digits(n_train, seed)
    xte, yte = synth_digits(3000, seed + 999)
    params = init_mlp(jax.random.PRNGKey(seed), MNIST_LAYERS)
    opt = _init_opt(params)
    update = _make_update(MNIST_ACTS, lr=1e-3)
    steps = 1200 if not FAST else 150
    rng = np.random.default_rng(seed)
    for step in range(steps):
        take = rng.integers(0, n_train, 128)
        params, opt, loss = update(params, opt, jnp.asarray(xtr[take]),
                                   jnp.asarray(ytr[take]))
        if verbose and (step + 1) % 300 == 0:
            print(f"[mnist512] step {step+1} loss {float(loss):.4f}")
    acc = _accuracy(params, MNIST_ACTS, xte, yte)
    if verbose:
        print(f"[mnist512] test_acc {acc:.4f}")
    return params, {"test_accuracy": float(acc)}, (xte[:512], yte[:512])
