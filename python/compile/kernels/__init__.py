"""Layer-1 Pallas kernels for the ICSML reproduction.

The compiled comparator path ("TFLite" stand-in) lowers the L2 JAX model —
built on these kernels — to HLO text executed from Rust via PJRT.

Kernels are authored for TPU structure (MXU-aligned BlockSpec tiling,
HBM->VMEM streaming) but lowered with ``interpret=True`` so the CPU PJRT
client can execute them; see DESIGN.md §Hardware-Adaptation.
"""

from .dense import dense, apply_activation, ACTIVATIONS
from .quant_dense import quant_dense, quantize_weights

__all__ = [
    "dense",
    "apply_activation",
    "quant_dense",
    "quantize_weights",
    "ACTIVATIONS",
]
