"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has an oracle here with an identical
signature; ``python/tests/test_kernel.py`` asserts allclose across a
hypothesis-driven sweep of shapes, activations and quantization schemes.
"""

import jax.numpy as jnp

from .dense import apply_activation
from .quant_dense import SCHEMES


def dense_ref(x, w, b, *, activation: str = "linear", alpha: float = 0.01):
    """Oracle for :func:`kernels.dense.dense`."""
    return apply_activation(x @ w + b[None, :], activation, alpha)


def quant_dense_ref(x, w_q, s_w, b, s_x, *, scheme: str = "SINT",
                    activation: str = "linear", alpha: float = 0.01):
    """Oracle for :func:`kernels.quant_dense.quant_dense`."""
    qmax = float(jnp.iinfo(SCHEMES[scheme]).max)
    x_q = jnp.clip(jnp.round(x / s_x[0]), -qmax, qmax).astype(jnp.int32)
    acc = x_q @ w_q.astype(jnp.int32)
    y = acc.astype(jnp.float32) * (s_x[0] * s_w)[None, :] + b[None, :]
    return apply_activation(y, activation, alpha)
