"""Integer-quantized dense layer as a Pallas kernel (paper §6.1).

The paper's quantization scheme for a 512-in/512-out layer (Table 2):

* weights stored as SINT (int8) / INT (int16) / DINT (int32),
* one REAL scale factor per output neuron plus one input scale factor
  (513 REALs = 2052 bytes — exactly the paper's "Scaling Factors" column),
* biases kept as REAL.

Inference quantizes the input vector once (1024 FP multiplies for the
paper's layer: 512 divides + 512 rounding ops), runs the 262,144-element
dot product entirely in integer arithmetic, then dequantizes with
``s_x * s_w[n]`` and adds the float bias (512 FP adds) — matching the
operation counts reported in §6.1.

TPU mapping: int8 weights quadruple effective VMEM capacity; the integer
dot product targets the MXU int8 path with an int32 accumulator, and the
dequantize + bias + activation epilogue runs on the VPU.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dense import apply_activation, _pick_block

# IEC 61131-3 integer type name -> jnp dtype (paper Table 2 schemes).
SCHEMES = {
    "SINT": jnp.int8,
    "INT": jnp.int16,
    "DINT": jnp.int32,
}


def quantize_weights(w, scheme: str = "SINT"):
    """Symmetric per-output-neuron weight quantization.

    Returns ``(w_q, s_w)`` with ``w ≈ w_q * s_w[None, :]``.
    """
    dtype = SCHEMES[scheme]
    qmax = float(jnp.iinfo(dtype).max)
    absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-12)
    s_w = absmax / qmax
    w_q = jnp.clip(jnp.round(w / s_w[None, :]), -qmax, qmax).astype(dtype)
    return w_q, s_w.astype(jnp.float32)


def _quant_dense_kernel(x_ref, wq_ref, sw_ref, b_ref, sx_ref, o_ref, *,
                        activation: str, alpha: float, qmax: float):
    # Quantize the input tile once (FP divide + round), then integer GEMM.
    s_x = sx_ref[0]
    x_q = jnp.clip(jnp.round(x_ref[...] / s_x), -qmax, qmax).astype(jnp.int32)
    acc = jnp.dot(x_q, wq_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    # Dequantize epilogue: one FP multiply per output + float bias.
    y = acc.astype(jnp.float32) * (s_x * sw_ref[...])[None, :] + b_ref[...][None, :]
    o_ref[...] = apply_activation(y, activation, alpha)


@partial(jax.jit, static_argnames=("activation", "alpha", "scheme", "interpret"))
def quant_dense(x, w_q, s_w, b, s_x, *, scheme: str = "SINT",
                activation: str = "linear", alpha: float = 0.01,
                interpret: bool = True):
    """Quantized dense layer ``act(dequant(quant(x) @ w_q) + b)``.

    Args:
      x: ``f32[B, K]`` activations (float; quantized inside the kernel).
      w_q: ``int[K, N]`` quantized weights from :func:`quantize_weights`.
      s_w: ``f32[N]`` per-neuron weight scales.
      b: ``f32[N]`` float biases.
      s_x: ``f32[1]`` input scale factor.
      scheme: "SINT" | "INT" | "DINT" (IEC 61131-3 integer types).
    """
    bsz, k = x.shape
    k2, n = w_q.shape
    assert k == k2
    qmax = float(jnp.iinfo(SCHEMES[scheme]).max)

    block_n = _pick_block(n, 512)
    grid = (1, n // block_n)

    return pl.pallas_call(
        partial(_quant_dense_kernel, activation=activation, alpha=alpha,
                qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bsz, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, s_w, b, s_x)
