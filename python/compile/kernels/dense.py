"""Fused dense layer ``y = act(x @ w + b)`` as a Pallas kernel.

TPU mapping of the paper's hot spot (the ICSML DOT_PRODUCT + activation):

* Grid tiles the output over ``(B / block_m, N / block_n)``; the reduction
  dimension ``K`` is kept whole per block (all models in the paper are
  small enough that a ``(block_m, K)`` activation tile and a
  ``(K, block_n)`` weight tile fit VMEM comfortably; see the footprint
  estimate in DESIGN.md §Hardware-Adaptation).
* ``block_n`` is chosen as a multiple of 128 (MXU lane width) whenever the
  layer width allows, so each block is one systolic-array pass.
* Bias add + activation are fused in the epilogue (VPU ops) — the memory
  traffic the paper saves by hand-fusing in ST, we save by fusion.

``interpret=True`` is mandatory on CPU PJRT: real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation epilogues available inside the kernel. Mirrors the ICSML ST
# activation set (python/../rust assets/activations.st); Softmax is applied
# at the model level because it needs a full-row reduction.
ACTIVATIONS = (
    "linear",
    "relu",
    "leaky_relu",
    "elu",
    "sigmoid",
    "tanh",
    "swish",
    "binary_step",
)


def apply_activation(y, activation: str, alpha: float = 0.01):
    """Activation epilogue; shared by the kernel and the pure-jnp oracle."""
    if activation == "linear":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "leaky_relu":
        return jnp.where(y >= 0.0, y, alpha * y)
    if activation == "elu":
        return jnp.where(y >= 0.0, y, alpha * (jnp.exp(y) - 1.0))
    if activation == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-y))
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "swish":
        return y / (1.0 + jnp.exp(-y))
    if activation == "binary_step":
        return jnp.where(y >= 0.0, 1.0, 0.0)
    raise ValueError(f"unknown activation {activation!r}")


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str, alpha: float):
    # One (block_m, block_n) output tile: a single MXU pass over the full
    # reduction dimension, with the bias/activation epilogue fused.
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = acc + b_ref[...][None, :]
    o_ref[...] = apply_activation(y, activation, alpha)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= target, preferring multiples of
    128 (MXU lane width) when available."""
    if n <= target:
        return n
    best = 1
    for d in range(1, target + 1):
        if n % d == 0:
            if d % 128 == 0 or best % 128 != 0 or d > best:
                if d % 128 == 0 or best % 128 != 0:
                    best = d
    return best


@partial(jax.jit, static_argnames=("activation", "alpha", "interpret"))
def dense(x, w, b, *, activation: str = "linear", alpha: float = 0.01,
          interpret: bool = True):
    """Fused dense layer ``act(x @ w + b)``.

    Args:
      x: ``f32[B, K]`` activations.
      w: ``f32[K, N]`` weights (ICSML stores the transpose; the porting
         tool handles the layout swap).
      b: ``f32[N]`` bias.
      activation: one of :data:`ACTIVATIONS`.
      alpha: slope/scale for leaky_relu / elu.
    """
    bsz, k = x.shape
    k2, n = w.shape
    assert k == k2, f"reduction mismatch: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    block_m = bsz  # batches in this repo are tiny (1..64)
    block_n = _pick_block(n, 512)
    grid = (bsz // block_m, n // block_n)

    return pl.pallas_call(
        partial(_dense_kernel, activation=activation, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)
