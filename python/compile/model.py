"""L2: JAX model definitions built on the L1 Pallas kernels.

Three model families, all from the paper:

* ``mlp`` — generic dense MLP used for the Fig. 4 layer-stacking and
  §5.3 layer-size benchmark sweeps (64-in/64-out stacks; 32-in width
  sweeps) and as the compiled "TFLite" comparator.
* ``classifier`` — the §7 MSF-desalination anomaly detector:
  400 inputs (2 features x 10 Hz x 20 s window) -> 64 -> 32 -> 16 -> 2,
  ReLU hidden activations, logits out.
* ``mnist512`` — the §6.1 quantization-study model: 784 -> 512 -> 512
  -> 10 (the isolated second hidden layer is the 512x512 layer the paper
  quantizes).

Everything here is build-time only; the lowered HLO text is the runtime
artifact.
"""

from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import dense

# Architecture constants shared with the Rust side via the manifest.
CLASSIFIER_LAYERS = (400, 64, 32, 16, 2)
CLASSIFIER_ACTS = ("relu", "relu", "relu", "linear")
MNIST_LAYERS = (784, 512, 512, 10)
MNIST_ACTS = ("relu", "relu", "linear")


def init_mlp(key, sizes: Sequence[int]):
    """He-initialized MLP parameters as a list of ``(w, b)`` pairs.

    Weights are stored ``[fan_in, fan_out]`` (JAX layout); the porting
    tool transposes to ICSML's per-neuron row layout.
    """
    params = []
    for k_in, k_out in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (k_in, k_out), jnp.float32)
        w = w * jnp.sqrt(2.0 / k_in)
        params.append((w, jnp.zeros((k_out,), jnp.float32)))
    return params


def mlp_forward(params, x, acts: Sequence[str], *, interpret: bool = True):
    """Forward pass through a dense MLP using the fused Pallas kernel."""
    assert len(params) == len(acts)
    for (w, b), act in zip(params, acts):
        x = dense(x, w, b, activation=act, interpret=interpret)
    return x


def classifier_forward(params, x, *, interpret: bool = True):
    """The §7 anomaly-detection classifier (logits over {normal, attack})."""
    return mlp_forward(params, x, CLASSIFIER_ACTS, interpret=interpret)


def mnist_forward(params, x, *, interpret: bool = True):
    """The §6.1 quantization-study classifier (logits over 10 classes)."""
    return mlp_forward(params, x, MNIST_ACTS, interpret=interpret)


def bench_stack_sizes(depth: int, width: int = 64):
    """Fig. 4 layer-stacking benchmark architecture: ``width`` in/out,
    ``depth`` hidden dense+ReLU layers."""
    return (width,) + (width,) * depth


def bench_stack_acts(depth: int):
    return ("relu",) * depth


def bench_width_sizes(neurons: int, n_in: int = 32):
    """§5.3 layer-size benchmark: 32 input features, one dense layer of
    ``neurons`` outputs with ReLU."""
    return (n_in, neurons)
