"""AOT compile path: train models, lower to HLO **text**, export weights.

This is the single build-time entry point (``make artifacts``). It writes:

* ``artifacts/hlo/*.hlo.txt`` — AOT-lowered forwards for the Rust PJRT
  runtime (the "TFLite" comparator path). HLO *text* is the interchange
  format: jax >= 0.5 serializes HloModuleProto with 64-bit instruction
  ids which xla_extension 0.5.1 rejects; the text parser reassigns ids.
  Trained weights are closed over as constants so the runtime feeds only
  the input vector.
* ``artifacts/weights/<model>/l{i}_{w,b}.bin`` — ICSML binary weight files
  (little-endian f32, per-neuron row-major ``[out][in]`` layout — what the
  ST ``BINARR`` loader and the paper's §4.3 porting flow expect).
* ``artifacts/dataset/`` — raw eval slices for Rust-side accuracy checks.
* ``artifacts/golden/msf_trace.json`` — plant cross-validation trace.
* ``artifacts/manifest.json`` — the index all Rust components load.

Python never runs at request time; after this script the Rust binary is
self-contained.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import plant, train
from .kernels import dense, quant_dense, quantize_weights
from .model import (CLASSIFIER_ACTS, CLASSIFIER_LAYERS, MNIST_ACTS,
                    MNIST_LAYERS, mlp_forward)

STACK_DEPTHS = (1, 2, 3, 4, 5, 6, 7, 8, 9, 10)          # Fig. 4 sweep
WIDTHS = (32, 64, 128, 256, 512, 1024, 2048, 4096)       # §5.3 sweep
QUANT_SCHEMES = ("SINT", "INT", "DINT")                   # §6.1 / Table 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default printer elides big weight
    # constants as `constant({...})`, which would silently destroy the
    # embedded parameters on the text round-trip.
    return comp.as_hlo_text(True)


def lower_mlp(params, acts, batch: int, n_in: int) -> str:
    """Lower an MLP forward with weights embedded as constants."""
    frozen = [(jnp.asarray(w), jnp.asarray(b)) for w, b in params]

    def fwd(x):
        return (mlp_forward(frozen, x, acts),)

    spec = jax.ShapeDtypeStruct((batch, n_in), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_quant_layer(w, b, scheme: str, batch: int = 1) -> str:
    """Lower the isolated §6.1 quantized 512x512 layer."""
    w_q, s_w = quantize_weights(jnp.asarray(w), scheme)
    s_x = jnp.asarray([0.05], jnp.float32)
    bj = jnp.asarray(b)

    def fwd(x):
        return (quant_dense(x, w_q, s_w, bj, s_x, scheme=scheme,
                            activation="relu"),)

    spec = jax.ShapeDtypeStruct((batch, w.shape[0]), jnp.float32)
    return to_hlo_text(jax.jit(fwd).lower(spec))


def lower_smoke() -> str:
    """Tiny fn for runtime unit tests: (x @ y) + 2 over f32[2,2]."""
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def export_weights(out_dir: str, params) -> list:
    """ICSML binary export: per layer, weights transposed to [out][in]
    row-major f32 LE + bias vector. Returns manifest entries."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for i, (w, b) in enumerate(params):
        w_icsml = np.asarray(w, np.float32).T.copy()     # [out, in]
        bv = np.asarray(b, np.float32)
        wp, bp = f"l{i}_w.bin", f"l{i}_b.bin"
        w_icsml.tofile(os.path.join(out_dir, wp))
        bv.tofile(os.path.join(out_dir, bp))
        entries.append({
            "inputs": int(w.shape[0]), "neurons": int(w.shape[1]),
            "weights": wp, "biases": bp,
        })
    return entries


def write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory")
    args = ap.parse_args()
    root = os.path.abspath(args.out)
    os.makedirs(root, exist_ok=True)
    hlo_dir = os.path.join(root, "hlo")
    manifest = {"hlo": {}, "models": {}, "dataset": {}, "plant": {},
                "fast_mode": train.FAST}

    # ---- train the paper's models -------------------------------------
    print("== training MSF anomaly classifier (§7)")
    clf_params, clf_report, (xev, yev) = train.train_classifier()
    print("== training quantization-study model (§6.1)")
    mn_params, mn_report, (mxev, myev) = train.train_mnist()

    # ---- HLO artifacts -------------------------------------------------
    print("== lowering HLO artifacts")
    write(os.path.join(hlo_dir, "smoke.hlo.txt"), lower_smoke())
    manifest["hlo"]["smoke"] = "hlo/smoke.hlo.txt"

    for batch in (1, 8):
        name = f"classifier_b{batch}"
        write(os.path.join(hlo_dir, f"{name}.hlo.txt"),
              lower_mlp(clf_params, CLASSIFIER_ACTS, batch,
                        CLASSIFIER_LAYERS[0]))
        manifest["hlo"][name] = f"hlo/{name}.hlo.txt"

    write(os.path.join(hlo_dir, "mnist512_b1.hlo.txt"),
          lower_mlp(mn_params, MNIST_ACTS, 1, MNIST_LAYERS[0]))
    manifest["hlo"]["mnist512_b1"] = "hlo/mnist512_b1.hlo.txt"

    # Fig. 4 layer-stacking comparator models (64-in/64-out dense stacks).
    key = jax.random.PRNGKey(0)
    from .model import init_mlp, bench_stack_sizes, bench_stack_acts
    for d in STACK_DEPTHS:
        params = init_mlp(key, bench_stack_sizes(d))
        name = f"bench_stack_d{d}"
        write(os.path.join(hlo_dir, f"{name}.hlo.txt"),
              lower_mlp(params, bench_stack_acts(d), 1, 64))
        manifest["hlo"][name] = f"hlo/{name}.hlo.txt"

    # §5.3 layer-width comparator models (32 inputs, one dense+ReLU).
    for wdt in WIDTHS:
        params = init_mlp(key, (32, wdt))
        name = f"bench_width_{wdt}"
        write(os.path.join(hlo_dir, f"{name}.hlo.txt"),
              lower_mlp(params, ("relu",), 1, 32))
        manifest["hlo"][name] = f"hlo/{name}.hlo.txt"

    # §6.1 isolated 512x512 layer: f32 baseline + three quant schemes.
    w512, b512 = mn_params[1]
    params512 = [(w512, b512)]
    write(os.path.join(hlo_dir, "dense512_f32.hlo.txt"),
          lower_mlp(params512, ("relu",), 1, 512))
    manifest["hlo"]["dense512_f32"] = "hlo/dense512_f32.hlo.txt"
    for scheme in QUANT_SCHEMES:
        name = f"quant512_{scheme}"
        write(os.path.join(hlo_dir, f"{name}.hlo.txt"),
              lower_quant_layer(np.asarray(w512), np.asarray(b512), scheme))
        manifest["hlo"][name] = f"hlo/{name}.hlo.txt"

    # ---- ICSML weight export (paper §4.3 porting step) -----------------
    print("== exporting ICSML weight binaries")
    manifest["models"]["classifier"] = {
        "sizes": list(CLASSIFIER_LAYERS),
        "activations": list(CLASSIFIER_ACTS),
        "weights_dir": "weights/classifier",
        "layers": export_weights(os.path.join(root, "weights/classifier"),
                                 clf_params),
        "report": clf_report,
        "window": train.WINDOW,
        "features": ["tb0", "wd"],
    }
    manifest["models"]["mnist512"] = {
        "sizes": list(MNIST_LAYERS),
        "activations": list(MNIST_ACTS),
        "weights_dir": "weights/mnist512",
        "layers": export_weights(os.path.join(root, "weights/mnist512"),
                                 mn_params),
        "report": mn_report,
    }

    # ---- eval slices ----------------------------------------------------
    ds = os.path.join(root, "dataset")
    os.makedirs(ds, exist_ok=True)
    xev.astype(np.float32).tofile(os.path.join(ds, "eval_windows.bin"))
    yev.astype(np.int32).tofile(os.path.join(ds, "eval_labels.bin"))
    mxev.astype(np.float32).tofile(os.path.join(ds, "mnist_eval_x.bin"))
    myev.astype(np.int32).tofile(os.path.join(ds, "mnist_eval_y.bin"))
    # Expected logits (ground truth for the Rust backends: the ST
    # interpreter, the native engine and the PJRT runtime must all agree
    # with these to float tolerance).
    clf_logits = np.asarray(mlp_forward(
        [(jnp.asarray(w), jnp.asarray(b)) for w, b in clf_params],
        jnp.asarray(xev), CLASSIFIER_ACTS, interpret=True))
    clf_logits.astype(np.float32).tofile(os.path.join(ds, "eval_logits.bin"))
    mn_logits = np.asarray(mlp_forward(
        [(jnp.asarray(w), jnp.asarray(b)) for w, b in mn_params],
        jnp.asarray(mxev), MNIST_ACTS, interpret=True))
    mn_logits.astype(np.float32).tofile(
        os.path.join(ds, "mnist_eval_logits.bin"))
    manifest["dataset"] = {
        "eval_windows": "dataset/eval_windows.bin",
        "eval_labels": "dataset/eval_labels.bin",
        "eval_logits": "dataset/eval_logits.bin",
        "eval_n": int(len(yev)),
        "mnist_eval_x": "dataset/mnist_eval_x.bin",
        "mnist_eval_y": "dataset/mnist_eval_y.bin",
        "mnist_eval_logits": "dataset/mnist_eval_logits.bin",
        "mnist_eval_n": int(len(myev)),
    }

    # ---- golden plant trace + constants ---------------------------------
    print("== emitting golden plant trace")
    trace = plant.golden_trace()
    write(os.path.join(root, "golden/msf_trace.json"),
          json.dumps(trace))
    manifest["golden_trace"] = "golden/msf_trace.json"
    manifest["plant"] = plant.constants_manifest()

    with open(os.path.join(root, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== manifest written: {os.path.join(root, 'manifest.json')}")


if __name__ == "__main__":
    main()
