"""Build-time MSF desalination plant model + cascaded PID + attack injector.

This is the Python twin of ``rust/src/msf/`` (the runtime HITL plant). The
paper drives a MATLAB Simulink model of the Khubar II MSF plant (Ali 2002);
we substitute a reduced-order nonlinear flash model with the same control
structure — see DESIGN.md §2. **The discrete dynamics here are the
normative spec**: the Rust plant implements the identical equations in the
identical evaluation order, and ``artifacts/golden/msf_trace.json``
(emitted by ``aot.py``) pins them together to ~1e-9.

Model (all flows tons/min, temperatures °C, time minutes):

  states   tb0   top brine temperature (after the brine heater)
           tbot  bottom/reject-section brine temperature
           wd    distillate product flow rate (first-order production lag)

  t_in       = tbot + R_RECOV * (tb0 - tbot)        # condenser preheat
  d tb0 /dt  = (LAMBDA_S * ws - wr * CP * (tb0 - t_in)) / C_H
  flash_heat = wr * CP * (tb0 - tbot)
  d tbot/dt  = (F_FLASH * flash_heat - wrej * CP * (tbot - T_SEA)) / C_B
  wd_inst    = flash_heat / LAMBDA_V
  d wd  /dt  = (wd_inst - wd) / TAU_D

Steady state (nominal): tb0=90, tbot=40, wd=19.1818 t/min (the paper's
Fig. 8 mean is 19.18), ws=5.7545.

The PLC runs a cascaded PID each 100 ms scan cycle: the outer loop maps
the Wd error to a TB0 setpoint, the inner loop maps the TB0 error to the
steam flow command Ws — exactly the paper's §7 control topology (PLC
inputs: TB0, Wd; output: Ws).
"""

import json
import math
from dataclasses import dataclass, field

# ---------------------------------------------------------------- constants
DT = 0.1 / 60.0          # scan period: 100 ms, in minutes
T_SEA = 35.0             # seawater temperature (°C)
T_STEAM = 97.0           # heater steam temperature (°C) — informational
LAMBDA_S = 550.0         # steam latent heat (kcal/kg, ton-consistent units)
LAMBDA_V = 550.0         # vapor latent heat
CP = 1.0                 # brine specific heat
R_RECOV = 0.7            # condenser heat-recovery fraction
F_FLASH = 0.1            # flash-heat fraction reaching the reject section
C_H = 800.0              # brine-heater thermal capacity
C_B = 1500.0             # reject-section thermal capacity
TAU_D = 0.5              # distillate production lag (min)

WR_NOM = 211.0           # recycle brine flow (tons/min)
WREJ_NOM = 211.0         # reject seawater flow (tons/min)
WS_NOM = 3165.0 / 550.0  # steady-state steam flow = 5.754545...
WS_MAX = 12.0
TB0_NOM = 90.0
TBOT_NOM = 40.0
WD_SET = 211.0 * 50.0 / 550.0  # 19.1818... (paper Fig. 8: 19.18)

# Cascaded PID gains (tuned on this plant; mirrored in rust/src/msf/pid.rs)
OUTER_KP = 2.0           # °C per (ton/min) Wd error
OUTER_KI = 0.8           # °C per (ton/min · min)
TB0_SET_MIN, TB0_SET_MAX = 75.0, 95.0
INNER_KP = 0.6           # (ton/min steam) per °C TB0 error
INNER_KI = 0.35
WS_MIN = 0.0

# ADC models (14-bit over the instrument range; calibrated so the Wd
# series matches the paper's Fig. 8 σ ≈ 9.5e-4 with quantization steps
# still visible as the §7.1 'horizontal dot segments')
TB0_ADC_LO, TB0_ADC_HI = 0.0, 150.0
WD_ADC_LO, WD_ADC_HI = 0.0, 40.0
ADC_LEVELS = 16383.0
TB0_NOISE = 0.02         # sensor noise std-dev (°C)
WD_NOISE = 0.0005        # sensor noise std-dev (tons/min)


def adc(value: float, lo: float, hi: float) -> float:
    """12-bit ADC quantization over [lo, hi] (paper §7.1 'horizontal dot
    segments')."""
    v = min(max(value, lo), hi)
    code = math.floor((v - lo) / (hi - lo) * ADC_LEVELS + 0.5)
    return lo + code * (hi - lo) / ADC_LEVELS


class SplitMix64:
    """Deterministic PRNG shared (by spec) with rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return z ^ (z >> 31)

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self) -> float:
        # Box-Muller, one sample per call pair (second discarded for spec
        # simplicity; identical in the Rust twin).
        u1 = max(self.next_f64(), 1e-300)
        u2 = self.next_f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


# ---------------------------------------------------------------- attacks
ATTACK_FAMILIES = (
    "steam_bias",        # 1. Ws actuator scaling
    "recycle_reduction", # 2. recycle brine flow cut
    "reject_manipulation", # 3. reject seawater flow scaling
    "tb0_fdi",           # 4. false data injection on TB0 sensor
    "wd_fdi",            # 5. false data injection on Wd sensor
    "setpoint_tamper",   # 6. Wd setpoint tampering
    "combined",          # 7. brine + steam + reject (Fig. 7 scenario)
)


@dataclass
class Attack:
    """One process-aware attack instance (family + magnitude + window)."""

    family: str
    magnitude: float
    start_step: int
    end_step: int

    def active(self, step: int) -> bool:
        return self.start_step <= step < self.end_step


@dataclass
class PlantState:
    tb0: float = TB0_NOM
    tbot: float = TBOT_NOM
    wd: float = WD_SET


@dataclass
class PidState:
    outer_i: float = 0.0
    inner_i: float = 0.0


def plant_step(s: PlantState, ws: float, wr: float, wrej: float) -> PlantState:
    """One Euler step of the plant ODEs (normative evaluation order)."""
    t_in = s.tbot + R_RECOV * (s.tb0 - s.tbot)
    d_tb0 = (LAMBDA_S * ws - wr * CP * (s.tb0 - t_in)) / C_H
    flash_heat = wr * CP * (s.tb0 - s.tbot)
    d_tbot = (F_FLASH * flash_heat - wrej * CP * (s.tbot - T_SEA)) / C_B
    wd_inst = flash_heat / LAMBDA_V
    d_wd = (wd_inst - s.wd) / TAU_D
    return PlantState(
        tb0=s.tb0 + DT * d_tb0,
        tbot=s.tbot + DT * d_tbot,
        wd=s.wd + DT * d_wd,
    )


def pid_step(p: PidState, tb0_meas: float, wd_meas: float,
             wd_set: float) -> float:
    """Cascaded PID (runs inside the PLC scan cycle). Returns Ws command.

    Anti-windup: integrators are clamped alongside their outputs.
    """
    e_outer = wd_set - wd_meas
    p.outer_i += e_outer * DT
    p.outer_i = min(max(p.outer_i, -20.0), 20.0)
    tb0_set = TB0_NOM + OUTER_KP * e_outer + OUTER_KI * p.outer_i
    tb0_set = min(max(tb0_set, TB0_SET_MIN), TB0_SET_MAX)

    e_inner = tb0_set - tb0_meas
    p.inner_i += e_inner * DT
    p.inner_i = min(max(p.inner_i, -30.0), 30.0)
    ws = WS_NOM + INNER_KP * e_inner + INNER_KI * p.inner_i
    return min(max(ws, WS_MIN), WS_MAX)


@dataclass
class Simulator:
    """Closed-loop HITL twin: plant + ADC + cascaded PID + attack injector."""

    seed: int = 7
    noise: bool = True
    state: PlantState = field(default_factory=PlantState)
    pid: PidState = field(default_factory=PidState)
    attacks: list = field(default_factory=list)
    step_idx: int = 0

    def __post_init__(self):
        self.rng = SplitMix64(self.seed)

    def _attack_params(self):
        """Fold all active attacks into actuator/sensor/setpoint effects."""
        wr, wrej = WR_NOM, WREJ_NOM
        ws_scale = 1.0
        tb0_bias, wd_scale, wd_set = 0.0, 1.0, WD_SET
        active = False
        for a in self.attacks:
            if not a.active(self.step_idx):
                continue
            active = True
            m = a.magnitude
            if a.family == "steam_bias":
                ws_scale *= 1.0 + m
            elif a.family == "recycle_reduction":
                wr *= 1.0 - m
            elif a.family == "reject_manipulation":
                wrej *= 1.0 + m
            elif a.family == "tb0_fdi":
                tb0_bias += m
            elif a.family == "wd_fdi":
                wd_scale *= 1.0 - m
            elif a.family == "setpoint_tamper":
                wd_set = WD_SET + m
            elif a.family == "combined":
                wr *= 1.0 - 0.6 * m
                ws_scale *= 1.0 + 0.4 * m
                wrej *= 1.0 - 0.8 * m
            else:
                raise ValueError(a.family)
        return wr, wrej, ws_scale, tb0_bias, wd_scale, wd_set, active

    def step(self):
        """One 100 ms scan cycle. Returns the PLC's view of the world:
        ``(tb0_adc, wd_adc, ws_cmd, attack_active)``."""
        wr, wrej, ws_scale, tb0_bias, wd_scale, wd_set, active = \
            self._attack_params()

        # Sensor path: true value -> (FDI) -> noise -> ADC.
        tb0_s = self.state.tb0 + tb0_bias
        wd_s = self.state.wd * wd_scale
        if self.noise:
            tb0_s += TB0_NOISE * self.rng.normal()
            wd_s += WD_NOISE * self.rng.normal()
        tb0_adc = adc(tb0_s, TB0_ADC_LO, TB0_ADC_HI)
        wd_adc = adc(wd_s, WD_ADC_LO, WD_ADC_HI)

        # PLC control task (cascaded PID), then actuator path.
        ws_cmd = pid_step(self.pid, tb0_adc, wd_adc, wd_set)
        ws_applied = min(max(ws_cmd * ws_scale, WS_MIN), WS_MAX)

        self.state = plant_step(self.state, ws_applied, wr, wrej)
        self.step_idx += 1
        return tb0_adc, wd_adc, ws_cmd, active


def golden_trace(n_steps: int = 1200) -> dict:
    """Noise-free deterministic trace pinning the Python and Rust plants
    together. Includes a mid-trace combined attack so the attack path is
    covered too."""
    sim = Simulator(seed=1, noise=False,
                    attacks=[Attack("combined", 0.5, 600, 1200)])
    rows = []
    for _ in range(n_steps):
        tb0, wd, ws, active = sim.step()
        rows.append([tb0, wd, ws,
                     sim.state.tb0, sim.state.tbot, sim.state.wd,
                     1 if active else 0])
    return {
        "dt_minutes": DT,
        "columns": ["tb0_adc", "wd_adc", "ws_cmd",
                    "tb0", "tbot", "wd", "attack"],
        "rows": rows,
    }


def constants_manifest() -> dict:
    """Plant constants exported to the Rust side for self-checks."""
    return {
        "dt": DT, "t_sea": T_SEA, "lambda_s": LAMBDA_S,
        "lambda_v": LAMBDA_V, "cp": CP, "r_recov": R_RECOV,
        "f_flash": F_FLASH, "c_h": C_H, "c_b": C_B, "tau_d": TAU_D,
        "wr_nom": WR_NOM, "wrej_nom": WREJ_NOM, "ws_nom": WS_NOM,
        "tb0_nom": TB0_NOM, "wd_set": WD_SET,
        "outer_kp": OUTER_KP, "outer_ki": OUTER_KI,
        "inner_kp": INNER_KP, "inner_ki": INNER_KI,
    }


if __name__ == "__main__":
    trace = golden_trace()
    print(json.dumps(trace["rows"][-1]))
