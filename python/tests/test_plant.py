"""Plant-twin invariants: steady state, control, attacks, ADC, PRNG."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from compile import plant
from compile.plant import (Attack, PidState, PlantState, Simulator,
                           SplitMix64, adc, pid_step, plant_step)


def test_nominal_steady_state_is_fixed_point():
    """At the documented nominal operating point the ODE derivatives
    vanish (the calibration behind Fig. 8's Wd = 19.18)."""
    s = PlantState()
    s2 = plant_step(s, plant.WS_NOM, plant.WR_NOM, plant.WREJ_NOM)
    assert abs(s2.tb0 - s.tb0) < 1e-9
    assert abs(s2.tbot - s.tbot) < 1e-9
    assert abs(s2.wd - s.wd) < 1e-9


def test_closed_loop_converges_to_setpoint():
    sim = Simulator(seed=1, noise=False)
    for _ in range(24000):  # 40 min plant time
        sim.step()
    assert abs(sim.state.wd - plant.WD_SET) < 0.01
    assert abs(sim.state.tb0 - plant.TB0_NOM) < 0.5


def test_closed_loop_rejects_step_disturbance():
    """PID recovers Wd after a transient recycle-flow excursion."""
    sim = Simulator(seed=1, noise=False,
                    attacks=[Attack("recycle_reduction", 0.1, 1000, 4000)])
    for _ in range(30000):
        sim.step()
    assert abs(sim.state.wd - plant.WD_SET) < 0.05


@pytest.mark.parametrize("family", plant.ATTACK_FAMILIES)
def test_every_attack_family_perturbs_observables(family):
    """Each of the 7 families must visibly move the PLC-visible series —
    otherwise the §7 classifier could not possibly detect it."""
    mag = {"tb0_fdi": 3.0, "setpoint_tamper": 2.0}.get(family, 0.3)
    base = Simulator(seed=2, noise=False)
    attacked = Simulator(seed=2, noise=False,
                         attacks=[Attack(family, mag, 1000, 9000)])
    deviation = 0.0
    for i in range(9000):
        tb_b, wd_b, _, _ = base.step()
        tb_a, wd_a, _, _ = attacked.step()
        if i > 2000:
            deviation = max(deviation,
                            abs(tb_a - tb_b) / 90.0 + abs(wd_a - wd_b) / 19.0)
    assert deviation > 0.002, (family, deviation)


def test_attack_window_bounds():
    a = Attack("combined", 0.5, 10, 20)
    assert not a.active(9) and a.active(10) and a.active(19) \
        and not a.active(20)


def test_adc_quantizes_to_grid():
    v = adc(19.1837, plant.WD_ADC_LO, plant.WD_ADC_HI)
    lsb = (plant.WD_ADC_HI - plant.WD_ADC_LO) / plant.ADC_LEVELS
    assert abs(v / lsb - round(v / lsb)) < 1e-6
    assert abs(v - 19.1837) <= lsb / 2 + 1e-9


@given(x=st.floats(-100, 300))
@settings(max_examples=100, deadline=None)
def test_adc_clamps_and_bounds_error(x):
    v = adc(x, plant.TB0_ADC_LO, plant.TB0_ADC_HI)
    assert plant.TB0_ADC_LO <= v <= plant.TB0_ADC_HI
    if plant.TB0_ADC_LO <= x <= plant.TB0_ADC_HI:
        lsb = (plant.TB0_ADC_HI - plant.TB0_ADC_LO) / plant.ADC_LEVELS
        assert abs(v - x) <= lsb / 2 + 1e-9


def test_splitmix64_reference_vector():
    """Pin the PRNG to its published reference stream (seed=0) — the Rust
    twin asserts the identical vector."""
    r = SplitMix64(0)
    got = [r.next_u64() for _ in range(3)]
    assert got == [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4,
                   0x06C45D188009454F]


def test_splitmix64_normal_moments():
    r = SplitMix64(42)
    xs = [r.normal() for _ in range(20000)]
    mean = sum(xs) / len(xs)
    var = sum((x - mean) ** 2 for x in xs) / len(xs)
    assert abs(mean) < 0.03
    assert abs(var - 1.0) < 0.05


def test_pid_anti_windup_clamps():
    p = PidState()
    for _ in range(100000):
        pid_step(p, 150.0, 40.0, plant.WD_SET)   # hugely wrong readings
    assert -30.0 <= p.inner_i <= 30.0
    assert -20.0 <= p.outer_i <= 20.0


def test_golden_trace_deterministic():
    t1 = plant.golden_trace(100)
    t2 = plant.golden_trace(100)
    assert t1 == t2
    assert t1["rows"][50][6] == 0          # no attack yet at step 50
    t3 = plant.golden_trace(700)
    assert t3["rows"][650][6] == 1         # combined attack active
