"""Model shape / architecture checks + training-path math."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import (CLASSIFIER_ACTS, CLASSIFIER_LAYERS, MNIST_ACTS,
                           MNIST_LAYERS, bench_stack_sizes,
                           bench_width_sizes, classifier_forward, init_mlp,
                           mlp_forward, mnist_forward)


def test_classifier_architecture_matches_paper():
    # §7: 400 inputs = 2 features x 10 Hz x 20 s; hidden 64/32/16; 2 out.
    assert CLASSIFIER_LAYERS == (400, 64, 32, 16, 2)
    assert CLASSIFIER_ACTS == ("relu", "relu", "relu", "linear")
    assert CLASSIFIER_LAYERS[0] == 2 * 10 * 20


def test_mnist_architecture_matches_paper():
    # §6.1: 3-layer fully connected MNIST model, 512x512 second layer.
    assert MNIST_LAYERS == (784, 512, 512, 10)
    assert MNIST_LAYERS[1] * MNIST_LAYERS[2] == 262_144  # paper op count


def test_classifier_forward_shapes():
    params = init_mlp(jax.random.PRNGKey(0), CLASSIFIER_LAYERS)
    x = jnp.zeros((3, 400), jnp.float32)
    out = classifier_forward(params, x)
    assert out.shape == (3, 2)


def test_mnist_forward_shapes():
    params = init_mlp(jax.random.PRNGKey(0), MNIST_LAYERS)
    out = mnist_forward(params, jnp.zeros((2, 784), jnp.float32))
    assert out.shape == (2, 10)


def test_init_mlp_he_scale():
    params = init_mlp(jax.random.PRNGKey(3), (256, 512))
    w, b = params[0]
    assert abs(float(jnp.std(w)) - np.sqrt(2.0 / 256)) < 0.01
    assert float(jnp.abs(b).max()) == 0.0


@settings(max_examples=10, deadline=None)
@given(depth=st.integers(1, 10))
def test_bench_stack_sizes(depth):
    sizes = bench_stack_sizes(depth)
    assert len(sizes) == depth + 1
    assert all(s == 64 for s in sizes)


def test_bench_width_sizes():
    assert bench_width_sizes(512) == (32, 512)


def test_mlp_forward_matches_manual():
    params = init_mlp(jax.random.PRNGKey(1), (8, 4, 2))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8))
    got = mlp_forward(params, x, ("relu", "linear"))
    (w0, b0), (w1, b1) = params
    want = jnp.maximum(x @ w0 + b0, 0) @ w1 + b1
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
