"""Training pipeline checks (dataset construction, windows, normalization
folding, synthetic digits). Heavy training runs only in ICSML_FAST mode —
these tests exercise the pieces, not full convergence."""

import numpy as np

from compile import plant, train


def test_attack_schedule_covers_all_families_twice():
    rng = plant.SplitMix64(1)
    sched = train.attack_schedule(800_000, rng)
    fams = [a.family for a in sched]
    assert len(sched) == 14
    for fam in plant.ATTACK_FAMILIES:
        assert fams.count(fam) == 2
    # Blocks are disjoint and ordered.
    for a, b in zip(sched, sched[1:]):
        assert a.end_step < b.start_step
    # Attack duty cycle near the paper's 48.8%.
    frac = sum(a.end_step - a.start_step for a in sched) / 800_000
    assert 0.45 < frac < 0.52


def test_window_matrix_layout():
    """Windows are [tb0 oldest..newest | wd oldest..newest], label at end."""
    n = 500
    tb0 = np.arange(n, dtype=np.float32)
    wd = np.arange(n, dtype=np.float32) + 10_000
    lab = (np.arange(n) % 2).astype(np.int32)
    idx = np.array([300, 421])
    x, y = train.window_matrix(tb0, wd, lab, idx)
    assert x.shape == (2, 400)
    assert x[0, 0] == 300 - 199 and x[0, 199] == 300
    assert x[0, 200] == 10_000 + 300 - 199 and x[0, 399] == 10_000 + 300
    assert y[0] == lab[300] and y[1] == lab[421]


def test_normalize_per_channel():
    x = np.ones((4, 400), np.float32)
    x[:, :200] = 90.0
    x[:, 200:] = 19.0
    mu = np.array([90.0, 19.0], np.float32)
    sd = np.array([2.0, 0.5], np.float32)
    out = train.normalize(x, mu, sd)
    assert np.allclose(out, 0.0)
    assert np.allclose(x[:, :200], 90.0)   # input not mutated


def test_synth_digits_properties():
    x, y = train.synth_digits(64, seed=3)
    assert x.shape == (64, 784) and y.shape == (64,)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))
    # Deterministic for a fixed seed.
    x2, y2 = train.synth_digits(64, seed=3)
    assert np.array_equal(x, x2) and np.array_equal(y, y2)
    # Different digits are visually distinct on average.
    x0 = x[y == y[0]]
    if (y != y[0]).any():
        x1 = x[y != y[0]]
        assert abs(x0.mean() - x1.mean()) >= 0.0  # sanity (non-degenerate)


def test_simulate_series_labels_match_schedule():
    rng = plant.SplitMix64(11 ^ 0xA5A5)
    sched = train.attack_schedule(6000, rng)
    sim = plant.Simulator(seed=11, noise=True, attacks=sched)
    labels = [sim.step()[3] for _ in range(6000)]
    for a in sched[:2]:
        if a.start_step + 1 < 6000:
            assert labels[a.start_step + 1]
    assert not labels[0]


def test_forward_jnp_matches_kernel_math():
    import jax, jax.numpy as jnp
    from compile.model import init_mlp
    from compile.kernels import dense
    params = init_mlp(jax.random.PRNGKey(0), (16, 8, 2))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16))
    got = train._forward_jnp(params, x, ("relu", "linear"))
    want = x
    for (w, b), act in zip(params, ("relu", "linear")):
        want = dense(want, w, b, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
