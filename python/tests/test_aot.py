"""AOT artifact schema checks (run against a throwaway fast build when no
artifacts exist; against the real artifacts/ when present)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
ARTIFACTS = os.path.join(ROOT, "artifacts")
FALLBACK = os.path.join(ROOT, "artifacts_fast")


def _artifact_dir():
    for d in (ARTIFACTS, FALLBACK):
        if os.path.exists(os.path.join(d, "manifest.json")):
            return d
    pytest.skip("no artifacts built (run `make artifacts` first)")


@pytest.fixture(scope="module")
def manifest():
    d = _artifact_dir()
    with open(os.path.join(d, "manifest.json")) as f:
        return d, json.load(f)


def test_manifest_schema(manifest):
    d, m = manifest
    for key in ("hlo", "models", "dataset", "plant", "golden_trace"):
        assert key in m, key
    assert "classifier" in m["models"] and "mnist512" in m["models"]


def test_hlo_artifacts_exist_and_have_full_constants(manifest):
    d, m = manifest
    for name, rel in m["hlo"].items():
        path = os.path.join(d, rel)
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule")
        # The elided-constant marker must never appear (it would mean the
        # embedded weights were destroyed on the text round-trip).
        assert "constant({...})" not in text, name


def test_classifier_manifest_matches_architecture(manifest):
    d, m = manifest
    c = m["models"]["classifier"]
    assert c["sizes"] == [400, 64, 32, 16, 2]
    assert c["activations"] == ["relu", "relu", "relu", "linear"]
    for i, layer in enumerate(c["layers"]):
        w = os.path.join(d, c["weights_dir"], layer["weights"])
        b = os.path.join(d, c["weights_dir"], layer["biases"])
        assert os.path.getsize(w) == 4 * layer["inputs"] * layer["neurons"]
        assert os.path.getsize(b) == 4 * layer["neurons"]


def test_weight_binaries_row_major_out_in(manifest):
    """ICSML layout: l0_w.bin is [out][in] row-major f32 LE."""
    d, m = manifest
    c = m["models"]["classifier"]
    l0 = c["layers"][0]
    w = np.fromfile(os.path.join(d, c["weights_dir"], l0["weights"]),
                    np.float32)
    assert w.size == l0["inputs"] * l0["neurons"]
    assert np.isfinite(w).all()


def test_eval_slices_consistent(manifest):
    d, m = manifest
    ds = m["dataset"]
    n = ds["eval_n"]
    x = np.fromfile(os.path.join(d, ds["eval_windows"]), np.float32)
    y = np.fromfile(os.path.join(d, ds["eval_labels"]), np.int32)
    z = np.fromfile(os.path.join(d, ds["eval_logits"]), np.float32)
    assert x.size == n * 400 and y.size == n and z.size == n * 2
    assert set(np.unique(y)).issubset({0, 1})


def test_eval_logits_reproduce_labels_reasonably(manifest):
    """argmax(exported logits) should beat chance comfortably on the eval
    slice — guards against scrambled export order."""
    d, m = manifest
    ds = m["dataset"]
    n = ds["eval_n"]
    y = np.fromfile(os.path.join(d, ds["eval_labels"]), np.int32)
    z = np.fromfile(os.path.join(d, ds["eval_logits"]),
                    np.float32).reshape(n, 2)
    acc = float((z.argmax(1) == y).mean())
    assert acc > 0.7, acc


def test_golden_trace_schema(manifest):
    d, m = manifest
    with open(os.path.join(d, m["golden_trace"])) as f:
        trace = json.load(f)
    assert trace["columns"] == ["tb0_adc", "wd_adc", "ws_cmd",
                                "tb0", "tbot", "wd", "attack"]
    assert len(trace["rows"]) >= 1000
    assert all(len(r) == 7 for r in trace["rows"][:10])


def test_plant_constants_exported(manifest):
    d, m = manifest
    from compile import plant
    assert abs(m["plant"]["wd_set"] - plant.WD_SET) < 1e-12
    assert abs(m["plant"]["dt"] - plant.DT) < 1e-15
