"""Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes, activations and quantization schemes; every
case asserts allclose between the interpret-mode Pallas kernel and the
reference implementation in kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, quant_dense, quantize_weights, ACTIVATIONS
from compile.kernels.quant_dense import SCHEMES
from compile.kernels.ref import dense_ref, quant_dense_ref


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     jnp.float32)


# ----------------------------------------------------------- dense kernel
@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_dense_all_activations(activation):
    x, w, b = _rand(0, (4, 96)), _rand(1, (96, 64)), _rand(2, (64,))
    got = dense(x, w, b, activation=activation)
    want = dense_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 8),
    k=st.integers(1, 96),
    n=st.integers(1, 160),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_shape_sweep(batch, k, n, act, seed):
    x = _rand(seed, (batch, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    got = dense(x, w, b, activation=act)
    want = dense_ref(x, w, b, activation=act)
    assert got.shape == (batch, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dense_paper_shapes():
    """The exact shapes the paper benchmarks: 64x64 stack layers, the
    512x512 quantization-study layer, the 784x512 pruning layer, and the
    400-input classifier head."""
    for (k, n) in [(64, 64), (512, 512), (784, 512), (400, 64)]:
        x, w, b = _rand(3, (1, k)), _rand(4, (k, n), 0.1), _rand(5, (n,))
        np.testing.assert_allclose(
            dense(x, w, b, activation="relu"),
            dense_ref(x, w, b, activation="relu"), rtol=1e-4, atol=1e-4)


def test_dense_rejects_bad_shapes():
    x, w, b = _rand(0, (1, 8)), _rand(1, (9, 4)), _rand(2, (4,))
    with pytest.raises(AssertionError):
        dense(x, w, b)


# ---------------------------------------------------- quantized kernel
@pytest.mark.parametrize("scheme", list(SCHEMES))
def test_quant_dense_schemes(scheme):
    x = _rand(0, (2, 128), 0.5)
    w = _rand(1, (128, 96), 0.2)
    b = _rand(2, (96,), 0.1)
    w_q, s_w = quantize_weights(w, scheme)
    s_x = jnp.asarray([0.01], jnp.float32)
    got = quant_dense(x, w_q, s_w, b, s_x, scheme=scheme, activation="relu")
    want = quant_dense_ref(x, w_q, s_w, b, s_x, scheme=scheme,
                           activation="relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(4, 160),
    n=st.integers(2, 96),
    scheme=st.sampled_from(sorted(SCHEMES)),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_dense_shape_sweep(k, n, scheme, seed):
    x = _rand(seed, (1, k), 0.5)
    w = _rand(seed + 1, (k, n), 0.3)
    b = _rand(seed + 2, (n,), 0.1)
    w_q, s_w = quantize_weights(w, scheme)
    s_x = jnp.asarray([0.02], jnp.float32)
    got = quant_dense(x, w_q, s_w, b, s_x, scheme=scheme)
    want = quant_dense_ref(x, w_q, s_w, b, s_x, scheme=scheme)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_quantization_error_bounds():
    """Dequantized weights must be within half an LSB of the original —
    the §6.1 premise that accuracy loss is controllable."""
    w = _rand(7, (512, 512), 0.25)
    absmax = jnp.max(jnp.abs(w), axis=0)
    for scheme in ("SINT", "INT", "DINT"):
        w_q, s_w = quantize_weights(w, scheme)
        w_hat = w_q.astype(jnp.float32) * s_w[None, :]
        # Half an LSB from rounding plus f32 arithmetic slack (dominant
        # for DINT, whose LSB is below f32 resolution of |w|).
        tol = 0.5 * s_w[None, :] + 4.0 * 2.0**-23 * absmax[None, :]
        err = jnp.abs(w_hat - w)
        assert bool(jnp.all(err <= tol)), scheme


def test_quant_sint_end_to_end_close():
    """SINT-quantized layer output stays close to the f32 layer (the
    paper reports acceptable accuracy loss)."""
    x = _rand(0, (8, 512), 0.5)
    w = _rand(1, (512, 512), 0.1)
    b = _rand(2, (512,), 0.1)
    w_q, s_w = quantize_weights(w, "SINT")
    s_x = jnp.asarray([float(jnp.max(jnp.abs(x))) / 127.0], jnp.float32)
    got = quant_dense(x, w_q, s_w, b, s_x, scheme="SINT")
    want = dense_ref(x, w, b)
    # int8 x int8 over 512 terms: relative error well under 5%.
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.05, rel
