//! §5.4 — understanding the ICSML-vs-compiled performance gap on a
//! 512-neuron dense layer. The paper decomposes ~20-30x into:
//!   ~2x  profiler instrumentation (Codesys),
//!   ~4x  conservative/no compiler optimization (-O0 vs -O3),
//!   ~3x  no optimized math libraries vs TFLite.
//!
//! Our stack reproduces each rung: instrumented-vs-plain modeled time
//! (exactly 2x by construction), the ST interpreter vs the native
//! engine (the "faithfully reimplemented in C++ -O3" comparator), and
//! the native engine vs XLA (the optimized-library rung).

use icsml::engine::{Act, Layer, Model};
use icsml::plc::HwProfile;
use icsml::runtime::Runtime;
use icsml::util::bench::{Bench, Table};
use icsml::util::benchkit as bk;
use icsml::util::rng::SplitMix64;

fn main() {
    let bench = Bench::from_env();
    let profile = HwProfile::beaglebone();

    // The workload: 512-in / 512-out dense + ReLU.
    let (spec, dir) =
        bk::random_spec("perf512", &[512, 512], &["relu"], 3);
    let mut st = bk::st_model(&spec, &dir, true);
    bk::st_set_inputs(&mut st, &vec![0.3f32; 512]);
    let meter = bk::st_infer_meter(&mut st);

    // Rung 1: profiler instrumentation (modeled).
    let plain = profile.time_us(&meter);
    let instrumented = profile.time_us_instrumented(&meter);

    // Rung 2: interpreted ST vs compiled native engine (wall-clock).
    let st_wall = bench.run("st", || {
        let _ = bk::st_infer_meter(&mut st);
    });
    let mut rng = SplitMix64::new(3);
    let w: Vec<f32> =
        (0..512 * 512).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
    let b: Vec<f32> = (0..512).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
    let mut engine = Model::new(vec![Layer::dense(w, b, 512, Act::Relu)]);
    let x = vec![0.3f32; 512];
    let eng_wall = bench.run("engine", || {
        let _ = std::hint::black_box(engine.infer(&x));
    });

    // Rung 3: native engine vs XLA (optimized library, wall-clock).
    let xla_wall = Runtime::cpu().ok().and_then(|rt| {
        let path = icsml::artifacts_dir().join("hlo/dense512_f32.hlo.txt");
        rt.load_hlo(&path).ok().map(|exe| {
            bench.run("xla", || {
                let _ = std::hint::black_box(
                    exe.run_f32(&x, &[1, 512]).unwrap(),
                );
            })
        })
    });

    println!("\n§5.4 — performance decomposition (512x512 dense + ReLU)");
    let mut t = Table::new(&["Rung", "this repo", "paper"]);
    t.row(&[
        "profiler instrumentation".into(),
        format!("{:.1}x ({:.1} -> {:.1} ms modeled)",
                instrumented / plain, instrumented / 1e3, plain / 1e3),
        "~2x".into(),
    ]);
    t.row(&[
        "compilation/optimization (ST interp vs native)".into(),
        format!("{:.1}x ({:.0} -> {:.0} µs wall)",
                st_wall.mean_us() / eng_wall.mean_us(),
                st_wall.mean_us(), eng_wall.mean_us()),
        "~4x (-O0 vs -O3)".into(),
    ]);
    if let Some(x_wall) = &xla_wall {
        t.row(&[
            "optimized math library (native vs XLA)".into(),
            format!("{:.1}x ({:.0} -> {:.0} µs wall)",
                    eng_wall.mean_us() / x_wall.mean_us(),
                    eng_wall.mean_us(), x_wall.mean_us()),
            "~3x".into(),
        ]);
        t.row(&[
            "end-to-end interpreted vs compiled".into(),
            format!("{:.1}x", st_wall.mean_us() / x_wall.mean_us()),
            "20.8-44.7x (ICSML vs TFLite)".into(),
        ]);
    }
    t.print();
    println!(
        "note: our 'no optimization' rung is an interpreter (the vendor \
         runtime substitute), so its gap exceeds the paper's 4x compiled \
         -O0; the end-to-end interpreted-vs-compiled ratio is the \
         comparable quantity."
    );
}
