//! Fleet-scale closed-loop bench: ≥ 200 independently seeded plants
//! over a loopback `netserve` server, run twice to prove replay
//! identity, plus a deadline-pressure run that exercises the shed
//! path. Writes `BENCH_fleet.json` (per-class deadline hit rate and
//! latency percentiles, shed rate, per-family recall/time-to-detect)
//! with `--json`; `--smoke` runs the small CI gate only.
//!
//! Usage: `cargo bench --bench fleet -- [--smoke] [--json[=PATH]]`

use std::sync::Arc;

use icsml::api::{EngineBackend, SharedBackend};
use icsml::fleet::{
    detector_model, run_fleet, FleetConfig, FleetReport, FleetTarget,
};
use icsml::netserve::{
    Client, ModelRegistry, NetServer, RegistryConfig, RetryPolicy,
    ServerConfig, StaticLoader,
};
use icsml::serve::{PoolConfig, Priority};
use icsml::util::benchkit::{
    json_flag, smoke_flag, write_bench_json, BenchRecord,
};
use icsml::util::json::Json;

/// MACs per detector inference (400×4 + 4×2 dense).
const DETECTOR_OPS: u64 = 400 * 4 + 4 * 2;

fn spawn_server(workers: usize) -> NetServer {
    let mut loader = StaticLoader::new();
    let backend: SharedBackend = Arc::new(EngineBackend::new(detector_model()));
    loader.insert("detector", backend, 1);
    let registry = Arc::new(ModelRegistry::new(
        Box::new(loader),
        RegistryConfig {
            max_models: usize::MAX,
            max_bytes: u64::MAX,
            pool: PoolConfig {
                workers,
                max_batch: 16,
            },
        },
    ));
    // Lock-step pipelining keeps up to three step-batches in flight on
    // one connection; at 200 plants with Defense-class double-checks
    // that can brush the default 1024 per-connection cap, and a
    // connection-overload refusal is timing-dependent — which would
    // poison the replay-identity assertion. Raise the cap so the only
    // sheds are the deterministic deadline ones.
    let cfg = ServerConfig {
        max_inflight_per_conn: 4096,
        ..ServerConfig::default()
    };
    NetServer::bind("127.0.0.1:0", registry, cfg).expect("bind loopback")
}

fn net_target(server: &NetServer) -> FleetTarget {
    let client = Client::connect_with(server.local_addr(), RetryPolicy::new())
        .expect("loopback connect");
    FleetTarget::Net {
        client,
        model: "detector".to_string(),
    }
}

fn run_against(server: &NetServer, cfg: &FleetConfig) -> FleetReport {
    let report = run_fleet(cfg, net_target(server));
    assert_eq!(
        report.outcome.unresolved(),
        0,
        "every request must resolve (logits or typed error)"
    );
    report
}

fn main() {
    let smoke = smoke_flag();
    let json_path = json_flag("fleet");

    // ---------------- correctness gate (always) ----------------------
    // Tiny fleet over the loopback server: zero unresolved requests,
    // recall sanity on every attacked family, no false positives.
    let server = spawn_server(4);
    let gate_cfg = FleetConfig {
        plants: 12,
        steps: 1_400,
        seed: 42,
        ..FleetConfig::default()
    };
    let gate = run_against(&server, &gate_cfg);
    let total = gate.outcome.total();
    assert_eq!(total.served, total.submitted, "no-deadline run serves all");
    assert!(!gate.outcome.families.is_empty(), "mix must assign attacks");
    for fam in &gate.outcome.families {
        assert!(
            fam.recall() >= 0.5,
            "family {} recall {:.2}",
            fam.family.name(),
            fam.recall()
        );
    }
    assert_eq!(gate.outcome.false_positives, 0);
    println!(
        "gate: {} plants x {} steps, {} requests served, {} families detected, wall {:.2}s",
        gate.outcome.plants,
        gate.outcome.steps,
        total.served,
        gate.outcome.families.len(),
        gate.timing.wall_secs
    );
    if smoke {
        server.shutdown();
        println!("smoke pass");
        return;
    }

    // ---------------- replay-identity at scale ------------------------
    // 200 plants through the netserve path, twice: the deterministic
    // outcome half must be byte-for-byte identical.
    let fleet_cfg = FleetConfig {
        plants: 200,
        steps: 1_500,
        seed: 7,
        ..FleetConfig::default()
    };
    let first = run_against(&server, &fleet_cfg);
    let second = run_against(&server, &fleet_cfg);
    assert_eq!(
        first.outcome, second.outcome,
        "fleet outcome must replay identically"
    );
    first.print_summary();

    // ---------------- deadline-pressure run ---------------------------
    // Same fleet under a 250 µs scan budget: the serving tier must
    // shed typed (DeadlineExceeded / Overloaded), never hang.
    let pressure_cfg = FleetConfig {
        plants: 200,
        steps: 600,
        seed: 7,
        deadline: true,
        period_us: 250.0,
        ..FleetConfig::default()
    };
    let pressure = run_against(&server, &pressure_cfg);
    println!(
        "pressure: shed_rate {:.4} (shed {} overloaded {} of {})",
        pressure.outcome.shed_rate(),
        pressure.outcome.total().shed,
        pressure.outcome.total().overloaded,
        pressure.outcome.total().submitted
    );
    server.shutdown();

    // ---------------- JSON report -------------------------------------
    if let Some(path) = json_path {
        let mut records = Vec::new();
        for p in Priority::ALL.iter() {
            let l = &first.timing.latency[p.band()];
            if l.is_empty() {
                continue;
            }
            records.push(BenchRecord {
                name: format!("fleet/{}_detection_latency", p.name()),
                mean_ns: l.mean_us() * 1e3,
                median_ns: l.percentile_us(50.0) * 1e3,
                ops_per_inference: DETECTOR_OPS,
            });
        }
        let extras = vec![
            ("fleet", first.to_json()),
            ("pressure", pressure.to_json()),
            ("replay_identical", Json::Bool(true)),
        ];
        write_bench_json(&path, "fleet", &records, extras)
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        println!("wrote {}", path.display());
    }
}
