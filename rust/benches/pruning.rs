//! §6.2 — weight pruning experiments on the WAGO PFC100: a 784-input /
//! 512-neuron dense layer under six configurations.
//!
//! Paper numbers (dot-product time):
//!   f32 original 52.13 ms | f32 all-zero 47.62 ms | f32 IF-skip 50.84 ms
//!   SINT 36.39 ms | SINT all-zero 35.69 ms | SINT IF-skip 20.87 ms
//!   SINT skip w&x 34.19 ms
//! Conclusion reproduced: no automatic runtime speedup from zeros; the
//! IF-skip pays off when combined with quantization.

use icsml::icsml_st;
use icsml::plc::HwProfile;
use icsml::st::{Interp, Value};
use icsml::util::bench::Table;
use icsml::util::rng::SplitMix64;

const INPUTS: usize = 784;
const NEURONS: usize = 512;

fn program(quant: bool, skipzw: bool, skipzx: bool) -> String {
    let (decl, wiring, call) = if quant {
        (
            format!(
                "    wq : ARRAY[0..{}] OF SINT;\n    xq : ARRAY[0..{}] OF DINT;\n    sw : ARRAY[0..{}] OF REAL;\n    qd : FB_QuantDenseS;\n",
                INPUTS * NEURONS - 1,
                INPUTS - 1,
                NEURONS - 1
            ),
            format!(
                "    qd.wq := ADR(wq); qd.xq := ADR(xq);\n\
                 \x20   qd.scales := (address := ADR(sw), length := {n}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.biases := (address := ADR(b), length := {n}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.inMem := (address := ADR(x), length := {i}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.outMem := (address := ADR(y), length := {n}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.s_x := 0.01; qd.neurons := {n}; qd.inputs := {i};\n\
                 \x20   qd.skipzw := {zw}; qd.skipzx := {zx};\n",
                n = NEURONS,
                i = INPUTS,
                zw = if skipzw { "TRUE" } else { "FALSE" },
                zx = if skipzx { "TRUE" } else { "FALSE" },
            ),
            "    ok := qd.eval();\n",
        )
    } else {
        (
            "    dense : FB_Dense;\n".to_string(),
            format!(
                "    dense.weights := (address := ADR(w), length := {wl}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   dense.biases := (address := ADR(b), length := {n}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   dense.inMem := (address := ADR(x), length := {i}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   dense.outMem := (address := ADR(y), length := {n}, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   dense.neurons := {n}; dense.inputs := {i};\n\
                 \x20   dense.pruned := {p};\n",
                wl = INPUTS * NEURONS,
                n = NEURONS,
                i = INPUTS,
                p = if skipzw { "TRUE" } else { "FALSE" },
            ),
            "    ok := dense.eval();\n",
        )
    };
    format!(
        "PROGRAM MAIN\nVAR\n\
         \x20   x : ARRAY[0..{xi}] OF REAL;\n\
         \x20   y : ARRAY[0..{yn}] OF REAL;\n\
         \x20   w : ARRAY[0..{wn}] OF REAL;\n\
         \x20   b : ARRAY[0..{yn}] OF REAL;\n\
         {decl}\
         \x20   dims : ARRAY[0..0] OF UDINT := [{n}];\n\
         \x20   initialized : BOOL := FALSE;\n\
         \x20   ok : BOOL;\n\
         END_VAR\n\
         IF NOT initialized THEN\n{wiring}    initialized := TRUE;\nEND_IF\n\
         {call}END_PROGRAM",
        xi = INPUTS - 1,
        yn = NEURONS - 1,
        wn = INPUTS * NEURONS - 1,
        n = NEURONS,
    )
}

/// Load + fill weights (zeroed or random) and measure one inference.
fn measure(quant: bool, zero_weights: bool, skipzw: bool, skipzx: bool) -> f64 {
    let mut it: Interp =
        icsml_st::load(&program(quant, skipzw, skipzx)).unwrap();
    let inst = it.program_instance("MAIN").unwrap();
    let mut rng = SplitMix64::new(11);
    for field in ["x", "w", "b", "sw"] {
        if let Some(Value::ArrF32(a)) = it.instance_field(inst, field) {
            for v in a.borrow_mut().iter_mut() {
                *v = if field == "w" && zero_weights {
                    0.0
                } else {
                    rng.uniform(-0.5, 0.5) as f32
                };
            }
        }
    }
    if let Some(Value::ArrInt(a)) = it.instance_field(inst, "wq") {
        for v in a.borrow_mut().iter_mut() {
            *v = if zero_weights {
                0
            } else {
                (rng.next_u64() % 255) as i64 - 127
            };
        }
    }
    it.run_program("MAIN").unwrap(); // init
    let before = it.meter.clone();
    it.run_program("MAIN").unwrap();
    HwProfile::wago_pfc100().time_us(&it.meter.since(&before)) / 1e3
}

fn main() {
    println!("\n§6.2 — pruning experiments (784x512 dense, WAGO PFC100)");
    let mut t = Table::new(&["Configuration", "modeled ms", "paper ms"]);
    let rows: Vec<(&str, f64, &str)> = vec![
        ("REAL, original weights", measure(false, false, false, false), "52.13"),
        ("REAL, all weights zero", measure(false, true, false, false), "47.62"),
        ("REAL, IF-skip zero w", measure(false, true, true, false), "50.84"),
        ("SINT, original weights", measure(true, false, false, false), "36.39"),
        ("SINT, all weights zero", measure(true, true, false, false), "35.69"),
        ("SINT, IF-skip zero w", measure(true, true, true, false), "20.87"),
        ("SINT, IF-skip zero w&x", measure(true, false, true, true), "34.19"),
    ];
    for (name, ms, paper) in &rows {
        t.row(&[name.to_string(), format!("{ms:.2}"), paper.to_string()]);
    }
    t.print();
    println!(
        "shape checks: (1) zeros alone give no automatic speedup \
         (rows 1≈2 and 4≈5 — the paper's conclusion); (2) the IF-skip \
         pays off with quantization (row 6 far below row 4); (3) \
         skipping on non-sparse data adds overhead (row 7 ≈ row 4)."
    );
}
