//! §6.3 — multipart inference: output latency of a MobileNet-style
//! model (~10 M MACs) on the BBB profile as a function of the scan
//! cycle length. Paper reference: 90 ms scan cycle → 1.17 s latency.

use icsml::coordinator::MultipartSession;
use icsml::engine::{Act, Layer, Model};
use icsml::plc::HwProfile;
use icsml::util::bench::Table;
use icsml::util::rng::SplitMix64;

fn randv(rng: &mut SplitMix64, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.uniform(-s as f64, s as f64) as f32).collect()
}

fn mobilenet_ish() -> Model {
    let mut r = SplitMix64::new(99);
    let sc = |r: &mut SplitMix64, c: usize, dim: usize| Layer::Scale {
        scales: (0..c).map(|_| 0.9 + 0.2 * r.next_f64() as f32).collect(),
        shifts: randv(r, c, 0.05),
        channels: c,
        dim,
        act: Act::Relu,
        alpha: 0.0,
    };
    Model::new(vec![
        Layer::Conv2D { w: randv(&mut r, 16 * 3 * 9, 0.2), b: randv(&mut r, 16, 0.05), in_c: 3, in_h: 96, in_w: 96, out_c: 16, k_h: 3, k_w: 3, stride: 2, act: Act::None, alpha: 0.0 },
        sc(&mut r, 16, 16 * 47 * 47),
        Layer::ConvDW { w: randv(&mut r, 16 * 9, 0.3), b: randv(&mut r, 16, 0.05), chans: 16, in_h: 47, in_w: 47, k_h: 3, k_w: 3, stride: 1, act: Act::None, alpha: 0.0 },
        sc(&mut r, 16, 16 * 45 * 45),
        Layer::Conv2D { w: randv(&mut r, 32 * 16, 0.2), b: randv(&mut r, 32, 0.05), in_c: 16, in_h: 45, in_w: 45, out_c: 32, k_h: 1, k_w: 1, stride: 1, act: Act::None, alpha: 0.0 },
        sc(&mut r, 32, 32 * 45 * 45),
        Layer::ConvDW { w: randv(&mut r, 32 * 9, 0.3), b: randv(&mut r, 32, 0.05), chans: 32, in_h: 45, in_w: 45, k_h: 3, k_w: 3, stride: 2, act: Act::None, alpha: 0.0 },
        sc(&mut r, 32, 32 * 22 * 22),
        Layer::Conv2D { w: randv(&mut r, 64 * 32, 0.2), b: randv(&mut r, 64, 0.05), in_c: 32, in_h: 22, in_w: 22, out_c: 64, k_h: 1, k_w: 1, stride: 1, act: Act::None, alpha: 0.0 },
        sc(&mut r, 64, 64 * 22 * 22),
        Layer::ConvDW { w: randv(&mut r, 64 * 9, 0.3), b: randv(&mut r, 64, 0.05), chans: 64, in_h: 22, in_w: 22, k_h: 3, k_w: 3, stride: 1, act: Act::None, alpha: 0.0 },
        sc(&mut r, 64, 64 * 20 * 20),
        Layer::Conv2D { w: randv(&mut r, 128 * 64 * 9, 0.1), b: randv(&mut r, 128, 0.05), in_c: 64, in_h: 20, in_w: 20, out_c: 128, k_h: 3, k_w: 3, stride: 2, act: Act::None, alpha: 0.0 },
        sc(&mut r, 128, 128 * 9 * 9),
        Layer::dense(randv(&mut r, 128 * 81 * 10, 0.02), randv(&mut r, 10, 0.01), 128 * 81, Act::None),
    ])
}

fn main() {
    let model = mobilenet_ish();
    println!(
        "\n§6.3 — multipart inference: MobileNet-style, {:.1} M MACs, \
         {} layers (4x Conv2D, 7x BN+ReLU, 3x ConvDW + head)",
        model.macs() as f64 / 1e6,
        model.layers().len()
    );
    let mut rng = SplitMix64::new(5);
    let x: Vec<f32> =
        (0..3 * 96 * 96).map(|_| rng.next_f64() as f32).collect();
    let profile = HwProfile::beaglebone();
    let control_us = 2000.0;

    let mut t = Table::new(&[
        "scan cycle ms",
        "cycles",
        "output latency s",
        "max ML ms/cycle",
    ]);
    for scan_ms in [30.0, 60.0, 90.0, 150.0, 300.0] {
        let budget = scan_ms * 1e3 - control_us;
        let mut sess = MultipartSession::new(mobilenet_ish(), profile.clone());
        let (out, cycles) = sess
            .run_to_completion(&x, budget, 1_000_000)
            .expect("backend error")
            .expect("must finish");
        std::hint::black_box(&out);
        t.row(&[
            format!("{scan_ms:.0}"),
            cycles.to_string(),
            format!("{:.2}", cycles as f64 * scan_ms / 1e3),
            format!("{:.1}", sess.stats.max_cycle_us / 1e3),
        ]);
    }
    t.print();
    println!("paper: 90 ms scan cycle -> 1.17 s output latency (α=0.25 \
              MobileNet-class model on the BBB).");
}
