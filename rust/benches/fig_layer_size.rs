//! §5.3 — layer-size scaling: a 32-input model with one dense+ReLU
//! layer whose width doubles each step. Paper: ≈9.33 µs per neuron on
//! the BBB / 13.72 µs on the WAGO; compiled runtime 20.8x / 30.7x
//! faster.

use icsml::plc::HwProfile;
use icsml::runtime::Runtime;
use icsml::util::bench::{Bench, Table};
use icsml::util::benchkit as bk;

const WIDTHS: [usize; 8] = [32, 64, 128, 256, 512, 1024, 2048, 4096];

fn main() {
    let bbb = HwProfile::beaglebone();
    let wago = HwProfile::wago_pfc100();
    let bench = Bench::from_env();
    let rt = Runtime::cpu().ok();
    let artifacts = icsml::artifacts_dir();

    let mut table = Table::new(&[
        "neurons",
        "BBB us",
        "BBB us/neuron",
        "WAGO us/neuron",
        "ST wall us",
        "XLA us",
        "ST/XLA",
    ]);

    for width in WIDTHS {
        let (spec, dir) = bk::random_spec(
            &format!("w{width}"),
            &[32, width],
            &["relu"],
            width as u64,
        );
        let mut it = bk::st_model(&spec, &dir, true);
        bk::st_set_inputs(&mut it, &vec![0.25f32; 32]);
        let meter = bk::st_infer_meter(&mut it);
        let st_wall = bench.run(&format!("st_w{width}"), || {
            let _ = bk::st_infer_meter(&mut it);
        });

        let (xla_us, ratio) = match &rt {
            Some(rt) => {
                let path =
                    artifacts.join(format!("hlo/bench_width_{width}.hlo.txt"));
                match rt.load_hlo(&path) {
                    Ok(exe) => {
                        let x = vec![0.25f32; 32];
                        let s = bench.run(&format!("xla_w{width}"), || {
                            let _ = std::hint::black_box(
                                exe.run_f32(&x, &[1, 32]).unwrap(),
                            );
                        });
                        (
                            format!("{:.1}", s.mean_us()),
                            format!("{:.1}x", st_wall.mean_us() / s.mean_us()),
                        )
                    }
                    Err(_) => ("n/a".into(), "n/a".into()),
                }
            }
            None => ("n/a".into(), "n/a".into()),
        };

        table.row(&[
            width.to_string(),
            format!("{:.0}", bbb.time_us(&meter)),
            format!("{:.2}", bbb.time_us(&meter) / width as f64),
            format!("{:.2}", wago.time_us(&meter) / width as f64),
            format!("{:.0}", st_wall.mean_us()),
            xla_us,
            ratio,
        ]);
    }

    println!("\n§5.3 — layer-size scaling (32 inputs, dense+ReLU)");
    table.print();
    println!(
        "paper: ≈9.33 µs/neuron (BBB), 13.72 µs/neuron (WAGO); compiled \
         20.8x/30.7x faster. Shape check: per-neuron cost is flat \
         (linear scaling) and the interpreted/compiled gap is >>1."
    );
}
