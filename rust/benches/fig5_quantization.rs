//! Fig. 5 + Table 2 — §6.1 integer quantization of the 512x512 layer:
//! inference latency under SINT/INT/DINT schemes split into dot /
//! activation / other (input quantization), plus the memory table.
//!
//! Paper: SINT −59.71%, INT −56.52%, DINT −37.23% latency vs REAL;
//! WAGO REAL dot ≈ 52.13 ms → SINT 36.39 ms.

use icsml::icsml_st;
use icsml::plc::HwProfile;
use icsml::quant::{memory_requirements, Scheme};
use icsml::st::{Interp, Meter, Value};
use icsml::util::bench::Table;
use icsml::util::rng::SplitMix64;

/// Build the §6.1 bench program: one 512x512 layer, f32 or quantized,
/// plus a separate ReLU activation layer. `neurons_override` lets the
/// "other" phase be isolated (neurons=0 runs input quantization only).
fn program(scheme: Option<Scheme>, neurons: usize) -> String {
    let qdecl = match scheme {
        None => String::new(),
        Some(s) => format!(
            "    wq : ARRAY[0..262143] OF {};\n    xq : ARRAY[0..511] OF DINT;\n    sw : ARRAY[0..511] OF REAL;\n",
            s.name()
        ),
    };
    let (layer_decl, wiring, evalcall) = match scheme {
        None => (
            "    dense : FB_Dense;\n".to_string(),
            "    dense.weights := (address := ADR(w), length := 262144, dimensions := ADR(dims), dimensions_num := 1);\n\
             \x20   dense.biases := (address := ADR(b), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
             \x20   dense.inMem := (address := ADR(x), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
             \x20   dense.outMem := (address := ADR(h), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
             \x20   dense.neurons := NEURONS; dense.inputs := 512;\n"
                .to_string(),
            "    ok := dense.eval();\n".to_string(),
        ),
        Some(s) => {
            let fb = match s {
                Scheme::Sint => "FB_QuantDenseS",
                Scheme::Int => "FB_QuantDenseI",
                Scheme::Dint => "FB_QuantDenseD",
            };
            (
                format!("    qd : {fb};\n"),
                "    qd.wq := ADR(wq); qd.xq := ADR(xq);\n\
                 \x20   qd.scales := (address := ADR(sw), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.biases := (address := ADR(b), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.inMem := (address := ADR(x), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.outMem := (address := ADR(h), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
                 \x20   qd.s_x := 0.01; qd.neurons := NEURONS; qd.inputs := 512;\n"
                    .to_string(),
                "    ok := qd.eval();\n".to_string(),
            )
        }
    };
    format!(
        "PROGRAM MAIN\n\
         VAR CONSTANT NEURONS : DINT := {neurons}; END_VAR\n\
         VAR\n\
         \x20   x : ARRAY[0..511] OF REAL;\n\
         \x20   h : ARRAY[0..511] OF REAL;\n\
         \x20   y : ARRAY[0..511] OF REAL;\n\
         \x20   w : ARRAY[0..262143] OF REAL;\n\
         \x20   b : ARRAY[0..511] OF REAL;\n\
         {qdecl}{layer_decl}\
         \x20   relu : FB_Activation;\n\
         \x20   dims : ARRAY[0..0] OF UDINT := [512];\n\
         \x20   initialized : BOOL := FALSE;\n\
         \x20   ok : BOOL;\n\
         END_VAR\n\
         IF NOT initialized THEN\n\
         {wiring}\
         \x20   relu.inMem := (address := ADR(h), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
         \x20   relu.outMem := (address := ADR(y), length := 512, dimensions := ADR(dims), dimensions_num := 1);\n\
         \x20   relu.act := ACT_RELU;\n\
         \x20   initialized := TRUE;\n\
         END_IF\n\
         {evalcall}\
         ok := relu.eval();\n\
         END_PROGRAM"
    )
}

fn load(scheme: Option<Scheme>, neurons: usize) -> Interp {
    let mut it = icsml_st::load(&program(scheme, neurons)).unwrap();
    // Fill weights/inputs with plausible values.
    let inst = it.program_instance("MAIN").unwrap();
    let mut rng = SplitMix64::new(7);
    for field in ["x", "w", "b", "sw"] {
        if let Some(Value::ArrF32(a)) = it.instance_field(inst, field) {
            for v in a.borrow_mut().iter_mut() {
                *v = rng.uniform(-0.5, 0.5) as f32;
            }
        }
    }
    if let Some(Value::ArrInt(a)) = it.instance_field(inst, "wq") {
        let qmax = scheme.map(|s| s.qmax() as i64).unwrap_or(127);
        for v in a.borrow_mut().iter_mut() {
            *v = (rng.next_u64() % (2 * qmax as u64 + 1)) as i64 - qmax;
        }
    }
    if let Some(Value::ArrF32(a)) = it.instance_field(inst, "sw") {
        for v in a.borrow_mut().iter_mut() {
            *v = 0.004;
        }
    }
    it.run_program("MAIN").unwrap(); // init scan
    it
}

fn measure(scheme: Option<Scheme>) -> (Meter, Meter, Meter) {
    // act-only: isolate FB_Activation by measuring neurons=0 with no
    // input-quantization either (f32 dense with 0 neurons = copy loop
    // skipped entirely).
    let mut full = load(scheme, 512);
    let b0 = full.meter.clone();
    full.run_program("MAIN").unwrap();
    let total = full.meter.since(&b0);

    let mut other_it = load(scheme, 0);
    let b1 = other_it.meter.clone();
    other_it.run_program("MAIN").unwrap();
    let overhead = other_it.meter.since(&b1); // act + input quant (+ copy)

    let mut act_it = load(None, 0);
    let b2 = act_it.meter.clone();
    act_it.run_program("MAIN").unwrap();
    let act = act_it.meter.since(&b2); // act only

    let dot = total.since(&overhead);
    let other = overhead.since(&act);
    (dot, act, other)
}

fn main() {
    println!("\nTable 2 — memory of the 512x512 layer (bytes)");
    let mut t2 = Table::new(&["Scheme", "Weights", "Biases", "Scaling", "Total"]);
    for (name, s) in [
        ("SINT (8-bit)", Some(Scheme::Sint)),
        ("INT (16-bit)", Some(Scheme::Int)),
        ("DINT (32-bit)", Some(Scheme::Dint)),
        ("REAL (32-bit)", None),
    ] {
        let r = memory_requirements(512, 512, s);
        t2.row(&[
            name.into(),
            r.weights.to_string(),
            r.biases.to_string(),
            if s.is_some() { r.scaling.to_string() } else { "N/A".into() },
            r.total.to_string(),
        ]);
    }
    t2.print();

    println!("\nFig. 5 — 512x512 dense + ReLU latency under quantization");
    let wago = HwProfile::wago_pfc100();
    let bbb = HwProfile::beaglebone();
    let mut t = Table::new(&[
        "Scheme",
        "WAGO dot ms",
        "WAGO act ms",
        "WAGO other ms",
        "WAGO total ms",
        "vs REAL",
        "BBB total ms",
    ]);
    let real_total = {
        let (d, a, o) = measure(None);
        wago.time_us(&d) + wago.time_us(&a) + wago.time_us(&o)
    };
    for (name, scheme) in [
        ("REAL", None),
        ("SINT", Some(Scheme::Sint)),
        ("INT", Some(Scheme::Int)),
        ("DINT", Some(Scheme::Dint)),
    ] {
        let (d, a, o) = measure(scheme);
        let (dm, am, om) =
            (wago.time_us(&d), wago.time_us(&a), wago.time_us(&o));
        let total = dm + am + om;
        let bbb_total =
            bbb.time_us(&d) + bbb.time_us(&a) + bbb.time_us(&o);
        t.row(&[
            name.into(),
            format!("{:.2}", dm / 1e3),
            format!("{:.2}", am / 1e3),
            format!("{:.2}", om / 1e3),
            format!("{:.2}", total / 1e3),
            format!("{:+.1}%", 100.0 * (total - real_total) / real_total),
            format!("{:.2}", bbb_total / 1e3),
        ]);
    }
    t.print();
    println!(
        "paper: SINT −59.7%, INT −56.5%, DINT −37.2% total latency; \
         quantization affects the dot portion, activation unchanged, \
         other (input quantization + dequant) negligible-to-small."
    );
}
