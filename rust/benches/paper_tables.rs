//! T1 + F3 — the paper's static data artifacts, regenerated:
//! Table 1 (PLC hardware specs) and Fig. 3 (PLC memory vs Keras model
//! sizes), plus the Fig. 3 conclusion check (which models fit which
//! PLCs).

use icsml::plc::profiles::{KERAS_MODEL_SIZES, PLC_SPECS};
use icsml::util::bench::Table;

fn main() {
    println!("\nTable 1 — PLC hardware specifications by manufacturer");
    let mut t = Table::new(&[
        "Manufacturer",
        "Models",
        "Time/Instr (us)",
        "Memory/RAM",
    ]);
    for s in PLC_SPECS {
        t.row(&[
            s.manufacturer.into(),
            s.models.into(),
            s.time_per_instruction_us.into(),
            s.memory.into(),
        ]);
    }
    t.print();

    println!("\nFig. 3 — Keras models vs PLC memory");
    let plcs: [(&str, f64); 8] = [
        ("AB Micro 810", 0.002),
        ("Siemens S7-1200", 0.15),
        ("Mitsubishi iQ-R", 4.0),
        ("Hitachi HX", 16.0),
        ("Festo CECC-S", 44.0),
        ("Eaton XC152", 64.0),
        ("WAGO PFC100", 256.0),
        ("WAGO PFC200", 512.0),
    ];
    let mut t2 = Table::new(&["Model", "Size MB (f32)", "fits on"]);
    for (name, mparams) in KERAS_MODEL_SIZES {
        let mb = mparams * 4.0;
        let fits: Vec<&str> = plcs
            .iter()
            .filter(|(_, ram)| mb < ram * 0.75)
            .map(|(n, _)| *n)
            .collect();
        t2.row(&[
            name.to_string(),
            format!("{mb:.1}"),
            if fits.is_empty() {
                "none".into()
            } else {
                fits.first().map(|f| format!("{f}+")).unwrap()
            },
        ]);
    }
    t2.print();
    println!(
        "=> the paper's Fig. 3 conclusion: most PLCs can only run the \
         smaller models; only high-end devices (WAGO-class) hold the \
         large Keras models."
    );
}
