//! Interp-vs-VM wall clock on dense-MLP forward passes — the ISSUE 2
//! acceptance benchmark for the bytecode tier.
//!
//! Every configuration runs the same generated ICSML ST program on both
//! tiers with identical weights and inputs; before timing, outputs are
//! checked bit-identical and `Meter` deltas exactly equal (a slow
//! differential harness is a useless one if the fast tier cheats).
//!
//! Modes:
//!   (default)        timing table on stdout
//!   --json[=PATH]    also write BENCH_st_vm.json (ns/inference,
//!                    ops per abstract-op figures, speedups)
//!   --smoke          one differential iteration per config, no timing
//!                    (CI's fast bytecode-regression gate)

use icsml::st::Meter;
use icsml::util::bench::Bench;
use icsml::util::benchkit::{
    self, json_flag, smoke_flag, write_bench_json, BenchRecord,
};
use icsml::util::json::Json;
use icsml::util::rng::SplitMix64;

struct Config {
    label: &'static str,
    sizes: &'static [usize],
}

const CONFIGS: &[Config] = &[
    Config { label: "mlp_8_16_4", sizes: &[8, 16, 4] },
    Config { label: "dense_64x64x3", sizes: &[64, 64, 64, 64] },
    Config { label: "dense_128x128", sizes: &[128, 128, 128] },
];

fn main() {
    let smoke = smoke_flag();
    let json_path = json_flag("st_vm");
    let bench = Bench::from_env();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut speedups: Vec<(&str, f64)> = Vec::new();

    println!("\nST execution tiers — tree-walker (oracle) vs register-bytecode VM");
    let mut t = icsml::util::bench::Table::new(&[
        "model",
        "interp ns/inf",
        "vm ns/inf",
        "speedup",
        "ops/inf",
        "vm ops/us",
    ]);

    for cfg in CONFIGS {
        let acts: Vec<&str> = std::iter::repeat("relu")
            .take(cfg.sizes.len() - 2)
            .chain(std::iter::once("linear"))
            .collect();
        let (spec, dir) =
            benchkit::random_spec(cfg.label, cfg.sizes, &acts, 0xC0FFEE);
        let mut it = benchkit::st_model(&spec, &dir, true);
        let mut vm = benchkit::st_model_vm(&spec, &dir, true);

        let mut rng = SplitMix64::new(17);
        let x: Vec<f32> = (0..cfg.sizes[0])
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        benchkit::st_set_inputs(&mut it, &x);
        benchkit::vm_set_inputs(&mut vm, &x);

        // Differential gate before any timing: bit-identical outputs,
        // exactly equal meter deltas.
        let im: Meter = benchkit::st_infer_meter(&mut it);
        let vmm: Meter = benchkit::vm_infer_meter(&mut vm);
        assert_eq!(im, vmm, "{}: meter divergence between tiers", cfg.label);
        let inst = it.program_instance("MAIN").unwrap();
        let a = match it.instance_field(inst, "outputs").unwrap() {
            icsml::st::Value::ArrF32(a) => a.borrow().clone(),
            other => panic!("outputs: {other:?}"),
        };
        let b = benchkit::vm_outputs(&vm);
        assert_eq!(a.len(), b.len(), "{}: output dims", cfg.label);
        for (i, (x0, x1)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x0.to_bits(),
                x1.to_bits(),
                "{}: output[{i}] diverged ({x0} vs {x1})",
                cfg.label
            );
        }
        let ops = im.total_ops();
        if smoke {
            println!("smoke OK: {} ({} abstract ops, meters equal)", cfg.label, ops);
            continue;
        }

        let si = bench.run(&format!("interp/{}", cfg.label), || {
            std::hint::black_box(benchkit::st_infer_meter(&mut it));
        });
        let sv = bench.run(&format!("vm/{}", cfg.label), || {
            std::hint::black_box(benchkit::vm_infer_meter(&mut vm));
        });

        let speedup = si.mean_ns / sv.mean_ns.max(1.0);
        t.row(&[
            cfg.label.to_string(),
            format!("{:.0}", si.mean_ns),
            format!("{:.0}", sv.mean_ns),
            format!("{speedup:.2}x"),
            ops.to_string(),
            format!("{:.1}", ops as f64 / (sv.mean_ns / 1e3)),
        ]);
        records.push(BenchRecord {
            name: format!("interp/{}", cfg.label),
            mean_ns: si.mean_ns,
            median_ns: si.median_ns,
            ops_per_inference: ops,
        });
        records.push(BenchRecord {
            name: format!("vm/{}", cfg.label),
            mean_ns: sv.mean_ns,
            median_ns: sv.median_ns,
            ops_per_inference: ops,
        });
        speedups.push((cfg.label, speedup));
    }

    if smoke {
        println!("bytecode smoke: all configs bit-identical across tiers");
        return;
    }
    t.print();
    println!(
        "acceptance target: >= 3x VM speedup on dense-MLP forward passes."
    );

    if let Some(path) = json_path {
        let extras = vec![(
            "speedup",
            Json::obj(
                speedups
                    .iter()
                    .map(|(k, v)| (*k, Json::Num(*v)))
                    .collect(),
            ),
        )];
        write_bench_json(&path, "st_vm", &records, extras)
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        println!("wrote {}", path.display());
    }
}
