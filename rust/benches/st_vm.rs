//! Interp-vs-VM wall clock on dense-MLP forward passes — the ISSUE 2
//! acceptance benchmark for the bytecode tier, extended (ISSUE 9) with
//! the superinstruction tier: every configuration now runs **three**
//! executions — tree-walking interpreter (oracle), plain VM (fusion
//! off), and fused VM (the default) — from identical weights and
//! inputs.
//!
//! Before timing, the differential gate checks all three produce
//! bit-identical outputs and exactly equal `Meter` deltas (a slow
//! differential harness is a useless one if the fast tier cheats).
//!
//! Modes:
//!   (default)        timing table on stdout
//!   --json[=PATH]    also write BENCH_st_vm.json (ns/inference,
//!                    ops per abstract-op figures, speedups, and the
//!                    fusion{...} plain-vs-fused section)
//!   --smoke          one differential iteration per config across all
//!                    three tiers, no timing (CI's fast gate)

use icsml::st::{FusionConfig, Meter};
use icsml::util::bench::Bench;
use icsml::util::benchkit::{
    self, json_flag, smoke_flag, write_bench_json, BenchRecord,
};
use icsml::util::json::Json;
use icsml::util::rng::SplitMix64;

struct Config {
    label: &'static str,
    sizes: &'static [usize],
}

const CONFIGS: &[Config] = &[
    Config { label: "mlp_8_16_4", sizes: &[8, 16, 4] },
    Config { label: "dense_64x64x3", sizes: &[64, 64, 64, 64] },
    Config { label: "dense_128x128", sizes: &[128, 128, 128] },
];

fn outputs_of(it: &mut icsml::st::Interp) -> Vec<f32> {
    let inst = it.program_instance("MAIN").unwrap();
    match it.instance_field(inst, "outputs").unwrap() {
        icsml::st::Value::ArrF32(a) => a.borrow().clone(),
        other => panic!("outputs: {other:?}"),
    }
}

fn assert_bits_eq(label: &str, tier: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{label}: {tier} output dims");
    for (i, (x0, x1)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x0.to_bits(),
            x1.to_bits(),
            "{label}: {tier} output[{i}] diverged ({x0} vs {x1})"
        );
    }
}

fn main() {
    let smoke = smoke_flag();
    let json_path = json_flag("st_vm");
    let bench = Bench::from_env();
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    let mut fusion: Vec<(&str, Json)> = Vec::new();

    println!(
        "\nST execution tiers — tree-walker (oracle) vs plain VM vs fused VM"
    );
    let mut t = icsml::util::bench::Table::new(&[
        "model",
        "interp ns/inf",
        "plain ns/inf",
        "fused ns/inf",
        "fused/plain",
        "fused/interp",
        "ops/inf",
    ]);

    for cfg in CONFIGS {
        let acts: Vec<&str> = std::iter::repeat("relu")
            .take(cfg.sizes.len() - 2)
            .chain(std::iter::once("linear"))
            .collect();
        let (spec, dir) =
            benchkit::random_spec(cfg.label, cfg.sizes, &acts, 0xC0FFEE);
        let mut it = benchkit::st_model(&spec, &dir, true);
        let mut fused = benchkit::st_model_vm_with(
            &spec,
            &dir,
            true,
            &FusionConfig { enabled: true },
        );
        let mut plain = benchkit::st_model_vm_with(
            &spec,
            &dir,
            true,
            &FusionConfig { enabled: false },
        );
        let n_fused = fused.code().fused_ops();
        assert!(
            n_fused > 0,
            "{}: fusion produced no superinstructions",
            cfg.label
        );
        assert_eq!(
            plain.code().fused_ops(),
            0,
            "{}: fusion-off stream contains fused ops",
            cfg.label
        );

        let mut rng = SplitMix64::new(17);
        let x: Vec<f32> = (0..cfg.sizes[0])
            .map(|_| rng.uniform(-1.0, 1.0) as f32)
            .collect();
        benchkit::st_set_inputs(&mut it, &x);
        benchkit::vm_set_inputs(&mut fused, &x);
        benchkit::vm_set_inputs(&mut plain, &x);

        // Differential gate before any timing: bit-identical outputs,
        // exactly equal meter deltas, fusion on AND off.
        let im: Meter = benchkit::st_infer_meter(&mut it);
        let fm: Meter = benchkit::vm_infer_meter(&mut fused);
        let pm: Meter = benchkit::vm_infer_meter(&mut plain);
        if let Some((name, a, b)) = im.first_divergence(&fm) {
            panic!(
                "{}: fused-VM meter `{name}` diverged (interp {a}, vm {b})",
                cfg.label
            );
        }
        if let Some((name, a, b)) = im.first_divergence(&pm) {
            panic!(
                "{}: plain-VM meter `{name}` diverged (interp {a}, vm {b})",
                cfg.label
            );
        }
        let oracle = outputs_of(&mut it);
        assert_bits_eq(cfg.label, "fused", &oracle, &benchkit::vm_outputs(&fused));
        assert_bits_eq(cfg.label, "plain", &oracle, &benchkit::vm_outputs(&plain));
        let ops = im.total_ops();
        if smoke {
            println!(
                "smoke OK: {} ({} abstract ops, {} fused ops, \
                 meters equal on all tiers)",
                cfg.label, ops, n_fused
            );
            continue;
        }

        let si = bench.run(&format!("interp/{}", cfg.label), || {
            std::hint::black_box(benchkit::st_infer_meter(&mut it));
        });
        let sp = bench.run(&format!("vm_plain/{}", cfg.label), || {
            std::hint::black_box(benchkit::vm_infer_meter(&mut plain));
        });
        let sf = bench.run(&format!("vm/{}", cfg.label), || {
            std::hint::black_box(benchkit::vm_infer_meter(&mut fused));
        });

        let fused_over_plain = sp.mean_ns / sf.mean_ns.max(1.0);
        let fused_over_interp = si.mean_ns / sf.mean_ns.max(1.0);
        t.row(&[
            cfg.label.to_string(),
            format!("{:.0}", si.mean_ns),
            format!("{:.0}", sp.mean_ns),
            format!("{:.0}", sf.mean_ns),
            format!("{fused_over_plain:.2}x"),
            format!("{fused_over_interp:.2}x"),
            ops.to_string(),
        ]);
        records.push(BenchRecord {
            name: format!("interp/{}", cfg.label),
            mean_ns: si.mean_ns,
            median_ns: si.median_ns,
            ops_per_inference: ops,
        });
        records.push(BenchRecord {
            name: format!("vm_plain/{}", cfg.label),
            mean_ns: sp.mean_ns,
            median_ns: sp.median_ns,
            ops_per_inference: ops,
        });
        records.push(BenchRecord {
            name: format!("vm/{}", cfg.label),
            mean_ns: sf.mean_ns,
            median_ns: sf.median_ns,
            ops_per_inference: ops,
        });
        speedups.push((cfg.label, fused_over_interp));
        fusion.push((
            cfg.label,
            Json::obj(vec![
                ("interp_ns", Json::Num(si.mean_ns)),
                ("plain_ns", Json::Num(sp.mean_ns)),
                ("fused_ns", Json::Num(sf.mean_ns)),
                ("fused_over_plain", Json::Num(fused_over_plain)),
                ("fused_over_interp", Json::Num(fused_over_interp)),
                ("fused_op_count", Json::Num(n_fused as f64)),
            ]),
        ));
    }

    if smoke {
        println!(
            "bytecode smoke: all configs bit-identical across all \
             three tiers (fusion on and off)"
        );
        return;
    }
    t.print();
    println!(
        "acceptance targets: >= 3x fused-VM speedup over the interpreter \
         and >= 1.5x over the plain VM on dense-MLP forward passes."
    );

    if let Some(path) = json_path {
        let extras = vec![
            (
                "speedup",
                Json::obj(
                    speedups
                        .iter()
                        .map(|(k, v)| (*k, Json::Num(*v)))
                        .collect(),
                ),
            ),
            ("fusion", Json::obj(fusion)),
        ];
        write_bench_json(&path, "st_vm", &records, extras)
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        println!("wrote {}", path.display());
    }
}
