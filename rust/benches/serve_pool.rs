//! `serve::Pool` throughput at 1→N workers — the ISSUE 3 acceptance
//! benchmark for the Engine/Session split.
//!
//! One shared `EngineBackend` (a dense 128-128-128-10 MLP), a wave of
//! pipelined requests per configuration, wall-clock requests/s. Before
//! any timing, a correctness gate checks the pooled results are
//! bit-identical to one sequential session (a fast pool that cheats is
//! useless).
//!
//! Modes:
//!   (default)        throughput table + deadline scenario + open-loop
//!                    network latency percentiles on stdout
//!   --json[=PATH]    also write BENCH_serve.json (ns/request per
//!                    worker count, scaling vs 1 worker,
//!                    deadline-hit/shed rates, open_loop{...}
//!                    percentiles over the netserve client)
//!   --smoke          correctness gate + netserve loopback smoke +
//!                    chaos smoke, no timing (CI's fast regression
//!                    check: pooled and networked results
//!                    bit-identical to a sequential session,
//!                    mixed-class wave, zero sheds, clean shutdown,
//!                    and injected faults contained to their own
//!                    tickets with the pool restaffing itself)

use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use icsml::api::{
    Backend, EngineBackend, InferenceError, Session as _, SharedBackend,
};
use icsml::engine::{Act, Layer, Model};
use icsml::netserve::proto::ErrorCode;
use icsml::netserve::{
    Client, ModelRegistry, NetOptions, NetServer, RegistryConfig,
    ServerConfig, StaticLoader,
};
use icsml::serve::{
    Deadline, Fault, FaultBackend, FaultPlan, Pool, PoolConfig, Priority,
    SubmitOptions,
};
use icsml::util::benchkit::{
    json_flag, smoke_flag, write_bench_json, BenchRecord,
};
use icsml::util::fixtures::mlp_8_16_4;
use icsml::util::json::Json;
use icsml::util::rng::SplitMix64;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];
const MAX_BATCH: usize = 8;

fn dense_model(sizes: &[usize], seed: u64) -> Model {
    let mut rng = SplitMix64::new(seed);
    let layers = sizes
        .windows(2)
        .enumerate()
        .map(|(i, w)| {
            let weights: Vec<f32> = (0..w[0] * w[1])
                .map(|_| rng.uniform(-0.5, 0.5) as f32)
                .collect();
            let biases: Vec<f32> =
                (0..w[1]).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
            let act = if i + 2 == sizes.len() { Act::None } else { Act::Relu };
            Layer::dense(weights, biases, w[0], act)
        })
        .collect();
    Model::new(layers)
}

fn request_wave(in_dim: usize, count: usize) -> Vec<Vec<f32>> {
    let mut rng = SplitMix64::new(0xD15EA5E);
    (0..count)
        .map(|_| {
            (0..in_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
        })
        .collect()
}

/// Submit the whole wave pipelined, wait for every ticket, return
/// (elapsed seconds, outputs).
fn drive(pool: &Pool, wave: &[Vec<f32>]) -> (f64, Vec<Vec<f32>>) {
    let t0 = Instant::now();
    let tickets: Vec<_> = wave.iter().map(|x| pool.submit(x)).collect();
    let outs: Vec<Vec<f32>> = tickets
        .into_iter()
        .map(|t| t.wait().expect("pool request failed"))
        .collect();
    (t0.elapsed().as_secs_f64(), outs)
}

fn main() {
    let smoke = smoke_flag();
    let json_path = json_flag("serve");
    let sizes = [128usize, 128, 128, 10];
    let backend: SharedBackend =
        Arc::new(EngineBackend::new(dense_model(&sizes, 0xC0FFEE)));

    // ---------------- correctness gate (always) -----------------------
    let gate_wave = request_wave(sizes[0], 64);
    let mut reference = backend.session().expect("session");
    let want: Vec<Vec<f32>> = gate_wave
        .iter()
        .map(|x| reference.infer(x).expect("reference inference"))
        .collect();
    {
        let pool = Pool::new(
            Arc::clone(&backend),
            PoolConfig { workers: 2, max_batch: MAX_BATCH },
        );
        let (_, outs) = drive(&pool, &gate_wave);
        for (i, (got, want)) in outs.iter().zip(&want).enumerate() {
            assert_eq!(got.len(), want.len(), "request {i}: output dims");
            for (k, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "request {i} logit {k}: pool {a} vs sequential {b}"
                );
            }
        }
        assert_eq!(pool.errors(), 0, "gate wave saw errors");
        assert_eq!(
            pool.shed(),
            0,
            "no-deadline load must never shed — the deadline scheduler \
             must be invisible to plain FIFO traffic"
        );
    }
    if smoke {
        println!(
            "serve-pool smoke OK: {} pooled requests bit-identical to the \
             sequential session, zero sheds under no-deadline load",
            gate_wave.len()
        );
        netserve_smoke(&backend, &gate_wave, &want);
        chaos_smoke(&backend, &gate_wave, &want);
        return;
    }

    // ---------------- throughput sweep --------------------------------
    let requests = 4000usize;
    let wave = request_wave(sizes[0], requests);
    println!(
        "\nserve::Pool throughput — shared engine backend, dense \
         {sizes:?}, {requests} pipelined requests, micro-batch {MAX_BATCH}"
    );
    let mut t = icsml::util::bench::Table::new(&[
        "workers",
        "req/s",
        "ns/req",
        "mean batch",
        "scaling vs w1",
    ]);
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut scaling: Vec<(String, f64)> = Vec::new();
    let mut base_rps = 0.0f64;

    for &workers in &WORKER_COUNTS {
        let pool = Pool::new(
            Arc::clone(&backend),
            PoolConfig { workers, max_batch: MAX_BATCH },
        );
        // Warmup wave: spin sessions up, settle allocator high-water.
        let _ = drive(&pool, &wave[..256.min(wave.len())]);
        let (secs, outs) = drive(&pool, &wave);
        assert_eq!(outs.len(), requests);
        let rps = requests as f64 / secs.max(1e-12);
        if workers == WORKER_COUNTS[0] {
            base_rps = rps;
        }
        let ns_per_req = secs * 1e9 / requests as f64;
        let mean_batch =
            pool.served() as f64 / pool.batches().max(1) as f64;
        let rel = rps / base_rps.max(1e-12);
        t.row(&[
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{ns_per_req:.0}"),
            format!("{mean_batch:.2}"),
            format!("{rel:.2}x"),
        ]);
        records.push(BenchRecord {
            name: format!("pool/w{workers}"),
            mean_ns: ns_per_req,
            median_ns: ns_per_req,
            ops_per_inference: 0,
        });
        scaling.push((format!("w{workers}"), rel));
    }
    t.print();
    println!(
        "(pipelined wall-clock; scaling >1x at w>1 shows the shared \
         backend serves threads concurrently)"
    );

    // ---------------- deadline scenario -------------------------------
    // Mixed-criticality burst: 25% control-class with a tight
    // deadline, 25% defense-class with a looser one, 50% batch-class
    // without any. Budgets are multiples of a calibrated sequential
    // per-request cost so the scenario stresses the scheduler
    // comparably on any machine. Reported (not asserted): per-class
    // deadline hit rates and the overall shed rate.
    const CONTROL_BUDGET_X: f64 = 50.0;
    const DEFENSE_BUDGET_X: f64 = 400.0;
    let t0 = Instant::now();
    for x in wave.iter().take(256) {
        let _ = reference.infer(x).expect("calibration inference");
    }
    let per_req_us = t0.elapsed().as_secs_f64() * 1e6 / 256.0;

    let dl_requests = 3000usize;
    let pool = Pool::new(
        Arc::clone(&backend),
        PoolConfig { workers: 4, max_batch: MAX_BATCH },
    );
    // class index: 0 = control, 1 = defense, 2 = batch (no deadline)
    let class_of = |i: usize| match i % 4 {
        0 => 0usize,
        1 => 1,
        _ => 2,
    };
    let tickets: Vec<_> = wave
        .iter()
        .take(dl_requests)
        .enumerate()
        .map(|(i, x)| match class_of(i) {
            0 => pool
                .submit_with(
                    x,
                    SubmitOptions::new()
                        .priority(Priority::Control)
                        .deadline(Deadline::within_us(
                            per_req_us * CONTROL_BUDGET_X,
                        )),
                )
                .expect("no admission gate"),
            1 => pool
                .submit_with(
                    x,
                    SubmitOptions::new()
                        .priority(Priority::Defense)
                        .deadline(Deadline::within_us(
                            per_req_us * DEFENSE_BUDGET_X,
                        )),
                )
                .expect("no admission gate"),
            _ => pool.submit(x),
        })
        .collect();
    let mut ok = [0u64; 3];
    let mut shed = [0u64; 3];
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Ok(_) => ok[class_of(i)] += 1,
            Err(InferenceError::DeadlineExceeded { .. }) => {
                shed[class_of(i)] += 1
            }
            Err(e) => panic!("deadline wave request {i} failed: {e}"),
        }
    }
    let rate = |k: usize| {
        let tot = ok[k] + shed[k];
        if tot == 0 {
            1.0
        } else {
            ok[k] as f64 / tot as f64
        }
    };
    let (control_hit, defense_hit) = (rate(0), rate(1));
    let deadlined_ok = ok[0] + ok[1];
    let deadlined_tot = deadlined_ok + shed[0] + shed[1];
    let hit_rate = deadlined_ok as f64 / (deadlined_tot as f64).max(1.0);
    let shed_rate = pool.shed() as f64 / dl_requests as f64;
    assert_eq!(
        ok[2] as usize,
        dl_requests - dl_requests / 4 - dl_requests / 4,
        "batch-class (no deadline) requests can never be shed"
    );
    println!(
        "\ndeadline scenario — {dl_requests} mixed requests, calibrated \
         {per_req_us:.1} us/request, budgets {CONTROL_BUDGET_X:.0}x \
         (control) / {DEFENSE_BUDGET_X:.0}x (defense):"
    );
    println!(
        "  control hit {:.1}%  defense hit {:.1}%  overall deadline hit \
         {:.1}%  shed rate {:.1}%",
        control_hit * 100.0,
        defense_hit * 100.0,
        hit_rate * 100.0,
        shed_rate * 100.0
    );

    // ---------------- open-loop network latency -----------------------
    // Closed-loop throughput hides queueing: a closed-loop driver
    // only submits as fast as replies return, so the queue never
    // grows and the tail looks flat. An open-loop generator fires at
    // a fixed arrival rate regardless of completions — the shape real
    // sensor traffic has — and queue delay shows up where it belongs,
    // in p95/p99. Probe the network path's closed-loop capacity
    // first, then drive open-loop at fractions of it.
    let net_requests = 2000usize;
    let registry = bench_registry(&backend, 4);
    let server = NetServer::bind(
        "127.0.0.1:0",
        registry,
        ServerConfig::default(),
    )
    .expect("bind loopback");
    let addr = server.local_addr();

    let capacity_rps = {
        let mut c = Client::connect(addr).expect("connect");
        c.set_timeout(Some(Duration::from_secs(60))).expect("timeout");
        let probe = net_requests / 2;
        let t0 = Instant::now();
        for i in 0..probe {
            c.submit("bench", &wave[i % wave.len()], &NetOptions::new())
                .expect("submit");
        }
        for _ in 0..probe {
            let r = c.recv().expect("recv");
            r.result.unwrap_or_else(|e| {
                panic!("capacity probe request {} failed: {}", r.id, e.msg)
            });
        }
        probe as f64 / t0.elapsed().as_secs_f64().max(1e-12)
    };
    println!(
        "\nopen-loop network latency — loopback netserve, 4 workers, \
         closed-loop capacity {capacity_rps:.0} req/s:"
    );
    let mut open_loop_runs: Vec<Json> = Vec::new();
    let mut ot = icsml::util::bench::Table::new(&[
        "load",
        "rate req/s",
        "p50 us",
        "p95 us",
        "p99 us",
        "errors",
    ]);
    for &load in &[0.5f64, 0.8] {
        let rate = (capacity_rps * load).max(1.0);
        let (lat_us, sheds, errors) =
            open_loop(addr, &wave, net_requests, rate);
        let (p50, p95, p99) =
            (pct(&lat_us, 0.50), pct(&lat_us, 0.95), pct(&lat_us, 0.99));
        ot.row(&[
            format!("{:.0}%", load * 100.0),
            format!("{rate:.0}"),
            format!("{p50:.0}"),
            format!("{p95:.0}"),
            format!("{p99:.0}"),
            format!("{}", sheds + errors),
        ]);
        open_loop_runs.push(Json::obj(vec![
            ("load_factor", Json::Num(load)),
            ("rate_rps", Json::Num(rate)),
            ("requests", Json::Num(net_requests as f64)),
            ("p50_us", Json::Num(p50)),
            ("p95_us", Json::Num(p95)),
            ("p99_us", Json::Num(p99)),
            ("sheds", Json::Num(sheds as f64)),
            ("errors", Json::Num(errors as f64)),
        ]));
    }
    ot.print();
    println!(
        "(arrival-rate-driven over the netserve client; queue delay \
         surfaces in the tail as load approaches capacity)"
    );
    server.shutdown();

    if let Some(path) = json_path {
        let extras = vec![
            (
                "scaling_vs_w1",
                Json::obj(
                    scaling
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::Num(*v)))
                        .collect(),
                ),
            ),
            ("requests", Json::Num(requests as f64)),
            ("max_batch", Json::Num(MAX_BATCH as f64)),
            (
                "open_loop",
                Json::obj(vec![
                    ("capacity_rps", Json::Num(capacity_rps)),
                    ("runs", Json::Arr(open_loop_runs.clone())),
                ]),
            ),
            (
                "deadline",
                Json::obj(vec![
                    ("calibration_us_per_req", Json::Num(per_req_us)),
                    ("control_budget_x", Json::Num(CONTROL_BUDGET_X)),
                    ("defense_budget_x", Json::Num(DEFENSE_BUDGET_X)),
                    ("control_hit_rate", Json::Num(control_hit)),
                    ("defense_hit_rate", Json::Num(defense_hit)),
                    ("deadline_hit_rate", Json::Num(hit_rate)),
                    ("shed_rate", Json::Num(shed_rate)),
                ]),
            ),
        ];
        write_bench_json(&path, "serve", &records, extras)
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        println!("wrote {}", path.display());
    }
}

/// Registry exposing the bench backend as `"bench"` plus a small
/// second model `"aux"` (multi-model routing stays on the smoke path).
fn bench_registry(
    backend: &SharedBackend,
    workers: usize,
) -> Arc<ModelRegistry> {
    let mut loader = StaticLoader::new();
    loader.insert("bench", Arc::clone(backend), 1);
    let aux: SharedBackend =
        Arc::new(EngineBackend::new(mlp_8_16_4(3)));
    loader.insert("aux", aux, 1);
    Arc::new(ModelRegistry::new(
        Box::new(loader),
        RegistryConfig {
            max_models: usize::MAX,
            max_bytes: u64::MAX,
            pool: PoolConfig { workers, max_batch: MAX_BATCH },
        },
    ))
}

/// CI loopback smoke: spawn a server, pipeline the gate wave through
/// the network client with mixed priority classes (generous deadlines
/// on the deadlined classes), and require every reply bit-identical
/// to the sequential reference, zero sheds, and a clean shutdown.
fn netserve_smoke(
    backend: &SharedBackend,
    gate_wave: &[Vec<f32>],
    want: &[Vec<f32>],
) {
    let server = NetServer::bind(
        "127.0.0.1:0",
        bench_registry(backend, 2),
        ServerConfig::default(),
    )
    .expect("bind loopback");
    let mut c = Client::connect(server.local_addr()).expect("connect");
    c.set_timeout(Some(Duration::from_secs(60))).expect("timeout");
    let classes =
        [Priority::Control, Priority::Defense, Priority::Batch];
    for (i, x) in gate_wave.iter().enumerate() {
        // Deadlines generous enough to never shed (10 s): the smoke
        // asserts the happy path end-to-end, not load behavior.
        let mut opts = NetOptions::new().priority(classes[i % 3]);
        if i % 3 != 2 {
            opts = opts.deadline_us(10_000_000.0);
        }
        c.submit("bench", x, &opts).expect("submit");
    }
    let mut got: Vec<Option<Vec<f32>>> = vec![None; gate_wave.len()];
    for _ in 0..gate_wave.len() {
        let r = c.recv().expect("recv");
        let y = r.result.unwrap_or_else(|e| {
            panic!("smoke request {} failed: {}", r.id, e.msg)
        });
        got[r.id as usize] = Some(y);
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let g = g.as_ref().expect("reply for every request");
        assert_eq!(g.len(), w.len(), "request {i}: output dims");
        for (k, (a, b)) in g.iter().zip(w).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "request {i} logit {k}: network {a} vs sequential {b}"
            );
        }
    }
    // The second registered model serves on the same connection.
    let y = c
        .infer("aux", &[0.25; 8], &NetOptions::new())
        .expect("aux model");
    assert_eq!(y.len(), 4);
    assert_eq!(
        server.stats().error_frames(),
        0,
        "mixed-class smoke wave must see zero sheds/errors"
    );
    server.shutdown();
    println!(
        "netserve smoke OK: {} mixed-class networked requests \
         bit-identical to the sequential session across 2 models, zero \
         sheds, clean shutdown",
        gate_wave.len()
    );
}

/// CI chaos smoke: one fault wave through a supervised pool behind a
/// `FaultBackend` — a panic, a typed error and a latency spike fire
/// at known request indices. The panic and the error each fail
/// exactly one ticket, every survivor stays bit-identical to the
/// sequential reference, and the pool restaffs to full strength.
fn chaos_smoke(
    backend: &SharedBackend,
    gate_wave: &[Vec<f32>],
    want: &[Vec<f32>],
) {
    let plan = FaultPlan::new()
        .at(5, Fault::Panic)
        .at(11, Fault::Error)
        .at(17, Fault::Latency(Duration::from_millis(1)));
    let faulty = FaultBackend::shared(Arc::clone(backend), plan);
    let pool =
        Pool::new(faulty, PoolConfig { workers: 2, max_batch: 1 });
    let tickets: Vec<_> =
        gate_wave.iter().map(|x| pool.submit(x)).collect();
    let (mut panics, mut typed) = (0u64, 0u64);
    let mut served = 0usize;
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Ok(y) => {
                assert_eq!(
                    y, want[i],
                    "chaos survivor {i} stays bit-identical"
                );
                served += 1;
            }
            Err(InferenceError::BackendPanicked { .. }) => panics += 1,
            Err(InferenceError::ExecutionFailed { .. }) => typed += 1,
            Err(e) => {
                panic!("chaos smoke request {i}: unplanned failure {e}")
            }
        }
    }
    assert_eq!(
        (panics, typed),
        (1, 1),
        "each injected fault fails exactly one ticket"
    );
    assert_eq!(served, gate_wave.len() - 2);
    let t0 = Instant::now();
    while !pool.health().is_healthy() {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "pool never restaffed: {:?}",
            pool.health()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(pool.health().panics_contained, 1);
    println!(
        "chaos smoke OK: injected panic/error/latency contained to \
         their own tickets, {served} survivors bit-identical, pool \
         restaffed to full strength"
    );
}

/// Drive `n` requests at a fixed arrival rate (open loop) and return
/// (sorted latencies in us, sheds, other errors). One thread paces
/// submissions, the caller's thread drains replies; send timestamps
/// cross threads through release/acquire atomics indexed by wire id.
fn open_loop(
    addr: SocketAddr,
    wave: &[Vec<f32>],
    n: usize,
    rate_rps: f64,
) -> (Vec<f64>, u64, u64) {
    let sender_client = Client::connect(addr).expect("connect");
    let mut recv_client =
        sender_client.try_clone().expect("clone connection");
    recv_client
        .set_timeout(Some(Duration::from_secs(60)))
        .expect("timeout");
    let send_ns: Arc<Vec<AtomicU64>> =
        Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    let t0 = Instant::now();
    let sender = {
        let send_ns = Arc::clone(&send_ns);
        let inputs: Vec<Vec<f32>> = wave.to_vec();
        std::thread::spawn(move || {
            let mut c = sender_client;
            for i in 0..n {
                let target =
                    Duration::from_secs_f64(i as f64 / rate_rps);
                loop {
                    let elapsed = t0.elapsed();
                    if elapsed >= target {
                        break;
                    }
                    std::thread::sleep(
                        (target - elapsed).min(Duration::from_micros(500)),
                    );
                }
                send_ns[i].store(
                    t0.elapsed().as_nanos() as u64,
                    Ordering::Release,
                );
                c.submit(
                    "bench",
                    &inputs[i % inputs.len()],
                    &NetOptions::new(),
                )
                .expect("open-loop submit");
            }
        })
    };
    let mut lat_us = Vec::with_capacity(n);
    let (mut sheds, mut errors) = (0u64, 0u64);
    for _ in 0..n {
        let r = recv_client.recv().expect("open-loop recv");
        let now_ns = t0.elapsed().as_nanos() as u64;
        let sent_ns = send_ns[r.id as usize].load(Ordering::Acquire);
        match r.result {
            Ok(_) => {
                lat_us.push((now_ns.saturating_sub(sent_ns)) as f64 / 1e3)
            }
            Err(e) if e.code == ErrorCode::DeadlineExceeded => sheds += 1,
            Err(_) => errors += 1,
        }
    }
    sender.join().expect("sender thread");
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (lat_us, sheds, errors)
}

/// Quantile of an ascending-sorted sample (nearest-rank).
fn pct(sorted_us: &[f64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}
