//! `serve::Pool` throughput at 1→N workers — the ISSUE 3 acceptance
//! benchmark for the Engine/Session split.
//!
//! One shared `EngineBackend` (a dense 128-128-128-10 MLP), a wave of
//! pipelined requests per configuration, wall-clock requests/s. Before
//! any timing, a correctness gate checks the pooled results are
//! bit-identical to one sequential session (a fast pool that cheats is
//! useless).
//!
//! Modes:
//!   (default)        throughput table + deadline scenario on stdout
//!   --json[=PATH]    also write BENCH_serve.json (ns/request per
//!                    worker count, scaling vs 1 worker,
//!                    deadline-hit/shed rates)
//!   --smoke          correctness gate only, no timing (CI's fast
//!                    serve-pool regression check; also asserts zero
//!                    sheds under no-deadline load)

use std::sync::Arc;
use std::time::Instant;

use icsml::api::{
    Backend, EngineBackend, InferenceError, Session as _, SharedBackend,
};
use icsml::engine::{Act, Layer, Model};
use icsml::serve::{Deadline, Pool, PoolConfig, Priority, SubmitOptions};
use icsml::util::benchkit::{
    json_flag, smoke_flag, write_bench_json, BenchRecord,
};
use icsml::util::json::Json;
use icsml::util::rng::SplitMix64;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];
const MAX_BATCH: usize = 8;

fn dense_model(sizes: &[usize], seed: u64) -> Model {
    let mut rng = SplitMix64::new(seed);
    let layers = sizes
        .windows(2)
        .enumerate()
        .map(|(i, w)| {
            let weights: Vec<f32> = (0..w[0] * w[1])
                .map(|_| rng.uniform(-0.5, 0.5) as f32)
                .collect();
            let biases: Vec<f32> =
                (0..w[1]).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
            let act = if i + 2 == sizes.len() { Act::None } else { Act::Relu };
            Layer::dense(weights, biases, w[0], act)
        })
        .collect();
    Model::new(layers)
}

fn request_wave(in_dim: usize, count: usize) -> Vec<Vec<f32>> {
    let mut rng = SplitMix64::new(0xD15EA5E);
    (0..count)
        .map(|_| {
            (0..in_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
        })
        .collect()
}

/// Submit the whole wave pipelined, wait for every ticket, return
/// (elapsed seconds, outputs).
fn drive(pool: &Pool, wave: &[Vec<f32>]) -> (f64, Vec<Vec<f32>>) {
    let t0 = Instant::now();
    let tickets: Vec<_> = wave.iter().map(|x| pool.submit(x)).collect();
    let outs: Vec<Vec<f32>> = tickets
        .into_iter()
        .map(|t| t.wait().expect("pool request failed"))
        .collect();
    (t0.elapsed().as_secs_f64(), outs)
}

fn main() {
    let smoke = smoke_flag();
    let json_path = json_flag("serve");
    let sizes = [128usize, 128, 128, 10];
    let backend: SharedBackend =
        Arc::new(EngineBackend::new(dense_model(&sizes, 0xC0FFEE)));

    // ---------------- correctness gate (always) -----------------------
    let gate_wave = request_wave(sizes[0], 64);
    let mut reference = backend.session().expect("session");
    let want: Vec<Vec<f32>> = gate_wave
        .iter()
        .map(|x| reference.infer(x).expect("reference inference"))
        .collect();
    {
        let pool = Pool::new(
            Arc::clone(&backend),
            PoolConfig { workers: 2, max_batch: MAX_BATCH },
        );
        let (_, outs) = drive(&pool, &gate_wave);
        for (i, (got, want)) in outs.iter().zip(&want).enumerate() {
            assert_eq!(got.len(), want.len(), "request {i}: output dims");
            for (k, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "request {i} logit {k}: pool {a} vs sequential {b}"
                );
            }
        }
        assert_eq!(pool.errors(), 0, "gate wave saw errors");
        assert_eq!(
            pool.shed(),
            0,
            "no-deadline load must never shed — the deadline scheduler \
             must be invisible to plain FIFO traffic"
        );
    }
    if smoke {
        println!(
            "serve-pool smoke OK: {} pooled requests bit-identical to the \
             sequential session, zero sheds under no-deadline load",
            gate_wave.len()
        );
        return;
    }

    // ---------------- throughput sweep --------------------------------
    let requests = 4000usize;
    let wave = request_wave(sizes[0], requests);
    println!(
        "\nserve::Pool throughput — shared engine backend, dense \
         {sizes:?}, {requests} pipelined requests, micro-batch {MAX_BATCH}"
    );
    let mut t = icsml::util::bench::Table::new(&[
        "workers",
        "req/s",
        "ns/req",
        "mean batch",
        "scaling vs w1",
    ]);
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut scaling: Vec<(String, f64)> = Vec::new();
    let mut base_rps = 0.0f64;

    for &workers in &WORKER_COUNTS {
        let pool = Pool::new(
            Arc::clone(&backend),
            PoolConfig { workers, max_batch: MAX_BATCH },
        );
        // Warmup wave: spin sessions up, settle allocator high-water.
        let _ = drive(&pool, &wave[..256.min(wave.len())]);
        let (secs, outs) = drive(&pool, &wave);
        assert_eq!(outs.len(), requests);
        let rps = requests as f64 / secs.max(1e-12);
        if workers == WORKER_COUNTS[0] {
            base_rps = rps;
        }
        let ns_per_req = secs * 1e9 / requests as f64;
        let mean_batch =
            pool.served() as f64 / pool.batches().max(1) as f64;
        let rel = rps / base_rps.max(1e-12);
        t.row(&[
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{ns_per_req:.0}"),
            format!("{mean_batch:.2}"),
            format!("{rel:.2}x"),
        ]);
        records.push(BenchRecord {
            name: format!("pool/w{workers}"),
            mean_ns: ns_per_req,
            median_ns: ns_per_req,
            ops_per_inference: 0,
        });
        scaling.push((format!("w{workers}"), rel));
    }
    t.print();
    println!(
        "(pipelined wall-clock; scaling >1x at w>1 shows the shared \
         backend serves threads concurrently)"
    );

    // ---------------- deadline scenario -------------------------------
    // Mixed-criticality burst: 25% control-class with a tight
    // deadline, 25% defense-class with a looser one, 50% batch-class
    // without any. Budgets are multiples of a calibrated sequential
    // per-request cost so the scenario stresses the scheduler
    // comparably on any machine. Reported (not asserted): per-class
    // deadline hit rates and the overall shed rate.
    const CONTROL_BUDGET_X: f64 = 50.0;
    const DEFENSE_BUDGET_X: f64 = 400.0;
    let t0 = Instant::now();
    for x in wave.iter().take(256) {
        let _ = reference.infer(x).expect("calibration inference");
    }
    let per_req_us = t0.elapsed().as_secs_f64() * 1e6 / 256.0;

    let dl_requests = 3000usize;
    let pool = Pool::new(
        Arc::clone(&backend),
        PoolConfig { workers: 4, max_batch: MAX_BATCH },
    );
    // class index: 0 = control, 1 = defense, 2 = batch (no deadline)
    let class_of = |i: usize| match i % 4 {
        0 => 0usize,
        1 => 1,
        _ => 2,
    };
    let tickets: Vec<_> = wave
        .iter()
        .take(dl_requests)
        .enumerate()
        .map(|(i, x)| match class_of(i) {
            0 => pool
                .submit_with(
                    x,
                    SubmitOptions::new()
                        .priority(Priority::Control)
                        .deadline(Deadline::within_us(
                            per_req_us * CONTROL_BUDGET_X,
                        )),
                )
                .expect("no admission gate"),
            1 => pool
                .submit_with(
                    x,
                    SubmitOptions::new()
                        .priority(Priority::Defense)
                        .deadline(Deadline::within_us(
                            per_req_us * DEFENSE_BUDGET_X,
                        )),
                )
                .expect("no admission gate"),
            _ => pool.submit(x),
        })
        .collect();
    let mut ok = [0u64; 3];
    let mut shed = [0u64; 3];
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Ok(_) => ok[class_of(i)] += 1,
            Err(InferenceError::DeadlineExceeded { .. }) => {
                shed[class_of(i)] += 1
            }
            Err(e) => panic!("deadline wave request {i} failed: {e}"),
        }
    }
    let rate = |k: usize| {
        let tot = ok[k] + shed[k];
        if tot == 0 {
            1.0
        } else {
            ok[k] as f64 / tot as f64
        }
    };
    let (control_hit, defense_hit) = (rate(0), rate(1));
    let deadlined_ok = ok[0] + ok[1];
    let deadlined_tot = deadlined_ok + shed[0] + shed[1];
    let hit_rate = deadlined_ok as f64 / (deadlined_tot as f64).max(1.0);
    let shed_rate = pool.shed() as f64 / dl_requests as f64;
    assert_eq!(
        ok[2] as usize,
        dl_requests - dl_requests / 4 - dl_requests / 4,
        "batch-class (no deadline) requests can never be shed"
    );
    println!(
        "\ndeadline scenario — {dl_requests} mixed requests, calibrated \
         {per_req_us:.1} us/request, budgets {CONTROL_BUDGET_X:.0}x \
         (control) / {DEFENSE_BUDGET_X:.0}x (defense):"
    );
    println!(
        "  control hit {:.1}%  defense hit {:.1}%  overall deadline hit \
         {:.1}%  shed rate {:.1}%",
        control_hit * 100.0,
        defense_hit * 100.0,
        hit_rate * 100.0,
        shed_rate * 100.0
    );

    if let Some(path) = json_path {
        let extras = vec![
            (
                "scaling_vs_w1",
                Json::obj(
                    scaling
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::Num(*v)))
                        .collect(),
                ),
            ),
            ("requests", Json::Num(requests as f64)),
            ("max_batch", Json::Num(MAX_BATCH as f64)),
            (
                "deadline",
                Json::obj(vec![
                    ("calibration_us_per_req", Json::Num(per_req_us)),
                    ("control_budget_x", Json::Num(CONTROL_BUDGET_X)),
                    ("defense_budget_x", Json::Num(DEFENSE_BUDGET_X)),
                    ("control_hit_rate", Json::Num(control_hit)),
                    ("defense_hit_rate", Json::Num(defense_hit)),
                    ("deadline_hit_rate", Json::Num(hit_rate)),
                    ("shed_rate", Json::Num(shed_rate)),
                ]),
            ),
        ];
        write_bench_json(&path, "serve", &records, extras)
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        println!("wrote {}", path.display());
    }
}
