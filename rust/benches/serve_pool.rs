//! `serve::Pool` throughput at 1→N workers — the ISSUE 3 acceptance
//! benchmark for the Engine/Session split.
//!
//! One shared `EngineBackend` (a dense 128-128-128-10 MLP), a wave of
//! pipelined requests per configuration, wall-clock requests/s. Before
//! any timing, a correctness gate checks the pooled results are
//! bit-identical to one sequential session (a fast pool that cheats is
//! useless).
//!
//! Modes:
//!   (default)        throughput table on stdout
//!   --json[=PATH]    also write BENCH_serve.json (ns/request per
//!                    worker count, scaling vs 1 worker)
//!   --smoke          correctness gate only, no timing (CI's fast
//!                    serve-pool regression check)

use std::sync::Arc;
use std::time::Instant;

use icsml::api::{Backend, EngineBackend, Session as _, SharedBackend};
use icsml::engine::{Act, Layer, Model};
use icsml::serve::{Pool, PoolConfig};
use icsml::util::benchkit::{
    json_flag, smoke_flag, write_bench_json, BenchRecord,
};
use icsml::util::json::Json;
use icsml::util::rng::SplitMix64;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];
const MAX_BATCH: usize = 8;

fn dense_model(sizes: &[usize], seed: u64) -> Model {
    let mut rng = SplitMix64::new(seed);
    let layers = sizes
        .windows(2)
        .enumerate()
        .map(|(i, w)| {
            let weights: Vec<f32> = (0..w[0] * w[1])
                .map(|_| rng.uniform(-0.5, 0.5) as f32)
                .collect();
            let biases: Vec<f32> =
                (0..w[1]).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
            let act = if i + 2 == sizes.len() { Act::None } else { Act::Relu };
            Layer::dense(weights, biases, w[0], act)
        })
        .collect();
    Model::new(layers)
}

fn request_wave(in_dim: usize, count: usize) -> Vec<Vec<f32>> {
    let mut rng = SplitMix64::new(0xD15EA5E);
    (0..count)
        .map(|_| {
            (0..in_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
        })
        .collect()
}

/// Submit the whole wave pipelined, wait for every ticket, return
/// (elapsed seconds, outputs).
fn drive(pool: &Pool, wave: &[Vec<f32>]) -> (f64, Vec<Vec<f32>>) {
    let t0 = Instant::now();
    let tickets: Vec<_> = wave.iter().map(|x| pool.submit(x)).collect();
    let outs: Vec<Vec<f32>> = tickets
        .into_iter()
        .map(|t| t.wait().expect("pool request failed"))
        .collect();
    (t0.elapsed().as_secs_f64(), outs)
}

fn main() {
    let smoke = smoke_flag();
    let json_path = json_flag("serve");
    let sizes = [128usize, 128, 128, 10];
    let backend: SharedBackend =
        Arc::new(EngineBackend::new(dense_model(&sizes, 0xC0FFEE)));

    // ---------------- correctness gate (always) -----------------------
    let gate_wave = request_wave(sizes[0], 64);
    let mut reference = backend.session().expect("session");
    let want: Vec<Vec<f32>> = gate_wave
        .iter()
        .map(|x| reference.infer(x).expect("reference inference"))
        .collect();
    {
        let pool = Pool::new(
            Arc::clone(&backend),
            PoolConfig { workers: 2, max_batch: MAX_BATCH },
        );
        let (_, outs) = drive(&pool, &gate_wave);
        for (i, (got, want)) in outs.iter().zip(&want).enumerate() {
            assert_eq!(got.len(), want.len(), "request {i}: output dims");
            for (k, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "request {i} logit {k}: pool {a} vs sequential {b}"
                );
            }
        }
        assert_eq!(pool.errors(), 0, "gate wave saw errors");
    }
    if smoke {
        println!(
            "serve-pool smoke OK: {} pooled requests bit-identical to the \
             sequential session",
            gate_wave.len()
        );
        return;
    }

    // ---------------- throughput sweep --------------------------------
    let requests = 4000usize;
    let wave = request_wave(sizes[0], requests);
    println!(
        "\nserve::Pool throughput — shared engine backend, dense \
         {sizes:?}, {requests} pipelined requests, micro-batch {MAX_BATCH}"
    );
    let mut t = icsml::util::bench::Table::new(&[
        "workers",
        "req/s",
        "ns/req",
        "mean batch",
        "scaling vs w1",
    ]);
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut scaling: Vec<(String, f64)> = Vec::new();
    let mut base_rps = 0.0f64;

    for &workers in &WORKER_COUNTS {
        let pool = Pool::new(
            Arc::clone(&backend),
            PoolConfig { workers, max_batch: MAX_BATCH },
        );
        // Warmup wave: spin sessions up, settle allocator high-water.
        let _ = drive(&pool, &wave[..256.min(wave.len())]);
        let (secs, outs) = drive(&pool, &wave);
        assert_eq!(outs.len(), requests);
        let rps = requests as f64 / secs.max(1e-12);
        if workers == WORKER_COUNTS[0] {
            base_rps = rps;
        }
        let ns_per_req = secs * 1e9 / requests as f64;
        let mean_batch =
            pool.served() as f64 / pool.batches().max(1) as f64;
        let rel = rps / base_rps.max(1e-12);
        t.row(&[
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{ns_per_req:.0}"),
            format!("{mean_batch:.2}"),
            format!("{rel:.2}x"),
        ]);
        records.push(BenchRecord {
            name: format!("pool/w{workers}"),
            mean_ns: ns_per_req,
            median_ns: ns_per_req,
            ops_per_inference: 0,
        });
        scaling.push((format!("w{workers}"), rel));
    }
    t.print();
    println!(
        "(pipelined wall-clock; scaling >1x at w>1 shows the shared \
         backend serves threads concurrently)"
    );

    if let Some(path) = json_path {
        let extras = vec![
            (
                "scaling_vs_w1",
                Json::obj(
                    scaling
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::Num(*v)))
                        .collect(),
                ),
            ),
            ("requests", Json::Num(requests as f64)),
            ("max_batch", Json::Num(MAX_BATCH as f64)),
        ];
        write_bench_json(&path, "serve", &records, extras)
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        println!("wrote {}", path.display());
    }
}
