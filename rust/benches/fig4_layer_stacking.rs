//! Fig. 4 — layer-stacking scaling: CPU time of the dot product,
//! activation and whole model vs the number of 64-in/64-out dense+ReLU
//! layers, on the WAGO PFC100 and BeagleBone Black (modeled from
//! metered ST execution) and on the compiled XLA comparator
//! ("TFLite" role, wall-clock on this host).
//!
//! Paper anchors: per layer BBB +455.2 µs dot / +181.8 µs act /
//! +741.9 µs model; WAGO +696.4 / +248.3 / +1093.6 µs; TFLite 29.4x /
//! 44.7x faster than ICSML(BBB/WAGO).

use icsml::plc::HwProfile;
use icsml::runtime::Runtime;
use icsml::util::bench::{Bench, Table};
use icsml::util::benchkit as bk;

const DEPTHS: [usize; 6] = [1, 2, 4, 6, 8, 10];

fn main() {
    let bbb = HwProfile::beaglebone();
    let wago = HwProfile::wago_pfc100();
    let bench = Bench::from_env();
    let rt = Runtime::cpu().ok();
    let artifacts = icsml::artifacts_dir();

    let mut table = Table::new(&[
        "layers",
        "BBB dot us",
        "BBB act us",
        "BBB model us",
        "WAGO model us",
        "ST wallclock us",
        "XLA us",
        "ST/XLA",
    ]);
    let mut last_ratio = 0.0;

    for depth in DEPTHS {
        let (spec, dir) = bk::random_spec(
            &format!("fig4_d{depth}"),
            &bk::stack_sizes(depth, 64),
            &bk::stack_acts(depth),
            depth as u64,
        );
        // Separate dense/activation layers, like the paper's benchmark.
        let mut it = bk::st_model(&spec, &dir, false);
        bk::st_set_inputs(&mut it, &vec![0.5f32; 64]);
        let meter = bk::st_infer_meter(&mut it);

        // Split the meter into dot vs act by re-measuring a fused model
        // (dense only, linear) of the same shape.
        let mut it_lin = bk::st_model(
            &spec_linear(&spec),
            &dir,
            true,
        );
        bk::st_set_inputs(&mut it_lin, &vec![0.5f32; 64]);
        let dot_meter = bk::st_infer_meter(&mut it_lin);
        let act_meter = meter.since(&dot_meter.clone_min(&meter));

        // ST interpreter wall-clock (same host as XLA -> fair ratio).
        let st_wall = bench.run(&format!("st_d{depth}"), || {
            let _ = bk::st_infer_meter(&mut it);
        });

        // XLA comparator on the AOT artifact for this depth.
        let (xla_us, ratio) = match (&rt, artifacts.join("manifest.json").exists()) {
            (Some(rt), true) => {
                let path =
                    artifacts.join(format!("hlo/bench_stack_d{depth}.hlo.txt"));
                match rt.load_hlo(&path) {
                    Ok(exe) => {
                        let x = vec![0.5f32; 64];
                        let s = bench.run(&format!("xla_d{depth}"), || {
                            let _ = std::hint::black_box(
                                exe.run_f32(&x, &[1, 64]).unwrap(),
                            );
                        });
                        let r = st_wall.mean_us() / s.mean_us();
                        last_ratio = r;
                        (format!("{:.1}", s.mean_us()), format!("{r:.1}x"))
                    }
                    Err(_) => ("n/a".into(), "n/a".into()),
                }
            }
            _ => ("n/a".into(), "n/a".into()),
        };

        table.row(&[
            depth.to_string(),
            format!("{:.0}", bbb.time_us(&dot_meter)),
            format!("{:.0}", bbb.time_us(&act_meter)),
            format!("{:.0}", bbb.time_us(&meter)),
            format!("{:.0}", wago.time_us(&meter)),
            format!("{:.0}", st_wall.mean_us()),
            xla_us,
            ratio,
        ]);
    }

    println!("\nFig. 4 — layer stacking (64-in/64-out dense + ReLU stacks)");
    table.print();
    println!(
        "paper: +455.2/+181.8/+741.9 µs per layer (BBB), +696.4/+248.3/\
         +1093.6 µs (WAGO); compiled runtime 29.4x (BBB) / 44.7x (WAGO) \
         faster.\nmeasured compiled-vs-interpreted ratio on this host: \
         {last_ratio:.1}x (shape: interpreted ST is 1-2 orders slower — \
         holds)."
    );
}

/// Same spec with all activations linear (isolates the dot product).
fn spec_linear(spec: &icsml::porting::ModelSpec) -> icsml::porting::ModelSpec {
    let mut s = spec.clone();
    for a in s.activations.iter_mut() {
        *a = "linear".to_string();
    }
    s
}

/// Meter subtraction helper: clamp to avoid underflow when the linear
/// model's counters exceed the full model's in some class.
trait MeterExt {
    fn clone_min(&self, other: &icsml::st::Meter) -> icsml::st::Meter;
}

impl MeterExt for icsml::st::Meter {
    fn clone_min(&self, other: &icsml::st::Meter) -> icsml::st::Meter {
        icsml::st::Meter {
            loads: self.loads.min(other.loads),
            stores: self.stores.min(other.stores),
            fp_add: self.fp_add.min(other.fp_add),
            fp_mul: self.fp_mul.min(other.fp_mul),
            fp_div: self.fp_div.min(other.fp_div),
            fp_trans: self.fp_trans.min(other.fp_trans),
            int_ops: self.int_ops.min(other.int_ops),
            cmp: self.cmp.min(other.cmp),
            fp_cmp: self.fp_cmp.min(other.fp_cmp),
            branches: self.branches.min(other.branches),
            calls: self.calls.min(other.calls),
            copy_bytes: self.copy_bytes.min(other.copy_bytes),
            converts: self.converts.min(other.converts),
            io_calls: self.io_calls.min(other.io_calls),
            io_bytes: self.io_bytes.min(other.io_bytes),
        }
    }
}
