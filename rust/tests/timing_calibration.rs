//! Timing-model calibration: the BBB/WAGO profiles must reproduce the
//! paper's published anchor numbers (§5.2) from metered ST execution:
//!
//! * BBB: each 64-in/64-out dense+ReLU layer adds ≈ 455.2 µs (dot),
//!   ≈ 181.8 µs (activation), ≈ 741.9 µs (total, incl. model overhead).
//! * WAGO: ≈ 696.4 / 248.3 / 1093.6 µs.
//! * §5.3: ≈ 9.33 µs per neuron (BBB) / 13.72 µs (WAGO) for a 32-input
//!   dense layer.
//!
//! Tolerance is ±20% — the model is calibrated, not fitted per-run.

use icsml::icsml_st;
use icsml::plc::HwProfile;
use icsml::st::{Meter, Value};

/// Run a 64x64 dense + separate ReLU through the ST framework and
/// return (dot_meter, act_meter).
fn layer_meters() -> (Meter, Meter) {
    let app = "
PROGRAM p
VAR
    x : ARRAY[0..63] OF REAL;
    h : ARRAY[0..63] OF REAL;
    y : ARRAY[0..63] OF REAL;
    w : ARRAY[0..4095] OF REAL;
    b : ARRAY[0..63] OF REAL;
    dims : ARRAY[0..0] OF UDINT := [64];
    dense : FB_Dense;
    relu : FB_Activation;
    phase : DINT := 0;
    ok : BOOL;
END_VAR
IF phase = 0 THEN
    dense.weights := (address := ADR(w), length := 4096,
                      dimensions := ADR(dims), dimensions_num := 1);
    dense.biases := (address := ADR(b), length := 64,
                     dimensions := ADR(dims), dimensions_num := 1);
    dense.inMem := (address := ADR(x), length := 64,
                    dimensions := ADR(dims), dimensions_num := 1);
    dense.outMem := (address := ADR(h), length := 64,
                     dimensions := ADR(dims), dimensions_num := 1);
    dense.neurons := 64; dense.inputs := 64;
    relu.inMem := dense.outMem;
    relu.outMem := (address := ADR(y), length := 64,
                    dimensions := ADR(dims), dimensions_num := 1);
    relu.act := ACT_RELU;
    phase := 1;
ELSIF phase = 1 THEN
    ok := dense.eval();
    phase := 2;
ELSE
    ok := relu.eval();
    phase := 1;
END_IF
END_PROGRAM";
    let mut it = icsml_st::load(app).unwrap();
    it.run_program("p").unwrap(); // wiring
    let m0 = it.meter.clone();
    it.run_program("p").unwrap(); // dense
    let m1 = it.meter.clone();
    it.run_program("p").unwrap(); // relu
    let m2 = it.meter.clone();
    (m1.since(&m0), m2.since(&m1))
}

fn within(actual: f64, target: f64, tol: f64) -> bool {
    (actual - target).abs() <= tol * target
}

#[test]
fn bbb_matches_paper_layer_anchors() {
    let (dot, act) = layer_meters();
    let bbb = HwProfile::beaglebone();
    let dot_us = bbb.time_us(&dot);
    let act_us = bbb.time_us(&act);
    assert!(
        within(dot_us, 455.2, 0.20),
        "BBB dense 64x64 modeled {dot_us:.1} µs, paper 455.2 µs"
    );
    assert!(
        within(act_us, 181.8, 0.20),
        "BBB activation modeled {act_us:.1} µs, paper 181.8 µs"
    );
    let total = dot_us + act_us;
    assert!(
        within(total, 741.9, 0.25),
        "BBB layer total modeled {total:.1} µs, paper ≈741.9 µs"
    );
}

#[test]
fn wago_matches_paper_layer_anchors() {
    let (dot, act) = layer_meters();
    let wago = HwProfile::wago_pfc100();
    let dot_us = wago.time_us(&dot);
    let act_us = wago.time_us(&act);
    assert!(
        within(dot_us, 696.4, 0.20),
        "WAGO dense 64x64 modeled {dot_us:.1} µs, paper 696.4 µs"
    );
    assert!(
        within(act_us, 248.3, 0.30),
        "WAGO activation modeled {act_us:.1} µs, paper 248.3 µs"
    );
}

#[test]
fn per_neuron_cost_matches_layer_size_anchor() {
    // §5.3: 32-input dense layer — ≈9.33 µs/neuron BBB, 13.72 WAGO
    // (dot + activation + model overhead per neuron).
    let app = "
PROGRAM p
VAR
    x : ARRAY[0..31] OF REAL;
    y : ARRAY[0..511] OF REAL;
    w : ARRAY[0..16383] OF REAL;
    b : ARRAY[0..511] OF REAL;
    dims : ARRAY[0..0] OF UDINT := [512];
    dense : FB_Dense;
    phase : DINT := 0;
    ok : BOOL;
END_VAR
IF phase = 0 THEN
    dense.weights := (address := ADR(w), length := 16384,
                      dimensions := ADR(dims), dimensions_num := 1);
    dense.biases := (address := ADR(b), length := 512,
                     dimensions := ADR(dims), dimensions_num := 1);
    dense.inMem := (address := ADR(x), length := 32,
                    dimensions := ADR(dims), dimensions_num := 1);
    dense.outMem := (address := ADR(y), length := 512,
                     dimensions := ADR(dims), dimensions_num := 1);
    dense.neurons := 512; dense.inputs := 32;
    dense.act := ACT_RELU;
    phase := 1;
ELSE
    ok := dense.eval();
END_IF
END_PROGRAM";
    let mut it = icsml_st::load(app).unwrap();
    it.run_program("p").unwrap();
    let m0 = it.meter.clone();
    it.run_program("p").unwrap();
    let d = it.meter.since(&m0);
    let per_neuron_bbb = HwProfile::beaglebone().time_us(&d) / 512.0;
    let per_neuron_wago = HwProfile::wago_pfc100().time_us(&d) / 512.0;
    assert!(
        within(per_neuron_bbb, 9.326, 0.35),
        "BBB per-neuron modeled {per_neuron_bbb:.2} µs, paper 9.33 µs"
    );
    assert!(
        within(per_neuron_wago, 13.722, 0.35),
        "WAGO per-neuron modeled {per_neuron_wago:.2} µs, paper 13.72 µs"
    );
}

#[test]
fn binarr_arrbin_costs_match_anchors() {
    // §5.2: BINARR ≈ 396 µs / ARRBIN ≈ 530 µs per call on the BBB for
    // the 64-feature vectors (447/535 µs WAGO).
    let dir = std::env::temp_dir().join("icsml_io_calib");
    std::fs::create_dir_all(&dir).unwrap();
    let app = "
PROGRAM p
VAR
    a : ARRAY[0..63] OF REAL;
    ok : BOOL;
END_VAR
ok := ARRBIN('calib.bin', 64 * SIZEOF(REAL), ADR(a));
ok := BINARR('calib.bin', 64 * SIZEOF(REAL), ADR(a));
END_PROGRAM";
    let mut it = icsml_st::load(app).unwrap();
    it.io_dir = dir;
    it.run_program("p").unwrap();
    let m = it.meter.clone();
    assert_eq!(m.io_calls, 2);
    // Two calls with 256 bytes each; the model charges a fixed cost +
    // per-byte cost. Mean per call should land between the paper's
    // BINARR/ARRBIN anchors.
    let bbb_per_call = HwProfile::beaglebone().time_us(&Meter {
        io_calls: m.io_calls,
        io_bytes: m.io_bytes,
        ..Meter::default()
    }) / 2.0;
    assert!(
        (350.0..550.0).contains(&bbb_per_call),
        "BBB file-I/O per call modeled {bbb_per_call:.0} µs, paper 396–530 µs"
    );
}

/// Table the calibration actually achieved (printed for EXPERIMENTS.md).
#[test]
fn print_calibration_summary() {
    let (dot, act) = layer_meters();
    eprintln!("dot meter: {dot:?}");
    eprintln!("act meter: {act:?}");
    for profile in [HwProfile::beaglebone(), HwProfile::wago_pfc100()] {
        eprintln!(
            "{:>18}: dot {:.1} µs | act {:.1} µs | layer {:.1} µs",
            profile.name,
            profile.time_us(&dot),
            profile.time_us(&act),
            profile.time_us(&dot) + profile.time_us(&act),
        );
    }
}
