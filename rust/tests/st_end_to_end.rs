//! End-to-end tests for the ST substrate: compile + execute realistic
//! programs and check values, IEC restriction enforcement, and cost
//! metering.

use icsml::st::{self, Value};

fn run(src: &str, program: &str) -> st::Interp {
    let unit = st::compile(src).expect("compile");
    let mut it = st::Interp::new(unit);
    it.run_program(program).expect("run");
    it
}

fn field_f32(it: &st::Interp, prog: &str, name: &str) -> f32 {
    let inst = it.program_instance(prog).unwrap();
    match it.instance_field(inst, name).unwrap() {
        Value::Real(v) => v,
        other => panic!("expected REAL, got {other:?}"),
    }
}

fn field_int(it: &st::Interp, prog: &str, name: &str) -> i64 {
    let inst = it.program_instance(prog).unwrap();
    match it.instance_field(inst, name).unwrap() {
        Value::Int(v) => v,
        other => panic!("expected INT, got {other:?}"),
    }
}

#[test]
fn arithmetic_and_precedence() {
    let it = run(
        "PROGRAM p VAR x : REAL; i : DINT; END_VAR\n\
         x := 2.0 + 3.0 * 4.0 - 1.0 / 2.0;\n\
         i := 17 MOD 5 + 2 * 3;\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "x"), 13.5);
    assert_eq!(field_int(&it, "p", "i"), 8);
}

#[test]
fn for_loop_sum_and_exit() {
    let it = run(
        "PROGRAM p VAR s, j : DINT; i : DINT; END_VAR\n\
         FOR i := 1 TO 100 DO\n\
           s := s + i;\n\
           IF i = 10 THEN EXIT; END_IF\n\
         END_FOR\n\
         FOR i := 10 TO 0 BY -2 DO j := j + 1; END_FOR\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_int(&it, "p", "s"), 55);
    assert_eq!(field_int(&it, "p", "j"), 6);
}

#[test]
fn while_repeat_case() {
    let it = run(
        "PROGRAM p VAR n, r, c : DINT; END_VAR\n\
         n := 5;\n\
         WHILE n > 0 DO r := r + n; n := n - 1; END_WHILE\n\
         REPEAT c := c + 1; UNTIL c >= 3 END_REPEAT\n\
         CASE r OF\n\
           0..9: r := -1;\n\
           15: r := 100;\n\
           ELSE r := -2;\n\
         END_CASE\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_int(&it, "p", "r"), 100);
    assert_eq!(field_int(&it, "p", "c"), 3);
}

#[test]
fn function_call_returns_value() {
    let it = run(
        "FUNCTION add3 : REAL\n\
         VAR_INPUT a, b, c : REAL; END_VAR\n\
         add3 := a + b + c;\n\
         END_FUNCTION\n\
         PROGRAM p VAR x : REAL; END_VAR\n\
         x := add3(1.0, 2.0, 3.5);\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "x"), 6.5);
}

#[test]
fn var_input_arrays_are_copied_and_metered() {
    // Paper §3.1 / §4.2.1: VAR_INPUT arrays are duplicated per call.
    let src = "FUNCTION first : REAL\n\
         VAR_INPUT a : ARRAY[0..255] OF REAL; END_VAR\n\
         a[0] := 42.0;  // mutates the COPY only\n\
         first := a[0];\n\
         END_FUNCTION\n\
         PROGRAM p VAR arr : ARRAY[0..255] OF REAL; x, y : REAL; END_VAR\n\
         arr[0] := 7.0;\n\
         x := first(arr);\n\
         y := arr[0];\n\
         END_PROGRAM";
    let it = run(src, "p");
    assert_eq!(field_f32(&it, "p", "x"), 42.0);
    assert_eq!(field_f32(&it, "p", "y"), 7.0, "caller array must be unchanged");
    // 256 * 4 bytes metered for the call-by-value copy.
    assert!(it.meter.copy_bytes >= 1024, "copy_bytes={}", it.meter.copy_bytes);
}

#[test]
fn var_in_out_shares_storage() {
    let it = run(
        "FUNCTION fill : BOOL\n\
         VAR_IN_OUT a : ARRAY[0..3] OF REAL; END_VAR\n\
         VAR i : DINT; END_VAR\n\
         FOR i := 0 TO 3 DO a[i] := INT_TO_REAL(DINT_TO_INT(i)) * 2.0; END_FOR\n\
         fill := TRUE;\n\
         END_FUNCTION\n\
         PROGRAM p VAR arr : ARRAY[0..3] OF REAL; x : REAL; ok : BOOL; END_VAR\n\
         ok := fill(arr);\n\
         x := arr[3];\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "x"), 6.0);
}

#[test]
fn pointers_and_adr() {
    let it = run(
        "PROGRAM p VAR\n\
           a : ARRAY[0..9] OF REAL;\n\
           pr : POINTER TO REAL;\n\
           x, y : REAL; i : DINT;\n\
         END_VAR\n\
         FOR i := 0 TO 9 DO a[i] := 0.5 * DINT_TO_REAL(i); END_FOR\n\
         pr := ADR(a);\n\
         x := pr^ + pr[4];\n\
         pr := ADR(a[5]);\n\
         y := pr[2];\n\
         pr[2] := 99.0;\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "x"), 2.0);
    assert_eq!(field_f32(&it, "p", "y"), 3.5);
    let inst = it.program_instance("p").unwrap();
    if let Value::ArrF32(a) = it.instance_field(inst, "a").unwrap() {
        assert_eq!(a.borrow()[7], 99.0, "pointer store hits the array");
    } else {
        panic!()
    }
}

#[test]
fn structs_and_initializers() {
    let it = run(
        "TYPE point : STRUCT x : REAL; y : REAL; tag : DINT; END_STRUCT END_TYPE\n\
         PROGRAM p VAR\n\
           a : point := (x := 1.0, y := 2.0);\n\
           b : point;\n\
           r : REAL;\n\
         END_VAR\n\
         b := a;\n\
         b.y := 10.0;\n\
         r := a.y + b.y + a.x;\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "r"), 13.0);
}

#[test]
fn fb_methods_and_fields() {
    let it = run(
        "FUNCTION_BLOCK FB_Acc\n\
         VAR total : REAL; n : DINT; END_VAR\n\
         METHOD push : BOOL\n\
         VAR_INPUT v : REAL; END_VAR\n\
           total := total + v;\n\
           n := n + 1;\n\
           push := TRUE;\n\
         END_METHOD\n\
         METHOD mean : REAL\n\
           IF n > 0 THEN mean := total / DINT_TO_REAL(n); END_IF\n\
         END_METHOD\n\
         END_FUNCTION_BLOCK\n\
         PROGRAM p VAR acc : FB_Acc; m : REAL; ok : BOOL; END_VAR\n\
         ok := acc.push(2.0);\n\
         ok := acc.push(4.0);\n\
         m := acc.mean();\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "m"), 3.0);
}

#[test]
fn interface_dispatch() {
    let it = run(
        "INTERFACE IOp\n\
           METHOD apply : REAL VAR_INPUT x : REAL; END_VAR END_METHOD\n\
         END_INTERFACE\n\
         FUNCTION_BLOCK FB_Twice IMPLEMENTS IOp\n\
         METHOD apply : REAL VAR_INPUT x : REAL; END_VAR\n\
           apply := 2.0 * x;\n\
         END_METHOD\n\
         END_FUNCTION_BLOCK\n\
         FUNCTION_BLOCK FB_Square IMPLEMENTS IOp\n\
         METHOD apply : REAL VAR_INPUT x : REAL; END_VAR\n\
           apply := x * x;\n\
         END_METHOD\n\
         END_FUNCTION_BLOCK\n\
         PROGRAM p VAR\n\
           t : FB_Twice; s : FB_Square;\n\
           ops : ARRAY[0..1] OF IOp;\n\
           i : DINT; r : REAL; op : IOp;\n\
         END_VAR\n\
         ops[0] := t; ops[1] := s;\n\
         FOR i := 0 TO 1 DO\n\
           op := ops[i];\n\
           r := r + op.apply(3.0);\n\
         END_FOR\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "r"), 15.0); // 6 + 9
}

#[test]
fn fb_invocation_with_body() {
    let it = run(
        "FUNCTION_BLOCK FB_Ctr\n\
         VAR_INPUT inc : DINT; END_VAR\n\
         VAR_OUTPUT out : DINT; END_VAR\n\
         VAR count : DINT; END_VAR\n\
         count := count + inc;\n\
         out := count;\n\
         END_FUNCTION_BLOCK\n\
         PROGRAM p VAR c : FB_Ctr; got : DINT; END_VAR\n\
         c(inc := 5);\n\
         c(inc := 7, out => got);\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_int(&it, "p", "got"), 12);
}

#[test]
fn recursion_is_rejected_at_compile_time() {
    let err = st::compile(
        "FUNCTION f : DINT\n\
         VAR_INPUT n : DINT; END_VAR\n\
         f := f(n - 1);\n\
         END_FUNCTION",
    )
    .unwrap_err();
    assert!(format!("{err}").to_lowercase().contains("recursion"));
}

#[test]
fn mutual_recursion_rejected() {
    let err = st::compile(
        "FUNCTION a : DINT\nVAR_INPUT n : DINT; END_VAR\n a := b(n); END_FUNCTION\n\
         FUNCTION b : DINT\nVAR_INPUT n : DINT; END_VAR\n b := a(n); END_FUNCTION",
    )
    .unwrap_err();
    assert!(format!("{err}").to_lowercase().contains("recursion"));
}

#[test]
fn const_array_bounds() {
    let it = run(
        "PROGRAM p\n\
         VAR CONSTANT n : DINT := 8; m : DINT := n * 2; END_VAR\n\
         VAR a : ARRAY[0..m - 1] OF REAL; s : REAL; i : DINT; END_VAR\n\
         FOR i := 0 TO m - 1 DO a[i] := 1.0; END_FOR\n\
         FOR i := 0 TO m - 1 DO s := s + a[i]; END_FOR\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "s"), 16.0);
}

#[test]
fn index_out_of_bounds_is_runtime_error() {
    let unit = st::compile(
        "PROGRAM p VAR a : ARRAY[0..3] OF REAL; i : DINT; x : REAL; END_VAR\n\
         i := 7;\n\
         x := a[i];\n\
         END_PROGRAM",
    )
    .unwrap();
    let mut it = st::Interp::new(unit);
    let err = it.run_program("p").unwrap_err();
    assert!(err.message.contains("out of bounds"));
}

#[test]
fn unbound_interface_call_is_runtime_error() {
    let unit = st::compile(
        "INTERFACE IOp METHOD go : BOOL END_METHOD END_INTERFACE\n\
         FUNCTION_BLOCK FB_A IMPLEMENTS IOp\n\
         METHOD go : BOOL go := TRUE; END_METHOD\n\
         END_FUNCTION_BLOCK\n\
         PROGRAM p VAR op : IOp; ok : BOOL; END_VAR\n\
         ok := op.go();\n\
         END_PROGRAM",
    )
    .unwrap();
    let mut it = st::Interp::new(unit);
    let err = it.run_program("p").unwrap_err();
    assert!(err.message.contains("not bound"));
}

#[test]
fn multidim_arrays_flatten_row_major() {
    let it = run(
        "PROGRAM p VAR\n\
           m : ARRAY[0..2, 0..3] OF REAL;\n\
           x : REAL; i, j : DINT;\n\
         END_VAR\n\
         FOR i := 0 TO 2 DO\n\
           FOR j := 0 TO 3 DO\n\
             m[i, j] := DINT_TO_REAL(i) * 10.0 + DINT_TO_REAL(j);\n\
           END_FOR\n\
         END_FOR\n\
         x := m[2, 1];\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "x"), 21.0);
}

#[test]
fn binarr_arrbin_round_trip() {
    let dir = std::env::temp_dir().join("icsml_st_io_test");
    std::fs::create_dir_all(&dir).unwrap();
    let src = "PROGRAM p VAR\n\
           a : ARRAY[0..7] OF REAL;\n\
           b : ARRAY[0..7] OF REAL;\n\
           i : DINT; ok : BOOL; s : REAL;\n\
         END_VAR\n\
         FOR i := 0 TO 7 DO a[i] := DINT_TO_REAL(i) * 1.5; END_FOR\n\
         ok := ARRBIN('roundtrip.bin', 8 * SIZEOF(REAL), ADR(a));\n\
         ok := BINARR('roundtrip.bin', 8 * SIZEOF(REAL), ADR(b));\n\
         FOR i := 0 TO 7 DO s := s + b[i]; END_FOR\n\
         END_PROGRAM";
    let unit = st::compile(src).unwrap();
    let mut it = st::Interp::new(unit).with_io_dir(&dir);
    it.run_program("p").unwrap();
    assert_eq!(field_f32(&it, "p", "s"), 1.5 * 28.0);
    assert!(it.meter.io_calls >= 2);
}

#[test]
fn meter_counts_dot_product_ops() {
    // 64-element dot product: exactly 64 multiplies.
    let src = "PROGRAM p VAR\n\
           w, x : ARRAY[0..63] OF REAL; s : REAL; i : DINT;\n\
         END_VAR\n\
         FOR i := 0 TO 63 DO w[i] := 1.0; x[i] := 2.0; END_FOR\n\
         s := 0.0;\n\
         FOR i := 0 TO 63 DO s := s + w[i] * x[i]; END_FOR\n\
         END_PROGRAM";
    let unit = st::compile(src).unwrap();
    let mut it = st::Interp::new(unit);
    let before = it.meter.clone();
    it.run_program("p").unwrap();
    let d = it.meter.since(&before);
    assert_eq!(field_f32(&it, "p", "s"), 128.0);
    assert_eq!(d.fp_mul, 64);
    assert!(d.fp_add >= 64);
}

#[test]
fn integer_width_wrapping() {
    let it = run(
        "PROGRAM p VAR s : SINT; u : USINT; big : DINT; END_VAR\n\
         big := 300;\n\
         s := DINT_TO_SINT(big);\n\
         u := DINT_TO_USINT(big);\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_int(&it, "p", "s"), 44);   // 300 wraps to 44 in i8
    assert_eq!(field_int(&it, "p", "u"), 44);   // 300 & 0xFF
}

#[test]
fn builtin_math() {
    let it = run(
        "PROGRAM p VAR a, b, c, d : REAL; t : DINT; END_VAR\n\
         a := SQRT(16.0);\n\
         b := EXP(0.0) + LN(1.0);\n\
         c := MAX(1.5, MIN(9.0, 3.25));\n\
         d := LIMIT(0.0, -5.0, 1.0);\n\
         t := TRUNC(3.9);\n\
         END_PROGRAM",
        "p",
    );
    assert_eq!(field_f32(&it, "p", "a"), 4.0);
    assert_eq!(field_f32(&it, "p", "b"), 1.0);
    assert_eq!(field_f32(&it, "p", "c"), 3.25);
    assert_eq!(field_f32(&it, "p", "d"), 0.0);
    assert_eq!(field_int(&it, "p", "t"), 3);
}

#[test]
fn globals_shared_across_programs() {
    let src = "VAR_GLOBAL g : REAL; END_VAR\n\
         PROGRAM writer g := 5.5; END_PROGRAM\n\
         PROGRAM reader VAR x : REAL; END_VAR x := g * 2.0; END_PROGRAM";
    let unit = st::compile(src).unwrap();
    let mut it = st::Interp::new(unit);
    it.run_program("writer").unwrap();
    it.run_program("reader").unwrap();
    assert_eq!(field_f32(&it, "reader", "x"), 11.0);
}

#[test]
fn program_state_persists_across_scans() {
    let unit = st::compile(
        "PROGRAM p VAR count : DINT; END_VAR count := count + 1; END_PROGRAM",
    )
    .unwrap();
    let mut it = st::Interp::new(unit);
    for _ in 0..5 {
        it.run_program("p").unwrap();
    }
    assert_eq!(field_int(&it, "p", "count"), 5);
}

#[test]
fn type_errors_rejected() {
    assert!(st::compile(
        "PROGRAM p VAR x : REAL; b : BOOL; END_VAR x := b; END_PROGRAM"
    )
    .is_err());
    assert!(st::compile(
        "PROGRAM p VAR x : REAL; END_VAR IF x THEN x := 1.0; END_IF END_PROGRAM"
    )
    .is_err());
    assert!(st::compile(
        "PROGRAM p VAR i : DINT; x : REAL; END_VAR i := x; END_PROGRAM"
    )
    .is_err(), "narrowing REAL->DINT must need explicit conversion");
}

#[test]
fn unknown_names_rejected() {
    assert!(st::compile("PROGRAM p nope := 1; END_PROGRAM").is_err());
    assert!(st::compile(
        "PROGRAM p VAR x : REAL; END_VAR x := mystery(); END_PROGRAM"
    )
    .is_err());
}
