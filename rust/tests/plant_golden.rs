//! Golden-trace cross-validation: the Rust MSF plant twin must
//! reproduce the Python plant's trajectory (emitted by `make
//! artifacts` into `artifacts/golden/msf_trace.json`) to float
//! tolerance — both twins integrate the identical discrete dynamics in
//! the identical evaluation order. The comparison is driven through
//! `Simulator::run_collect`, with a step-by-step mirror sim asserting
//! the collected trace is bit-for-bit the stepped trace.

use icsml::msf::{Attack, AttackFamily, ScanReading, Simulator};
use icsml::util::json::Json;

/// The golden scenario: seed=1, no noise, combined 0.5 attack on
/// steps [600, 1200) — same as python `plant.golden_trace()`.
fn golden_sim() -> Simulator {
    Simulator::new(
        1,
        false,
        vec![Attack::new(AttackFamily::Combined, 0.5, 600, 1200)],
    )
}

fn assert_bit_identical(i: usize, a: &ScanReading, b: &ScanReading) {
    assert_eq!(
        a.tb0_adc.to_bits(),
        b.tb0_adc.to_bits(),
        "step {i} tb0_adc: collected {} vs stepped {}",
        a.tb0_adc,
        b.tb0_adc
    );
    assert_eq!(a.wd_adc.to_bits(), b.wd_adc.to_bits(), "step {i} wd_adc");
    assert_eq!(a.ws_cmd.to_bits(), b.ws_cmd.to_bits(), "step {i} ws_cmd");
    assert_eq!(a.attack_active, b.attack_active, "step {i} attack flag");
}

#[test]
fn run_collect_is_bit_identical_to_step_loop() {
    let mut collected = golden_sim();
    let mut stepped = golden_sim();
    let trace = collected.run_collect(2_000);
    assert_eq!(trace.len(), 2_000);
    for (i, r) in trace.iter().enumerate() {
        let s = stepped.step();
        assert_bit_identical(i, r, &s);
    }
    assert_eq!(collected.step_idx, stepped.step_idx);
    assert_eq!(collected.state.tb0.to_bits(), stepped.state.tb0.to_bits());
    assert_eq!(collected.state.tbot.to_bits(), stepped.state.tbot.to_bits());
    assert_eq!(collected.state.wd.to_bits(), stepped.state.wd.to_bits());
}

#[test]
fn rust_plant_matches_python_golden_trace() {
    let root = icsml::artifacts_dir();
    let path = root.join("golden/msf_trace.json");
    if !path.exists() {
        eprintln!("skipping: no golden trace (run `make artifacts`)");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let rows = j.expect("rows").as_arr().unwrap();
    assert!(rows.len() >= 1000, "trace too short");

    // The collected trace carries the per-step readings; the mirror
    // sim replays step-by-step so the per-step *state* columns are
    // comparable too — and pins collected == stepped bit-for-bit
    // along the way.
    let trace = golden_sim().run_collect(rows.len() as u64);
    let mut mirror = golden_sim();
    for (i, row) in rows.iter().enumerate() {
        let r = row.as_arr().unwrap();
        let got = mirror.step();
        assert_bit_identical(i, &trace[i], &got);
        let cols = [
            ("tb0_adc", got.tb0_adc, r[0].as_f64().unwrap()),
            ("wd_adc", got.wd_adc, r[1].as_f64().unwrap()),
            ("ws_cmd", got.ws_cmd, r[2].as_f64().unwrap()),
            ("tb0", mirror.state.tb0, r[3].as_f64().unwrap()),
            ("tbot", mirror.state.tbot, r[4].as_f64().unwrap()),
            ("wd", mirror.state.wd, r[5].as_f64().unwrap()),
        ];
        for (name, rust_v, py_v) in cols {
            let tol = 1e-9 * py_v.abs().max(1.0);
            assert!(
                (rust_v - py_v).abs() <= tol,
                "step {i}, column {name}: rust {rust_v} vs python {py_v}"
            );
        }
        let attack = r[6].as_f64().unwrap() != 0.0;
        assert_eq!(got.attack_active, attack, "step {i} attack flag");
    }
}
