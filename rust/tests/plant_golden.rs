//! Golden-trace cross-validation: the Rust MSF plant twin must
//! reproduce the Python plant's trajectory (emitted by `make
//! artifacts` into `artifacts/golden/msf_trace.json`) to float
//! tolerance — both twins integrate the identical discrete dynamics in
//! the identical evaluation order.

use icsml::msf::{Attack, AttackFamily, Simulator};
use icsml::util::json::Json;

#[test]
fn rust_plant_matches_python_golden_trace() {
    let root = icsml::artifacts_dir();
    let path = root.join("golden/msf_trace.json");
    if !path.exists() {
        eprintln!("skipping: no golden trace (run `make artifacts`)");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let rows = j.expect("rows").as_arr().unwrap();
    assert!(rows.len() >= 1000, "trace too short");

    // Same scenario as python plant.golden_trace(): seed=1, no noise,
    // combined 0.5 attack on steps [600, 1200).
    let mut sim = Simulator::new(
        1,
        false,
        vec![Attack::new(AttackFamily::Combined, 0.5, 600, 1200)],
    );
    for (i, row) in rows.iter().enumerate() {
        let r = row.as_arr().unwrap();
        let got = sim.step();
        let cols = [
            ("tb0_adc", got.tb0_adc, r[0].as_f64().unwrap()),
            ("wd_adc", got.wd_adc, r[1].as_f64().unwrap()),
            ("ws_cmd", got.ws_cmd, r[2].as_f64().unwrap()),
            ("tb0", sim.state.tb0, r[3].as_f64().unwrap()),
            ("tbot", sim.state.tbot, r[4].as_f64().unwrap()),
            ("wd", sim.state.wd, r[5].as_f64().unwrap()),
        ];
        for (name, rust_v, py_v) in cols {
            let tol = 1e-9 * py_v.abs().max(1.0);
            assert!(
                (rust_v - py_v).abs() <= tol,
                "step {i}, column {name}: rust {rust_v} vs python {py_v}"
            );
        }
        let attack = r[6].as_f64().unwrap() != 0.0;
        assert_eq!(got.attack_active, attack, "step {i} attack flag");
    }
}
