//! Integration tests of the IEC 61131-3 §2.7 task model: the
//! CONFIGURATION → RESOURCE → TASK front end, the sema error surface,
//! and the [`TaskScheduler`] cyclic executive — including the PR's
//! load-bearing invariant, that a multi-task configuration runs
//! **bit-identically and meter-exactly per task** on the tree-walking
//! interpreter oracle and on both bytecode tiers (fused and plain),
//! and that priority starvation is deterministic: the budget-starved
//! low-priority task skips cycles visibly while the control task
//! never does.

use icsml::plc::HwProfile;
use icsml::st::{
    self, FusionConfig, Interp, TaskScheduler, Trigger, Value, Vm,
};

// ------------------------------------------------------- error surface

fn compile_err(src: &str) -> String {
    match st::compile(src) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected a compile error for:\n{src}"),
    }
}

/// Every rejected configuration shape, with the diagnostic substring
/// sema must produce (one table so a regressed message names its
/// source immediately).
#[test]
fn configuration_error_table() {
    let cases: &[(&str, &str)] = &[
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#10ms, PRIORITY := 0);
               TASK t(INTERVAL := T#20ms, PRIORITY := 1);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "duplicate TASK t",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               PROGRAM i WITH nosuch : p;
             END_RESOURCE END_CONFIGURATION",
            "bound to undeclared TASK nosuch",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#0ms, PRIORITY := 0);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "INTERVAL must be positive",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#10, PRIORITY := 0);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "bad INTERVAL duration T#10",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(PRIORITY := 0);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "needs an INTERVAL or SINGLE trigger",
        ),
        (
            "VAR_GLOBAL go : BOOL; END_VAR
             PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#10ms, SINGLE := go);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "INTERVAL and SINGLE are mutually exclusive",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(SINGLE := nosuch);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "SINGLE trigger nosuch is not a global variable",
        ),
        (
            "VAR_GLOBAL go : REAL; END_VAR
             PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(SINGLE := go);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "SINGLE trigger go must be a global BOOL",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#10ms, PRIORITY := -1);
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "PRIORITY must be non-negative",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#10ms);
               PROGRAM i WITH t : p;
               PROGRAM i WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "duplicate program instance i",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#10ms);
               PROGRAM i WITH t : NoSuchProg;
             END_RESOURCE END_CONFIGURATION",
            "unknown PROGRAM type NoSuchProg",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c RESOURCE r ON cpu
               TASK t(INTERVAL := T#10ms);
               PROGRAM a WITH t : p;
               PROGRAM b WITH t : p;
             END_RESOURCE END_CONFIGURATION",
            "bound more than once",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c END_CONFIGURATION",
            "declares no RESOURCE",
        ),
        (
            "PROGRAM p END_PROGRAM
             CONFIGURATION c1 RESOURCE r ON cpu
               PROGRAM i : p;
             END_RESOURCE END_CONFIGURATION
             CONFIGURATION c2 RESOURCE r ON cpu
               PROGRAM j : p;
             END_RESOURCE END_CONFIGURATION",
            "multiple CONFIGURATION blocks",
        ),
    ];
    for (src, want) in cases {
        let msg = compile_err(src);
        assert!(
            msg.contains(want),
            "error {msg:?} must contain {want:?} for source:\n{src}"
        );
    }
}

// -------------------------------------------------------- model shape

/// The two-task ICSML deployment used throughout: a priority-0 PID
/// control task every 100 ms and a priority-1 MLP-flavoured detection
/// task every 300 ms, sharing a sensor image through globals, plus
/// one unbound (freewheeling) logger instance.
const TWO_TASK_SRC: &str = "
VAR_GLOBAL
    g_pv : REAL;
    g_mv : REAL;
    g_alarm : REAL;
    g_log : DINT;
END_VAR
PROGRAM PidCtrl
VAR
    integ : REAL;
    err : REAL;
END_VAR
    err := 0.66 - g_pv;
    integ := integ + err * 0.1;
    g_mv := 2.5 * err + 0.8 * integ;
    g_pv := g_pv + g_mv * 0.01 + 0.003;
END_PROGRAM
PROGRAM MlpDetect
VAR
    x : ARRAY[0..7] OF REAL;
    w : ARRAY[0..7] OF REAL;
    h : REAL;
    i : DINT;
    scans : DINT;
END_VAR
    FOR i := 0 TO 7 DO
        x[i] := g_pv * DINT_TO_REAL(i + 1) * 0.125;
        w[i] := 0.25 - DINT_TO_REAL(i) * 0.03;
    END_FOR
    h := 0.0;
    FOR i := 0 TO 7 DO
        h := h + w[i] * x[i];
    END_FOR
    IF h > 0.5 THEN
        g_alarm := g_alarm + 1.0;
    END_IF
    scans := scans + 1;
END_PROGRAM
PROGRAM Logger
    g_log := g_log + 1;
END_PROGRAM
CONFIGURATION IcsmlPlant
    RESOURCE main ON plc
        TASK t_ctrl(INTERVAL := T#100ms, PRIORITY := 0);
        TASK t_detect(INTERVAL := T#300ms, PRIORITY := 1);
        PROGRAM pCtrl WITH t_ctrl : PidCtrl;
        PROGRAM pDet WITH t_detect : MlpDetect;
        PROGRAM pLog : Logger;
    END_RESOURCE
END_CONFIGURATION
";

#[test]
fn two_task_model_compiles_to_the_expected_shape() {
    let unit = st::compile(TWO_TASK_SRC).expect("compiles");
    let model = unit.tasks.as_ref().expect("has a task model");
    assert_eq!(model.config_name, "IcsmlPlant");
    assert_eq!(model.resource_name, "main");
    assert_eq!(model.processor, "plc");
    // Two declared tasks plus one synthetic freewheeling task for the
    // unbound Logger instance, in that order.
    assert_eq!(model.tasks.len(), 3);
    let ctrl = &model.tasks[model.find_task("T_CTRL").expect("ctrl")];
    assert_eq!(ctrl.priority, 0);
    assert_eq!(ctrl.trigger, Trigger::Cyclic { interval_us: 100_000 });
    assert_eq!(ctrl.programs.len(), 1);
    assert_eq!(ctrl.programs[0].instance, "pCtrl");
    let det = &model.tasks[model.find_task("t_detect").expect("det")];
    assert_eq!(det.priority, 1);
    assert_eq!(det.trigger, Trigger::Cyclic { interval_us: 300_000 });
    let free = &model.tasks[2];
    assert_eq!(free.name, "__free_pLog");
    assert_eq!(free.trigger, Trigger::Freewheeling);
    assert_eq!(free.priority, u32::MAX);
}

// --------------------------------------------- differential invariant

/// Assert bit-identical observable state between two tiers: every
/// global and every field of every program instance.
fn assert_state_eq(a: &st::Host, b: &st::Host, label: &str) {
    for (g, (va, vb)) in
        a.unit.globals.iter().zip(a.globals.iter().zip(&b.globals))
    {
        assert!(
            va.bits_eq(vb),
            "{label}: global {} diverged: {va:?} vs {vb:?}",
            g.name
        );
    }
    for (pid, p) in a.unit.programs.iter().enumerate() {
        let (ia, ib) = (a.program_instances[pid], b.program_instances[pid]);
        for f in &p.fields {
            let va = a.instance_field(ia, &f.name).expect("field");
            let vb = b.instance_field(ib, &f.name).expect("field");
            assert!(
                va.bits_eq(&vb),
                "{label}: {}.{} diverged: {va:?} vs {vb:?}",
                p.name,
                f.name
            );
        }
    }
}

/// The tentpole acceptance criterion: a two-task CONFIGURATION driven
/// by the scheduler produces bit-identical state and *exactly* equal
/// per-task meters on the interpreter oracle and on both bytecode
/// tiers, tick for tick.
#[test]
fn two_task_configuration_is_meter_exact_across_tiers() {
    let unit = st::compile(TWO_TASK_SRC).expect("compiles");
    let profile = HwProfile::beaglebone();

    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new_with(unit.clone(), &FusionConfig::default());
    let mut vm_plain =
        Vm::new_with(unit, &FusionConfig { enabled: false });
    let mut sc_it =
        TaskScheduler::for_runtime(&it, profile.clone()).expect("model");
    let mut sc_vm =
        TaskScheduler::for_runtime(&vm, profile.clone()).expect("model");
    let mut sc_plain =
        TaskScheduler::for_runtime(&vm_plain, profile).expect("model");

    for tick in 0..12 {
        let ra = sc_it.tick(&mut it).expect("interp tick");
        let rb = sc_vm.tick(&mut vm).expect("vm tick");
        let rc = sc_plain.tick(&mut vm_plain).expect("plain vm tick");
        assert_eq!(ra.now_us, rb.now_us, "tick {tick}: clock drift");
        assert_eq!(ra.ran, rb.ran, "tick {tick}: schedule drift");
        assert_eq!(ra.ran, rc.ran, "tick {tick}: plain schedule drift");
        assert_eq!(ra.skipped, rb.skipped, "tick {tick}: skip drift");
        for task in 0..sc_it.model().tasks.len() {
            let name = &sc_it.model().tasks[task].name;
            if let Some((field, iv, vv)) = sc_it
                .task_meter(task)
                .first_divergence(sc_vm.task_meter(task))
            {
                panic!(
                    "tick {tick}, task {name}: fused vm meter diverged \
                     on {field}: interp {iv} vs vm {vv}"
                );
            }
            if let Some((field, iv, vv)) = sc_it
                .task_meter(task)
                .first_divergence(sc_plain.task_meter(task))
            {
                panic!(
                    "tick {tick}, task {name}: plain vm meter diverged \
                     on {field}: interp {iv} vs vm {vv}"
                );
            }
        }
        assert_state_eq(&it, &vm, &format!("tick {tick} (fused)"));
        assert_state_eq(&it, &vm_plain, &format!("tick {tick} (plain)"));
    }

    // Schedule arithmetic: the control task ran every 100 ms tick, the
    // detection task every third one, the logger freewheels each tick
    // — and nothing was ever skipped or overran at these budgets.
    let ctrl = sc_it.model().find_task("t_ctrl").unwrap();
    let det = sc_it.model().find_task("t_detect").unwrap();
    let states = sc_it.states();
    assert_eq!(states[ctrl].activations, 12);
    assert_eq!(states[det].activations, 4);
    assert_eq!(states[2].activations, 12, "freewheeling logger");
    for s in states {
        assert_eq!(s.skipped, 0);
        assert_eq!(s.overruns(), 0);
    }
    assert_eq!(sc_it.now_us(), 1_100_000, "11 releases past t=0");
    match it.global("g_log") {
        Some(Value::Int(n)) => assert_eq!(n, 12),
        other => panic!("g_log: {other:?}"),
    }
}

// ------------------------------------------------------- starvation

/// Build a source where a heavy control task and a detection task
/// share one interval sized *below* the control task's own modeled
/// cost, so every coincident release leaves the low-priority task
/// with zero remaining budget.
fn starved_src() -> String {
    let heavy = "
PROGRAM Heavy
VAR
    acc : REAL;
    i : DINT;
END_VAR
    FOR i := 0 TO 499 DO
        acc := acc + DINT_TO_REAL(i) * 0.001;
    END_FOR
END_PROGRAM
PROGRAM Light
VAR n : DINT; END_VAR
    n := n + 1;
END_PROGRAM
";
    // Price one Heavy scan on the scheduler's profile, then pick an
    // interval no larger than that cost: at every release instant the
    // priority-0 task alone exhausts the whole interval.
    let probe = st::compile(heavy).expect("probe compiles");
    let mut it = Interp::new(probe);
    it.run_program("Heavy").expect("probe scan");
    let cost_us = HwProfile::beaglebone().time_us(&it.meter);
    let n = (cost_us.floor() as u64).max(1);
    format!(
        "{heavy}
CONFIGURATION Starved
    RESOURCE main ON plc
        TASK fast(INTERVAL := T#{n}us, PRIORITY := 0);
        TASK slow(INTERVAL := T#{n}us, PRIORITY := 1);
        PROGRAM pFast WITH fast : Heavy;
        PROGRAM pSlow WITH slow : Light;
    END_RESOURCE
END_CONFIGURATION"
    )
}

/// Deterministic starvation: with the shared interval consumed
/// entirely by the priority-0 task, the low-priority task skips every
/// cycle — visibly, with a counter — while the control task never
/// skips. Identically on both tiers.
#[test]
fn starved_low_priority_task_skips_deterministically() {
    let src = starved_src();
    let unit = st::compile(&src).expect("compiles");
    let profile = HwProfile::beaglebone();
    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new(unit);
    let mut sc_it =
        TaskScheduler::for_runtime(&it, profile.clone()).expect("model");
    let mut sc_vm =
        TaskScheduler::for_runtime(&vm, profile).expect("model");

    let fast = sc_it.model().find_task("fast").unwrap();
    let slow = sc_it.model().find_task("slow").unwrap();
    const TICKS: u64 = 10;
    for _ in 0..TICKS {
        let ra = sc_it.tick(&mut it).expect("interp tick");
        let rb = sc_vm.tick(&mut vm).expect("vm tick");
        assert_eq!(ra.ran, rb.ran);
        assert_eq!(ra.skipped, rb.skipped);
        assert_eq!(ra.ran, vec![fast], "only the control task runs");
        assert_eq!(ra.skipped, vec![slow], "the ML task skips, visibly");
    }
    for sc in [&sc_it, &sc_vm] {
        let states = sc.states();
        assert_eq!(states[fast].activations, TICKS);
        assert_eq!(states[fast].skipped, 0, "priority 0 can never skip");
        assert_eq!(states[slow].activations, 0);
        assert_eq!(states[slow].skipped, TICKS);
    }
    // The starved program never ran: its counter is untouched.
    let inst = it.program_instance("Light").unwrap();
    match it.instance_field(inst, "n") {
        Some(Value::Int(0)) => {}
        other => panic!("Light.n must be 0, got {other:?}"),
    }
}

// ------------------------------------------------- §6.3 yield pattern

/// A long ML inference expressed the §6.3 way: the detection program
/// processes a bounded chunk of rows per activation through a
/// persistent cursor, so each activation fits its interval and the
/// control task keeps every deadline while inference completes across
/// scans.
#[test]
fn ml_task_yields_across_activations_without_starving_control() {
    let src = "
VAR_GLOBAL
    g_done : BOOL;
    g_sum : REAL;
END_VAR
PROGRAM Ctrl
VAR ticks : DINT; END_VAR
    ticks := ticks + 1;
END_PROGRAM
PROGRAM MlChunk
VAR
    row : DINT;
    i : DINT;
END_VAR
    IF NOT g_done THEN
        FOR i := 0 TO 15 DO
            g_sum := g_sum + DINT_TO_REAL(row * 16 + i) * 0.5;
        END_FOR
        row := row + 1;
        IF row >= 8 THEN
            g_done := TRUE;
        END_IF
    END_IF
END_PROGRAM
CONFIGURATION Yielding
    RESOURCE main ON plc
        TASK t_ctrl(INTERVAL := T#100ms, PRIORITY := 0);
        TASK t_ml(INTERVAL := T#100ms, PRIORITY := 2);
        PROGRAM pCtrl WITH t_ctrl : Ctrl;
        PROGRAM pMl WITH t_ml : MlChunk;
    END_RESOURCE
END_CONFIGURATION";
    let unit = st::compile(src).expect("compiles");
    let profile = HwProfile::beaglebone();
    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new(unit);
    let mut sc_it =
        TaskScheduler::for_runtime(&it, profile.clone()).expect("model");
    let mut sc_vm =
        TaskScheduler::for_runtime(&vm, profile).expect("model");

    let ctrl = sc_it.model().find_task("t_ctrl").unwrap();
    let ml = sc_it.model().find_task("t_ml").unwrap();
    let mut ticks = 0u64;
    while !matches!(it.global("g_done"), Some(Value::Bool(true))) {
        sc_it.tick(&mut it).expect("interp tick");
        sc_vm.tick(&mut vm).expect("vm tick");
        ticks += 1;
        assert!(ticks <= 64, "inference never completed");
    }
    // 8 rows at one row per activation: the full job took 8 scans.
    assert_eq!(ticks, 8);
    assert_eq!(sc_it.states()[ml].activations, 8);
    // The control task held every one of those deadlines.
    assert_eq!(sc_it.states()[ctrl].activations, 8);
    assert_eq!(sc_it.states()[ctrl].skipped, 0);
    assert_eq!(sc_it.states()[ctrl].overruns(), 0);
    // And the tiers agree on the final state and per-task meters.
    assert_state_eq(&it, &vm, "after yield-completion");
    for task in [ctrl, ml] {
        assert_eq!(
            sc_it.task_meter(task).first_divergence(sc_vm.task_meter(task)),
            None,
            "task {task} meter drift"
        );
    }
    // Interval slack is what the §6.3 session planner consumes: at
    // these budgets each activation leaves nearly the whole interval.
    assert!(sc_it.interval_budget_us(ml, 10.0) > 99_000.0);
}

// ----------------------------------------------------- SINGLE trigger

/// `SINGLE := flag` tasks release exactly once per rising edge of the
/// trigger global — a held-high flag does not re-fire.
#[test]
fn single_task_fires_on_rising_edges_only() {
    let src = "
VAR_GLOBAL go : BOOL; END_VAR
PROGRAM Tick
VAR n : DINT; END_VAR
    n := n + 1;
END_PROGRAM
PROGRAM Shot
VAR n : DINT; END_VAR
    n := n + 1;
END_PROGRAM
CONFIGURATION OneShot
    RESOURCE main ON plc
        TASK t_cyc(INTERVAL := T#10ms, PRIORITY := 0);
        TASK t_edge(SINGLE := go, PRIORITY := 1);
        PROGRAM pTick WITH t_cyc : Tick;
        PROGRAM pShot WITH t_edge : Shot;
    END_RESOURCE
END_CONFIGURATION";
    let unit = st::compile(src).expect("compiles");
    let mut it = Interp::new(unit);
    let mut sc = TaskScheduler::for_runtime(&it, HwProfile::beaglebone())
        .expect("model");
    let edge = sc.model().find_task("t_edge").unwrap();

    let shot_count = |it: &Interp| -> i64 {
        let inst = it.program_instance("Shot").unwrap();
        match it.instance_field(inst, "n") {
            Some(Value::Int(n)) => n,
            other => panic!("Shot.n: {other:?}"),
        }
    };

    sc.tick(&mut it).expect("tick");
    assert_eq!(shot_count(&it), 0, "no edge yet");
    it.set_global("go", Value::Bool(true));
    sc.tick(&mut it).expect("tick");
    assert_eq!(shot_count(&it), 1, "rising edge fires once");
    sc.tick(&mut it).expect("tick");
    sc.tick(&mut it).expect("tick");
    assert_eq!(shot_count(&it), 1, "held-high trigger must not re-fire");
    it.set_global("go", Value::Bool(false));
    sc.tick(&mut it).expect("tick");
    it.set_global("go", Value::Bool(true));
    sc.tick(&mut it).expect("tick");
    assert_eq!(shot_count(&it), 2, "second rising edge fires again");
    assert_eq!(sc.states()[edge].activations, 2);
}
