//! Meter/semantics edge coverage for the superinstruction tier, plus
//! the snapshot-restore regression: every program runs on **three**
//! configurations — interpreter (oracle), fused VM, plain (fusion-off)
//! VM — and must produce bit-identical state, exactly equal meters,
//! and identical runtime errors on all of them.

use std::sync::Arc;

use icsml::icsml_st;
use icsml::st::{
    self, bytecode, FusionConfig, Host, Interp, RuntimeError, Vm,
};

const ON: FusionConfig = FusionConfig { enabled: true };
const OFF: FusionConfig = FusionConfig { enabled: false };

fn assert_state_eq(it: &Interp, vm: &Vm, prog: &str, ctx: &str) {
    let pid = it.unit.find_program(prog).expect("program exists");
    let inst = it.program_instances[pid];
    assert_eq!(inst, vm.program_instances[pid], "{ctx}: layout diverged");
    for f in &it.unit.programs[pid].fields {
        let a = it.instance_field(inst, &f.name).unwrap();
        let b = vm.instance_field(inst, &f.name).unwrap();
        assert!(
            a.bits_eq(&b),
            "{ctx}: field {}: interp {a:?} vs vm {b:?}",
            f.name
        );
    }
}

fn assert_meters_eq(it: &Interp, vm: &Vm, ctx: &str) {
    if let Some((name, a, b)) = it.meter.first_divergence(&vm.meter) {
        panic!("{ctx}: meter `{name}` diverged: interp {a} vm {b}");
    }
}

/// Run `prog` on all three tiers for `scans` scans. On success every
/// scan is cross-checked; on a runtime error, all three must fail with
/// the same message and line, and the error is returned.
fn run_three(
    unit: &st::ir::Unit,
    prog: &str,
    scans: usize,
) -> Option<RuntimeError> {
    let mut it = Interp::new(unit.clone());
    let mut fused = Vm::new_with(unit.clone(), &ON);
    let mut plain = Vm::new_with(unit.clone(), &OFF);
    assert!(fused.code().fused_ops() >= plain.code().fused_ops());
    assert_eq!(plain.code().fused_ops(), 0, "fusion-off emitted fused ops");
    for scan in 0..scans {
        let a = it.run_program(prog);
        let b = fused.run_program(prog);
        let c = plain.run_program(prog);
        match (a, b, c) {
            (Ok(()), Ok(()), Ok(())) => {
                assert_meters_eq(&it, &fused, &format!("scan {scan} fused"));
                assert_meters_eq(&it, &plain, &format!("scan {scan} plain"));
                assert_state_eq(&it, &fused, prog, &format!("scan {scan} fused"));
                assert_state_eq(&it, &plain, prog, &format!("scan {scan} plain"));
            }
            (Err(e1), Err(e2), Err(e3)) => {
                assert_eq!(e1.message, e2.message, "fused error message");
                assert_eq!(e1.line, e2.line, "fused error line");
                assert_eq!(e1.message, e3.message, "plain error message");
                assert_eq!(e1.line, e3.line, "plain error line");
                return Some(e1);
            }
            (a, b, c) => panic!(
                "scan {scan}: tier disagreement:\n interp {a:?}\n \
                 fused {b:?}\n plain {c:?}"
            ),
        }
    }
    None
}

fn run_three_src(src: &str, prog: &str, scans: usize) -> Option<RuntimeError> {
    run_three(&st::compile(src).expect("compile"), prog, scans)
}

fn run_three_framework(
    app: &str,
    prog: &str,
    scans: usize,
) -> Option<RuntimeError> {
    run_three(
        &icsml_st::compile_with_framework(app).expect("compile"),
        prog,
        scans,
    )
}

// ------------------------------------------------- IntTy wrap boundaries

/// Narrowing conversions at the exact wrap boundaries, inside loops so
/// the values flow through fused FOR machinery where eligible.
#[test]
fn int_wrap_boundaries_fused_vs_unfused() {
    let err = run_three_src(
        "PROGRAM p VAR\n\
           s8 : SINT; u8 : USINT; i16 : INT;\n\
           i, big : DINT;\n\
         END_VAR\n\
         FOR i := 0 TO 6 DO\n\
           big := 125 + i;\n\
           s8 := DINT_TO_SINT(big);\n\
           u8 := DINT_TO_USINT(253 + i);\n\
           i16 := DINT_TO_INT(32765 + i);\n\
         END_FOR\n\
         FOR i := 0 TO 6 DO\n\
           s8 := DINT_TO_SINT(-125 - i);\n\
           u8 := DINT_TO_USINT(3 - i);\n\
           i16 := DINT_TO_INT(-32765 - i);\n\
         END_FOR\n\
         END_PROGRAM",
        "p",
        3,
    );
    assert!(err.is_none(), "wrap program errored: {err:?}");
}

// ------------------------------------------ loop-trip-count edge cases

/// Zero-, single- and negative-step iteration through the *fused* FOR
/// head (DOT_PRODUCT's loop fuses; n controls the trip count).
#[test]
fn zero_single_and_negative_iteration_loops() {
    let err = run_three_framework(
        "PROGRAM p VAR\n\
           a : ARRAY[0..7] OF REAL;\n\
           r0, r1, r2 : REAL; i, j : DINT;\n\
         END_VAR\n\
         FOR i := 0 TO 7 DO a[i] := DINT_TO_REAL(i) * 0.5; END_FOR\n\
         r0 := DOT_PRODUCT(ADR(a), ADR(a), 0);\n\
         r1 := DOT_PRODUCT(ADR(a), ADR(a), 1);\n\
         r2 := DOT_PRODUCT(ADR(a), ADR(a), 8);\n\
         FOR i := 5 TO 0 BY -2 DO j := j + 1; END_FOR\n\
         FOR i := 3 TO 0 DO j := j + 100; END_FOR\n\
         END_PROGRAM",
        "p",
        2,
    );
    assert!(err.is_none(), "loop program errored: {err:?}");
}

/// Out-of-bounds pointer walk through the fused DOT kernel: all three
/// tiers must raise the identical error at the identical line.
#[test]
fn fused_pointer_error_parity() {
    let err = run_three_framework(
        "PROGRAM p VAR\n\
           a : ARRAY[0..7] OF REAL; r : REAL;\n\
         END_VAR\n\
         r := DOT_PRODUCT(ADR(a), ADR(a), 16);\n\
         END_PROGRAM",
        "p",
        1,
    )
    .expect("program must fail");
    assert!(
        err.message.contains("out of bounds"),
        "unexpected error: {}",
        err.message
    );
}

// ------------------------------------------------- pruned FB_Dense path

/// The §6.2 pruned row walk (`IF wv <> 0.0 THEN` skip) with zero-mixed
/// weights — exercises FusedMacLoad (self-field `inputs` operand),
/// FusedIfCmpF32Br and FusedMacStep against both unfused tiers.
#[test]
fn pruned_fb_dense_rows_fused_parity() {
    let err = run_three_framework(
        "PROGRAM p\n\
         VAR\n\
             x : ARRAY[0..3] OF REAL := [0.5, -0.25, 1.0, 2.0];\n\
             w : ARRAY[0..7] OF REAL :=\n\
                 [0.1, 0.0, -0.3, 0.0, 0.0, 0.7, 0.2, 0.0];\n\
             b : ARRAY[0..1] OF REAL := [0.05, -0.1];\n\
             y : ARRAY[0..1] OF REAL;\n\
             dims : ARRAY[0..0] OF UDINT := [4];\n\
             d : FB_Dense;\n\
             ok : BOOL;\n\
         END_VAR\n\
             d.weights := (address := ADR(w), length := 8,\n\
                           dimensions := ADR(dims), dimensions_num := 1);\n\
             d.biases := (address := ADR(b), length := 2,\n\
                          dimensions := ADR(dims), dimensions_num := 1);\n\
             d.inMem := (address := ADR(x), length := 4,\n\
                         dimensions := ADR(dims), dimensions_num := 1);\n\
             d.outMem := (address := ADR(y), length := 2,\n\
                          dimensions := ADR(dims), dimensions_num := 1);\n\
             d.neurons := 2; d.inputs := 4;\n\
             d.act := ACT_NONE;\n\
             d.pruned := TRUE;\n\
             ok := d.eval();\n\
         END_PROGRAM",
        "p",
        2,
    );
    assert!(err.is_none(), "pruned dense errored: {err:?}");
}

// --------------------------------------------- snapshot-restore parity

/// `HostImage` snapshot of a fused VM restored into units compiled
/// with AND without fusion: state adoption must be fusion-invariant —
/// both restored VMs continue in lockstep with the oracle.
#[test]
fn host_image_restore_is_fusion_invariant() {
    let app = "PROGRAM p VAR\n\
           t : DINT; r : REAL;\n\
           a : ARRAY[0..7] OF REAL; i : DINT;\n\
         END_VAR\n\
         t := t + 1;\n\
         FOR i := 0 TO 7 DO\n\
           a[i] := a[i] + DINT_TO_REAL(t) * 0.25;\n\
         END_FOR\n\
         r := r + DOT_PRODUCT(ADR(a), ADR(a), 8);\n\
         END_PROGRAM";
    let unit = icsml_st::compile_with_framework(app).expect("compile");
    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new_with(unit.clone(), &ON);
    for scan in 0..2 {
        it.run_program("p").unwrap();
        vm.run_program("p").unwrap();
        assert_meters_eq(&it, &vm, &format!("pre-snapshot scan {scan}"));
        assert_state_eq(&it, &vm, "p", &format!("pre-snapshot scan {scan}"));
    }

    // Snapshot the fused VM mid-run; adopt the image under both
    // compilation configs.
    let img = vm.image();
    let fused_code = Arc::new(bytecode::compile_unit_with(&unit, &ON));
    let plain_code = Arc::new(bytecode::compile_unit_with(&unit, &OFF));
    let mut r_fused = Vm::with_host(Host::from_image(&img), fused_code);
    let mut r_plain = Vm::with_host(Host::from_image(&img), plain_code);

    for scan in 0..3 {
        it.run_program("p").unwrap();
        r_fused.run_program("p").unwrap();
        r_plain.run_program("p").unwrap();
        let ctx = format!("post-restore scan {scan}");
        // The two restored tiers stay in exact lockstep with each
        // other (meters included — restore is fusion-invariant)...
        if let Some((name, a, b)) =
            r_fused.meter.first_divergence(&r_plain.meter)
        {
            panic!("{ctx}: restored meter `{name}`: fused {a} plain {b}");
        }
        // ...and bit-identical in state to the oracle that never
        // stopped running.
        assert_state_eq(&it, &r_fused, "p", &format!("{ctx} fused"));
        assert_state_eq(&it, &r_plain, "p", &format!("{ctx} plain"));
    }
}
