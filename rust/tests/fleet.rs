//! Closed-loop fleet tests: a small deterministic fleet of mixed
//! scenarios over a loopback `netserve` server. Pins the ISSUE-8
//! acceptance properties at test scale: every request resolves,
//! per-family detection recall clears its floor, defense feedback
//! measurably alters attacked-plant trajectories vs a
//! feedback-disabled control run, and identical seeds (and even
//! transports) produce identical `FleetOutcome`s.

use std::sync::Arc;

use icsml::api::{EngineBackend, SharedBackend};
use icsml::fleet::{
    detector_model, run_fleet, AttackMix, FleetConfig, FleetTarget,
};
use icsml::netserve::{
    Client, ModelRegistry, NetServer, RegistryConfig, RetryPolicy,
    ServerConfig, StaticLoader,
};
use icsml::serve::{PoolConfig, Priority};

fn detector_registry(workers: usize) -> Arc<ModelRegistry> {
    let mut loader = StaticLoader::new();
    let backend: SharedBackend = Arc::new(EngineBackend::new(detector_model()));
    loader.insert("detector", backend, 1);
    Arc::new(ModelRegistry::new(
        Box::new(loader),
        RegistryConfig {
            max_models: usize::MAX,
            max_bytes: u64::MAX,
            pool: PoolConfig {
                workers,
                max_batch: 8,
            },
        },
    ))
}

fn net_target(server: &NetServer) -> FleetTarget {
    let client = Client::connect_with(server.local_addr(), RetryPolicy::new())
        .expect("loopback connect");
    FleetTarget::Net {
        client,
        model: "detector".to_string(),
    }
}

fn small_cfg() -> FleetConfig {
    FleetConfig {
        plants: 16,
        steps: 2_000,
        seed: 42,
        mix: AttackMix::uniform(),
        ..FleetConfig::default()
    }
}

#[test]
fn fleet_over_loopback_netserve_resolves_and_detects() {
    let server =
        NetServer::bind("127.0.0.1:0", detector_registry(4), ServerConfig::default())
            .expect("bind loopback");
    let cfg = small_cfg();
    let report = run_fleet(&cfg, net_target(&server));

    // Every request resolved: logits or typed error — and with no
    // deadlines attached, a healthy loopback serves everything.
    assert_eq!(report.outcome.unresolved(), 0);
    let total = report.outcome.total();
    assert!(total.submitted > 0);
    assert_eq!(
        total.served, total.submitted,
        "healthy loopback must serve everything: {total:?}"
    );
    assert!(report.outcome.class(Priority::Control).served > 0);
    assert!(
        report.outcome.class(Priority::Batch).served > 0,
        "sweeps must ride along"
    );
    // Attack waves produce Defense-class confirmation traffic.
    assert!(report.outcome.class(Priority::Defense).submitted > 0);

    // Recall floor per attacked family (uniform mix over 16 plants
    // gives each family 2-3 plants).
    assert!(!report.outcome.families.is_empty());
    for fam in &report.outcome.families {
        assert!(fam.plants > 0);
        assert!(
            fam.recall() >= 0.5,
            "family {} recall {:.2} ({} of {} plants)",
            fam.family.name(),
            fam.recall(),
            fam.detected,
            fam.plants
        );
    }
    // The detector bands sit ~100σ above benign noise.
    assert_eq!(report.outcome.false_positives, 0);
    // Feedback actually engaged somewhere.
    assert!(report.outcome.clamps > 0);

    server.shutdown();
}

#[test]
fn identical_seeds_give_identical_outcomes_across_transports() {
    let server =
        NetServer::bind("127.0.0.1:0", detector_registry(3), ServerConfig::default())
            .expect("bind loopback");
    let cfg = FleetConfig {
        plants: 12,
        steps: 1_500,
        seed: 7,
        ..FleetConfig::default()
    };

    let net_a = run_fleet(&cfg, net_target(&server));
    let net_b = run_fleet(&cfg, net_target(&server));
    assert_eq!(
        net_a.outcome, net_b.outcome,
        "identical seeds must replay identically over the network"
    );

    // The deterministic half is transport-independent too: the same
    // config through in-process pools gives the same outcome.
    let pooled = run_fleet(&cfg, FleetTarget::pools(2, 2, 8));
    assert_eq!(
        net_a.outcome, pooled.outcome,
        "outcome must not depend on the transport"
    );

    // A different seed must not collide.
    let other = run_fleet(
        &FleetConfig {
            seed: 8,
            ..cfg.clone()
        },
        FleetTarget::pools(2, 2, 8),
    );
    assert_ne!(net_a.outcome.trajectory_digest, other.outcome.trajectory_digest);

    server.shutdown();
}

#[test]
fn feedback_alters_attacked_plant_trajectories() {
    // Actuator-heavy mix so the defense ladder (clamp → lockout) has
    // physical effect; identical seeds with feedback on vs off.
    let mix = AttackMix::parse("actuator=3,ramp=1").expect("mix");
    let base = FleetConfig {
        plants: 8,
        steps: 2_500,
        seed: 21,
        mix,
        ..FleetConfig::default()
    };
    let with_feedback = run_fleet(&base, FleetTarget::pools(2, 2, 8));
    let control = run_fleet(
        &FleetConfig {
            feedback: false,
            ..base.clone()
        },
        FleetTarget::pools(2, 2, 8),
    );

    // Same seeds, same scenarios — the only difference is the defense
    // responses, and they must show up in the physics.
    assert!(with_feedback.outcome.clamps > 0, "ladder must engage");
    assert!(with_feedback.outcome.lockouts > 0, "ladder must reach rung 2");
    assert_eq!(control.outcome.clamps, 0);
    assert_eq!(control.outcome.lockouts, 0);
    assert_ne!(
        with_feedback.outcome.trajectory_digest, control.outcome.trajectory_digest,
        "feedback must change plant trajectories"
    );
    assert!(
        with_feedback.outcome.mean_true_wd_dev
            < control.outcome.mean_true_wd_dev,
        "defense must reduce physical damage: {} (feedback) vs {} (control)",
        with_feedback.outcome.mean_true_wd_dev,
        control.outcome.mean_true_wd_dev
    );
    assert_eq!(with_feedback.outcome.unresolved(), 0);
    assert_eq!(control.outcome.unresolved(), 0);
}
