//! End-to-end tests for the network front door: wire-protocol
//! robustness (hostile bytes never panic or wedge the reactor),
//! typed errors over the wire, and the headline scale property —
//! 1000+ concurrent in-flight requests across multiple registered
//! models served by O(workers) threads.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use icsml::api::{
    Backend, EngineBackend, InferenceError, Session as _, SharedBackend,
};
use icsml::netserve::proto::{
    self, Decoded, ErrorCode, Frame, RequestFrame, DEFAULT_MAX_FRAME,
};
use icsml::netserve::{
    Client, ModelRegistry, NetOptions, NetServer, RegistryConfig,
    ServerConfig, StaticLoader,
};
use icsml::serve::{PoolConfig, Priority};
use icsml::util::fixtures;

/// Two distinct fixture models (8 inputs, 4 outputs, different
/// weights) behind a registry with the given pool size.
fn two_model_registry(workers: usize) -> Arc<ModelRegistry> {
    let mut loader = StaticLoader::new();
    let alpha: SharedBackend =
        Arc::new(EngineBackend::new(fixtures::mlp_8_16_4(1)));
    let beta: SharedBackend =
        Arc::new(EngineBackend::new(fixtures::mlp_8_16_4(2)));
    loader.insert("alpha", alpha, 1);
    loader.insert("beta", beta, 1);
    Arc::new(ModelRegistry::new(
        Box::new(loader),
        RegistryConfig {
            max_models: usize::MAX,
            max_bytes: u64::MAX,
            pool: PoolConfig { workers, max_batch: 8 },
        },
    ))
}

fn spawn_server(workers: usize) -> NetServer {
    NetServer::bind(
        "127.0.0.1:0",
        two_model_registry(workers),
        ServerConfig::default(),
    )
    .expect("bind loopback")
}

/// What the engine itself says for `x` — the reference the network
/// path must match bit-for-bit.
fn reference(seed: u64, x: &[f32]) -> Vec<f32> {
    EngineBackend::new(fixtures::mlp_8_16_4(seed))
        .session()
        .unwrap()
        .infer(x)
        .unwrap()
}

/// Read frames off a raw socket until one decodes (or EOF).
fn read_one_frame(stream: &mut TcpStream) -> Option<Frame> {
    let mut acc = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        match proto::decode(&acc, DEFAULT_MAX_FRAME) {
            Decoded::Frame(f, _) => return Some(f),
            Decoded::Corrupt(msg) => panic!("server sent garbage: {msg}"),
            Decoded::Incomplete => {}
        }
        match stream.read(&mut buf) {
            Ok(0) => return None,
            Ok(n) => acc.extend_from_slice(&buf[..n]),
            Err(e) => panic!("read: {e}"),
        }
    }
}

#[test]
fn network_path_is_bit_identical_to_the_engine() {
    let server = spawn_server(2);
    let mut c = Client::connect(server.local_addr()).unwrap();
    let x: Vec<f32> = (0..8).map(|i| 0.25 * i as f32 - 1.0).collect();
    let y = c.infer("alpha", &x, &NetOptions::new()).unwrap();
    assert_eq!(y, reference(1, &x), "alpha over TCP == alpha in process");
    let y = c.infer("beta", &x, &NetOptions::new()).unwrap();
    assert_eq!(y, reference(2, &x), "beta over TCP == beta in process");
    server.shutdown();
}

#[test]
fn model_not_found_is_an_error_frame_not_a_dropped_connection() {
    let server = spawn_server(1);
    let mut c = Client::connect(server.local_addr()).unwrap();
    match c.infer("ghost", &[0.0; 8], &NetOptions::new()) {
        Err(InferenceError::ModelNotFound { model }) => {
            assert_eq!(model, "ghost");
        }
        other => panic!("expected ModelNotFound, got {other:?}"),
    }
    // The connection survived the typed failure.
    let y = c.infer("alpha", &[0.0; 8], &NetOptions::new()).unwrap();
    assert_eq!(y.len(), 4);
}

#[test]
fn shape_mismatch_travels_as_a_typed_error_frame() {
    let server = spawn_server(1);
    let mut c = Client::connect(server.local_addr()).unwrap();
    match c.infer("alpha", &[0.0; 3], &NetOptions::new()) {
        Err(InferenceError::ShapeMismatch { expected, got, .. }) => {
            assert_eq!((expected, got), (8, 3));
        }
        other => panic!("expected ShapeMismatch, got {other:?}"),
    }
    let _ = server;
}

#[test]
fn expired_deadline_is_shed_with_a_typed_error() {
    let server = spawn_server(1);
    let mut c = Client::connect(server.local_addr()).unwrap();
    let opts = NetOptions::new()
        .priority(Priority::Defense)
        .deadline_us(0.0);
    match c.infer("alpha", &[0.0; 8], &opts) {
        Err(InferenceError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    // Shed, not wedged: an undeadlined request still succeeds.
    let y = c.infer("alpha", &[0.0; 8], &NetOptions::new()).unwrap();
    assert_eq!(y.len(), 4);
}

#[test]
fn truncated_frame_and_disconnect_do_not_wedge_the_reactor() {
    let server = spawn_server(1);
    {
        // A valid frame, cut mid-body, then a hard disconnect.
        let mut wire = Vec::new();
        Frame::Request(RequestFrame {
            id: 1,
            priority: Priority::Batch,
            deadline_us: None,
            model: "alpha".into(),
            payload: vec![0.0; 8],
        })
        .encode(&mut wire);
        let mut raw = TcpStream::connect(server.local_addr()).unwrap();
        raw.write_all(&wire[..wire.len() / 2]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
    } // dropped here, mid-frame
    {
        // A complete request whose sender vanishes before the reply.
        let mut wire = Vec::new();
        Frame::Request(RequestFrame {
            id: 2,
            priority: Priority::Batch,
            deadline_us: None,
            model: "alpha".into(),
            payload: vec![0.0; 8],
        })
        .encode(&mut wire);
        let mut raw = TcpStream::connect(server.local_addr()).unwrap();
        raw.write_all(&wire).unwrap();
    } // dropped with the reply still in flight
    // The reactor must still serve fresh connections.
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.set_timeout(Some(Duration::from_secs(10))).unwrap();
    let y = c.infer("alpha", &[0.5; 8], &NetOptions::new()).unwrap();
    assert_eq!(y, reference(1, &[0.5; 8]));
    server.shutdown();
}

#[test]
fn oversized_length_prefix_gets_protocol_error_then_close() {
    let server = spawn_server(1);
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    raw.write_all(&(u32::MAX).to_le_bytes()).unwrap();
    match read_one_frame(&mut raw) {
        Some(Frame::Error(e)) => {
            assert_eq!(e.code, ErrorCode::Protocol);
            assert!(e.msg.contains("exceeds"), "msg: {}", e.msg);
        }
        other => panic!("expected protocol error frame, got {other:?}"),
    }
    // After the error frame the server closes the connection.
    let mut rest = Vec::new();
    raw.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    // And keeps serving everyone else.
    let mut c = Client::connect(server.local_addr()).unwrap();
    assert!(c.infer("beta", &[0.0; 8], &NetOptions::new()).is_ok());
}

#[test]
fn unknown_version_gets_protocol_error() {
    let server = spawn_server(1);
    let mut wire = Vec::new();
    Frame::Request(RequestFrame {
        id: 5,
        priority: Priority::Batch,
        deadline_us: None,
        model: "alpha".into(),
        payload: vec![0.0; 8],
    })
    .encode(&mut wire);
    wire[6] = 99; // version byte
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    raw.write_all(&wire).unwrap();
    match read_one_frame(&mut raw) {
        Some(Frame::Error(e)) => {
            assert_eq!(e.code, ErrorCode::Protocol);
            assert!(e.msg.contains("version"), "msg: {}", e.msg);
        }
        other => panic!("expected protocol error frame, got {other:?}"),
    }
    let _ = server;
}

/// The acceptance headline: >= 1000 requests in flight at once,
/// spread across two registered models and mixed priority classes,
/// all answered correctly by a fixed thread budget (1 reactor +
/// 2 models x 2 workers), with zero sheds.
#[test]
fn sustains_a_thousand_concurrent_inflight_requests() {
    let server = spawn_server(2);
    let addr = server.local_addr();
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 300;

    let handles: Vec<_> = (0..CLIENTS)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.set_timeout(Some(Duration::from_secs(60))).unwrap();
                let model = if t % 2 == 0 { "alpha" } else { "beta" };
                let seed = if t % 2 == 0 { 1 } else { 2 };
                let class = match t % 3 {
                    0 => Priority::Control,
                    1 => Priority::Defense,
                    _ => Priority::Batch,
                };
                let opts = NetOptions::new().priority(class);
                let x: Vec<f32> =
                    (0..8).map(|i| (t + i) as f32 * 0.125).collect();
                let want = reference(seed, &x);
                // Pipeline the whole wave before draining a single
                // reply: every request is simultaneously in flight.
                for _ in 0..PER_CLIENT {
                    c.submit(model, &x, &opts).unwrap();
                }
                for _ in 0..PER_CLIENT {
                    let reply = c.recv().unwrap();
                    let y = reply.result.unwrap_or_else(|e| {
                        panic!("request {} failed: {}", reply.id, e.msg)
                    });
                    assert_eq!(y, want, "replies stay bit-identical");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(server.stats().requests(), total);
    assert_eq!(server.stats().responses(), total);
    assert_eq!(server.stats().error_frames(), 0, "zero sheds or errors");
    server.shutdown();
}
