//! Concurrency contract of the Engine/Session split (run in CI with
//! `--release`):
//!
//! * N threads × M sessions over **one shared backend** produce
//!   bit-identical outputs to sequential `infer_into` — engine and ST;
//! * router statistics stay consistent under contention (every request
//!   accounted for exactly once);
//! * the `serve::Pool` answers pipelined traffic bit-identically to a
//!   single sequential session;
//! * the deadline scheduler's semantics hold: an expired request is
//!   shed (never served), an urgent request is never delayed behind
//!   batch-class traffic, no-deadline traffic keeps exact FIFO order,
//!   and `Ticket::wait` never hangs on a dead pool;
//! * the shared handles really are `Send + Sync` (compile-time
//!   assertions).

use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use icsml::api::{
    Backend, EngineBackend, InferenceError, ModelSpec, Session,
    SharedBackend, StBackend,
};
use icsml::coordinator::{InferenceRouter, RoutePolicy};
use icsml::serve::{Deadline, Pool, PoolConfig, Priority, SubmitOptions};
use icsml::util::fixtures::{mlp_8_16_4, ported_mlp_8_16_4};

const THREADS: usize = 4;
const SESSIONS_PER_THREAD: usize = 2;

/// Deterministic input corpus: `count` vectors of length `dim`.
fn corpus(dim: usize, count: usize) -> Vec<Vec<f32>> {
    (0..count)
        .map(|i| {
            (0..dim)
                .map(|k| ((i * dim + k) as f32 * 0.0937).sin() * 1.3)
                .collect()
        })
        .collect()
}

/// Serve the whole corpus through one fresh session, returning the
/// logits as bit patterns.
fn serve_corpus(
    backend: &dyn Backend,
    inputs: &[Vec<f32>],
) -> Vec<Vec<u32>> {
    let mut session = backend.session().expect("session");
    let out_dim = session.spec().out_dim;
    let mut out = vec![0.0f32; out_dim];
    inputs
        .iter()
        .map(|x| {
            session.infer_into(x, &mut out).expect("infer");
            out.iter().map(|v| v.to_bits()).collect()
        })
        .collect()
}

/// The acceptance property: ≥ THREADS threads, each running
/// SESSIONS_PER_THREAD sessions over the same shared backend, all
/// bit-identical to the sequential reference.
fn assert_concurrent_bit_identical(backend: SharedBackend, in_dim: usize) {
    let inputs = Arc::new(corpus(in_dim, 24));
    let want = Arc::new(serve_corpus(backend.as_ref(), &inputs));

    thread::scope(|scope| {
        for t in 0..THREADS {
            let backend = Arc::clone(&backend);
            let inputs = Arc::clone(&inputs);
            let want = Arc::clone(&want);
            scope.spawn(move || {
                // Sessions are minted inside the thread (they are
                // intentionally not Send); interleave M of them so the
                // test also exercises session independence.
                let mut sessions: Vec<Box<dyn Session>> = (0
                    ..SESSIONS_PER_THREAD)
                    .map(|_| backend.session().expect("session"))
                    .collect();
                for (i, x) in inputs.iter().enumerate() {
                    for (si, s) in sessions.iter_mut().enumerate() {
                        let got = s.infer(x).expect("infer");
                        let bits: Vec<u32> =
                            got.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(
                            bits, want[i],
                            "thread {t} session {si} input {i}: \
                             concurrent result diverged from sequential"
                        );
                    }
                }
            });
        }
    });
}

#[test]
fn engine_concurrent_sessions_bit_identical_to_sequential() {
    let backend: SharedBackend = Arc::new(EngineBackend::new(mlp_8_16_4(91)));
    assert_concurrent_bit_identical(backend, 8);
}

#[test]
fn st_concurrent_sessions_bit_identical_to_sequential() {
    // The ported ICSML program: shared compiled bytecode + state
    // image; every session replays the BINARR weight loading from the
    // fixture dir on its first scan (concurrent reads of the same
    // files).
    let (st, _) = ported_mlp_8_16_4(91, "concurrency");
    let backend: SharedBackend = Arc::new(st);
    assert_concurrent_bit_identical(backend, 8);
}

#[test]
fn mixed_single_shot_and_partial_sessions_do_not_interfere() {
    // One thread drives a suspended §6.3 partial inference while
    // others hammer single-shot traffic on the same backend.
    let backend: SharedBackend = Arc::new(EngineBackend::new(mlp_8_16_4(17)));
    let x_partial: Vec<f32> =
        (0..8).map(|k| (k as f32 * 0.31).cos()).collect();
    let want_partial = backend.session().unwrap().infer(&x_partial).unwrap();
    let inputs = corpus(8, 16);
    let want = serve_corpus(backend.as_ref(), &inputs);

    thread::scope(|scope| {
        {
            let backend = Arc::clone(&backend);
            let x_partial = x_partial.clone();
            let want_partial = want_partial.clone();
            scope.spawn(move || {
                let mut s = backend.session().unwrap();
                let p = s.partial().expect("engine resumes");
                p.begin(&x_partial).unwrap();
                // Step one row at a time, yielding between steps so
                // the single-shot threads interleave heavily.
                while !p.finished() {
                    p.step(1).unwrap();
                    thread::yield_now();
                }
                let mut out = vec![0.0f32; want_partial.len()];
                p.finish(&mut out).unwrap();
                assert_eq!(out, want_partial, "suspended partial corrupted");
            });
        }
        for _ in 0..3 {
            let backend = Arc::clone(&backend);
            let inputs = inputs.clone();
            let want = want.clone();
            scope.spawn(move || {
                let mut s = backend.session().unwrap();
                for (i, x) in inputs.iter().enumerate() {
                    let got: Vec<u32> = s
                        .infer(x)
                        .unwrap()
                        .iter()
                        .map(|v| v.to_bits())
                        .collect();
                    assert_eq!(got, want[i]);
                }
            });
        }
    });
}

#[test]
fn router_stats_consistent_under_contention() {
    let mut router = InferenceRouter::new(RoutePolicy::FastestObserved);
    router.register("a", Arc::new(EngineBackend::new(mlp_8_16_4(5))));
    router.register("b", Arc::new(EngineBackend::new(mlp_8_16_4(5))));
    let router = Arc::new(router);

    const REQS_PER_THREAD: usize = 50;
    let x: Vec<f32> = (0..8).map(|k| (k as f32 * 0.21).sin()).collect();
    let want = router.session().infer(&x).unwrap().1;

    thread::scope(|scope| {
        for _ in 0..THREADS {
            let router = Arc::clone(&router);
            let x = x.clone();
            let want = want.clone();
            scope.spawn(move || {
                let mut sess = router.session();
                for _ in 0..REQS_PER_THREAD {
                    let (_, out) = sess.infer(&x).expect("routed");
                    assert_eq!(out, want);
                }
            });
        }
    });

    // Every request (including the warmup one above) is recorded
    // exactly once, across whichever backends ranking chose.
    let total: u64 = ["a", "b"]
        .iter()
        .map(|n| router.stats(n).unwrap().requests)
        .sum();
    assert_eq!(total, (THREADS * REQS_PER_THREAD) as u64 + 1);
    for n in ["a", "b"] {
        let s = router.stats(n).unwrap();
        assert_eq!(s.errors, 0, "backend {n} saw spurious errors");
    }
}

#[test]
fn pool_pipelined_traffic_is_bit_identical() {
    let backend: SharedBackend = Arc::new(EngineBackend::new(mlp_8_16_4(29)));
    let inputs = corpus(8, 64);
    let want = serve_corpus(backend.as_ref(), &inputs);

    let pool = Pool::new(
        Arc::clone(&backend),
        PoolConfig { workers: THREADS, max_batch: 5 },
    );
    let tickets: Vec<_> = inputs.iter().map(|x| pool.submit(x)).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let got: Vec<u32> =
            t.wait().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want[i], "pooled request {i} diverged");
    }
    assert_eq!(pool.served(), inputs.len() as u64);
    assert_eq!(pool.errors(), 0);
}

// ---------------------------------------------------------------------
// Deadline-scheduler semantics (PR 4)
// ---------------------------------------------------------------------

/// A backend whose sessions log the id tag (`x[0]`) of every request
/// they serve, optionally sleeping per request — the probe for
/// service-order and shed assertions.
struct RecordingBackend {
    inner: EngineBackend,
    log: Arc<Mutex<Vec<u32>>>,
    delay: Duration,
}

impl RecordingBackend {
    fn shared(
        log: Arc<Mutex<Vec<u32>>>,
        delay: Duration,
    ) -> SharedBackend {
        Arc::new(RecordingBackend {
            inner: EngineBackend::new(mlp_8_16_4(7)),
            log,
            delay,
        })
    }
}

impl Backend for RecordingBackend {
    fn name(&self) -> &'static str {
        "recording"
    }
    fn spec(&self) -> ModelSpec {
        self.inner.spec()
    }
    fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
        Ok(Box::new(RecordingSession {
            inner: self.inner.session()?,
            log: Arc::clone(&self.log),
            delay: self.delay,
        }))
    }
}

struct RecordingSession {
    inner: Box<dyn Session>,
    log: Arc<Mutex<Vec<u32>>>,
    delay: Duration,
}

impl Session for RecordingSession {
    fn name(&self) -> &'static str {
        "recording"
    }
    fn spec(&self) -> ModelSpec {
        self.inner.spec()
    }
    fn infer_into(
        &mut self,
        x: &[f32],
        out: &mut [f32],
    ) -> Result<(), InferenceError> {
        self.log.lock().unwrap().push(x[0] as u32);
        if !self.delay.is_zero() {
            thread::sleep(self.delay);
        }
        self.inner.infer_into(x, out)
    }
}

/// A valid 8-dim input carrying `id` in its first feature.
fn tagged(id: u32) -> Vec<f32> {
    let mut v = vec![0.25f32; 8];
    v[0] = id as f32;
    v
}

#[test]
fn expired_request_is_shed_never_served() {
    let log = Arc::new(Mutex::new(Vec::new()));
    let backend = RecordingBackend::shared(Arc::clone(&log), Duration::ZERO);
    let pool = Pool::new(backend, PoolConfig { workers: 2, max_batch: 4 });
    let r = pool
        .submit_with(
            &tagged(99),
            SubmitOptions::new().deadline(Deadline::within_us(0.0)),
        )
        .unwrap()
        .wait();
    match r {
        Err(InferenceError::DeadlineExceeded { stage: "queue", .. }) => {}
        other => panic!("want queue shed, got {other:?}"),
    }
    assert_eq!(pool.shed(), 1);
    // The backend never executed the shed request.
    assert!(
        !log.lock().unwrap().contains(&99),
        "an expired request must never reach the model"
    );
    // Healthy traffic is unaffected.
    assert_eq!(pool.infer(&tagged(1)).unwrap().len(), 4);
    assert!(log.lock().unwrap().contains(&1));
}

#[test]
fn no_deadline_traffic_stays_fifo_on_one_worker() {
    let log = Arc::new(Mutex::new(Vec::new()));
    let backend = RecordingBackend::shared(Arc::clone(&log), Duration::ZERO);
    let pool = Pool::new(backend, PoolConfig { workers: 1, max_batch: 4 });
    let tickets: Vec<_> =
        (0..24u32).map(|i| pool.submit(&tagged(i))).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(pool.shed(), 0, "no-deadline load must never shed");
    // With one worker and no deadlines the scheduler degenerates to
    // the old pool's exact FIFO service order (bit-identity of the
    // *results* is covered by pool_pipelined_traffic_is_bit_identical).
    let served = log.lock().unwrap().clone();
    assert_eq!(served, (0..24).collect::<Vec<u32>>());
}

#[test]
fn urgent_request_is_not_delayed_behind_batch_class() {
    let log = Arc::new(Mutex::new(Vec::new()));
    let delay = Duration::from_millis(150);
    let backend = RecordingBackend::shared(Arc::clone(&log), delay);
    let pool = Pool::new(backend, PoolConfig { workers: 1, max_batch: 4 });

    // Occupy the single worker, and wait until it has *started* (its
    // session logs before sleeping) so everything below queues.
    let filler = pool.submit(&tagged(0));
    let t0 = Instant::now();
    while log.lock().unwrap().is_empty() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "worker never started the filler request"
        );
        thread::yield_now();
    }

    // Six batch-class requests pile up, then one control-class
    // request arrives last.
    let batch_tickets: Vec<_> =
        (1..=6u32).map(|i| pool.submit(&tagged(i))).collect();
    let urgent = pool
        .submit_with(
            &tagged(7),
            SubmitOptions::new().priority(Priority::Control),
        )
        .unwrap();

    urgent.wait().unwrap();
    filler.wait().unwrap();
    for t in batch_tickets {
        t.wait().unwrap();
    }

    let served = log.lock().unwrap().clone();
    let pos = |id: u32| {
        served
            .iter()
            .position(|&v| v == id)
            .unwrap_or_else(|| panic!("request {id} never served"))
    };
    assert_eq!(pos(0), 0, "filler was being served first");
    for id in 1..=6u32 {
        assert!(
            pos(7) < pos(id),
            "control-class request served after batch-class {id} \
             (order: {served:?})"
        );
    }
}

/// A backend whose sessions panic on the first inference — the
/// worker-death scenario for the `Ticket::wait`-never-hangs fix.
struct PanickingBackend;
impl Backend for PanickingBackend {
    fn name(&self) -> &'static str {
        "panicking"
    }
    fn spec(&self) -> ModelSpec {
        ModelSpec::dense_f32(2, 2)
    }
    fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
        Ok(Box::new(PanickingSession))
    }
}
struct PanickingSession;
impl Session for PanickingSession {
    fn name(&self) -> &'static str {
        "panicking"
    }
    fn spec(&self) -> ModelSpec {
        ModelSpec::dense_f32(2, 2)
    }
    fn infer_into(
        &mut self,
        _x: &[f32],
        _out: &mut [f32],
    ) -> Result<(), InferenceError> {
        panic!("synthetic worker death");
    }
}

#[test]
fn ticket_wait_errors_instead_of_hanging_when_all_workers_exit() {
    let pool = Pool::new(
        Arc::new(PanickingBackend),
        PoolConfig { workers: 1, max_batch: 2 },
    );
    // Three pipelined requests; the lone worker dies serving the
    // first. Every ticket must resolve to a typed error — before the
    // fix, requests still queued when the last worker exited blocked
    // `wait` forever.
    let tickets = [
        pool.submit(&[0.0, 0.0]),
        pool.submit(&[0.0, 0.0]),
        pool.submit(&[0.0, 0.0]),
    ];
    for t in tickets {
        assert!(t.wait().is_err(), "dead pool must fail, not hang");
    }
    // And the dead pool keeps failing fast.
    assert!(pool.infer(&[0.0, 0.0]).is_err());
}

#[test]
fn ticket_timed_out_wait_does_not_lose_the_result() {
    // One worker, each request pinned under a 200 ms service time:
    // early probes *must* time out, and the eventual result must
    // still arrive on a later probe of the same ticket.
    let log = Arc::new(Mutex::new(Vec::new()));
    let backend =
        RecordingBackend::shared(Arc::clone(&log), Duration::from_millis(200));
    let pool = Pool::new(backend, PoolConfig { workers: 1, max_batch: 1 });
    let mut ticket = pool.submit(&tagged(42));

    // The request needs 200 ms of service; these probes land well
    // inside that window.
    assert!(ticket.try_wait().is_none(), "instant probe must miss");
    assert!(
        ticket.wait_timeout(Duration::from_millis(20)).is_none(),
        "a 20 ms probe of a 200 ms request must time out"
    );

    // Keep probing with short timeouts: the timed-out waits above
    // must not have consumed or dropped the eventual result.
    let t0 = Instant::now();
    let result = loop {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "result lost after a timed-out wait"
        );
        if let Some(r) = ticket.wait_timeout(Duration::from_millis(50)) {
            break r;
        }
    };
    assert_eq!(result.unwrap().len(), 4);
    assert!(log.lock().unwrap().contains(&42));

    // Once resolved (and the pool torn down), further probes report
    // the worker-side channel as gone rather than blocking or
    // panicking.
    drop(pool);
    assert!(ticket.try_wait().is_some());
}

#[test]
fn shared_handles_are_send_sync() {
    fn assert_send_sync<T: Send + Sync>() {}
    fn assert_send<T: Send>() {}
    assert_send_sync::<EngineBackend>();
    assert_send_sync::<StBackend>();
    assert_send_sync::<InferenceRouter>();
    assert_send_sync::<Pool>();
    assert_send_sync::<icsml::serve::Admission>();
    assert_send_sync::<icsml::serve::DeadlineQueue<Vec<f32>>>();
    assert_send_sync::<icsml::st::HostImage>();
    assert_send_sync::<icsml::st::ir::Unit>();
    assert_send_sync::<icsml::st::bytecode::CodeUnit>();
    assert_send_sync::<icsml::netserve::ModelRegistry>();
    assert_send_sync::<icsml::netserve::ServerStats>();
    assert_send_sync::<icsml::netserve::NetServer>();
    // A Ticket crosses threads (reactor completes what a pool worker
    // resolves) but is single-consumer, so Send without Sync.
    assert_send::<icsml::serve::Ticket>();
}
