//! Bytecode invariant property tests over the compiled corpus
//! (framework.st, a generated ICSML MLP, and inline programs), with
//! fusion on and off:
//!
//! * every jump target lands on an instruction boundary of the final
//!   (post-fusion, post-remap) stream;
//! * the constant pool is duplicate-free after dedup, every
//!   `ConstPool` index is in bounds, and no immediate `Const*` op
//!   survives pooling — while fusion-off leaves pools empty;
//! * the disassembly round-trips (`parse(render(op))` recovers the
//!   generic form of every op).

use std::collections::HashSet;

use icsml::icsml_st;
use icsml::porting::{codegen::CodegenOptions, generate_st_program};
use icsml::st::bytecode::{compile_unit_with, Code, Konst, Op};
use icsml::st::disasm::{disasm_code, op_to_generic, parse_line, render};
use icsml::st::{self, FusionConfig};
use icsml::util::benchkit;

const ON: FusionConfig = FusionConfig { enabled: true };
const OFF: FusionConfig = FusionConfig { enabled: false };

/// The compiled corpus the properties sweep: the whole ICSML framework
/// with a trivial app, a generated dense-MLP port, and an inline
/// control-flow zoo.
fn corpus() -> Vec<(String, st::ir::Unit)> {
    let mut units = Vec::new();
    units.push((
        "framework_trivial".to_string(),
        icsml_st::compile_with_framework(
            "PROGRAM p VAR x : REAL; END_VAR x := 1.0; END_PROGRAM",
        )
        .expect("framework compiles"),
    ));
    let (spec, _dir) = benchkit::random_spec(
        "bytecode_props_mlp",
        &[4, 6, 2],
        &["relu", "linear"],
        99,
    );
    let src = generate_st_program(
        &spec,
        &CodegenOptions { program: "MAIN".into(), fused_activations: true },
    );
    units.push((
        "generated_mlp".to_string(),
        icsml_st::compile_with_framework(&src).expect("MLP compiles"),
    ));
    units.push((
        "control_flow_zoo".to_string(),
        st::compile(
            "FUNCTION SUMSQ : REAL\n\
             VAR_INPUT pa : POINTER TO REAL; n : DINT; END_VAR\n\
             VAR s : REAL; i : DINT; END_VAR\n\
             FOR i := 0 TO n - 1 DO s := s + pa[i] * pa[i]; END_FOR\n\
             SUMSQ := s;\n\
             END_FUNCTION\n\
             PROGRAM p VAR\n\
               a : ARRAY[0..7] OF REAL; r : REAL; i, c, n : DINT;\n\
             END_VAR\n\
             FOR i := 0 TO 7 DO a[i] := DINT_TO_REAL(i) * 0.5; END_FOR\n\
             r := SUMSQ(ADR(a), 8) + SUMSQ(ADR(a), 8);\n\
             n := 5;\n\
             WHILE n > 0 DO c := c + n; n := n - 1; END_WHILE\n\
             REPEAT c := c + 1; UNTIL c >= 20 END_REPEAT\n\
             CASE c OF 0..9: r := 1.0; 20: r := 2.0; ELSE r := 0.0;\n\
             END_CASE\n\
             END_PROGRAM",
        )
        .expect("zoo compiles"),
    ));
    units
}

/// All pc operands an op can transfer control to.
fn jump_targets(op: &Op) -> Vec<u32> {
    match op {
        Op::Jump { t }
        | Op::JumpIfFalse { t, .. }
        | Op::CaseJump { t, .. }
        | Op::FusedForIncrJump { t, .. }
        | Op::FusedIfCmpF32Br { t, .. } => vec![*t],
        Op::ForCheck { exit, .. } | Op::FusedForHead { exit, .. } => {
            vec![*exit]
        }
        _ => Vec::new(),
    }
}

fn konst_key(k: &Konst) -> (u8, u64, String) {
    match k {
        Konst::Int(v) => (0, *v as u64, String::new()),
        Konst::F32(v) => (1, v.to_bits() as u64, String::new()),
        Konst::F64(v) => (2, v.to_bits(), String::new()),
        Konst::Str(s) => (3, 0, s.to_string()),
    }
}

fn for_each_code(f: &mut dyn FnMut(&str, bool, &Code)) {
    for (name, unit) in corpus() {
        for (cfg, fused) in [(ON, true), (OFF, false)] {
            let cu = compile_unit_with(&unit, &cfg);
            for code in cu.all_codes() {
                f(&name, fused, code);
            }
        }
    }
}

#[test]
fn jump_targets_land_on_instruction_boundaries() {
    for_each_code(&mut |unit, fused, code| {
        let len = code.ops.len() as u32;
        for (pc, op) in code.ops.iter().enumerate() {
            for t in jump_targets(op) {
                assert!(
                    t < len,
                    "{unit} fused={fused} {}: pc {pc} jumps to {t} \
                     outside [0, {len})",
                    code.name
                );
            }
        }
    });
}

#[test]
fn constant_pool_is_deduplicated_and_in_bounds() {
    for_each_code(&mut |unit, fused, code| {
        let mut seen = HashSet::new();
        for k in &code.pool {
            assert!(
                seen.insert(konst_key(k)),
                "{unit} fused={fused} {}: duplicate pool entry {k:?}",
                code.name
            );
        }
        for (pc, op) in code.ops.iter().enumerate() {
            match op {
                Op::ConstPool { idx, .. } => {
                    assert!(
                        (*idx as usize) < code.pool.len(),
                        "{unit} {}: pc {pc} pool index {idx} out of \
                         bounds ({})",
                        code.name,
                        code.pool.len()
                    );
                    assert!(fused, "{unit} {}: ConstPool with fusion off",
                        code.name);
                }
                // Pooling replaces every immediate literal load.
                Op::ConstInt { .. }
                | Op::ConstF32 { .. }
                | Op::ConstF64 { .. }
                | Op::ConstStr { .. } => assert!(
                    !fused,
                    "{unit} {}: pc {pc} immediate {op:?} survived pooling",
                    code.name
                ),
                _ => {}
            }
        }
        if !fused {
            assert!(
                code.pool.is_empty(),
                "{unit} {}: fusion off but pool populated",
                code.name
            );
            assert_eq!(
                code.ops.iter().filter(|o| o.is_fused()).count(),
                0,
                "{unit} {}: fusion off but fused ops present",
                code.name
            );
        }
    });
}

#[test]
fn disassembly_round_trips_over_the_corpus() {
    let mut seen = 0usize;
    for_each_code(&mut |unit, fused, code| {
        for op in &code.ops {
            let g = op_to_generic(op);
            let line = render(&g);
            let back = parse_line(&line).unwrap_or_else(|e| {
                panic!("{unit} fused={fused} {}: parse `{line}`: {e}",
                    code.name)
            });
            assert_eq!(
                back, g,
                "{unit} fused={fused} {}: `{line}` did not round-trip",
                code.name
            );
            seen += 1;
        }
        // The full listing stays line-per-entry: header + pool + ops.
        let listing = disasm_code(code);
        assert_eq!(
            listing.lines().count(),
            1 + code.pool.len() + code.ops.len(),
            "{unit} {}: listing shape",
            code.name
        );
    });
    assert!(seen > 1000, "corpus unexpectedly small: {seen} ops");
}

/// The corpus genuinely exercises the fused tier: the framework's
/// DOT_PRODUCT / FB_Dense kernels must fuse, and coalescing must not
/// leave any frame narrower than its IR slots.
#[test]
fn corpus_contains_fused_kernels() {
    let mut fused_total = 0usize;
    for (name, unit) in corpus() {
        let cu = compile_unit_with(&unit, &ON);
        fused_total += cu.fused_ops();
        assert!(
            cu.fused_ops() > 0,
            "{name}: no superinstructions emitted"
        );
    }
    assert!(fused_total > 10, "only {fused_total} fused ops in corpus");
}
