//! Contract tests for the `icsml::api` inference API (post
//! Engine/Session split):
//!
//! * the engine session hot path (`Session::infer_into`) performs
//!   **zero heap allocations** per call (counting global allocator);
//! * `infer_batch` equals N sequential `infer_into` calls on every
//!   backend (engine, ST interpreter, and XLA when artifacts exist);
//! * the router survives failing backends (policy fallback through a
//!   per-caller `RouterSession`).
//!
//! The N-threads × M-sessions bit-identity properties live in
//! `tests/concurrency.rs`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

use icsml::api::{
    Backend, EngineBackend, InferenceError, ModelSpec, Session,
};
use icsml::coordinator::{InferenceRouter, RoutePolicy};
use icsml::util::binio;
use icsml::util::fixtures::{mlp_8_16_4, ported_mlp_8_16_4};
use icsml::util::prop::{prop_assert, prop_check};

// ---------------------------------------------------------------------
// Counting allocator: per-thread allocation counter so parallel test
// threads don't pollute each other's counts. The thread-local is
// const-initialized and `Cell<u64>` has no destructor, so reading it
// inside the allocator cannot itself allocate or recurse.
// ---------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocations_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------
// Zero-allocation hot path
// ---------------------------------------------------------------------

#[test]
fn engine_session_infer_into_is_allocation_free() {
    let b = EngineBackend::new(mlp_8_16_4(42));
    // Session creation allocates (buffers are minted here, exactly so
    // the per-call path doesn't have to).
    let mut s = b.session().unwrap();
    let x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.9).cos()).collect();
    let mut out = [0.0f32; 4];

    // Warm up: first calls may touch lazily-grown internal scratch.
    for _ in 0..3 {
        s.infer_into(&x, &mut out).unwrap();
    }

    let before = allocations_on_this_thread();
    for _ in 0..1000 {
        s.infer_into(&x, &mut out).unwrap();
    }
    let delta = allocations_on_this_thread() - before;
    assert_eq!(
        delta, 0,
        "engine session infer_into allocated {delta} times over 1000 calls"
    );
}

#[test]
fn engine_session_batch_is_allocation_free() {
    let b = EngineBackend::new(mlp_8_16_4(43));
    let mut s = b.session().unwrap();
    let xs: Vec<f32> = (0..8 * 32).map(|i| (i as f32 * 0.13).sin()).collect();
    let mut out = vec![0.0f32; 4 * 32];
    for _ in 0..3 {
        s.infer_batch(&xs, &mut out).unwrap();
    }
    let before = allocations_on_this_thread();
    for _ in 0..100 {
        s.infer_batch(&xs, &mut out).unwrap();
    }
    assert_eq!(allocations_on_this_thread() - before, 0);
}

// ---------------------------------------------------------------------
// infer_batch == N x infer_into
// ---------------------------------------------------------------------

fn batch_matches_sequential(s: &mut dyn Session, tol: f32) {
    let ModelSpec { in_dim, out_dim, .. } = s.spec();
    prop_check(15, |g| {
        let n = g.usize_in(1..=5);
        let xs: Vec<f32> =
            (0..n * in_dim).map(|_| g.f32_in(-1.5, 1.5)).collect();
        let mut batched = vec![0.0f32; n * out_dim];
        let served = s.infer_batch(&xs, &mut batched).unwrap();
        prop_assert(served == n, format!("served {served} != {n}"))?;
        for i in 0..n {
            let mut one = vec![0.0f32; out_dim];
            s.infer_into(&xs[i * in_dim..(i + 1) * in_dim], &mut one)
                .unwrap();
            for k in 0..out_dim {
                let (a, c) = (batched[i * out_dim + k], one[k]);
                prop_assert(
                    (a - c).abs() <= tol,
                    format!("row {i} logit {k}: batch {a} vs sequential {c}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn engine_batch_matches_sequential() {
    let b = EngineBackend::new(mlp_8_16_4(7));
    let mut s = b.session().unwrap();
    batch_matches_sequential(s.as_mut(), 0.0);
}

#[test]
fn st_batch_matches_sequential() {
    let (b, _) = ported_mlp_8_16_4(7, "batch");
    let mut s = b.session().unwrap();
    batch_matches_sequential(s.as_mut(), 0.0);
}

#[test]
fn st_and_engine_agree_through_the_api() {
    let (st, reference) = ported_mlp_8_16_4(11, "agree");
    let eng = EngineBackend::new(reference);
    let mut st_s = st.session().unwrap();
    let mut eng_s = eng.session().unwrap();
    prop_check(10, |g| {
        let x: Vec<f32> = (0..8).map(|_| g.f32_in(-1.0, 1.0)).collect();
        let a = st_s.infer(&x).unwrap();
        let b = eng_s.infer(&x).unwrap();
        let dev = a
            .iter()
            .zip(&b)
            .map(|(p, q)| (p - q).abs())
            .fold(0.0f32, f32::max);
        prop_assert(dev < 1e-5, format!("st {a:?} vs engine {b:?}"))
    });
}

/// XLA leg of the batch property — runs only when AOT artifacts exist
/// (`make artifacts`), mirroring `runtime_integration.rs`.
#[test]
fn xla_batch_matches_sequential_when_artifacts_exist() {
    let root = icsml::artifacts_dir();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts built (run `make artifacts`)");
        return;
    }
    use icsml::porting::Manifest;
    use icsml::runtime::{Runtime, XlaBackend};
    let m = Manifest::load(&root).unwrap();
    let spec = m.model("classifier").unwrap();
    let (in_dim, out_dim) = (spec.in_dim(), spec.out_dim());
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&m.hlo_path("classifier_b1").unwrap()).unwrap();
    let xla = XlaBackend::new(exe, in_dim, out_dim);
    let mut s = xla.session().unwrap();

    let x = binio::read_f32(&m.dataset_path("eval_windows").unwrap()).unwrap();
    let n = 4usize;
    let mut batched = vec![0.0f32; n * out_dim];
    assert_eq!(s.infer_batch(&x[..n * in_dim], &mut batched).unwrap(), n);
    for i in 0..n {
        let mut one = vec![0.0f32; out_dim];
        s.infer_into(&x[i * in_dim..(i + 1) * in_dim], &mut one)
            .unwrap();
        assert_eq!(&batched[i * out_dim..(i + 1) * out_dim], &one[..]);
    }
}

// ---------------------------------------------------------------------
// Router resilience
// ---------------------------------------------------------------------

struct AlwaysFails;
impl Backend for AlwaysFails {
    fn name(&self) -> &'static str {
        "always-fails"
    }
    fn spec(&self) -> ModelSpec {
        ModelSpec::dense_f32(8, 4)
    }
    fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
        Ok(Box::new(AlwaysFailsSession))
    }
}
struct AlwaysFailsSession;
impl Session for AlwaysFailsSession {
    fn name(&self) -> &'static str {
        "always-fails"
    }
    fn spec(&self) -> ModelSpec {
        ModelSpec::dense_f32(8, 4)
    }
    fn infer_into(
        &mut self,
        _x: &[f32],
        _out: &mut [f32],
    ) -> Result<(), InferenceError> {
        Err(InferenceError::ExecutionFailed {
            backend: "always-fails".into(),
            source: anyhow::anyhow!("synthetic runtime fault"),
        })
    }
}

#[test]
fn router_serves_every_request_despite_failing_backend() {
    let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
    r.register("bad", Arc::new(AlwaysFails));
    r.register("engine", Arc::new(EngineBackend::new(mlp_8_16_4(3))));
    let mut sess = r.session();
    let x = [0.2f32; 8];
    for i in 0..20 {
        let (name, out) = sess.infer(&x).unwrap_or_else(|e| {
            panic!("request {i} failed despite healthy fallback: {e}")
        });
        assert_eq!(name, "engine");
        assert_eq!(out.len(), 4);
    }
    let bad = r.stats("bad").unwrap();
    let good = r.stats("engine").unwrap();
    assert_eq!(good.requests, 20);
    assert!(bad.errors >= 1, "failing backend was explored and penalized");
    assert!(
        bad.score_us() > good.score_us(),
        "error penalty must demote the failing backend"
    );
}
