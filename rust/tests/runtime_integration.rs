//! PJRT runtime integration: the AOT bridge end-to-end.
//!
//! Requires artifacts (`make artifacts`, or the fast-mode build).
//! Verifies the critical property of the interchange: HLO **text**
//! round-trips the embedded trained weights exactly (the classifier's
//! logits must match the Python-exported expected logits), and all
//! three Rust backends (ST interpreter, native engine, XLA) agree.

use icsml::api::{Backend, EngineBackend, Session as _, StBackend};
use icsml::porting::{self, codegen::CodegenOptions, Manifest};
use icsml::runtime::{Runtime, XlaBackend};
use icsml::util::binio;
use icsml::{artifacts_dir, icsml_st};

fn manifest_or_skip() -> Option<Manifest> {
    let root = artifacts_dir();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(&root).unwrap())
}

#[test]
fn smoke_hlo_round_trip() {
    let Some(m) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&m.hlo_path("smoke").unwrap()).unwrap();
    let x = [1f32, 2.0, 3.0, 4.0];
    let y = [1f32, 1.0, 1.0, 1.0];
    let out = exe.run_f32x2((&x, &[2, 2]), (&y, &[2, 2])).unwrap();
    assert_eq!(out, vec![5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn classifier_hlo_matches_python_logits() {
    let Some(m) = manifest_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&m.hlo_path("classifier_b1").unwrap()).unwrap();

    let ds = &m.dataset;
    let n = ds.expect("eval_n").as_usize().unwrap().min(64);
    let x = binio::read_f32(&m.dataset_path("eval_windows").unwrap()).unwrap();
    let z = binio::read_f32(&m.dataset_path("eval_logits").unwrap()).unwrap();

    for i in 0..n {
        let xi = &x[i * 400..(i + 1) * 400];
        let out = exe.run_f32(xi, &[1, 400]).unwrap();
        for k in 0..2 {
            let want = z[i * 2 + k];
            let got = out[k];
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                "sample {i} logit {k}: xla {got} vs python {want} \
                 (constants lost in the text round-trip?)"
            );
        }
    }
}

#[test]
fn three_backends_agree_on_the_classifier() {
    let Some(m) = manifest_or_skip() else { return };
    let spec = m.model("classifier").unwrap();
    let (in_dim, out_dim) = (spec.in_dim(), spec.out_dim());

    // Engine backend from exported weights.
    let engine = porting::load_engine_model(&m.root, spec).unwrap();
    let mut eng = EngineBackend::new(engine).session().unwrap();

    // ST backend from generated ICSML code.
    let st_src = porting::generate_st_program(spec, &CodegenOptions::default());
    let mut it = icsml_st::load(&st_src).unwrap();
    it.io_dir = m.root.join(&spec.weights_dir);
    let mut st = StBackend::new(it, "MAIN").unwrap().session().unwrap();

    // XLA backend from the AOT artifact (dims from the manifest).
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&m.hlo_path("classifier_b1").unwrap()).unwrap();
    let mut xla =
        XlaBackend::new(exe, in_dim, out_dim).session().unwrap();

    let x = binio::read_f32(&m.dataset_path("eval_windows").unwrap()).unwrap();

    for i in 0..8 {
        let xi = &x[i * in_dim..(i + 1) * in_dim];
        let a = eng.infer(xi).unwrap();
        let b = st.infer(xi).unwrap();
        let c = xla.infer(xi).unwrap();
        for k in 0..out_dim {
            assert!(
                (a[k] - b[k]).abs() < 1e-3,
                "sample {i}: engine {} vs st {}",
                a[k],
                b[k]
            );
            assert!(
                (a[k] - c[k]).abs() < 1e-3,
                "sample {i}: engine {} vs xla {}",
                a[k],
                c[k]
            );
        }
    }
}

#[test]
fn engine_accuracy_matches_training_report() {
    let Some(m) = manifest_or_skip() else { return };
    let spec = m.model("classifier").unwrap();
    let mut engine = porting::load_engine_model(&m.root, spec).unwrap();

    let ds = &m.dataset;
    let n = ds.expect("eval_n").as_usize().unwrap();
    let x = binio::read_f32(&m.dataset_path("eval_windows").unwrap()).unwrap();
    let y = binio::read_i32(&m.dataset_path("eval_labels").unwrap()).unwrap();

    let mut correct = 0usize;
    for i in 0..n {
        let out = engine.infer(&x[i * 400..(i + 1) * 400]);
        let pred = if out[1] > out[0] { 1 } else { 0 };
        if pred == y[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    let reported = spec
        .report
        .expect("test_accuracy")
        .as_f64()
        .unwrap();
    eprintln!("engine eval accuracy {acc:.4}, training report {reported:.4}");
    assert!(
        (acc - reported).abs() < 0.08,
        "ported accuracy {acc} deviates from training report {reported}"
    );
}
