//! Differential harness: the bytecode VM versus the tree-walking
//! interpreter (the reference oracle) over the end-to-end ST corpus and
//! the ICSML MLP models.
//!
//! The contract under test (ISSUE 2 acceptance): for every program,
//! both tiers produce **bit-identical** program state / outputs and
//! **exactly equal** `Meter` counters after every scan — the PLC timing
//! model consumes those counters, so VM speed must not change a single
//! modeled microsecond. Programs that fail at runtime must fail on both
//! tiers with the same message and line.

use icsml::icsml_st;
use icsml::porting::{codegen::CodegenOptions, generate_st_program};
use icsml::st::{self, Interp, Value, Vm};
use icsml::util::benchkit;

/// Run `prog` for `scans` scans on both tiers and assert meters and the
/// full program field state agree bit-for-bit after every scan.
fn diff_unit(unit: st::ir::Unit, prog: &str, scans: usize) -> (Interp, Vm) {
    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new(unit);
    for scan in 0..scans {
        it.run_program(prog).expect("interp scan");
        vm.run_program(prog).expect("vm scan");
        assert_eq!(
            it.meter, vm.meter,
            "meter divergence after scan {scan} of {prog}"
        );
        assert_program_state_eq(&it, &vm, prog);
    }
    (it, vm)
}

fn diff_src(src: &str, prog: &str, scans: usize) {
    let unit = st::compile(src).expect("compile");
    diff_unit(unit, prog, scans);
}

fn diff_framework_src(app: &str, prog: &str, scans: usize) {
    let unit = icsml_st::compile_with_framework(app).expect("compile");
    diff_unit(unit, prog, scans);
}

fn assert_program_state_eq(it: &Interp, vm: &Vm, prog: &str) {
    let pid = it.unit.find_program(prog).expect("program exists");
    let inst = it.program_instances[pid];
    assert_eq!(inst, vm.program_instances[pid], "instance layout diverged");
    for f in &it.unit.programs[pid].fields {
        let a = it.instance_field(inst, &f.name).unwrap();
        let b = vm.instance_field(inst, &f.name).unwrap();
        assert!(
            a.bits_eq(&b),
            "program {prog} field {}: interp {a:?} vs vm {b:?}",
            f.name
        );
    }
}

// ---------------------------------------------------------------- corpus

#[test]
fn arithmetic_and_precedence() {
    diff_src(
        "PROGRAM p VAR x : REAL; i : DINT; END_VAR\n\
         x := 2.0 + 3.0 * 4.0 - 1.0 / 2.0;\n\
         i := 17 MOD 5 + 2 * 3;\n\
         END_PROGRAM",
        "p",
        2,
    );
}

#[test]
fn loop_zoo() {
    diff_src(
        "PROGRAM p VAR s, j, c, r, n : DINT; i : DINT; END_VAR\n\
         s := 0; j := 0; c := 0; r := 0;\n\
         FOR i := 1 TO 100 DO\n\
           s := s + i;\n\
           IF i = 10 THEN EXIT; END_IF\n\
         END_FOR\n\
         FOR i := 10 TO 0 BY -2 DO j := j + 1; END_FOR\n\
         FOR i := 0 TO 9 DO\n\
           IF i MOD 2 = 0 THEN CONTINUE; END_IF\n\
           c := c + 1;\n\
         END_FOR\n\
         n := 5;\n\
         WHILE n > 0 DO r := r + n; n := n - 1; END_WHILE\n\
         REPEAT c := c + 1; UNTIL c >= 9 END_REPEAT\n\
         CASE r OF\n\
           0..9: r := -1;\n\
           15: r := 100;\n\
           ELSE r := -2;\n\
         END_CASE\n\
         END_PROGRAM",
        "p",
        3,
    );
}

#[test]
fn function_calls_and_copy_semantics() {
    diff_src(
        "FUNCTION first : REAL\n\
         VAR_INPUT a : ARRAY[0..255] OF REAL; END_VAR\n\
         a[0] := 42.0;\n\
         first := a[0];\n\
         END_FUNCTION\n\
         FUNCTION fill : BOOL\n\
         VAR_IN_OUT a : ARRAY[0..3] OF REAL; END_VAR\n\
         VAR i : DINT; END_VAR\n\
         FOR i := 0 TO 3 DO a[i] := INT_TO_REAL(DINT_TO_INT(i)) * 2.0; END_FOR\n\
         fill := TRUE;\n\
         END_FUNCTION\n\
         PROGRAM p VAR\n\
           arr : ARRAY[0..255] OF REAL;\n\
           small : ARRAY[0..3] OF REAL;\n\
           x, y, z : REAL; ok : BOOL;\n\
         END_VAR\n\
         arr[0] := 7.0;\n\
         x := first(arr);\n\
         y := arr[0];\n\
         ok := fill(small);\n\
         z := small[3];\n\
         END_PROGRAM",
        "p",
        2,
    );
}

#[test]
fn pointers_adr_and_pointer_stores() {
    diff_src(
        "PROGRAM p VAR\n\
           a : ARRAY[0..9] OF REAL;\n\
           pr : POINTER TO REAL;\n\
           x, y : REAL; i : DINT;\n\
         END_VAR\n\
         FOR i := 0 TO 9 DO a[i] := 0.5 * DINT_TO_REAL(i); END_FOR\n\
         pr := ADR(a);\n\
         x := pr^ + pr[4];\n\
         pr := ADR(a[5]);\n\
         y := pr[2];\n\
         pr[2] := 99.0;\n\
         END_PROGRAM",
        "p",
        2,
    );
}

#[test]
fn structs_literals_and_copies() {
    diff_src(
        "TYPE point : STRUCT x : REAL; y : REAL; tag : DINT; END_STRUCT END_TYPE\n\
         PROGRAM p VAR\n\
           a : point := (x := 1.0, y := 2.0);\n\
           b : point;\n\
           r : REAL;\n\
         END_VAR\n\
         b := a;\n\
         b.y := 10.0;\n\
         a := (x := r, y := b.y, tag := 3);\n\
         r := a.y + b.y + a.x;\n\
         END_PROGRAM",
        "p",
        2,
    );
}

#[test]
fn fb_methods_invocation_and_interfaces() {
    diff_src(
        "INTERFACE IOp\n\
           METHOD apply : REAL VAR_INPUT x : REAL; END_VAR END_METHOD\n\
         END_INTERFACE\n\
         FUNCTION_BLOCK FB_Twice IMPLEMENTS IOp\n\
         METHOD apply : REAL VAR_INPUT x : REAL; END_VAR\n\
           apply := 2.0 * x;\n\
         END_METHOD\n\
         END_FUNCTION_BLOCK\n\
         FUNCTION_BLOCK FB_Square IMPLEMENTS IOp\n\
         METHOD apply : REAL VAR_INPUT x : REAL; END_VAR\n\
           apply := x * x;\n\
         END_METHOD\n\
         END_FUNCTION_BLOCK\n\
         FUNCTION_BLOCK FB_Ctr\n\
         VAR_INPUT inc : DINT; END_VAR\n\
         VAR_OUTPUT out : DINT; END_VAR\n\
         VAR count : DINT; END_VAR\n\
         count := count + inc;\n\
         out := count;\n\
         END_FUNCTION_BLOCK\n\
         PROGRAM p VAR\n\
           t : FB_Twice; s : FB_Square;\n\
           ops : ARRAY[0..1] OF IOp;\n\
           c : FB_Ctr; got : DINT;\n\
           i : DINT; r : REAL; op : IOp;\n\
         END_VAR\n\
         ops[0] := t; ops[1] := s;\n\
         FOR i := 0 TO 1 DO\n\
           op := ops[i];\n\
           r := r + op.apply(3.0);\n\
         END_FOR\n\
         c(inc := 5);\n\
         c(inc := 7, out => got);\n\
         END_PROGRAM",
        "p",
        3,
    );
}

#[test]
fn multidim_arrays_and_conversions() {
    diff_src(
        "PROGRAM p VAR\n\
           m : ARRAY[0..2, 0..3] OF REAL;\n\
           s : SINT; u : USINT; big : DINT;\n\
           x : REAL; i, j : DINT; t : DINT;\n\
         END_VAR\n\
         FOR i := 0 TO 2 DO\n\
           FOR j := 0 TO 3 DO\n\
             m[i, j] := DINT_TO_REAL(i) * 10.0 + DINT_TO_REAL(j);\n\
           END_FOR\n\
         END_FOR\n\
         x := m[2, 1];\n\
         big := 300;\n\
         s := DINT_TO_SINT(big);\n\
         u := DINT_TO_USINT(big);\n\
         t := TRUNC(3.9) + FLOOR(-2.1) + REAL_TO_DINT(2.5);\n\
         END_PROGRAM",
        "p",
        2,
    );
}

#[test]
fn builtin_math_and_globals() {
    let src = "VAR_GLOBAL g : REAL; END_VAR\n\
         PROGRAM writer g := g + 5.5; END_PROGRAM\n\
         PROGRAM reader VAR x, a, b, c, d : REAL; END_VAR\n\
         x := g * 2.0;\n\
         a := SQRT(16.0) + EXP(0.0) + LN(1.0);\n\
         b := MAX(1.5, MIN(9.0, 3.25));\n\
         c := LIMIT(0.0, -5.0, 1.0);\n\
         d := ABS(-3.5) + SIN(0.0) + COS(0.0) + ATAN(1.0);\n\
         END_PROGRAM";
    let unit = st::compile(src).expect("compile");
    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new(unit);
    for _ in 0..2 {
        it.run_program("writer").unwrap();
        vm.run_program("writer").unwrap();
        it.run_program("reader").unwrap();
        vm.run_program("reader").unwrap();
    }
    assert_eq!(it.meter, vm.meter);
    for (g, (a, b)) in
        it.unit.globals.iter().zip(it.globals.iter().zip(&vm.globals))
    {
        assert!(a.bits_eq(b), "global {}: {a:?} vs {b:?}", g.name);
    }
    assert_program_state_eq(&it, &vm, "reader");
}

#[test]
fn binarr_arrbin_file_io() {
    let dir = std::env::temp_dir().join("icsml_st_diff_io");
    std::fs::create_dir_all(&dir).unwrap();
    let src = "PROGRAM p VAR\n\
           a : ARRAY[0..7] OF REAL;\n\
           b : ARRAY[0..7] OF REAL;\n\
           i : DINT; ok : BOOL; s : REAL;\n\
         END_VAR\n\
         FOR i := 0 TO 7 DO a[i] := DINT_TO_REAL(i) * 1.5; END_FOR\n\
         ok := ARRBIN('diff_roundtrip.bin', 8 * SIZEOF(REAL), ADR(a));\n\
         ok := BINARR('diff_roundtrip.bin', 8 * SIZEOF(REAL), ADR(b));\n\
         FOR i := 0 TO 7 DO s := s + b[i]; END_FOR\n\
         END_PROGRAM";
    let unit = st::compile(src).unwrap();
    let mut it = Interp::new(unit.clone()).with_io_dir(&dir);
    let mut vm = Vm::new(unit).with_io_dir(&dir);
    it.run_program("p").unwrap();
    vm.run_program("p").unwrap();
    assert_eq!(it.meter, vm.meter);
    assert_program_state_eq(&it, &vm, "p");
}

#[test]
fn function_results_match_via_host_call() {
    let src = "FUNCTION poly : REAL\n\
         VAR_INPUT x : REAL; END_VAR\n\
         poly := x * x * 0.5 - 3.0 * x + 1.0;\n\
         END_FUNCTION\n\
         PROGRAM p END_PROGRAM";
    let unit = st::compile(src).unwrap();
    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new(unit);
    for k in 0..8 {
        let x = Value::Real(k as f32 * 0.37 - 1.0);
        let a = it.call_function("poly", vec![x.clone()]).unwrap();
        let b = vm.call_function("poly", vec![x]).unwrap();
        assert!(a.bits_eq(&b), "poly({k}): {a:?} vs {b:?}");
    }
    assert_eq!(it.meter, vm.meter);
}

// ----------------------------------------------------- error-path parity

#[test]
fn runtime_errors_agree() {
    let cases = [
        (
            "PROGRAM p VAR a : ARRAY[0..3] OF REAL; i : DINT; x : REAL; END_VAR\n\
             i := 7;\n\
             x := a[i];\n\
             END_PROGRAM",
            "out of bounds",
        ),
        (
            "INTERFACE IOp METHOD go : BOOL END_METHOD END_INTERFACE\n\
             FUNCTION_BLOCK FB_A IMPLEMENTS IOp\n\
             METHOD go : BOOL go := TRUE; END_METHOD\n\
             END_FUNCTION_BLOCK\n\
             PROGRAM p VAR op : IOp; ok : BOOL; END_VAR\n\
             ok := op.go();\n\
             END_PROGRAM",
            "not bound",
        ),
        (
            "PROGRAM p VAR i, j : DINT; END_VAR\n\
             j := 0;\n\
             i := 10 / j;\n\
             END_PROGRAM",
            "division by zero",
        ),
        (
            "PROGRAM p VAR i, s : DINT; n : DINT; END_VAR\n\
             n := 0;\n\
             FOR i := 0 TO 5 BY n DO s := s + 1; END_FOR\n\
             END_PROGRAM",
            "FOR step of 0",
        ),
    ];
    for (src, needle) in cases {
        let unit = st::compile(src).expect("compile");
        let ie = Interp::new(unit.clone()).run_program("p").unwrap_err();
        let ve = Vm::new(unit).run_program("p").unwrap_err();
        assert!(
            ie.message.contains(needle),
            "oracle error {:?} missing {needle:?}",
            ie.message
        );
        assert_eq!(ie.message, ve.message, "error message diverged");
        assert_eq!(ie.line, ve.line, "error line diverged");
    }
}

// -------------------------------------------------- ICSML MLP models

/// The paper-table configuration: a dense MLP ported to ICSML ST with
/// weights on disk, run through both tiers across several scans and
/// inputs. Outputs must agree to the bit, meters exactly.
fn diff_mlp(fused: bool, seed: u64) {
    let name = format!("diff_mlp_{fused}_{seed}");
    let (spec, dir) =
        benchkit::random_spec(&name, &[8, 16, 4], &["relu", "linear"], seed);
    let src = generate_st_program(
        &spec,
        &CodegenOptions { program: "MAIN".into(), fused_activations: fused },
    );
    let unit = icsml_st::compile_with_framework(&src).expect("MLP compiles");
    let mut it = Interp::new(unit.clone()).with_io_dir(&dir);
    let mut vm = Vm::new(unit).with_io_dir(&dir);
    // Init scan (BINARR weight loading + layer wiring).
    it.run_program("MAIN").unwrap();
    vm.run_program("MAIN").unwrap();
    assert_eq!(it.meter, vm.meter, "init scan meters");

    let inst = it.program_instance("MAIN").unwrap();
    for trial in 0..5 {
        let x: Vec<f32> =
            (0..8).map(|i| ((i + 8 * trial) as f32 * 0.61).sin()).collect();
        benchkit::st_set_inputs(&mut it, &x);
        benchkit::vm_set_inputs(&mut vm, &x);
        it.run_program("MAIN").unwrap();
        vm.run_program("MAIN").unwrap();
        assert_eq!(it.meter, vm.meter, "inference meters, trial {trial}");
        let a = it.instance_field(inst, "outputs").unwrap();
        let b = vm.instance_field(inst, "outputs").unwrap();
        assert!(a.bits_eq(&b), "outputs diverged: {a:?} vs {b:?}");
        assert_program_state_eq(&it, &vm, "MAIN");
    }
}

#[test]
fn icsml_mlp_fused_activations_bit_identical() {
    diff_mlp(true, 1311);
}

#[test]
fn icsml_mlp_separate_activations_bit_identical() {
    diff_mlp(false, 2718);
}

/// The framework's quantized path (§6.1) through both tiers.
#[test]
fn icsml_quant_dense_bit_identical() {
    let app = "
PROGRAM p
VAR
    x : ARRAY[0..2] OF REAL := [0.5, -0.25, 1.0];
    b : ARRAY[0..1] OF REAL := [0.1, -0.2];
    yq : ARRAY[0..1] OF REAL;
    wq : ARRAY[0..5] OF SINT := [12, 25, -38, 6, -63, 31];
    xq : ARRAY[0..2] OF DINT;
    sw : ARRAY[0..1] OF REAL := [0.002, 0.004];
    dims : ARRAY[0..0] OF UDINT := [2];
    qd : FB_QuantDenseS;
    ok : BOOL;
END_VAR
    qd.wq := ADR(wq); qd.xq := ADR(xq);
    qd.scales := (address := ADR(sw), length := 2,
                  dimensions := ADR(dims), dimensions_num := 1);
    qd.biases := (address := ADR(b), length := 2,
                  dimensions := ADR(dims), dimensions_num := 1);
    qd.inMem := (address := ADR(x), length := 3,
                 dimensions := ADR(dims), dimensions_num := 1);
    qd.outMem := (address := ADR(yq), length := 2,
                  dimensions := ADR(dims), dimensions_num := 1);
    qd.s_x := 0.01;
    qd.neurons := 2; qd.inputs := 3;
    ok := qd.eval();
END_PROGRAM";
    diff_framework_src(app, "p", 2);
}

/// Softmax + concat layers exercise EXP, pointer loops and dataMem
/// copies through the whole FB_Model machinery.
#[test]
fn icsml_softmax_and_concat_bit_identical() {
    let app = "
PROGRAM p
VAR
    xa : ARRAY[0..1] OF REAL := [1.0, 2.0];
    xb : ARRAY[0..2] OF REAL := [3.0, 4.0, 5.0];
    cat_out : ARRAY[0..4] OF REAL;
    sm_out : ARRAY[0..4] OF REAL;
    dims : ARRAY[0..0] OF UDINT := [5];
    cat : FB_Concat;
    sm : FB_Activation;
    model : FB_Model;
    ok : BOOL;
END_VAR
    cat.inA := (address := ADR(xa), length := 2,
                dimensions := ADR(dims), dimensions_num := 1);
    cat.inB := (address := ADR(xb), length := 3,
                dimensions := ADR(dims), dimensions_num := 1);
    cat.outMem := (address := ADR(cat_out), length := 5,
                   dimensions := ADR(dims), dimensions_num := 1);
    sm.inMem := cat.outMem;
    sm.outMem := (address := ADR(sm_out), length := 5,
                  dimensions := ADR(dims), dimensions_num := 1);
    sm.act := ACT_SOFTMAX;
    ok := model.addLayer(cat);
    ok := model.addLayer(sm);
    ok := model.infer();
END_PROGRAM";
    diff_framework_src(app, "p", 2);
}
