//! Chaos suite: the whole stack under injected faults.
//!
//! A seeded [`FaultPlan`] drives panics, typed errors, latency spikes
//! and shape lies through registry-backed models behind a live
//! network server, and the tests assert the robustness contract end
//! to end: every request resolves (zero hangs), each fault's blast
//! radius is exactly one ticket, non-faulted replies stay
//! bit-identical to a clean in-process run, supervision restaffs the
//! pools, overload is refused with a typed retry hint, and a mid-
//! pipeline server death is survived by client failover with the
//! unrecoverable ids reported as a typed `ConnectionLost`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use icsml::api::{
    Backend, EngineBackend, InferenceError, Session as _, SharedBackend,
};
use icsml::netserve::{
    Client, ModelRegistry, NetOptions, NetServer, RegistryConfig,
    RetryPolicy, ServerConfig, StaticLoader,
};
use icsml::serve::{Fault, FaultBackend, FaultPlan, PoolConfig};
use icsml::util::fixtures;

/// Two fixture models behind fault wrappers — `alpha` misbehaves per
/// `plan_a`, `beta` per `plan_b`. Pools run `max_batch: 1` so every
/// fault index maps to exactly one request (the per-request worker
/// path; batch-path containment has its own unit tests). The fault
/// wrappers come back alongside the registry so tests can read their
/// injection counters.
fn chaos_registry(
    workers: usize,
    plan_a: FaultPlan,
    plan_b: FaultPlan,
) -> (Arc<ModelRegistry>, Arc<FaultBackend>, Arc<FaultBackend>) {
    let inner_a: SharedBackend =
        Arc::new(EngineBackend::new(fixtures::mlp_8_16_4(1)));
    let inner_b: SharedBackend =
        Arc::new(EngineBackend::new(fixtures::mlp_8_16_4(2)));
    let fa = Arc::new(FaultBackend::new(inner_a, plan_a));
    let fb = Arc::new(FaultBackend::new(inner_b, plan_b));
    let shared_a: SharedBackend = Arc::clone(&fa);
    let shared_b: SharedBackend = Arc::clone(&fb);
    let mut loader = StaticLoader::new();
    loader.insert("alpha", shared_a, 1);
    loader.insert("beta", shared_b, 1);
    let reg = Arc::new(ModelRegistry::new(
        Box::new(loader),
        RegistryConfig {
            max_models: usize::MAX,
            max_bytes: u64::MAX,
            pool: PoolConfig { workers, max_batch: 1 },
        },
    ));
    (reg, fa, fb)
}

/// What the clean engine says for `x` — the bar every non-faulted
/// networked reply must match bit-for-bit.
fn reference(seed: u64, x: &[f32]) -> Vec<f32> {
    EngineBackend::new(fixtures::mlp_8_16_4(seed))
        .session()
        .unwrap()
        .infer(x)
        .unwrap()
}

/// Block (bounded) until `name`'s pool is fully restaffed and out of
/// quarantine.
fn wait_healthy(reg: &ModelRegistry, name: &str) {
    let entry = reg.get_or_load(name).unwrap();
    let t0 = Instant::now();
    while !entry.pool().health().is_healthy() {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "{name}: pool never restaffed: {:?}",
            entry.pool().health()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// The headline soak: 4 clients pipeline 200 requests across two
/// registry models while a fault plan fires panics, typed errors,
/// latency spikes and shape lies into the pools. Every request must
/// resolve with a reply (zero hangs, zero dropped tickets), each
/// fault fails at most its own ticket with the right typed error,
/// survivors are bit-identical to the clean engine, and supervision
/// restaffs both pools to full strength afterwards.
#[test]
fn soak_with_injected_faults_resolves_every_request() {
    // alpha: one of each fault kind at hand-picked indices (plus a
    // second panic) — all inside its 100-request stream, so the
    // expected injection counts are exact. beta: a seeded plan, the
    // reproducible-randomness path.
    let plan_a = FaultPlan::new()
        .at(3, Fault::Panic)
        .at(17, Fault::Error)
        .at(29, Fault::Latency(Duration::from_millis(2)))
        .at(41, Fault::WrongShape)
        .at(77, Fault::Panic);
    let plan_b = FaultPlan::seeded(0xc4a05, 400, 0.03);
    let (reg, fa, fb) = chaos_registry(2, plan_a, plan_b);
    let server = NetServer::bind(
        "127.0.0.1:0",
        Arc::clone(&reg),
        ServerConfig::default(),
    )
    .expect("bind loopback");
    let addr = server.local_addr();

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 50;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.set_timeout(Some(Duration::from_secs(60))).unwrap();
                let model = if t % 2 == 0 { "alpha" } else { "beta" };
                let seed = if t % 2 == 0 { 1 } else { 2 };
                let x: Vec<f32> =
                    (0..8).map(|i| (t + i) as f32 * 0.125).collect();
                let want = reference(seed, &x);
                let opts = NetOptions::new();
                for _ in 0..PER_CLIENT {
                    c.submit(model, &x, &opts).unwrap();
                }
                let mut panicked = 0u64;
                for _ in 0..PER_CLIENT {
                    let reply = c.recv().unwrap();
                    match reply.result {
                        Ok(y) => assert_eq!(
                            y, want,
                            "non-faulted replies stay bit-identical"
                        ),
                        Err(e) => match e.to_error() {
                            InferenceError::BackendPanicked { .. } => {
                                panicked += 1;
                            }
                            InferenceError::ExecutionFailed { .. }
                            | InferenceError::ShapeMismatch { .. } => {}
                            other => {
                                panic!("unplanned failure kind: {other}")
                            }
                        },
                    }
                }
                assert!(
                    c.pending_ids().is_empty(),
                    "every pipelined id was answered"
                );
                if model == "alpha" {
                    panicked
                } else {
                    0
                }
            })
        })
        .collect();
    let alpha_panics: u64 =
        handles.into_iter().map(|h| h.join().unwrap()).sum();

    // Zero hangs, zero drops: every parsed request produced exactly
    // one reply frame (success or typed error).
    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(server.stats().requests(), total);
    assert_eq!(
        server.stats().responses() + server.stats().error_frames(),
        total,
        "every request resolved with a frame"
    );
    // The faults really fired, and each failed exactly one ticket.
    assert_eq!(fa.requests(), 100, "alpha served its whole stream");
    assert_eq!(fa.injected(), 5, "all five planned faults fired");
    assert_eq!(alpha_panics, 2, "each panic failed exactly one ticket");
    assert_eq!(fb.requests(), 100, "beta served its whole stream");
    // Supervision restaffed the pools behind the contained panics.
    wait_healthy(&reg, "alpha");
    wait_healthy(&reg, "beta");
    let alpha = reg.get_or_load("alpha").unwrap();
    let health = alpha.pool().health();
    assert_eq!(health.panics_contained, 2);
    assert!(health.respawns >= 2, "dead workers were replaced");
    assert!(!health.quarantined, "isolated panics never quarantine");
    server.shutdown();
}

/// A server that dies with a pipelined wave still in flight is
/// survived: the client reconnects (failing over to the second
/// address), reports exactly the lost wire ids as a typed
/// [`InferenceError::ConnectionLost`], and subsequent one-shot
/// traffic flows bit-identically through the survivor.
#[test]
fn connection_drop_mid_pipeline_fails_over_with_typed_losses() {
    // Server A stalls every request it will ever see, so the wave's
    // replies are guaranteed to still be in flight when A dies.
    // Server B is fault-free.
    let stall = FaultPlan::new()
        .at(0, Fault::Latency(Duration::from_secs(1)))
        .at(1, Fault::Latency(Duration::from_secs(1)))
        .at(2, Fault::Latency(Duration::from_secs(1)))
        .at(3, Fault::Latency(Duration::from_secs(1)));
    let (reg_a, _, _) = chaos_registry(2, stall, FaultPlan::new());
    let (reg_b, _, _) =
        chaos_registry(2, FaultPlan::new(), FaultPlan::new());
    let server_a =
        NetServer::bind("127.0.0.1:0", reg_a, ServerConfig::default())
            .expect("bind A");
    let server_b =
        NetServer::bind("127.0.0.1:0", reg_b, ServerConfig::default())
            .expect("bind B");
    let addrs = [server_a.local_addr(), server_b.local_addr()];

    let mut c = Client::connect_with(&addrs[..], RetryPolicy::new())
        .expect("connect via failover list");
    c.set_timeout(Some(Duration::from_secs(60))).unwrap();
    let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.125).collect();
    let opts = NetOptions::new();
    let mut sent = Vec::new();
    for _ in 0..4 {
        sent.push(c.submit("alpha", &x, &opts).unwrap());
    }
    assert_eq!(c.pending_ids(), &sent[..]);
    // Let A accept the wave into its (stalled) pool, then kill it with
    // every reply still pending.
    std::thread::sleep(Duration::from_millis(50));
    server_a.shutdown();

    match c.recv_reconnecting() {
        Err(InferenceError::ConnectionLost { lost_ids, reason }) => {
            assert_eq!(
                lost_ids, sent,
                "exactly the in-flight ids are reported lost"
            );
            assert!(!reason.is_empty());
        }
        other => panic!("expected ConnectionLost, got {other:?}"),
    }
    assert!(c.pending_ids().is_empty(), "the loss report is complete");
    // The client is already reconnected (to B): the idempotent
    // one-shot succeeds, bit-identical to the clean engine.
    let y = c.infer("alpha", &x, &opts).unwrap();
    assert_eq!(y, reference(1, &x));
    server_b.shutdown();
}

/// Requests beyond the per-connection in-flight cap are refused with
/// a typed [`InferenceError::Overloaded`] frame carrying a retry
/// hint — the connection survives and everything under the cap is
/// served normally.
#[test]
fn overload_is_refused_with_a_typed_retry_hint() {
    // One worker, stalled on its first request: the pipelined wave
    // behind it piles up against a tiny in-flight cap.
    let stall = FaultPlan::new()
        .at(0, Fault::Latency(Duration::from_millis(300)));
    let (reg, _, _) = chaos_registry(1, stall, FaultPlan::new());
    let cfg = ServerConfig {
        max_inflight_per_conn: 4,
        ..ServerConfig::default()
    };
    let server =
        NetServer::bind("127.0.0.1:0", reg, cfg).expect("bind loopback");
    let mut c = Client::connect(server.local_addr()).unwrap();
    c.set_timeout(Some(Duration::from_secs(60))).unwrap();
    let x = [0.25f32; 8];
    let opts = NetOptions::new();
    let want = reference(1, &x);
    for _ in 0..8 {
        c.submit("alpha", &x, &opts).unwrap();
    }
    let mut served = 0;
    let mut refused = 0;
    for _ in 0..8 {
        let reply = c.recv().unwrap();
        match reply.result {
            Ok(y) => {
                assert_eq!(y, want);
                served += 1;
            }
            Err(e) => match e.to_error() {
                InferenceError::Overloaded {
                    scope,
                    retry_after_us,
                } => {
                    assert_eq!(scope, "connection");
                    assert!(retry_after_us > 0.0, "retry hint present");
                    refused += 1;
                }
                other => panic!("expected Overloaded, got {other}"),
            },
        }
    }
    assert_eq!(
        (served, refused),
        (4, 4),
        "everything under the cap served, everything over refused"
    );
    assert_eq!(server.stats().overloaded(), 4);
    // The refusals did not cost the connection: it still serves.
    let y = c.infer("alpha", &x, &opts).unwrap();
    assert_eq!(y, want);
    server.shutdown();
}
