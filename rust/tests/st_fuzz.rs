//! Generative differential fuzzer: seeded random well-typed ST
//! programs through all three execution configurations — tree-walking
//! interpreter (oracle), fused VM, and fusion-off VM.
//!
//! This is the gate on the superinstruction tier (ISSUE 9): for every
//! seed, every scan, the tiers must produce bit-identical program
//! state and **exactly equal** `Meter` counters, and any runtime error
//! must carry the same message and line on all tiers. The generator is
//! a closed grammar over the fixed variable environment below —
//! arithmetic (int/real/bool), FOR/WHILE/REPEAT/CASE/IF control flow,
//! array and pointer access, function and FB-method calls — driven
//! only by `SplitMix64`, so every failure reproduces from its seed
//! (the failing program text is printed in the panic).

use icsml::st::{self, bytecode, FusionConfig, Interp, Vm};
use icsml::util::rng::SplitMix64;

const INT_VARS: [&str; 4] = ["i0", "i1", "i2", "w0"];
const REAL_VARS: [&str; 3] = ["r0", "r1", "r2"];
const BOOL_VARS: [&str; 2] = ["b0", "b1"];
const CMP_OPS: [&str; 6] = ["=", "<>", "<", "<=", ">", ">="];

/// Fixed POU preamble every generated program links against: a
/// DOT_PRODUCT-shaped pointer-walk function (always fuses), a scalar
/// helper, and an FB with state, an output, and a method.
const PREAMBLE: &str = "FUNCTION FDOT : REAL\n\
VAR_INPUT pa : POINTER TO REAL; pb : POINTER TO REAL; n : DINT; END_VAR\n\
VAR s : REAL; i : DINT; END_VAR\n\
FOR i := 0 TO n - 1 DO\n\
  s := s + pa[i] * pb[i];\n\
END_FOR\n\
FDOT := s;\n\
END_FUNCTION\n\
FUNCTION FMIX : REAL\n\
VAR_INPUT a : REAL; b : REAL; END_VAR\n\
FMIX := a * 0.5 + b;\n\
END_FUNCTION\n\
FUNCTION_BLOCK FB_ACC\n\
VAR_INPUT inc : DINT; END_VAR\n\
VAR_OUTPUT out : DINT; END_VAR\n\
VAR total : DINT; END_VAR\n\
METHOD scaled : REAL VAR_INPUT k : REAL; END_VAR\n\
  scaled := DINT_TO_REAL(total) * k;\n\
END_METHOD\n\
total := total + inc;\n\
out := total;\n\
END_FUNCTION_BLOCK\n";

struct Gen {
    rng: SplitMix64,
    /// Loop counters the enclosing statement owns — never assigned
    /// (or reused as counters) while locked.
    locked: Vec<&'static str>,
}

impl Gen {
    fn pick<'a>(&mut self, xs: &[&'a str]) -> &'a str {
        xs[self.rng.below(xs.len() as u64) as usize]
    }

    fn unlocked(&mut self, pool: &[&'static str]) -> Option<&'static str> {
        let free: Vec<&'static str> = pool
            .iter()
            .copied()
            .filter(|v| !self.locked.contains(v))
            .collect();
        if free.is_empty() {
            None
        } else {
            Some(free[self.rng.below(free.len() as u64) as usize])
        }
    }

    fn int_lit(&mut self) -> String {
        self.rng.below(20).to_string()
    }

    fn real_lit(&mut self) -> String {
        format!("{:.2}", self.rng.below(32) as f64 * 0.25)
    }

    fn real_lit_nonzero(&mut self) -> String {
        format!("{:.2}", (1 + self.rng.below(31)) as f64 * 0.25)
    }

    fn int_expr(&mut self, d: u32) -> String {
        if d == 0 {
            return match self.rng.below(3) {
                0 => self.int_lit(),
                1 => self.pick(&INT_VARS).to_string(),
                _ => format!("ai[{}]", self.rng.below(8)),
            };
        }
        match self.rng.below(9) {
            0 => self.int_lit(),
            1 => self.pick(&INT_VARS).to_string(),
            2 => format!("ai[{}]", self.rng.below(8)),
            3 => format!(
                "({} + {})",
                self.int_expr(d - 1),
                self.int_expr(d - 1)
            ),
            4 => format!(
                "({} - {})",
                self.int_expr(d - 1),
                self.int_expr(d - 1)
            ),
            5 => format!(
                "({} * {})",
                self.int_expr(d - 1),
                self.int_expr(d - 1)
            ),
            // Division and MOD only by nonzero literals: div-by-zero
            // parity is pinned separately, not left to seed luck.
            6 => format!(
                "({} MOD {})",
                self.int_expr(d - 1),
                1 + self.rng.below(9)
            ),
            7 => format!(
                "({} / {})",
                self.int_expr(d - 1),
                1 + self.rng.below(9)
            ),
            _ => format!("-({})", self.int_expr(d - 1)),
        }
    }

    fn real_expr(&mut self, d: u32) -> String {
        if d == 0 {
            return match self.rng.below(3) {
                0 => self.real_lit(),
                1 => self.pick(&REAL_VARS).to_string(),
                _ => format!("ar[{}]", self.rng.below(8)),
            };
        }
        match self.rng.below(10) {
            0 => self.real_lit(),
            1 => self.pick(&REAL_VARS).to_string(),
            2 => format!("ar[{}]", self.rng.below(8)),
            3 => format!("DINT_TO_REAL({})", self.int_expr(d - 1)),
            4 => format!(
                "({} + {})",
                self.real_expr(d - 1),
                self.real_expr(d - 1)
            ),
            5 => format!(
                "({} - {})",
                self.real_expr(d - 1),
                self.real_expr(d - 1)
            ),
            6 => format!(
                "({} * {})",
                self.real_expr(d - 1),
                self.real_expr(d - 1)
            ),
            7 => format!(
                "({} / {})",
                self.real_expr(d - 1),
                self.real_lit_nonzero()
            ),
            8 => format!("SQRT(ABS({}))", self.real_expr(d - 1)),
            _ => format!(
                "FMIX({}, {})",
                self.real_expr(d - 1),
                self.real_lit()
            ),
        }
    }

    fn bool_expr(&mut self, d: u32) -> String {
        if d == 0 {
            return match self.rng.below(4) {
                0 => "TRUE".into(),
                1 => "FALSE".into(),
                _ => self.pick(&BOOL_VARS).to_string(),
            };
        }
        match self.rng.below(7) {
            0 => self.pick(&BOOL_VARS).to_string(),
            1 | 2 => {
                let op = self.pick(&CMP_OPS);
                format!(
                    "({} {op} {})",
                    self.int_expr(d - 1),
                    self.int_expr(d - 1)
                )
            }
            3 => {
                let op = self.pick(&CMP_OPS);
                format!(
                    "({} {op} {})",
                    self.real_expr(d - 1),
                    self.real_expr(d - 1)
                )
            }
            4 => format!(
                "({} AND {})",
                self.bool_expr(d - 1),
                self.bool_expr(d - 1)
            ),
            5 => format!(
                "({} OR {})",
                self.bool_expr(d - 1),
                self.bool_expr(d - 1)
            ),
            _ => format!("NOT ({})", self.bool_expr(d - 1)),
        }
    }

    fn assign(&mut self, out: &mut String, pad: &str) {
        match self.rng.below(4) {
            0 => {
                if let Some(v) = self.unlocked(&INT_VARS) {
                    out.push_str(&format!(
                        "{pad}{v} := {};\n",
                        self.int_expr(2)
                    ));
                    return;
                }
                let v = self.pick(&REAL_VARS);
                out.push_str(&format!("{pad}{v} := {};\n", self.real_expr(2)));
            }
            1 => {
                let v = self.pick(&REAL_VARS);
                out.push_str(&format!("{pad}{v} := {};\n", self.real_expr(2)));
            }
            2 => {
                let v = self.pick(&BOOL_VARS);
                out.push_str(&format!("{pad}{v} := {};\n", self.bool_expr(2)));
            }
            _ => {
                let k = self.rng.below(8);
                if self.rng.below(2) == 0 {
                    out.push_str(&format!(
                        "{pad}ar[{k}] := {};\n",
                        self.real_expr(2)
                    ));
                } else {
                    out.push_str(&format!(
                        "{pad}ai[{k}] := {};\n",
                        self.int_expr(2)
                    ));
                }
            }
        }
    }

    fn stmt(&mut self, out: &mut String, ind: usize, d: u32) {
        let pad = "  ".repeat(ind);
        match self.rng.below(12) {
            0..=4 => self.assign(out, &pad),
            5 => {
                out.push_str(&format!(
                    "{pad}IF {} THEN\n",
                    self.bool_expr(2)
                ));
                self.stmt(out, ind + 1, d.saturating_sub(1));
                if self.rng.below(2) == 0 {
                    out.push_str(&format!("{pad}ELSE\n"));
                    self.stmt(out, ind + 1, d.saturating_sub(1));
                }
                out.push_str(&format!("{pad}END_IF\n"));
            }
            6 if d > 0 => {
                let counter =
                    match self.unlocked(&["i0", "i1", "i2"]) {
                        Some(c) => c,
                        None => return self.assign(out, &pad),
                    };
                let lo = self.rng.below(5);
                let span = self.rng.below(6);
                match self.rng.below(4) {
                    0 => out.push_str(&format!(
                        "{pad}FOR {counter} := {} TO {lo} BY -{} DO\n",
                        lo + span,
                        1 + self.rng.below(2)
                    )),
                    // Zero-iteration when span > 0: hi-to-lo, step +1.
                    1 => out.push_str(&format!(
                        "{pad}FOR {counter} := {} TO {lo} DO\n",
                        lo + span
                    )),
                    _ => out.push_str(&format!(
                        "{pad}FOR {counter} := {lo} TO {} BY {} DO\n",
                        lo + span,
                        1 + self.rng.below(2)
                    )),
                }
                self.locked.push(counter);
                for _ in 0..1 + self.rng.below(2) {
                    self.stmt(out, ind + 1, d - 1);
                }
                if self.rng.below(4) == 0 {
                    let kw = if self.rng.below(2) == 0 {
                        "EXIT"
                    } else {
                        "CONTINUE"
                    };
                    out.push_str(&format!(
                        "{pad}  IF ({counter} = {}) THEN {kw}; END_IF\n",
                        lo + self.rng.below(span + 1)
                    ));
                }
                self.locked.pop();
                out.push_str(&format!("{pad}END_FOR\n"));
            }
            7 if d > 0 && !self.locked.contains(&"w0") => {
                let n = 1 + self.rng.below(5);
                out.push_str(&format!("{pad}w0 := 0;\n"));
                let repeat = self.rng.below(2) == 0;
                if repeat {
                    out.push_str(&format!("{pad}REPEAT\n"));
                } else {
                    out.push_str(&format!("{pad}WHILE (w0 < {n}) DO\n"));
                }
                self.locked.push("w0");
                self.stmt(out, ind + 1, d - 1);
                self.locked.pop();
                out.push_str(&format!("{pad}  w0 := (w0 + 1);\n"));
                if repeat {
                    out.push_str(&format!(
                        "{pad}UNTIL (w0 >= {n}) END_REPEAT\n"
                    ));
                } else {
                    out.push_str(&format!("{pad}END_WHILE\n"));
                }
            }
            8 if d > 0 => {
                let sv = self.pick(&INT_VARS);
                let a = self.rng.below(4);
                let single = a + 1 + self.rng.below(3);
                out.push_str(&format!("{pad}CASE {sv} OF\n"));
                out.push_str(&format!(
                    "{pad}  0..{a}: r0 := {};\n",
                    self.real_expr(1)
                ));
                // Never assign to a locked loop counter from inside a
                // CASE arm — resetting the counter mid-loop can spin
                // a FOR forever.
                match self.unlocked(&INT_VARS) {
                    Some(v) => out.push_str(&format!(
                        "{pad}  {single}: {v} := {};\n",
                        self.int_expr(1)
                    )),
                    None => out.push_str(&format!(
                        "{pad}  {single}: r2 := {};\n",
                        self.real_expr(1)
                    )),
                }
                out.push_str(&format!(
                    "{pad}  ELSE b1 := {};\n",
                    self.bool_expr(1)
                ));
                out.push_str(&format!("{pad}END_CASE\n"));
            }
            9 => {
                let inc = self.rng.below(9);
                match self.unlocked(&INT_VARS) {
                    Some(v) if self.rng.below(2) == 0 => out.push_str(
                        &format!("{pad}acc(inc := {inc}, out => {v});\n"),
                    ),
                    _ => out.push_str(&format!("{pad}acc(inc := {inc});\n")),
                }
            }
            10 => {
                let v = self.pick(&REAL_VARS);
                out.push_str(&format!(
                    "{pad}{v} := acc.scaled({});\n",
                    self.real_lit()
                ));
            }
            11 => {
                let v = self.pick(&REAL_VARS);
                out.push_str(&format!(
                    "{pad}{v} := FDOT(ADR(ar), ADR(ar), 8);\n"
                ));
            }
            _ => self.assign(out, &pad),
        }
    }
}

/// Generate one complete, compilable program from a seed.
fn gen_program(seed: u64) -> String {
    let mut g = Gen { rng: SplitMix64::new(seed), locked: Vec::new() };
    let mut src = String::from(PREAMBLE);
    src.push_str(
        "PROGRAM fz\n\
         VAR\n  \
           i0, i1, i2, w0 : DINT;\n  \
           r0, r1, r2 : REAL;\n  \
           b0, b1 : BOOL;\n  \
           ar : ARRAY[0..7] OF REAL;\n  \
           ai : ARRAY[0..7] OF DINT;\n  \
           acc : FB_ACC;\n\
         END_VAR\n",
    );
    for _ in 0..4 + g.rng.below(6) {
        g.stmt(&mut src, 1, 2);
    }
    src.push_str("END_PROGRAM\n");
    src
}

fn assert_state_eq(it: &Interp, vm: &Vm, ctx: &str, src: &str) {
    let pid = it.unit.find_program("fz").expect("program exists");
    let inst = it.program_instances[pid];
    assert_eq!(
        inst, vm.program_instances[pid],
        "{ctx}: instance layout diverged\n{src}"
    );
    for f in &it.unit.programs[pid].fields {
        let a = it.instance_field(inst, &f.name).unwrap();
        let b = vm.instance_field(inst, &f.name).unwrap();
        assert!(
            a.bits_eq(&b),
            "{ctx}: field {}: interp {a:?} vs vm {b:?}\n{src}",
            f.name
        );
    }
}

/// Run one seed through interp vs VM under `cfg` for up to 3 scans.
fn run_seed_with(seed: u64, src: &str, unit: &st::ir::Unit, fused: bool) {
    let cfg = FusionConfig { enabled: fused };
    let mut it = Interp::new(unit.clone());
    let mut vm = Vm::new_with(unit.clone(), &cfg);
    for scan in 0..3 {
        let ctx = format!("seed {seed} scan {scan} fused={fused}");
        match (it.run_program("fz"), vm.run_program("fz")) {
            (Ok(()), Ok(())) => {
                if let Some((name, a, b)) =
                    it.meter.first_divergence(&vm.meter)
                {
                    panic!(
                        "{ctx}: meter `{name}` diverged: \
                         interp {a} vm {b}\n{src}"
                    );
                }
                assert_state_eq(&it, &vm, &ctx, src);
            }
            (Err(a), Err(b)) => {
                assert_eq!(a.message, b.message, "{ctx}: error msg\n{src}");
                assert_eq!(a.line, b.line, "{ctx}: error line\n{src}");
                // Deterministic error: later scans add nothing.
                return;
            }
            (a, b) => panic!(
                "{ctx}: tier disagreement: interp {a:?} vm {b:?}\n{src}"
            ),
        }
    }
}

fn run_seeds(range: std::ops::Range<u64>) {
    for seed in range {
        let src = gen_program(seed);
        let unit = st::compile(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        run_seed_with(seed, &src, &unit, true);
        run_seed_with(seed, &src, &unit, false);
    }
}

// Four shards so `cargo test` runs the 64-seed corpus in parallel.

#[test]
fn fuzz_seeds_00_15() {
    run_seeds(0..16);
}

#[test]
fn fuzz_seeds_16_31() {
    run_seeds(16..32);
}

#[test]
fn fuzz_seeds_32_47() {
    run_seeds(32..48);
}

#[test]
fn fuzz_seeds_48_63() {
    run_seeds(48..64);
}

// ------------------------------------------------- multi-task wrapper

/// Wrap two independently generated program bodies in a §2.7
/// CONFIGURATION — a 10 ms priority-0 task and a 30 ms priority-1
/// task — with a shared global both programs mutate, so the
/// differential invariant is exercised *through the task scheduler*:
/// per-task meters, interleaved global traffic, and the schedule
/// itself must match across tiers.
fn gen_two_task(seed: u64) -> String {
    fn inject_before_end(src: String, stmt: &str) -> String {
        let at = src.rfind("END_PROGRAM").expect("generated program end");
        let mut s = src;
        s.insert_str(at, stmt);
        s
    }
    let a = inject_before_end(
        gen_program(seed),
        "  g_link := (g_link + i0);\n",
    );
    let b = gen_program(seed ^ 0x9e37_79b9_7f4a_7c15);
    let b_prog = inject_before_end(
        b.strip_prefix(PREAMBLE)
            .expect("generated source starts with the preamble")
            .replacen("PROGRAM fz\n", "PROGRAM fz2\n", 1),
        "  g_link := (g_link * 2);\n",
    );
    format!(
        "VAR_GLOBAL g_link : DINT; END_VAR\n{a}{b_prog}\
         CONFIGURATION FuzzPlant\n\
           RESOURCE main ON plc\n\
             TASK fast(INTERVAL := T#10ms, PRIORITY := 0);\n\
             TASK slow(INTERVAL := T#30ms, PRIORITY := 1);\n\
             PROGRAM pa WITH fast : fz;\n\
             PROGRAM pb WITH slow : fz2;\n\
           END_RESOURCE\n\
         END_CONFIGURATION\n"
    )
}

/// Bit-equality of everything observable across two tiers of a
/// multi-task unit: the shared global plus both programs' fields.
fn assert_task_state_eq(it: &Interp, vm: &Vm, ctx: &str, src: &str) {
    for (g, (a, b)) in it
        .unit
        .globals
        .iter()
        .zip(it.globals.iter().zip(&vm.globals))
    {
        assert!(
            a.bits_eq(b),
            "{ctx}: global {}: interp {a:?} vs vm {b:?}\n{src}",
            g.name
        );
    }
    for (pid, p) in it.unit.programs.iter().enumerate() {
        let inst = it.program_instances[pid];
        for f in &p.fields {
            let a = it.instance_field(inst, &f.name).unwrap();
            let b = vm
                .instance_field(vm.program_instances[pid], &f.name)
                .unwrap();
            assert!(
                a.bits_eq(&b),
                "{ctx}: {}.{}: interp {a:?} vs vm {b:?}\n{src}",
                p.name,
                f.name
            );
        }
    }
}

#[test]
fn multi_task_fuzz_stays_exact_per_task() {
    use icsml::plc::HwProfile;
    use icsml::st::TaskScheduler;
    for seed in 0..8u64 {
        let src = gen_two_task(seed);
        let unit = st::compile(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        for fused in [true, false] {
            let mut it = Interp::new(unit.clone());
            let mut vm =
                Vm::new_with(unit.clone(), &FusionConfig { enabled: fused });
            let mut sa =
                TaskScheduler::for_runtime(&it, HwProfile::beaglebone())
                    .expect("task model");
            let mut sb =
                TaskScheduler::for_runtime(&vm, HwProfile::beaglebone())
                    .expect("task model");
            for tick in 0..6 {
                let ctx = format!("seed {seed} tick {tick} fused={fused}");
                match (sa.tick(&mut it), sb.tick(&mut vm)) {
                    (Ok(ra), Ok(rb)) => {
                        assert_eq!(ra.ran, rb.ran, "{ctx}: schedule\n{src}");
                        assert_eq!(
                            ra.skipped, rb.skipped,
                            "{ctx}: skips\n{src}"
                        );
                        for task in 0..sa.model().tasks.len() {
                            if let Some((name, a, b)) = sa
                                .task_meter(task)
                                .first_divergence(sb.task_meter(task))
                            {
                                panic!(
                                    "{ctx}: task {task} meter `{name}` \
                                     diverged: interp {a} vm {b}\n{src}"
                                );
                            }
                        }
                        assert_task_state_eq(&it, &vm, &ctx, &src);
                    }
                    (Err(a), Err(b)) => {
                        assert_eq!(
                            a.message, b.message,
                            "{ctx}: error msg\n{src}"
                        );
                        assert_eq!(
                            a.line, b.line,
                            "{ctx}: error line\n{src}"
                        );
                        break;
                    }
                    (a, b) => panic!(
                        "{ctx}: tier disagreement: interp {a:?} vm \
                         {b:?}\n{src}"
                    ),
                }
            }
        }
    }
}

/// The corpus is not vacuous: every seed links FDOT, so every unit
/// must contain fused superinstructions when fusion is on — and none
/// when it is off.
#[test]
fn every_seed_exercises_the_fused_tier() {
    for seed in 0..64 {
        let src = gen_program(seed);
        let unit = st::compile(&src)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        let fused = bytecode::compile_unit(&unit);
        assert!(fused.fused_ops() > 0, "seed {seed}: nothing fused\n{src}");
        let plain = bytecode::compile_unit_with(
            &unit,
            &FusionConfig { enabled: false },
        );
        assert_eq!(plain.fused_ops(), 0, "seed {seed}: fusion leaked");
    }
}
