//! Concurrent serving on top of the Engine/Session split, scheduled
//! by scan-cycle deadlines.
//!
//! The paper frames ICSML as one PLC running one scan loop; the
//! ROADMAP's north star is a serving system watching *fleets* of
//! controllers (the deployment model the PLC-security literature
//! assumes — many detection streams, one inference service). This
//! module is the concurrency substrate built on the two-level API
//! contract, in three layers:
//!
//! * [`queue`] — the scheduler: priority classes
//!   ([`Priority::Control`] > [`Priority::Defense`] >
//!   [`Priority::Batch`]), optional per-request [`Deadline`]s (given
//!   directly, or derived from the PLC cost model via
//!   [`Deadline::for_meter`] / [`Deadline::for_scan`]), and the
//!   lock-sheltered earliest-deadline-first [`DeadlineQueue`].
//! * [`admission`] — the ingress gate: an [`Admission`] estimate over
//!   `plc/profiles.rs` cost vectors rejects requests whose deadline
//!   provably cannot be met behind the current backlog.
//! * [`pool`] — the workers: a [`Pool`] shards requests across N
//!   threads, each owning a private [`crate::api::Session`] over one
//!   shared [`crate::api::Backend`], micro-batching queued requests
//!   only when every batch member's deadline survives the projected
//!   completion time, and *shedding* expired requests
//!   ([`crate::api::InferenceError::DeadlineExceeded`]) instead of
//!   serving them late. Workers are *supervised*: backend panics are
//!   contained per job ([`crate::api::InferenceError::BackendPanicked`]),
//!   dead workers respawn under capped backoff, and a backend that
//!   panics [`SupervisorConfig::quarantine_after`] times in a row is
//!   quarantined ([`Pool::health`] reports all of it).
//! * [`faults`] — deterministic fault injection: a seeded
//!   [`FaultPlan`] makes chosen requests panic / fail / stall /
//!   mis-shape through a [`FaultBackend`] wrapper, so the chaos suite
//!   (`tests/chaos.rs`) can drive the supervision machinery on
//!   purpose.
//!
//! Throughput scaling plus deadline-hit/shed rates are measured by
//! `benches/serve_pool.rs` (`BENCH_serve.json`);
//! bit-identical-to-sequential results and the deadline semantics
//! (expired ⇒ shed, urgent ⇒ never delayed by batch formation,
//! no deadlines ⇒ exact FIFO) are asserted by
//! `tests/concurrency.rs`. The end-to-end picture lives in
//! `docs/ARCHITECTURE.md`.
#![deny(missing_docs)]

pub mod admission;
pub mod faults;
pub mod pool;
pub mod queue;

pub use admission::Admission;
pub use faults::{Fault, FaultBackend, FaultPlan};
pub use pool::{Pool, PoolConfig, PoolHealth, SupervisorConfig, Ticket};
pub use queue::{Deadline, DeadlineQueue, Meta, Priority, SubmitOptions};
