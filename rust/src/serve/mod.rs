//! Concurrent serving on top of the Engine/Session split.
//!
//! The paper frames ICSML as one PLC running one scan loop; the
//! ROADMAP's north star is a serving system watching *fleets* of
//! controllers (the deployment model the PLC-security literature
//! assumes — many detection streams, one inference service). This
//! module is the first concurrency substrate built on the two-level
//! API contract: a [`Pool`] shards requests across N worker threads,
//! each worker owning a private [`crate::api::Session`] over one
//! shared [`crate::api::Backend`], with opportunistic micro-batching
//! of queued requests.
//!
//! Throughput scaling is measured by `benches/serve_pool.rs`
//! (`BENCH_serve.json`); bit-identical-to-sequential results are
//! asserted by `tests/concurrency.rs`.

pub mod pool;

pub use pool::{Pool, PoolConfig, Ticket};
