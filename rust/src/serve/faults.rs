//! Deterministic fault injection for robustness tests.
//!
//! [`FaultBackend`] wraps any [`Backend`] and makes chosen requests
//! misbehave — panic, fail with a typed error, stall, or report a
//! wrong shape — according to a seeded [`FaultPlan`]. The wrapper is
//! what the chaos suite (`tests/chaos.rs`) and the serve bench's
//! `--smoke` chaos pass drive the supervised [`Pool`](super::Pool)
//! with: faults fire at known request indices, everything else is
//! served by the inner backend bit-identically, so a test can assert
//! both that the blast radius of each fault is exactly one ticket and
//! that survivors match a clean reference run.
//!
//! Request indices are assigned by one shared atomic counter that
//! lives in the *backend* (not the session): every session minted from
//! the same `FaultBackend` — including the fresh sessions the pool
//! supervisor mints after a contained panic — draws from the same
//! sequence, so a plan entry fires exactly once no matter how workers
//! die and respawn around it.
//!
//! Everything here is deterministic given the plan: no wall clock, no
//! ambient randomness ([`FaultPlan::seeded`] uses the repo's
//! [`SplitMix64`] stream).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::api::{
    Backend, InferenceError, ModelSpec, Session, SharedBackend,
};
use crate::util::rng::SplitMix64;

/// One way a request can misbehave.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// The backend panics mid-request (the pool must contain it:
    /// exactly this ticket fails with
    /// [`InferenceError::BackendPanicked`]).
    Panic,
    /// The backend fails with a typed
    /// [`InferenceError::ExecutionFailed`] — the well-behaved failure
    /// mode; must not kill the worker or count toward quarantine.
    Error,
    /// The backend stalls for the given duration before serving
    /// normally — an injected latency spike (deadlined requests behind
    /// it get shed, undeadlined ones just wait).
    Latency(Duration),
    /// The backend reports a result-shape problem as a typed
    /// [`InferenceError::ShapeMismatch`]. (The pool hands sessions a
    /// correctly-sized output buffer by construction, so a
    /// wrong-shaped *write* cannot reach a caller; the observable
    /// misbehavior is the typed refusal.)
    WrongShape,
}

/// Which request indices misbehave, and how. Indices count every
/// `infer_into` row served through the wrapping [`FaultBackend`],
/// across all its sessions, starting at 0.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: HashMap<u64, Fault>,
}

impl FaultPlan {
    /// An empty plan: the wrapper is transparent.
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Make request `index` misbehave with `fault` (builder-style).
    pub fn at(mut self, index: u64, fault: Fault) -> FaultPlan {
        self.faults.insert(index, fault);
        self
    }

    /// A reproducible random plan: each request index in
    /// `0..horizon` misbehaves with probability `rate`, the fault kind
    /// drawn uniformly from panic / typed error / 2 ms latency spike /
    /// wrong shape. Same `seed` → same plan, on any machine.
    pub fn seeded(seed: u64, horizon: u64, rate: f64) -> FaultPlan {
        let mut rng = SplitMix64::new(seed);
        let mut plan = FaultPlan::new();
        for i in 0..horizon {
            if rng.next_f64() >= rate {
                continue;
            }
            let fault = match rng.below(4) {
                0 => Fault::Panic,
                1 => Fault::Error,
                2 => Fault::Latency(Duration::from_millis(2)),
                _ => Fault::WrongShape,
            };
            plan.faults.insert(i, fault);
        }
        plan
    }

    /// Number of faulted indices in the plan.
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// True when no index is faulted.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// How many of the plan's entries are panics (the quarantine-
    /// relevant kind).
    pub fn panics(&self) -> usize {
        self.faults.values().filter(|f| **f == Fault::Panic).count()
    }
}

/// A [`Backend`] wrapper that injects the faults of a [`FaultPlan`]
/// into an inner backend's request stream.
///
/// ```
/// use std::sync::Arc;
/// use icsml::api::{
///     Backend, EngineBackend, InferenceError, Session, SharedBackend,
/// };
/// use icsml::engine::{Act, Layer, Model};
/// use icsml::serve::{Fault, FaultBackend, FaultPlan};
///
/// let model = Model::new(vec![Layer::dense(
///     vec![0.5; 4],
///     vec![0.0; 2],
///     2,
///     Act::None,
/// )]);
/// let inner: SharedBackend = Arc::new(EngineBackend::new(model));
/// let faulty = FaultBackend::new(
///     inner,
///     FaultPlan::new().at(1, Fault::Error),
/// );
/// let mut session = faulty.session().unwrap();
/// assert!(session.infer(&[1.0, 1.0]).is_ok()); // index 0: clean
/// assert!(matches!(
///     session.infer(&[1.0, 1.0]),
///     Err(InferenceError::ExecutionFailed { .. })
/// )); // index 1: injected typed error
/// assert!(session.infer(&[1.0, 1.0]).is_ok()); // index 2: clean
/// assert_eq!(faulty.injected(), 1);
/// ```
pub struct FaultBackend {
    inner: SharedBackend,
    plan: Arc<FaultPlan>,
    /// Global request-index source, shared by every session.
    counter: Arc<AtomicU64>,
    /// Faults actually fired so far.
    injected: Arc<AtomicU64>,
}

impl FaultBackend {
    /// Wrap `inner` so the requests named by `plan` misbehave.
    pub fn new(inner: SharedBackend, plan: FaultPlan) -> FaultBackend {
        FaultBackend {
            inner,
            plan: Arc::new(plan),
            counter: Arc::new(AtomicU64::new(0)),
            injected: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Like [`FaultBackend::new`], boxed into the [`SharedBackend`]
    /// handle the pool and registry want.
    pub fn shared(inner: SharedBackend, plan: FaultPlan) -> SharedBackend {
        Arc::new(FaultBackend::new(inner, plan))
    }

    /// Requests that have entered the wrapper so far (clean + faulted).
    pub fn requests(&self) -> u64 {
        self.counter.load(Ordering::Relaxed)
    }

    /// Faults fired so far. Latency spikes count when they delay a
    /// request; panics count *before* unwinding, so a contained panic
    /// is visible here even though the request never completed.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }
}

impl Backend for FaultBackend {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn spec(&self) -> ModelSpec {
        self.inner.spec()
    }

    fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
        Ok(Box::new(FaultSession {
            inner: self.inner.session()?,
            plan: Arc::clone(&self.plan),
            counter: Arc::clone(&self.counter),
            injected: Arc::clone(&self.injected),
        }))
    }
}

struct FaultSession {
    inner: Box<dyn Session>,
    plan: Arc<FaultPlan>,
    counter: Arc<AtomicU64>,
    injected: Arc<AtomicU64>,
}

impl Session for FaultSession {
    fn name(&self) -> &'static str {
        "fault"
    }

    fn spec(&self) -> ModelSpec {
        self.inner.spec()
    }

    fn infer_into(
        &mut self,
        x: &[f32],
        out: &mut [f32],
    ) -> Result<(), InferenceError> {
        let i = self.counter.fetch_add(1, Ordering::Relaxed);
        match self.plan.faults.get(&i) {
            None => self.inner.infer_into(x, out),
            Some(Fault::Panic) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                panic!("injected fault: panic at request {i}");
            }
            Some(Fault::Error) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                Err(InferenceError::ExecutionFailed {
                    backend: "fault".into(),
                    source: anyhow::anyhow!(
                        "injected fault: typed error at request {i}"
                    ),
                })
            }
            Some(Fault::Latency(d)) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(*d);
                self.inner.infer_into(x, out)
            }
            Some(Fault::WrongShape) => {
                self.injected.fetch_add(1, Ordering::Relaxed);
                Err(InferenceError::ShapeMismatch {
                    what: "output (injected fault)",
                    expected: out.len(),
                    got: out.len() + 1,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EngineBackend;
    use crate::engine::{Act, Layer, Model};
    use crate::serve::{Pool, PoolConfig};

    fn inner() -> SharedBackend {
        Arc::new(EngineBackend::new(Model::new(vec![Layer::dense(
            (0..4 * 2).map(|i| 0.1 * (i as f32 + 1.0)).collect(),
            vec![0.0; 2],
            2,
            Act::None,
        )])))
    }

    #[test]
    fn plan_faults_fire_at_their_indices_and_nowhere_else() {
        let plan = FaultPlan::new()
            .at(1, Fault::Error)
            .at(3, Fault::WrongShape)
            .at(4, Fault::Latency(Duration::from_micros(100)));
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.panics(), 0);
        let fb = FaultBackend::new(inner(), plan);
        let mut clean = inner().session().unwrap();
        let mut s = fb.session().unwrap();
        let x = [0.4f32, -0.2];
        let want = clean.infer(&x).unwrap();

        assert_eq!(s.infer(&x).unwrap(), want, "index 0 is clean");
        assert!(matches!(
            s.infer(&x),
            Err(InferenceError::ExecutionFailed { .. })
        ));
        assert_eq!(s.infer(&x).unwrap(), want, "index 2 is clean");
        assert!(matches!(
            s.infer(&x),
            Err(InferenceError::ShapeMismatch { .. })
        ));
        // Index 4: delayed but correct — a latency fault never
        // corrupts the result.
        assert_eq!(s.infer(&x).unwrap(), want);
        assert_eq!(fb.requests(), 5);
        assert_eq!(fb.injected(), 3);
    }

    #[test]
    fn indices_are_shared_across_sessions() {
        let fb =
            FaultBackend::new(inner(), FaultPlan::new().at(1, Fault::Error));
        let mut a = fb.session().unwrap();
        let mut b = fb.session().unwrap();
        let x = [0.1f32, 0.1];
        assert!(a.infer(&x).is_ok(), "index 0 via session a");
        assert!(
            b.infer(&x).is_err(),
            "index 1 fires via a *different* session: the counter \
             lives in the backend"
        );
        assert_eq!(fb.injected(), 1);
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        let a = FaultPlan::seeded(42, 1000, 0.05);
        let b = FaultPlan::seeded(42, 1000, 0.05);
        assert_eq!(a.faults, b.faults, "same seed, same plan");
        assert!(!a.is_empty(), "5% of 1000 indices faults some");
        assert!(a.len() < 200, "rate stays in the right ballpark");
        let c = FaultPlan::seeded(43, 1000, 0.05);
        assert_ne!(a.faults, c.faults, "different seed, different plan");
    }

    #[test]
    fn injected_panic_is_contained_by_the_supervised_pool() {
        let fb = FaultBackend::shared(
            inner(),
            FaultPlan::new().at(2, Fault::Panic),
        );
        let pool =
            Pool::new(fb, PoolConfig { workers: 1, max_batch: 1 });
        let want = pool.infer(&[0.3, 0.3]).unwrap(); // index 0
        let mut outcomes = Vec::new();
        for _ in 0..4 {
            outcomes.push(pool.infer(&[0.3, 0.3])); // indices 1..=4
        }
        let panics = outcomes
            .iter()
            .filter(|r| {
                matches!(
                    r,
                    Err(InferenceError::BackendPanicked { .. })
                )
            })
            .count();
        assert_eq!(panics, 1, "exactly the planned request panicked");
        for r in outcomes.into_iter().filter(|r| r.is_ok()) {
            assert_eq!(r.unwrap(), want, "survivors are bit-identical");
        }
        // The pool restaffs after the contained panic.
        let t0 = std::time::Instant::now();
        while !pool.health().is_healthy() {
            assert!(t0.elapsed() < Duration::from_secs(30));
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.health().panics_contained, 1);
    }
}
