//! Ingress admission control: reject work that cannot finish in
//! budget *before* it queues.
//!
//! Shedding at the worker (see `serve::pool`) protects the pool from
//! serving stale answers; admission protects the *queue* — under
//! overload it is strictly better to refuse a doomed deadline at
//! submit time (the caller can fail over, degrade, or drop) than to
//! let it occupy queue slots and be shed later anyway. The estimate
//! comes from the repo's PLC cost model (`plc/profiles.rs` cost
//! vectors over a calibrated [`Meter`], or a coarse MAC count), the
//! same modeled microseconds the §6.3 multipart scheduler budgets
//! with.

use crate::api::InferenceError;
use crate::plc::HwProfile;
use crate::st::Meter;

use super::queue::Deadline;

/// A per-request cost estimate plus the admission formula.
///
/// Attached to a pool via `Pool::with_admission`, it turns
/// `Pool::submit_with` into a gate: a request whose deadline cannot be
/// met even if everything already queued is served on schedule is
/// rejected with [`InferenceError::DeadlineExceeded`] at submit time.
#[derive(Debug, Clone)]
pub struct Admission {
    profile: HwProfile,
    est_us: f64,
}

impl Admission {
    /// Gate on an explicit per-request estimate (µs).
    pub fn new(profile: HwProfile, est_us: f64) -> Admission {
        Admission { profile, est_us: est_us.max(0.0) }
    }

    /// Estimate from a calibrated abstract-op [`Meter`] (e.g. the
    /// `last_meter()` of one warmup inference on an ST session):
    /// `profile.time_us(meter)` modeled microseconds per request.
    pub fn from_meter(profile: HwProfile, m: &Meter) -> Admission {
        let est_us = profile.time_us(m);
        Admission::new(profile, est_us)
    }

    /// Coarse estimate from a dense MAC count (for substrates that do
    /// not meter): each MAC is costed as one FP multiply, one FP add
    /// and two loads on the profile's cost vector. A lower bound — it
    /// ignores activations, call and branch overhead.
    pub fn from_macs(profile: HwProfile, macs: f64) -> Admission {
        let mut m = Meter::new();
        let n = macs.max(0.0) as u64;
        m.fp_mul = n;
        m.fp_add = n;
        m.loads = 2 * n;
        Admission::from_meter(profile, &m)
    }

    /// The modeled per-request cost (µs).
    pub fn estimate_us(&self) -> f64 {
        self.est_us
    }

    /// The hardware profile the estimate is modeled on.
    pub fn profile(&self) -> &HwProfile {
        &self.profile
    }

    /// Modeled completion time (µs from now) of a request arriving
    /// behind `queued` requests on a pool with `workers` workers: the
    /// backlog is assumed evenly spread, so the new request waits
    /// `⌊queued / workers⌋` service times and then runs once.
    pub fn projected_us(&self, queued: usize, workers: usize) -> f64 {
        let ahead = (queued / workers.max(1)) + 1;
        self.est_us * ahead as f64
    }

    /// The admission formula: admit unless the request's deadline is
    /// sooner than its modeled completion
    /// ([`Admission::projected_us`]). Requests without a deadline are
    /// always admitted — there is nothing to miss.
    pub fn admit(
        &self,
        deadline: Option<&Deadline>,
        queued: usize,
        workers: usize,
    ) -> Result<(), InferenceError> {
        let Some(d) = deadline else { return Ok(()) };
        let needed = self.projected_us(queued, workers);
        let remaining = d.remaining_us();
        if remaining < needed {
            return Err(InferenceError::DeadlineExceeded {
                stage: "admission",
                late_us: needed - remaining,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(est_us: f64) -> Admission {
        Admission::new(HwProfile::beaglebone(), est_us)
    }

    #[test]
    fn no_deadline_always_admitted() {
        let a = gate(1e9);
        assert!(a.admit(None, 10_000, 1).is_ok());
    }

    #[test]
    fn infeasible_deadline_rejected_at_ingress() {
        let a = gate(1_000_000.0); // 1 s per request, modeled
        let d = Deadline::within_us(1_000.0); // 1 ms budget
        match a.admit(Some(&d), 0, 4) {
            Err(InferenceError::DeadlineExceeded { stage, late_us }) => {
                assert_eq!(stage, "admission");
                assert!(late_us > 0.0);
            }
            other => panic!("want DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn feasible_deadline_admitted() {
        let a = gate(100.0); // 100 µs per request
        let d = Deadline::within_us(1_000_000.0); // 1 s budget
        assert!(a.admit(Some(&d), 8, 4).is_ok());
    }

    #[test]
    fn backlog_counts_against_the_budget() {
        let a = gate(1_000.0);
        // Same generous-ish budget: an empty pool admits, a deep
        // backlog on one worker does not.
        let near = Deadline::within_us(5_000.0);
        assert!(a.admit(Some(&near), 0, 1).is_ok());
        let near = Deadline::within_us(5_000.0);
        assert!(a.admit(Some(&near), 100, 1).is_err());
        // More workers absorb the same backlog.
        let near = Deadline::within_us(5_000.0);
        assert!(a.admit(Some(&near), 3, 4).is_ok());
    }

    #[test]
    fn mac_estimate_scales_with_model() {
        let small = Admission::from_macs(HwProfile::beaglebone(), 1_000.0);
        let big = Admission::from_macs(HwProfile::beaglebone(), 100_000.0);
        assert!(big.estimate_us() > 50.0 * small.estimate_us());
        assert!(small.estimate_us() > 0.0);
    }

    #[test]
    fn meter_estimate_matches_profile_time() {
        let profile = HwProfile::beaglebone();
        let mut m = Meter::new();
        m.fp_mul = 8256;
        m.loads = 29_708;
        let a = Admission::from_meter(profile.clone(), &m);
        assert!((a.estimate_us() - profile.time_us(&m)).abs() < 1e-9);
        assert_eq!(a.profile().name, "BeagleBone Black");
    }
}
