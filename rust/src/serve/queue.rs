//! Deadline-aware request scheduling: the [`DeadlineQueue`] behind
//! [`crate::serve::Pool`].
//!
//! The paper's core constraint is that inference must fit inside a
//! PLC's hard scan-cycle budget (§6.3); a serving tier in front of a
//! controller fleet inherits the same law — an answer that arrives
//! after the scan cycle that needed it is worthless, and a defense
//! that blows the cycle is a defense operators turn off. So the pool's
//! old FIFO `mpsc` channel is replaced by a scheduler with three
//! properties:
//!
//! 1. **Priority bands** ([`Priority`]): `Control` (closes a control
//!    loop) preempts `Defense` (detection streams) preempts `Batch`
//!    (offline scoring). A band is drained before the next is looked
//!    at.
//! 2. **Earliest-deadline-first within a band**: requests carrying a
//!    [`Deadline`] pop before undeadlined ones, tightest first.
//!    Undeadlined requests keep strict FIFO order (submission
//!    sequence), so a pool fed only plain `submit` calls behaves
//!    bit-identically to the old FIFO queue.
//! 3. **Lock-sheltered**: one `Mutex` around three binary heaps plus a
//!    `Condvar`; the lock is held only to push/pop, never while
//!    serving. Workers block on the condvar, so an idle pool burns no
//!    CPU.
//!
//! Expiry is *not* handled here — the queue ranks, the worker sheds
//! (see `serve::pool`): a request whose deadline has passed when a
//! worker picks it up is answered with
//! [`crate::api::InferenceError::DeadlineExceeded`] instead of being
//! served late.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::plc::{HwProfile, ScanCycle};
use crate::st::Meter;

/// Request priority class, declared in scheduling order: an earlier
/// variant always pops before a later one, whatever the deadlines say.
///
/// The classes mirror the deployment model of the PLC-security
/// literature: `Control` requests close a control loop this scan
/// cycle, `Defense` requests feed the §7 detection streams, `Batch`
/// requests are throughput traffic (re-scoring, evaluation) that can
/// always wait.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// In-the-loop control inference: most urgent, never queued behind
    /// anything else.
    Control,
    /// Online detection / monitoring traffic.
    Defense,
    /// Offline or best-effort traffic (the default for plain
    /// `Pool::submit`).
    #[default]
    Batch,
}

/// Number of priority bands (one heap each).
pub(crate) const BANDS: usize = 3;

impl Priority {
    /// The band index this class schedules in (0 = most urgent).
    pub fn band(self) -> usize {
        self as usize
    }

    /// All classes, in scheduling order.
    pub const ALL: [Priority; BANDS] =
        [Priority::Control, Priority::Defense, Priority::Batch];

    /// Parse a class name as used by the `serve` CLI
    /// (`control`/`defense`/`batch`, case-insensitive).
    pub fn from_name(name: &str) -> Option<Priority> {
        match name.to_ascii_lowercase().as_str() {
            "control" => Some(Priority::Control),
            "defense" => Some(Priority::Defense),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }

    /// The class name (`"control"`/`"defense"`/`"batch"`).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Control => "control",
            Priority::Defense => "defense",
            Priority::Batch => "batch",
        }
    }
}

/// An absolute wall-clock expiry for one request.
///
/// A deadline can be given directly ([`Deadline::at`] /
/// [`Deadline::within`]) or derived from the repo's PLC cost model:
/// [`Deadline::for_meter`] budgets the wall-clock time the inference
/// *would* take on real PLC hardware (`HwProfile::time_us` over a
/// calibrated [`Meter`]), and [`Deadline::for_scan`] budgets the slack
/// a scan cycle has left after its control task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Deadline(Instant);

impl Deadline {
    /// Expire at an absolute instant.
    pub fn at(t: Instant) -> Deadline {
        Deadline(t)
    }

    /// Expire `d` from now.
    pub fn within(d: Duration) -> Deadline {
        Deadline(Instant::now() + d)
    }

    /// Expire `us` microseconds from now (negative or NaN budgets
    /// clamp to "already due").
    pub fn within_us(us: f64) -> Deadline {
        Deadline::within(Duration::from_secs_f64(us.max(0.0) / 1e6))
    }

    /// Budget the modeled PLC execution time of a metered workload:
    /// the serving tier commits to answering no later than the real
    /// controller hardware would have.
    pub fn for_meter(profile: &HwProfile, m: &Meter) -> Deadline {
        Deadline::within(profile.budget(m))
    }

    /// Budget a scan cycle's remaining ML slack (period minus the
    /// control task) — the §6.3 deadline of an in-cycle inference.
    pub fn for_scan(cycle: &ScanCycle, control_us: f64) -> Deadline {
        Deadline::within(cycle.ml_budget(control_us))
    }

    /// The absolute expiry instant.
    pub fn instant(&self) -> Instant {
        self.0
    }

    /// Microseconds left before expiry (0 once due).
    pub fn remaining_us(&self) -> f64 {
        self.0
            .saturating_duration_since(Instant::now())
            .as_secs_f64()
            * 1e6
    }

    /// The deadline is due at `now` (due-exactly-now counts as
    /// expired, so a zero budget always sheds).
    pub fn expired_at(&self, now: Instant) -> bool {
        now >= self.0
    }

    /// The deadline is due.
    pub fn expired(&self) -> bool {
        self.expired_at(Instant::now())
    }

    /// Microseconds past expiry at `now` (0 if still live).
    pub fn late_by_us(&self, now: Instant) -> f64 {
        now.saturating_duration_since(self.0).as_secs_f64() * 1e6
    }
}

/// Per-request scheduling options for `Pool::submit_with`.
///
/// The default (`SubmitOptions::default()`) is what plain
/// `Pool::submit` uses: `Batch` class, no deadline — the old FIFO
/// behavior.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Priority class (band) the request schedules in.
    pub priority: Priority,
    /// Optional expiry; an expired request is shed, never served late.
    pub deadline: Option<Deadline>,
}

impl SubmitOptions {
    /// Default options: `Batch` class, no deadline.
    pub fn new() -> SubmitOptions {
        SubmitOptions::default()
    }

    /// Set the priority class.
    pub fn priority(mut self, p: Priority) -> SubmitOptions {
        self.priority = p;
        self
    }

    /// Set the deadline.
    pub fn deadline(mut self, d: Deadline) -> SubmitOptions {
        self.deadline = Some(d);
        self
    }
}

/// Scheduling metadata travelling with each queued item.
#[derive(Debug, Clone, Copy)]
pub struct Meta {
    /// Priority class the item was submitted with.
    pub priority: Priority,
    /// Optional expiry.
    pub deadline: Option<Deadline>,
    /// Queue-assigned submission sequence number (the FIFO tie-break).
    pub seq: u64,
}

/// Heap entry: ordered so the max-heap's top is the next item to
/// serve — earliest deadline first, undeadlined items last among
/// their band, FIFO (lowest `seq`) within ties.
struct Ranked<T> {
    meta: Meta,
    item: T,
}

impl<T> Ord for Ranked<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // "Greater" pops first from BinaryHeap: an earlier deadline
        // ranks greater; a present deadline ranks greater than none;
        // ties resolve to the lower submission sequence (FIFO).
        let by_deadline = match (self.meta.deadline, other.meta.deadline) {
            (Some(a), Some(b)) => b.cmp(&a),
            (Some(_), None) => Ordering::Greater,
            (None, Some(_)) => Ordering::Less,
            (None, None) => Ordering::Equal,
        };
        by_deadline.then_with(|| other.meta.seq.cmp(&self.meta.seq))
    }
}

impl<T> PartialOrd for Ranked<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> PartialEq for Ranked<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Ranked<T> {}

struct Inner<T> {
    bands: [BinaryHeap<Ranked<T>>; BANDS],
    seq: u64,
    len: usize,
    closed: bool,
}

/// A closeable, priority-banded, earliest-deadline-first queue.
///
/// `push` is non-blocking; [`DeadlineQueue::pop_wait`] blocks on a
/// condvar until an item or close+empty. Batch formation uses
/// [`DeadlineQueue::try_pop_if`]: pop the *best* queued item only if a
/// caller predicate admits it — the predicate sees the item's [`Meta`]
/// and typically checks deadline compatibility with a forming batch.
pub struct DeadlineQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T> Default for DeadlineQueue<T> {
    fn default() -> Self {
        DeadlineQueue::new()
    }
}

impl<T> DeadlineQueue<T> {
    /// An open, empty queue.
    pub fn new() -> DeadlineQueue<T> {
        DeadlineQueue {
            inner: Mutex::new(Inner {
                bands: [
                    BinaryHeap::new(),
                    BinaryHeap::new(),
                    BinaryHeap::new(),
                ],
                seq: 0,
                len: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Ignore poisoning: the queue's state is just pending requests,
    /// and a panicking worker must not wedge its siblings (the pool
    /// additionally drains + fails pending requests when the *last*
    /// worker dies).
    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Enqueue one item. Returns `false` when the queue is closed —
    /// the item is dropped, which is how a pool with no live workers
    /// fails a `Ticket` (the dropped response channel reports it).
    pub fn push(
        &self,
        priority: Priority,
        deadline: Option<Deadline>,
        item: T,
    ) -> bool {
        {
            let mut q = self.lock();
            if q.closed {
                return false;
            }
            let meta = Meta { priority, deadline, seq: q.seq };
            q.seq += 1;
            q.len += 1;
            q.bands[priority.band()].push(Ranked { meta, item });
        }
        self.cv.notify_one();
        true
    }

    fn pop_best(q: &mut Inner<T>) -> Option<(Meta, T)> {
        let Inner { bands, len, .. } = q;
        for heap in bands.iter_mut() {
            if let Some(r) = heap.pop() {
                *len -= 1;
                return Some((r.meta, r.item));
            }
        }
        None
    }

    /// Blocking pop of the next item to serve. Returns `None` only
    /// once the queue is closed *and* drained — pending items are
    /// always handed out, even after close.
    pub fn pop_wait(&self) -> Option<(Meta, T)> {
        let mut q = self.lock();
        loop {
            if let Some(e) = Self::pop_best(&mut q) {
                return Some(e);
            }
            if q.closed {
                return None;
            }
            q = self
                .cv
                .wait(q)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking conditional pop: if the queue's best item passes
    /// `admit`, pop and return it; otherwise (or when empty) return
    /// `None` *without* popping. Never skips past the best item —
    /// scheduling order is preserved even when a batch stops filling.
    pub fn try_pop_if<F>(&self, mut admit: F) -> Option<(Meta, T)>
    where
        F: FnMut(&Meta) -> bool,
    {
        let mut q = self.lock();
        let Inner { bands, len, .. } = &mut *q;
        for heap in bands.iter_mut() {
            let admitted = match heap.peek() {
                Some(top) => admit(&top.meta),
                None => continue,
            };
            if !admitted {
                return None;
            }
            let r = heap.pop().expect("peeked entry vanished");
            *len -= 1;
            return Some((r.meta, r.item));
        }
        None
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.lock().len
    }

    /// No items queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: further pushes fail, blocked poppers drain the
    /// remaining items and then observe the close.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    /// Pop everything still queued (used by the pool to fail pending
    /// requests when the last worker exits).
    pub fn drain(&self) -> Vec<(Meta, T)> {
        let mut q = self.lock();
        let mut out = Vec::with_capacity(q.len);
        while let Some(e) = Self::pop_best(&mut q) {
            out.push(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_without_deadlines() {
        let q: DeadlineQueue<u32> = DeadlineQueue::new();
        for i in 0..10u32 {
            assert!(q.push(Priority::Batch, None, i));
        }
        for i in 0..10u32 {
            let (meta, item) = q.pop_wait().expect("queued");
            assert_eq!(item, i, "no-deadline traffic must stay FIFO");
            assert_eq!(meta.seq, i as u64);
        }
        assert!(q.is_empty());
    }

    #[test]
    fn earliest_deadline_first_within_band() {
        let q: DeadlineQueue<&str> = DeadlineQueue::new();
        let now = Instant::now();
        q.push(
            Priority::Batch,
            Some(Deadline::at(now + Duration::from_millis(30))),
            "late",
        );
        q.push(Priority::Batch, None, "none");
        q.push(
            Priority::Batch,
            Some(Deadline::at(now + Duration::from_millis(10))),
            "tight",
        );
        assert_eq!(q.pop_wait().unwrap().1, "tight");
        assert_eq!(q.pop_wait().unwrap().1, "late");
        assert_eq!(q.pop_wait().unwrap().1, "none");
    }

    #[test]
    fn priority_bands_preempt_deadlines() {
        let q: DeadlineQueue<&str> = DeadlineQueue::new();
        q.push(
            Priority::Batch,
            Some(Deadline::within(Duration::from_millis(1))),
            "batch-tight",
        );
        q.push(Priority::Defense, None, "defense");
        q.push(Priority::Control, None, "control");
        // Band order wins over any deadline in a lower band.
        assert_eq!(q.pop_wait().unwrap().1, "control");
        assert_eq!(q.pop_wait().unwrap().1, "defense");
        assert_eq!(q.pop_wait().unwrap().1, "batch-tight");
    }

    #[test]
    fn try_pop_if_respects_predicate_and_order() {
        let q: DeadlineQueue<u32> = DeadlineQueue::new();
        q.push(Priority::Batch, Some(Deadline::within_us(1e6)), 1);
        q.push(Priority::Batch, None, 2);
        // Predicate rejects the best (deadlined) entry: nothing pops,
        // including the compatible one *behind* it.
        assert!(q.try_pop_if(|m| m.deadline.is_none()).is_none());
        assert_eq!(q.len(), 2);
        // Accepting predicate pops in scheduling order.
        assert_eq!(q.try_pop_if(|_| true).unwrap().1, 1);
        assert_eq!(q.try_pop_if(|_| true).unwrap().1, 2);
        assert!(q.try_pop_if(|_| true).is_none());
    }

    #[test]
    fn close_drains_then_ends() {
        let q: DeadlineQueue<u32> = DeadlineQueue::new();
        q.push(Priority::Batch, None, 7);
        q.close();
        assert!(!q.push(Priority::Batch, None, 8), "push after close");
        assert_eq!(q.pop_wait().unwrap().1, 7, "pending items still served");
        assert!(q.pop_wait().is_none(), "closed + empty ends the loop");
    }

    #[test]
    fn pop_wait_blocks_until_push() {
        use std::sync::Arc;
        let q: Arc<DeadlineQueue<u32>> = Arc::new(DeadlineQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(Duration::from_millis(20));
        q.push(Priority::Control, None, 42);
        assert_eq!(h.join().unwrap().unwrap().1, 42);
    }

    #[test]
    fn deadline_arithmetic() {
        let d = Deadline::within_us(50_000.0);
        assert!(!d.expired());
        assert!(d.remaining_us() > 0.0);
        let past = Deadline::within_us(0.0);
        // A zero budget is due immediately ("now >= deadline").
        std::thread::sleep(Duration::from_millis(1));
        assert!(past.expired());
        assert!(past.late_by_us(Instant::now()) > 0.0);
        // Negative / NaN budgets clamp instead of panicking.
        let _ = Deadline::within_us(-5.0);
        let _ = Deadline::within_us(f64::NAN);
    }

    #[test]
    fn priority_parse_roundtrip() {
        for p in Priority::ALL {
            assert_eq!(Priority::from_name(p.name()), Some(p));
        }
        assert_eq!(Priority::from_name("CONTROL"), Some(Priority::Control));
        assert_eq!(Priority::from_name("nope"), None);
        assert_eq!(Priority::default(), Priority::Batch);
    }

    #[test]
    fn deadline_from_cost_model() {
        let profile = crate::plc::HwProfile::beaglebone();
        let mut m = Meter::new();
        m.fp_mul = 1_000_000; // ~34 ms modeled
        let d = Deadline::for_meter(&profile, &m);
        let rem = d.remaining_us();
        assert!(rem > 10_000.0 && rem < 60_000.0, "got {rem} µs");

        let cycle = ScanCycle::new(profile, 100_000.0);
        let d = Deadline::for_scan(&cycle, 40_000.0);
        let rem = d.remaining_us();
        assert!(rem > 30_000.0 && rem <= 60_000.0, "got {rem} µs");
    }
}
