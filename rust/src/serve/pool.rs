//! [`Pool`]: a fixed set of worker threads serving inference requests
//! from one shared backend.
//!
//! Design:
//!
//! * **Shared backend, private sessions.** Workers receive an
//!   `Arc<dyn Backend + Send + Sync>` and mint their [`Session`]
//!   *inside* the worker thread — sessions are deliberately not
//!   `Send`, so this is the only sound construction, and it is exactly
//!   what the Engine/Session split exists for.
//! * **One shared queue** (`Mutex<Receiver>`): the classic
//!   work-stealing-free competitive-consumer pool. Fairness comes from
//!   the OS scheduler; the lock is held only to pop, never to serve.
//! * **Micro-batching.** After blocking on one request, a worker
//!   drains up to `max_batch - 1` more without blocking and serves
//!   them through one [`Session::infer_batch`] call. For the engine
//!   this is exactly equivalent to sequential `infer_into` (the API
//!   contract), so batching never changes results — asserted in
//!   `tests/concurrency.rs`. If a substrate rejects a ragged batch
//!   (fixed-batch XLA), the worker falls back to per-request serving.
//! * **No new dependencies**: `std::sync::mpsc` + threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::{Backend, InferenceError, Session, SharedBackend};

/// Pool sizing knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker threads (each with a private session). Clamped to ≥ 1.
    pub workers: usize,
    /// Max requests served per `infer_batch` call. Clamped to ≥ 1.
    pub max_batch: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { workers: 4, max_batch: 8 }
    }
}

struct Job {
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>, InferenceError>>,
}

/// Per-pool counters (atomics: read without stopping the workers).
#[derive(Default)]
struct Counters {
    served: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
}

/// A handle to an in-flight request; [`Ticket::wait`] blocks for the
/// result. Submitting many tickets before waiting keeps every worker
/// busy (that is the bench's pipelining model).
pub struct Ticket {
    rx: Receiver<Result<Vec<f32>, InferenceError>>,
}

impl Ticket {
    pub fn wait(self) -> Result<Vec<f32>, InferenceError> {
        self.rx.recv().unwrap_or_else(|_| {
            Err(InferenceError::BackendUnavailable {
                backend: "pool".into(),
                reason: "worker disconnected before replying".into(),
            })
        })
    }
}

/// The worker pool. Dropping it shuts the queue and joins every
/// worker.
pub struct Pool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    counters: Arc<Counters>,
    worker_served: Arc<Vec<AtomicU64>>,
    in_dim: usize,
}

impl Pool {
    /// Spin up `cfg.workers` threads over one shared backend.
    pub fn new(backend: SharedBackend, cfg: PoolConfig) -> Pool {
        let n_workers = cfg.workers.max(1);
        let max_batch = cfg.max_batch.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let counters = Arc::new(Counters::default());
        let worker_served: Arc<Vec<AtomicU64>> = Arc::new(
            (0..n_workers).map(|_| AtomicU64::new(0)).collect(),
        );
        let in_dim = backend.spec().in_dim;
        let workers = (0..n_workers)
            .map(|w| {
                let backend = Arc::clone(&backend);
                let rx = Arc::clone(&rx);
                let counters = Arc::clone(&counters);
                let worker_served = Arc::clone(&worker_served);
                std::thread::spawn(move || {
                    worker_loop(
                        w,
                        backend,
                        rx,
                        max_batch,
                        counters,
                        worker_served,
                    )
                })
            })
            .collect();
        Pool {
            tx: Some(tx),
            workers,
            counters,
            worker_served,
            in_dim,
        }
    }

    /// Enqueue one request; returns immediately with a [`Ticket`].
    pub fn submit(&self, x: &[f32]) -> Ticket {
        let (resp, rx) = channel();
        let job = Job { x: x.to_vec(), resp };
        if let Some(tx) = &self.tx {
            // A send error means every worker is gone; the ticket then
            // reports BackendUnavailable from its closed channel.
            let _ = tx.send(job);
        }
        Ticket { rx }
    }

    /// Blocking convenience: submit + wait.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>, InferenceError> {
        self.submit(x).wait()
    }

    /// Requests answered successfully so far.
    pub fn served(&self) -> u64 {
        self.counters.served.load(Ordering::Relaxed)
    }

    /// Request groups executed (served / batches = mean group size,
    /// regardless of whether a group went through `infer_batch` or the
    /// per-request fallback).
    pub fn batches(&self) -> u64 {
        self.counters.batches.load(Ordering::Relaxed)
    }

    /// Requests answered with an error.
    pub fn errors(&self) -> u64 {
        self.counters.errors.load(Ordering::Relaxed)
    }

    /// Per-worker served counts (shard-balance introspection for the
    /// bench and tests).
    pub fn worker_served(&self) -> Vec<u64> {
        self.worker_served
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// The pool's expected input length (from the backend spec).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // Closing the channel ends every worker's recv loop.
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn unavailable(reason: &str) -> InferenceError {
    InferenceError::BackendUnavailable {
        backend: "pool".into(),
        reason: reason.to_string(),
    }
}

fn worker_loop(
    w: usize,
    backend: SharedBackend,
    rx: Arc<Mutex<Receiver<Job>>>,
    max_batch: usize,
    counters: Arc<Counters>,
    worker_served: Arc<Vec<AtomicU64>>,
) {
    // Sessions are minted on the worker thread (they are not Send).
    // A backend that cannot create sessions still drains the queue,
    // answering every request with the typed reason.
    let mut session: Option<Box<dyn Session>> = None;
    let mut session_err = String::new();
    match backend.session() {
        Ok(s) => session = Some(s),
        Err(e) => session_err = e.to_string(),
    }
    let (in_dim, out_dim, granularity) = match &session {
        Some(s) => {
            let spec = s.spec();
            (spec.in_dim, spec.out_dim, spec.batch_granularity.max(1))
        }
        None => (0, 0, 1),
    };

    // Reused across batches: after warmup these hit their high-water
    // capacity and stop allocating.
    let mut xs: Vec<f32> = Vec::new();
    let mut out: Vec<f32> = Vec::new();
    let mut jobs: Vec<Job> = Vec::new();

    loop {
        jobs.clear();
        {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(_) => return, // a sibling panicked; shut down
            };
            match guard.recv() {
                Ok(j) => jobs.push(j),
                Err(_) => return, // pool dropped: queue closed
            }
            while jobs.len() < max_batch {
                match guard.try_recv() {
                    Ok(j) => jobs.push(j),
                    Err(TryRecvError::Empty)
                    | Err(TryRecvError::Disconnected) => break,
                }
            }
        } // queue lock released before any inference work

        let Some(session) = session.as_mut() else {
            for j in jobs.drain(..) {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(unavailable(&session_err)));
            }
            continue;
        };

        // Split off malformed requests so one bad client cannot poison
        // a whole batch.
        let mut batch: Vec<Job> = Vec::with_capacity(jobs.len());
        for j in jobs.drain(..) {
            if j.x.len() == in_dim {
                batch.push(j);
            } else {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(InferenceError::ShapeMismatch {
                    what: "input",
                    expected: in_dim,
                    got: j.x.len(),
                }));
            }
        }
        if batch.is_empty() {
            continue;
        }

        // Fixed-batch substrates (XLA with compiled_batch > 1) can
        // only execute multiples of their granularity: cut the largest
        // servable head chunk and answer the remainder with a typed
        // error up front — single requests are *unservable* there, so
        // holding them back would strand them, and submitting a ragged
        // batch would doom the whole group.
        let head = if granularity > 1 {
            let m = (batch.len() / granularity) * granularity;
            for j in batch.drain(m..) {
                counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(InferenceError::ShapeMismatch {
                    what: "batch rows (must be a multiple of the \
                           compiled batch)",
                    expected: granularity,
                    got: 1,
                }));
            }
            m
        } else {
            batch.len()
        };
        if head == 0 {
            continue;
        }

        let n = batch.len();
        let mut group_served = 0u64;
        let mut served_batched = false;
        if n > 1 || granularity > 1 {
            xs.clear();
            for j in &batch {
                xs.extend_from_slice(&j.x);
            }
            out.clear();
            out.resize(n * out_dim, 0.0);
            // Batch path; equivalence with sequential infer_into is
            // part of the Session contract. If a substrate still
            // refuses the batch, fall through to the per-request path
            // below.
            if session.infer_batch(&xs, &mut out).is_ok() {
                for (i, j) in batch.drain(..).enumerate() {
                    group_served += 1;
                    worker_served[w].fetch_add(1, Ordering::Relaxed);
                    let _ = j
                        .resp
                        .send(Ok(out[i * out_dim..(i + 1) * out_dim].to_vec()));
                }
                served_batched = true;
            }
        }
        if !served_batched {
            for j in batch.drain(..) {
                out.clear();
                out.resize(out_dim, 0.0);
                match session.infer_into(&j.x, &mut out) {
                    Ok(()) => {
                        group_served += 1;
                        worker_served[w].fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Ok(out.clone()));
                    }
                    Err(e) => {
                        counters.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Err(e));
                    }
                }
            }
        }
        // One "batch" per drained group that served anything, whatever
        // path executed it — so served/batches is a true mean group
        // size even when a substrate forces per-request fallback.
        if group_served > 0 {
            counters.served.fetch_add(group_served, Ordering::Relaxed);
            counters.batches.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, EngineBackend};
    use crate::engine::{Act, Layer, Model};

    fn model() -> Model {
        Model::new(vec![
            Layer::dense(
                (0..8 * 6).map(|i| ((i % 11) as f32) * 0.1 - 0.5).collect(),
                vec![0.05; 6],
                8,
                Act::Relu,
            ),
            Layer::dense(
                (0..6 * 3).map(|i| 0.3 - (i % 4) as f32 * 0.1).collect(),
                vec![0.0; 3],
                6,
                Act::None,
            ),
        ])
    }

    #[test]
    fn pool_matches_sequential_session() {
        let backend = Arc::new(EngineBackend::new(model()));
        let mut reference = backend.session().unwrap();
        let inputs: Vec<Vec<f32>> = (0..40)
            .map(|i| {
                (0..8).map(|k| ((i * 8 + k) as f32 * 0.17).sin()).collect()
            })
            .collect();
        let want: Vec<Vec<f32>> =
            inputs.iter().map(|x| reference.infer(x).unwrap()).collect();

        let pool =
            Pool::new(backend, PoolConfig { workers: 3, max_batch: 4 });
        // Pipelined: all tickets in flight at once.
        let tickets: Vec<Ticket> =
            inputs.iter().map(|x| pool.submit(x)).collect();
        for (t, w) in tickets.into_iter().zip(&want) {
            let got = t.wait().unwrap();
            assert_eq!(&got, w, "pool result must be bit-identical");
        }
        assert_eq!(pool.served(), 40);
        assert_eq!(pool.errors(), 0);
        assert!(pool.batches() <= 40, "batching must coalesce, not inflate");
        let per_worker = pool.worker_served();
        assert_eq!(per_worker.iter().sum::<u64>(), 40);
    }

    #[test]
    fn pool_reports_shape_mismatch_per_request() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool = Pool::new(backend, PoolConfig::default());
        match pool.infer(&[0.0; 3]) {
            Err(InferenceError::ShapeMismatch { expected: 8, got: 3, .. }) => {}
            other => panic!("want ShapeMismatch, got {other:?}"),
        }
        // Healthy traffic still flows afterwards.
        assert_eq!(pool.infer(&[0.1; 8]).unwrap().len(), 3);
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool =
            Pool::new(backend, PoolConfig { workers: 2, max_batch: 2 });
        assert_eq!(pool.infer(&[0.2; 8]).unwrap().len(), 3);
        drop(pool); // joins workers; must not hang or panic
    }
}
