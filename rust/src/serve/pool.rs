//! [`Pool`]: a fixed set of worker threads serving inference requests
//! from one shared backend, under deadline-aware scheduling.
//!
//! Design:
//!
//! * **Shared backend, private sessions.** Workers receive an
//!   `Arc<dyn Backend + Send + Sync>` and mint their [`Session`]
//!   *inside* the worker thread — sessions are deliberately not
//!   `Send`, so this is the only sound construction, and it is exactly
//!   what the Engine/Session split exists for.
//! * **One shared [`DeadlineQueue`]** (`serve::queue`): priority bands
//!   (`Control` > `Defense` > `Batch`) with earliest-deadline-first
//!   ordering inside each band, strict FIFO for undeadlined traffic.
//!   The lock is held only to push/pop, never to serve.
//! * **Deadline-compatible micro-batching.** After blocking on one
//!   request, a worker drains up to `max_batch - 1` more *only while
//!   every member of the forming batch (and the candidate) can still
//!   meet its deadline at the projected batch completion time*,
//!   estimated from a per-worker moving average of measured service
//!   time. An urgent request is therefore never delayed by a filling
//!   batch; undeadlined traffic batches exactly like the old FIFO
//!   pool. Batches go through one [`Session::infer_batch`] call
//!   (bit-equivalent to sequential `infer_into` — the API contract,
//!   asserted in `tests/concurrency.rs`), with per-request fallback
//!   when a substrate rejects the batch.
//! * **Sheds, not stale answers.** A request whose [`Deadline`] has
//!   passed when a worker picks it up is answered with
//!   [`InferenceError::DeadlineExceeded`] instead of being served
//!   late ([`Pool::shed`] counts them). An optional ingress
//!   [`Admission`] gate rejects provably-infeasible deadlines at
//!   [`Pool::submit_with`] time, before they occupy queue slots.
//! * **No worker, no hang.** If every worker has exited (e.g. a
//!   backend that panics), pending and future requests fail with a
//!   typed error instead of blocking [`Ticket::wait`] forever.
//! * **No new dependencies**: `std::sync` primitives + threads.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, Receiver, RecvTimeoutError, Sender, TryRecvError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{Backend, InferenceError, Session, SharedBackend};

use super::admission::Admission;
use super::queue::{Deadline, DeadlineQueue, Meta, SubmitOptions};

/// Pool sizing knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker threads (each with a private session). Clamped to ≥ 1.
    pub workers: usize,
    /// Max requests served per `infer_batch` call. Clamped to ≥ 1.
    pub max_batch: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { workers: 4, max_batch: 8 }
    }
}

struct Job {
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>, InferenceError>>,
}

/// Per-pool counters (atomics: read without stopping the workers).
#[derive(Default)]
struct Counters {
    served: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
}

/// A handle to an in-flight request; [`Ticket::wait`] blocks for the
/// result, [`Ticket::try_wait`] / [`Ticket::wait_timeout`] probe it
/// without committing a thread. Submitting many tickets before
/// waiting keeps every worker busy (that is the bench's pipelining
/// model), and the non-blocking probes are how the `netserve` event
/// loop drives thousands of in-flight requests over W workers without
/// a blocked thread per request.
///
/// A ticket resolves exactly once: a probe that returns `None` leaves
/// the eventual result intact for a later probe or a final
/// [`Ticket::wait`]; after the result has been taken, further probes
/// report the serving side as disconnected.
pub struct Ticket {
    rx: Receiver<Result<Vec<f32>, InferenceError>>,
}

impl Ticket {
    /// The typed resolution of a dead serving side (queue closed, all
    /// workers exited, worker died mid-request).
    fn disconnected() -> Result<Vec<f32>, InferenceError> {
        Err(InferenceError::BackendUnavailable {
            backend: "pool".into(),
            reason: "worker disconnected before replying".into(),
        })
    }

    /// Block until the request resolves. Never hangs: if the serving
    /// side is gone (queue closed, all workers exited, worker died
    /// mid-request) the disconnected channel resolves to a typed
    /// [`InferenceError::BackendUnavailable`].
    pub fn wait(self) -> Result<Vec<f32>, InferenceError> {
        self.rx.recv().unwrap_or_else(|_| Ticket::disconnected())
    }

    /// Non-blocking readiness probe: `Some(result)` once the request
    /// has resolved (or the serving side is gone), `None` while it is
    /// still in flight. A `None` never loses the eventual result.
    pub fn try_wait(&mut self) -> Option<Result<Vec<f32>, InferenceError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Ticket::disconnected()),
        }
    }

    /// Bounded blocking wait: the result if the request resolves (or
    /// the serving side dies) within `timeout`, `None` on timeout. A
    /// timed-out wait never loses the eventual result — a later
    /// probe or [`Ticket::wait`] still returns it (asserted in
    /// `tests/concurrency.rs`).
    pub fn wait_timeout(
        &mut self,
        timeout: Duration,
    ) -> Option<Result<Vec<f32>, InferenceError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                Some(Ticket::disconnected())
            }
        }
    }
}

/// The worker pool. Dropping it shuts the queue and joins every
/// worker.
pub struct Pool {
    queue: Arc<DeadlineQueue<Job>>,
    workers: Vec<JoinHandle<()>>,
    n_workers: usize,
    counters: Arc<Counters>,
    worker_served: Arc<Vec<AtomicU64>>,
    admission: Option<Admission>,
    in_dim: usize,
}

impl Pool {
    /// Spin up `cfg.workers` threads over one shared backend.
    pub fn new(backend: SharedBackend, cfg: PoolConfig) -> Pool {
        Pool::build(backend, cfg, None)
    }

    /// Like [`Pool::new`], with an ingress [`Admission`] gate:
    /// [`Pool::submit_with`] rejects requests whose deadline the cost
    /// model says cannot be met behind the current backlog.
    pub fn with_admission(
        backend: SharedBackend,
        cfg: PoolConfig,
        admission: Admission,
    ) -> Pool {
        Pool::build(backend, cfg, Some(admission))
    }

    fn build(
        backend: SharedBackend,
        cfg: PoolConfig,
        admission: Option<Admission>,
    ) -> Pool {
        let n_workers = cfg.workers.max(1);
        let max_batch = cfg.max_batch.max(1);
        let queue = Arc::new(DeadlineQueue::new());
        let counters = Arc::new(Counters::default());
        let worker_served: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_workers).map(|_| AtomicU64::new(0)).collect());
        let live = Arc::new(AtomicUsize::new(n_workers));
        let in_dim = backend.spec().in_dim;
        let workers = (0..n_workers)
            .map(|w| {
                let ctx = WorkerCtx {
                    w,
                    backend: Arc::clone(&backend),
                    queue: Arc::clone(&queue),
                    max_batch,
                    counters: Arc::clone(&counters),
                    worker_served: Arc::clone(&worker_served),
                    live: Arc::clone(&live),
                };
                std::thread::spawn(move || worker_loop(ctx))
            })
            .collect();
        Pool {
            queue,
            workers,
            n_workers,
            counters,
            worker_served,
            admission,
            in_dim,
        }
    }

    fn enqueue(&self, x: &[f32], opts: SubmitOptions) -> Ticket {
        let (resp, rx) = channel();
        let job = Job { x: x.to_vec(), resp };
        // A failed push means the queue is closed (every worker gone);
        // the dropped job closes the response channel and the ticket
        // reports BackendUnavailable.
        let _ = self.queue.push(opts.priority, opts.deadline, job);
        Ticket { rx }
    }

    /// Enqueue one best-effort request (`Batch` class, no deadline —
    /// the old FIFO front door); returns immediately with a
    /// [`Ticket`].
    pub fn submit(&self, x: &[f32]) -> Ticket {
        self.enqueue(x, SubmitOptions::default())
    }

    /// Enqueue one request with scheduling options — the
    /// deadline-aware front door.
    ///
    /// With an [`Admission`] gate attached
    /// ([`Pool::with_admission`]), a deadline the cost model says
    /// cannot be met behind the current backlog is rejected here with
    /// [`InferenceError::DeadlineExceeded`] instead of queueing;
    /// without a gate, submission always succeeds and infeasible
    /// deadlines are shed at the worker.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use icsml::api::{EngineBackend, SharedBackend};
    /// use icsml::engine::{Act, Layer, Model};
    /// use icsml::serve::{Deadline, Pool, PoolConfig, Priority, SubmitOptions};
    ///
    /// let model = Model::new(vec![Layer::dense(
    ///     vec![0.5; 4],
    ///     vec![0.0; 2],
    ///     2,
    ///     Act::None,
    /// )]);
    /// let backend: SharedBackend = Arc::new(EngineBackend::new(model));
    /// let pool = Pool::new(backend, PoolConfig::default());
    ///
    /// // A control-class request with ten seconds of budget: served.
    /// let ticket = pool
    ///     .submit_with(
    ///         &[1.0, 1.0],
    ///         SubmitOptions::new()
    ///             .priority(Priority::Control)
    ///             .deadline(Deadline::within_us(10_000_000.0)),
    ///     )
    ///     .unwrap();
    /// assert_eq!(ticket.wait().unwrap().len(), 2);
    ///
    /// // A zero-budget deadline is shed, never served late.
    /// let late = pool
    ///     .submit_with(
    ///         &[1.0, 1.0],
    ///         SubmitOptions::new().deadline(Deadline::within_us(0.0)),
    ///     )
    ///     .unwrap()
    ///     .wait();
    /// assert!(late.is_err());
    /// assert_eq!(pool.shed(), 1);
    /// ```
    pub fn submit_with(
        &self,
        x: &[f32],
        opts: SubmitOptions,
    ) -> Result<Ticket, InferenceError> {
        if let Some(adm) = &self.admission {
            adm.admit(
                opts.deadline.as_ref(),
                self.queue.len(),
                self.n_workers,
            )?;
        }
        Ok(self.enqueue(x, opts))
    }

    /// Blocking convenience: submit + wait.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>, InferenceError> {
        self.submit(x).wait()
    }

    /// Requests answered successfully so far.
    pub fn served(&self) -> u64 {
        self.counters.served.load(Ordering::Relaxed)
    }

    /// Request groups executed (served / batches = mean group size,
    /// regardless of whether a group went through `infer_batch` or the
    /// per-request fallback).
    pub fn batches(&self) -> u64 {
        self.counters.batches.load(Ordering::Relaxed)
    }

    /// Requests answered with an error (excluding sheds).
    pub fn errors(&self) -> u64 {
        self.counters.errors.load(Ordering::Relaxed)
    }

    /// Requests shed because their deadline expired before service
    /// ([`InferenceError::DeadlineExceeded`]). Always 0 under
    /// no-deadline load — asserted by the serve_pool bench's `--smoke`
    /// gate.
    pub fn shed(&self) -> u64 {
        self.counters.shed.load(Ordering::Relaxed)
    }

    /// Requests currently queued (the admission gate's backlog
    /// signal).
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Per-worker served counts (shard-balance introspection for the
    /// bench and tests).
    pub fn worker_served(&self) -> Vec<u64> {
        self.worker_served
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// The pool's expected input length (from the backend spec).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // Closing the queue ends every worker's pop loop once the
        // pending items are drained and served.
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn unavailable(reason: &str) -> InferenceError {
    InferenceError::BackendUnavailable {
        backend: "pool".into(),
        reason: reason.to_string(),
    }
}

/// Everything one worker thread needs (bundled so the loop has a
/// single argument).
struct WorkerCtx {
    w: usize,
    backend: SharedBackend,
    queue: Arc<DeadlineQueue<Job>>,
    max_batch: usize,
    counters: Arc<Counters>,
    worker_served: Arc<Vec<AtomicU64>>,
    live: Arc<AtomicUsize>,
}

/// Runs on worker exit — including a panicking unwind. When the
/// *last* worker goes, pending requests would otherwise wait forever
/// on a queue nobody reads; close it and answer them with a typed
/// error (the `Ticket::wait`-never-hangs guarantee).
struct ExitGuard {
    queue: Arc<DeadlineQueue<Job>>,
    counters: Arc<Counters>,
    live: Arc<AtomicUsize>,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        if self.live.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.queue.close();
            for (_, job) in self.queue.drain() {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = job
                    .resp
                    .send(Err(unavailable("all pool workers exited")));
            }
        }
    }
}

/// `deadline` (if any) can still be met if service completes `us`
/// microseconds after `now`.
fn fits(deadline: Option<Deadline>, now: Instant, us: f64) -> bool {
    match deadline {
        None => true,
        Some(d) => now + Duration::from_secs_f64(us.max(0.0) / 1e6)
            <= d.instant(),
    }
}

fn worker_loop(ctx: WorkerCtx) {
    let _guard = ExitGuard {
        queue: Arc::clone(&ctx.queue),
        counters: Arc::clone(&ctx.counters),
        live: Arc::clone(&ctx.live),
    };
    // Sessions are minted on the worker thread (they are not Send).
    // A backend that cannot create sessions still drains the queue,
    // answering every request with the typed reason.
    let mut session: Option<Box<dyn Session>> = None;
    let mut session_err = String::new();
    match ctx.backend.session() {
        Ok(s) => session = Some(s),
        Err(e) => session_err = e.to_string(),
    }
    let (in_dim, out_dim, granularity) = match &session {
        Some(s) => {
            let spec = s.spec();
            (spec.in_dim, spec.out_dim, spec.batch_granularity.max(1))
        }
        None => (0, 0, 1),
    };

    // Per-worker moving average of measured per-request service time
    // (µs) — the batch-formation cost model. 0 until the first
    // measurement, which disables compatibility pruning exactly like
    // the old FIFO pool (nothing is known yet, and undeadlined
    // traffic never needs it).
    let mut est_us = 0.0f64;

    // Reused across batches: after warmup these hit their high-water
    // capacity and stop allocating.
    let mut xs: Vec<f32> = Vec::new();
    let mut out: Vec<f32> = Vec::new();
    let mut group: Vec<(Meta, Job)> = Vec::new();

    loop {
        group.clear();
        match ctx.queue.pop_wait() {
            Some(e) => group.push(e),
            None => return, // pool dropped: queue closed and drained
        }
        // Micro-batch formation: drain the queue's best entries while
        // (a) the batch has room and (b) the projected completion of
        // the *grown* batch still meets every member's deadline and
        // the candidate's own. The moment the best queued entry is
        // incompatible we stop — it will head its own group on the
        // next loop turn, never waiting out a batch it cannot afford.
        while group.len() < ctx.max_batch {
            let popped = if est_us > 0.0 {
                let projected = est_us * (group.len() + 1) as f64;
                let now = Instant::now();
                let group_deadline =
                    group.iter().filter_map(|(m, _)| m.deadline).min();
                if !fits(group_deadline, now, projected) {
                    break;
                }
                ctx.queue
                    .try_pop_if(|m| fits(m.deadline, now, projected))
            } else {
                ctx.queue.try_pop_if(|_| true)
            };
            match popped {
                Some(e) => group.push(e),
                None => break,
            }
        }

        let Some(session) = session.as_mut() else {
            for (_, j) in group.drain(..) {
                ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(unavailable(&session_err)));
            }
            continue;
        };

        // Shed expired requests (a deadline that passed while queued
        // is answered with the typed shed error, *never* served late)
        // and split off malformed ones so one bad client cannot
        // poison a whole batch.
        let now = Instant::now();
        let mut batch: Vec<Job> = Vec::with_capacity(group.len());
        for (meta, j) in group.drain(..) {
            match meta.deadline {
                Some(d) if d.expired_at(now) => {
                    ctx.counters.shed.fetch_add(1, Ordering::Relaxed);
                    let _ = j.resp.send(Err(
                        InferenceError::DeadlineExceeded {
                            stage: "queue",
                            late_us: d.late_by_us(now),
                        },
                    ));
                }
                _ if j.x.len() != in_dim => {
                    ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                    let _ =
                        j.resp.send(Err(InferenceError::ShapeMismatch {
                            what: "input",
                            expected: in_dim,
                            got: j.x.len(),
                        }));
                }
                _ => batch.push(j),
            }
        }
        if batch.is_empty() {
            continue;
        }

        // Fixed-batch substrates (XLA with compiled_batch > 1) can
        // only execute multiples of their granularity: cut the largest
        // servable head chunk and answer the remainder with a typed
        // error up front — single requests are *unservable* there, so
        // holding them back would strand them, and submitting a ragged
        // batch would doom the whole group.
        let head = if granularity > 1 {
            let m = (batch.len() / granularity) * granularity;
            for j in batch.drain(m..) {
                ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(InferenceError::ShapeMismatch {
                    what: "batch rows (must be a multiple of the \
                           compiled batch)",
                    expected: granularity,
                    got: 1,
                }));
            }
            m
        } else {
            batch.len()
        };
        if head == 0 {
            continue;
        }

        let n = batch.len();
        let t_serve = Instant::now();
        let mut group_served = 0u64;
        let mut served_batched = false;
        if n > 1 || granularity > 1 {
            xs.clear();
            for j in &batch {
                xs.extend_from_slice(&j.x);
            }
            out.clear();
            out.resize(n * out_dim, 0.0);
            // Batch path; equivalence with sequential infer_into is
            // part of the Session contract. If a substrate still
            // refuses the batch, fall through to the per-request path
            // below.
            if session.infer_batch(&xs, &mut out).is_ok() {
                for (i, j) in batch.drain(..).enumerate() {
                    group_served += 1;
                    ctx.worker_served[ctx.w]
                        .fetch_add(1, Ordering::Relaxed);
                    let _ = j.resp.send(Ok(
                        out[i * out_dim..(i + 1) * out_dim].to_vec()
                    ));
                }
                served_batched = true;
            }
        }
        if !served_batched {
            for j in batch.drain(..) {
                out.clear();
                out.resize(out_dim, 0.0);
                match session.infer_into(&j.x, &mut out) {
                    Ok(()) => {
                        group_served += 1;
                        ctx.worker_served[ctx.w]
                            .fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Ok(out.clone()));
                    }
                    Err(e) => {
                        ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Err(e));
                    }
                }
            }
        }
        // One "batch" per drained group that served anything, whatever
        // path executed it — so served/batches is a true mean group
        // size even when a substrate forces per-request fallback.
        if group_served > 0 {
            ctx.counters
                .served
                .fetch_add(group_served, Ordering::Relaxed);
            ctx.counters.batches.fetch_add(1, Ordering::Relaxed);
            // Fold the measured per-request service time into the
            // batch-formation estimate (moving average, α = 0.4).
            let per_req_us =
                t_serve.elapsed().as_secs_f64() * 1e6 / group_served as f64;
            est_us = if est_us <= 0.0 {
                per_req_us
            } else {
                0.6 * est_us + 0.4 * per_req_us
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, EngineBackend};
    use crate::engine::{Act, Layer, Model};
    use crate::plc::HwProfile;
    use crate::serve::Priority;

    fn model() -> Model {
        Model::new(vec![
            Layer::dense(
                (0..8 * 6).map(|i| ((i % 11) as f32) * 0.1 - 0.5).collect(),
                vec![0.05; 6],
                8,
                Act::Relu,
            ),
            Layer::dense(
                (0..6 * 3).map(|i| 0.3 - (i % 4) as f32 * 0.1).collect(),
                vec![0.0; 3],
                6,
                Act::None,
            ),
        ])
    }

    #[test]
    fn pool_matches_sequential_session() {
        let backend = Arc::new(EngineBackend::new(model()));
        let mut reference = backend.session().unwrap();
        let inputs: Vec<Vec<f32>> = (0..40)
            .map(|i| {
                (0..8).map(|k| ((i * 8 + k) as f32 * 0.17).sin()).collect()
            })
            .collect();
        let want: Vec<Vec<f32>> =
            inputs.iter().map(|x| reference.infer(x).unwrap()).collect();

        let pool =
            Pool::new(backend, PoolConfig { workers: 3, max_batch: 4 });
        // Pipelined: all tickets in flight at once.
        let tickets: Vec<Ticket> =
            inputs.iter().map(|x| pool.submit(x)).collect();
        for (t, w) in tickets.into_iter().zip(&want) {
            let got = t.wait().unwrap();
            assert_eq!(&got, w, "pool result must be bit-identical");
        }
        assert_eq!(pool.served(), 40);
        assert_eq!(pool.errors(), 0);
        assert_eq!(pool.shed(), 0, "no-deadline load must never shed");
        assert!(pool.batches() <= 40, "batching must coalesce, not inflate");
        let per_worker = pool.worker_served();
        assert_eq!(per_worker.iter().sum::<u64>(), 40);
    }

    #[test]
    fn pool_reports_shape_mismatch_per_request() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool = Pool::new(backend, PoolConfig::default());
        match pool.infer(&[0.0; 3]) {
            Err(InferenceError::ShapeMismatch { expected: 8, got: 3, .. }) => {}
            other => panic!("want ShapeMismatch, got {other:?}"),
        }
        // Healthy traffic still flows afterwards.
        assert_eq!(pool.infer(&[0.1; 8]).unwrap().len(), 3);
    }

    #[test]
    fn ticket_probes_resolve_without_losing_the_result() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool = Pool::new(backend, PoolConfig::default());
        let mut t = pool.submit(&[0.1; 8]);
        // Probe until resolved (bounded), then confirm the result was
        // delivered through the probe path, not lost.
        let mut got = None;
        for _ in 0..600 {
            if let Some(r) = t.wait_timeout(Duration::from_millis(50)) {
                got = Some(r);
                break;
            }
        }
        assert_eq!(got.expect("ticket never resolved").unwrap().len(), 3);

        // A dead pool resolves probes with the typed error instead of
        // returning None forever.
        let backend = Arc::new(EngineBackend::new(model()));
        let pool2 = Pool::new(backend, PoolConfig::default());
        let mut t2 = pool2.submit(&[0.1; 8]);
        let _ = t2.wait_timeout(Duration::from_secs(30)).expect("served");
        drop(pool2); // joins workers: the serving side is gone for sure
        let again = t2.try_wait().expect("resolved tickets stay resolved");
        assert!(again.is_err(), "second take reports disconnection");
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool =
            Pool::new(backend, PoolConfig { workers: 2, max_batch: 2 });
        assert_eq!(pool.infer(&[0.2; 8]).unwrap().len(), 3);
        drop(pool); // joins workers; must not hang or panic
    }

    #[test]
    fn expired_deadline_is_shed_not_served() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool = Pool::new(backend, PoolConfig::default());
        let r = pool
            .submit_with(
                &[0.1; 8],
                SubmitOptions::new().deadline(Deadline::within_us(0.0)),
            )
            .unwrap()
            .wait();
        match r {
            Err(InferenceError::DeadlineExceeded { stage: "queue", .. }) => {}
            other => panic!("want queue shed, got {other:?}"),
        }
        assert_eq!(pool.shed(), 1);
        assert_eq!(pool.served(), 0, "a shed request is never served");
        // A generous deadline is served normally afterwards.
        let ok = pool
            .submit_with(
                &[0.1; 8],
                SubmitOptions::new()
                    .priority(Priority::Control)
                    .deadline(Deadline::within_us(30_000_000.0)),
            )
            .unwrap()
            .wait();
        assert_eq!(ok.unwrap().len(), 3);
    }

    #[test]
    fn admission_gate_rejects_infeasible_budget_at_submit() {
        let backend = Arc::new(EngineBackend::new(model()));
        // A deliberately absurd modeled cost: every deadlined request
        // is infeasible, undeadlined traffic is untouched.
        let pool = Pool::with_admission(
            backend,
            PoolConfig::default(),
            Admission::new(HwProfile::beaglebone(), 1e12),
        );
        match pool.submit_with(
            &[0.1; 8],
            SubmitOptions::new().deadline(Deadline::within_us(1_000.0)),
        ) {
            Err(InferenceError::DeadlineExceeded {
                stage: "admission", ..
            }) => {}
            other => panic!("want admission rejection, got {other:?}"),
        }
        assert_eq!(pool.shed(), 0, "rejected at ingress, not queued");
        assert_eq!(pool.infer(&[0.1; 8]).unwrap().len(), 3);
    }
}
