//! [`Pool`]: a fixed set of worker threads serving inference requests
//! from one shared backend, under deadline-aware scheduling and panic
//! supervision.
//!
//! Design:
//!
//! * **Shared backend, private sessions.** Workers receive an
//!   `Arc<dyn Backend + Send + Sync>` and mint their [`Session`]
//!   *inside* the worker thread — sessions are deliberately not
//!   `Send`, so this is the only sound construction, and it is exactly
//!   what the Engine/Session split exists for.
//! * **One shared [`DeadlineQueue`]** (`serve::queue`): priority bands
//!   (`Control` > `Defense` > `Batch`) with earliest-deadline-first
//!   ordering inside each band, strict FIFO for undeadlined traffic.
//!   The lock is held only to push/pop, never to serve.
//! * **Deadline-compatible micro-batching.** After blocking on one
//!   request, a worker drains up to `max_batch - 1` more *only while
//!   every member of the forming batch (and the candidate) can still
//!   meet its deadline at the projected batch completion time*,
//!   estimated from a per-worker moving average of measured service
//!   time. An urgent request is therefore never delayed by a filling
//!   batch; undeadlined traffic batches exactly like the old FIFO
//!   pool. Batches go through one [`Session::infer_batch`] call
//!   (bit-equivalent to sequential `infer_into` — the API contract,
//!   asserted in `tests/concurrency.rs`), with per-request fallback
//!   when a substrate rejects the batch.
//! * **Sheds, not stale answers.** A request whose [`Deadline`] has
//!   passed when a worker picks it up is answered with
//!   [`InferenceError::DeadlineExceeded`] instead of being served
//!   late ([`Pool::shed`] counts them). An optional ingress
//!   [`Admission`] gate rejects provably-infeasible deadlines at
//!   [`Pool::submit_with`] time, before they occupy queue slots.
//! * **Supervised workers, contained panics.** Every backend call runs
//!   under `catch_unwind`: a panicking model fails *only its own
//!   ticket* (typed [`InferenceError::BackendPanicked`]), never the
//!   whole pool. The panicked worker retires (its session state is
//!   suspect) and a supervisor thread respawns it with capped,
//!   jittered exponential backoff; after
//!   [`SupervisorConfig::quarantine_after`] consecutive panics the
//!   backend is quarantined and the pool fails fast with a typed
//!   error instead of burning respawns on a broken model.
//!   [`Pool::health`] snapshots live workers / contained panics /
//!   respawns / quarantine for monitors and the chaos tests.
//! * **No worker, no hang.** If every worker is gone *and* none will
//!   return (shutdown or quarantine), pending and future requests fail
//!   with a typed error instead of blocking [`Ticket::wait`] forever.
//!   A transient zero (workers dead, respawn pending) just delays
//!   service — tickets still resolve once the supervisor restaffs.
//! * **No new dependencies**: `std::sync` primitives + threads.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{
    AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::mpsc::{
    channel, Receiver, RecvTimeoutError, Sender, TryRecvError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{Backend, InferenceError, Session, SharedBackend};
use crate::util::lock::lock_recover;
use crate::util::rng::SplitMix64;

use super::admission::Admission;
use super::queue::{Deadline, DeadlineQueue, Meta, SubmitOptions};

/// Pool sizing knobs.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker threads (each with a private session). Clamped to ≥ 1.
    pub workers: usize,
    /// Max requests served per `infer_batch` call. Clamped to ≥ 1.
    pub max_batch: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { workers: 4, max_batch: 8 }
    }
}

/// Worker-supervision knobs ([`Pool::with_supervisor`]). The defaults
/// suit tests and embedded deployments: near-immediate first respawn,
/// sub-second cap, quarantine after eight straight panics.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Contained panics in a row (across all workers, with no
    /// intervening successful request) after which the backend is
    /// quarantined: workers stop touching it and answer with a typed
    /// [`InferenceError::BackendUnavailable`]. Clamped to ≥ 1.
    pub quarantine_after: u32,
    /// Delay before the first respawn of a dead worker; doubles per
    /// consecutive death (capped), with up to 50% random jitter so a
    /// fleet of pools never thunders in lockstep.
    pub respawn_backoff: Duration,
    /// Upper bound on the (pre-jitter) respawn delay.
    pub max_respawn_backoff: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            quarantine_after: 8,
            respawn_backoff: Duration::from_millis(1),
            max_respawn_backoff: Duration::from_millis(100),
        }
    }
}

/// Point-in-time supervision snapshot ([`Pool::health`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolHealth {
    /// Worker threads the pool was configured with.
    pub workers_configured: usize,
    /// Worker threads currently alive. Dips below `workers_configured`
    /// while a respawn is in flight; recovers unless quarantined.
    pub workers_live: usize,
    /// Backend panics contained by `catch_unwind` so far.
    pub panics_contained: u64,
    /// Workers the supervisor has respawned so far.
    pub respawns: u64,
    /// Current run of contained panics with no intervening success
    /// (the quarantine trigger counter).
    pub consecutive_faults: u32,
    /// True once the backend has been quarantined; the pool now fails
    /// fast and no further respawns happen.
    pub quarantined: bool,
}

impl PoolHealth {
    /// Fully staffed and not quarantined.
    pub fn is_healthy(&self) -> bool {
        !self.quarantined && self.workers_live == self.workers_configured
    }
}

struct Job {
    x: Vec<f32>,
    resp: Sender<Result<Vec<f32>, InferenceError>>,
}

/// Per-pool counters (atomics: read without stopping the workers).
#[derive(Default)]
struct Counters {
    served: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
}

/// Supervision state shared by workers, the supervisor thread and the
/// [`Pool`] handle.
struct Supervision {
    cfg: SupervisorConfig,
    /// Set by `Pool::drop` before closing the queue: worker exits are
    /// expected and must not trigger respawns.
    shutdown: AtomicBool,
    /// Set after `quarantine_after` consecutive contained panics.
    quarantined: AtomicBool,
    panics: AtomicU64,
    respawns: AtomicU64,
    consecutive: AtomicU32,
}

impl Supervision {
    fn new(cfg: SupervisorConfig) -> Supervision {
        Supervision {
            cfg,
            shutdown: AtomicBool::new(false),
            quarantined: AtomicBool::new(false),
            panics: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            consecutive: AtomicU32::new(0),
        }
    }

    /// Record one contained panic; flips `quarantined` at the
    /// configured streak.
    fn record_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
        let streak = self.consecutive.fetch_add(1, Ordering::AcqRel) + 1;
        if streak >= self.cfg.quarantine_after.max(1) {
            self.quarantined.store(true, Ordering::SeqCst);
        }
    }
}

/// A handle to an in-flight request; [`Ticket::wait`] blocks for the
/// result, [`Ticket::try_wait`] / [`Ticket::wait_timeout`] probe it
/// without committing a thread. Submitting many tickets before
/// waiting keeps every worker busy (that is the bench's pipelining
/// model), and the non-blocking probes are how the `netserve` event
/// loop drives thousands of in-flight requests over W workers without
/// a blocked thread per request.
///
/// A ticket resolves exactly once: a probe that returns `None` leaves
/// the eventual result intact for a later probe or a final
/// [`Ticket::wait`]; after the result has been taken, further probes
/// report the serving side as disconnected.
pub struct Ticket {
    rx: Receiver<Result<Vec<f32>, InferenceError>>,
}

impl Ticket {
    /// The typed resolution of a dead serving side (queue closed, all
    /// workers exited, worker died mid-request).
    fn disconnected() -> Result<Vec<f32>, InferenceError> {
        Err(InferenceError::BackendUnavailable {
            backend: "pool".into(),
            reason: "worker disconnected before replying".into(),
        })
    }

    /// Block until the request resolves. Never hangs: if the serving
    /// side is gone (queue closed, all workers exited, worker died
    /// mid-request) the disconnected channel resolves to a typed
    /// [`InferenceError::BackendUnavailable`].
    pub fn wait(self) -> Result<Vec<f32>, InferenceError> {
        self.rx.recv().unwrap_or_else(|_| Ticket::disconnected())
    }

    /// Non-blocking readiness probe: `Some(result)` once the request
    /// has resolved (or the serving side is gone), `None` while it is
    /// still in flight. A `None` never loses the eventual result.
    pub fn try_wait(&mut self) -> Option<Result<Vec<f32>, InferenceError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Ticket::disconnected()),
        }
    }

    /// Bounded blocking wait: the result if the request resolves (or
    /// the serving side dies) within `timeout`, `None` on timeout. A
    /// timed-out wait never loses the eventual result — a later
    /// probe or [`Ticket::wait`] still returns it (asserted in
    /// `tests/concurrency.rs`).
    pub fn wait_timeout(
        &mut self,
        timeout: Duration,
    ) -> Option<Result<Vec<f32>, InferenceError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                Some(Ticket::disconnected())
            }
        }
    }
}

/// The worker pool. Dropping it shuts the queue, retires the
/// supervisor and joins every worker.
pub struct Pool {
    queue: Arc<DeadlineQueue<Job>>,
    /// Shared with the supervisor, which pushes respawned handles.
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    supervisor: Option<JoinHandle<()>>,
    n_workers: usize,
    counters: Arc<Counters>,
    worker_served: Arc<Vec<AtomicU64>>,
    live: Arc<AtomicUsize>,
    sup: Arc<Supervision>,
    admission: Option<Admission>,
    in_dim: usize,
}

impl Pool {
    /// Spin up `cfg.workers` threads over one shared backend, with
    /// default supervision ([`SupervisorConfig::default`]).
    pub fn new(backend: SharedBackend, cfg: PoolConfig) -> Pool {
        Pool::build(backend, cfg, None, SupervisorConfig::default())
    }

    /// Like [`Pool::new`], with explicit supervision knobs (respawn
    /// backoff, quarantine threshold).
    pub fn with_supervisor(
        backend: SharedBackend,
        cfg: PoolConfig,
        sup: SupervisorConfig,
    ) -> Pool {
        Pool::build(backend, cfg, None, sup)
    }

    /// Like [`Pool::new`], with an ingress [`Admission`] gate:
    /// [`Pool::submit_with`] rejects requests whose deadline the cost
    /// model says cannot be met behind the current backlog.
    pub fn with_admission(
        backend: SharedBackend,
        cfg: PoolConfig,
        admission: Admission,
    ) -> Pool {
        Pool::build(
            backend,
            cfg,
            Some(admission),
            SupervisorConfig::default(),
        )
    }

    fn build(
        backend: SharedBackend,
        cfg: PoolConfig,
        admission: Option<Admission>,
        sup_cfg: SupervisorConfig,
    ) -> Pool {
        let n_workers = cfg.workers.max(1);
        let max_batch = cfg.max_batch.max(1);
        let queue = Arc::new(DeadlineQueue::new());
        let counters = Arc::new(Counters::default());
        let worker_served: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_workers).map(|_| AtomicU64::new(0)).collect());
        let live = Arc::new(AtomicUsize::new(n_workers));
        let sup = Arc::new(Supervision::new(sup_cfg));
        let in_dim = backend.spec().in_dim;
        let (death_tx, death_rx) = channel::<usize>();
        let handles: Vec<JoinHandle<()>> = (0..n_workers)
            .map(|w| {
                spawn_worker(WorkerCtx {
                    w,
                    backend: Arc::clone(&backend),
                    queue: Arc::clone(&queue),
                    max_batch,
                    counters: Arc::clone(&counters),
                    worker_served: Arc::clone(&worker_served),
                    live: Arc::clone(&live),
                    sup: Arc::clone(&sup),
                    death_tx: death_tx.clone(),
                })
            })
            .collect();
        let workers = Arc::new(Mutex::new(handles));
        let supervisor = {
            let sctx = SupCtx {
                backend,
                queue: Arc::clone(&queue),
                max_batch,
                counters: Arc::clone(&counters),
                worker_served: Arc::clone(&worker_served),
                live: Arc::clone(&live),
                sup: Arc::clone(&sup),
                workers: Arc::clone(&workers),
                death_tx,
                death_rx,
            };
            std::thread::Builder::new()
                .name("pool-supervisor".into())
                .spawn(move || supervisor_loop(sctx))
                .expect("spawn pool supervisor")
        };
        Pool {
            queue,
            workers,
            supervisor: Some(supervisor),
            n_workers,
            counters,
            worker_served,
            live,
            sup,
            admission,
            in_dim,
        }
    }

    fn enqueue(&self, x: &[f32], opts: SubmitOptions) -> Ticket {
        let (resp, rx) = channel();
        let job = Job { x: x.to_vec(), resp };
        // A failed push means the queue is closed (shutdown, or
        // quarantined with no survivors); the dropped job closes the
        // response channel and the ticket reports BackendUnavailable.
        let _ = self.queue.push(opts.priority, opts.deadline, job);
        Ticket { rx }
    }

    /// Enqueue one best-effort request (`Batch` class, no deadline —
    /// the old FIFO front door); returns immediately with a
    /// [`Ticket`].
    pub fn submit(&self, x: &[f32]) -> Ticket {
        self.enqueue(x, SubmitOptions::default())
    }

    /// Enqueue one request with scheduling options — the
    /// deadline-aware front door.
    ///
    /// With an [`Admission`] gate attached
    /// ([`Pool::with_admission`]), a deadline the cost model says
    /// cannot be met behind the current backlog is rejected here with
    /// [`InferenceError::DeadlineExceeded`] instead of queueing;
    /// without a gate, submission always succeeds and infeasible
    /// deadlines are shed at the worker.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use icsml::api::{EngineBackend, SharedBackend};
    /// use icsml::engine::{Act, Layer, Model};
    /// use icsml::serve::{Deadline, Pool, PoolConfig, Priority, SubmitOptions};
    ///
    /// let model = Model::new(vec![Layer::dense(
    ///     vec![0.5; 4],
    ///     vec![0.0; 2],
    ///     2,
    ///     Act::None,
    /// )]);
    /// let backend: SharedBackend = Arc::new(EngineBackend::new(model));
    /// let pool = Pool::new(backend, PoolConfig::default());
    ///
    /// // A control-class request with ten seconds of budget: served.
    /// let ticket = pool
    ///     .submit_with(
    ///         &[1.0, 1.0],
    ///         SubmitOptions::new()
    ///             .priority(Priority::Control)
    ///             .deadline(Deadline::within_us(10_000_000.0)),
    ///     )
    ///     .unwrap();
    /// assert_eq!(ticket.wait().unwrap().len(), 2);
    ///
    /// // A zero-budget deadline is shed, never served late.
    /// let late = pool
    ///     .submit_with(
    ///         &[1.0, 1.0],
    ///         SubmitOptions::new().deadline(Deadline::within_us(0.0)),
    ///     )
    ///     .unwrap()
    ///     .wait();
    /// assert!(late.is_err());
    /// assert_eq!(pool.shed(), 1);
    /// ```
    pub fn submit_with(
        &self,
        x: &[f32],
        opts: SubmitOptions,
    ) -> Result<Ticket, InferenceError> {
        if let Some(adm) = &self.admission {
            adm.admit(
                opts.deadline.as_ref(),
                self.queue.len(),
                self.n_workers,
            )?;
        }
        Ok(self.enqueue(x, opts))
    }

    /// Blocking convenience: submit + wait.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>, InferenceError> {
        self.submit(x).wait()
    }

    /// Requests answered successfully so far.
    pub fn served(&self) -> u64 {
        self.counters.served.load(Ordering::Relaxed)
    }

    /// Request groups executed (served / batches = mean group size,
    /// regardless of whether a group went through `infer_batch` or the
    /// per-request fallback).
    pub fn batches(&self) -> u64 {
        self.counters.batches.load(Ordering::Relaxed)
    }

    /// Requests answered with an error (excluding sheds).
    pub fn errors(&self) -> u64 {
        self.counters.errors.load(Ordering::Relaxed)
    }

    /// Requests shed because their deadline expired before service
    /// ([`InferenceError::DeadlineExceeded`]). Always 0 under
    /// no-deadline load — asserted by the serve_pool bench's `--smoke`
    /// gate.
    pub fn shed(&self) -> u64 {
        self.counters.shed.load(Ordering::Relaxed)
    }

    /// Requests currently queued (the admission gate's backlog
    /// signal).
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Per-worker served counts (shard-balance introspection for the
    /// bench and tests).
    pub fn worker_served(&self) -> Vec<u64> {
        self.worker_served
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// The pool's expected input length (from the backend spec).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Supervision snapshot: live worker count, contained panics,
    /// respawns, quarantine. `workers_live` dips while a respawn
    /// backoff is pending and recovers once the supervisor restaffs —
    /// the chaos soak asserts exactly that.
    pub fn health(&self) -> PoolHealth {
        PoolHealth {
            workers_configured: self.n_workers,
            workers_live: self.live.load(Ordering::SeqCst),
            panics_contained: self.sup.panics.load(Ordering::Relaxed),
            respawns: self.sup.respawns.load(Ordering::Relaxed),
            consecutive_faults: self
                .sup
                .consecutive
                .load(Ordering::Relaxed),
            quarantined: self.sup.quarantined.load(Ordering::SeqCst),
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // Order matters: mark shutdown (so worker exits don't trigger
        // respawns), close the queue (ends every worker's pop loop
        // once pending items are drained and served), retire the
        // supervisor (it exits when the last worker reports in), then
        // join the workers.
        self.sup.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        for h in lock_recover(&self.workers).drain(..) {
            let _ = h.join();
        }
    }
}

fn unavailable(reason: &str) -> InferenceError {
    InferenceError::BackendUnavailable {
        backend: "pool".into(),
        reason: reason.to_string(),
    }
}

/// Human-readable image of a `catch_unwind` payload.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Mint a session without letting a panicking constructor take the
/// worker down uncontained.
fn mint_session(
    backend: &SharedBackend,
) -> Result<Box<dyn Session>, String> {
    match catch_unwind(AssertUnwindSafe(|| backend.session())) {
        Ok(Ok(s)) => Ok(s),
        Ok(Err(e)) => Err(e.to_string()),
        Err(p) => Err(format!(
            "session constructor panicked: {}",
            panic_message(p.as_ref())
        )),
    }
}

/// Everything one worker thread needs (bundled so the loop has a
/// single argument; the supervisor rebuilds one per respawn).
struct WorkerCtx {
    w: usize,
    backend: SharedBackend,
    queue: Arc<DeadlineQueue<Job>>,
    max_batch: usize,
    counters: Arc<Counters>,
    worker_served: Arc<Vec<AtomicU64>>,
    live: Arc<AtomicUsize>,
    sup: Arc<Supervision>,
    death_tx: Sender<usize>,
}

fn spawn_worker(ctx: WorkerCtx) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("pool-worker-{}", ctx.w))
        .spawn(move || worker_loop(ctx))
        .expect("spawn pool worker")
}

/// Runs on worker exit — graceful or poisoned. Decrements the live
/// count, fails pending requests when no worker will ever return
/// (shutdown or quarantine — the `Ticket::wait`-never-hangs
/// guarantee), and reports the death to the supervisor, which decides
/// whether to respawn.
struct ExitGuard {
    w: usize,
    queue: Arc<DeadlineQueue<Job>>,
    counters: Arc<Counters>,
    live: Arc<AtomicUsize>,
    sup: Arc<Supervision>,
    death_tx: Sender<usize>,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        let remaining = self.live.fetch_sub(1, Ordering::AcqRel) - 1;
        let terminal = self.sup.shutdown.load(Ordering::SeqCst)
            || self.sup.quarantined.load(Ordering::SeqCst);
        if remaining == 0 && terminal {
            self.queue.close();
            for (_, job) in self.queue.drain() {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = job
                    .resp
                    .send(Err(unavailable("all pool workers exited")));
            }
        }
        // After the live count is settled, so the supervisor observes
        // a consistent world when the note arrives.
        let _ = self.death_tx.send(self.w);
    }
}

/// Everything the supervisor thread needs to restaff workers.
struct SupCtx {
    backend: SharedBackend,
    queue: Arc<DeadlineQueue<Job>>,
    max_batch: usize,
    counters: Arc<Counters>,
    worker_served: Arc<Vec<AtomicU64>>,
    live: Arc<AtomicUsize>,
    sup: Arc<Supervision>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    death_tx: Sender<usize>,
    death_rx: Receiver<usize>,
}

/// A quiet spell this long resets the respawn backoff to its floor —
/// deaths separated by healthy stretches are independent incidents,
/// not a crash loop.
const BACKOFF_RESET: Duration = Duration::from_secs(2);

/// The supervisor: receives one death note per exiting worker and
/// respawns it under capped, jittered exponential backoff — unless the
/// pool is shutting down (expected exits) or the backend is
/// quarantined (respawning a worker onto a broken backend only burns
/// CPU). Exits once no supervised worker remains.
fn supervisor_loop(s: SupCtx) {
    let mut backoff = s.sup.cfg.respawn_backoff;
    let mut last_death: Option<Instant> = None;
    // Jitter stream; seed is arbitrary but fixed so pool behavior is
    // reproducible under test.
    let mut rng = SplitMix64::new(0x5eed_0f_5afe7f);
    while let Ok(w) = s.death_rx.recv() {
        if s.sup.shutdown.load(Ordering::SeqCst) {
            if s.live.load(Ordering::SeqCst) == 0 {
                break;
            }
            continue;
        }
        if s.sup.quarantined.load(Ordering::SeqCst) {
            if s.live.load(Ordering::SeqCst) == 0 {
                // No survivors and no respawns coming: fail pending
                // work now (the ExitGuard may have raced the
                // quarantine flag; this backstop is idempotent).
                s.queue.close();
                for (_, job) in s.queue.drain() {
                    s.counters.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = job.resp.send(Err(unavailable(
                        "backend quarantined; all pool workers exited",
                    )));
                }
                break;
            }
            continue;
        }
        if let Some(t) = last_death {
            if t.elapsed() >= BACKOFF_RESET {
                backoff = s.sup.cfg.respawn_backoff;
            }
        }
        last_death = Some(Instant::now());
        let jitter = Duration::from_secs_f64(
            backoff.as_secs_f64() * 0.5 * rng.next_f64(),
        );
        std::thread::sleep(backoff + jitter);
        backoff = (backoff * 2).min(s.sup.cfg.max_respawn_backoff);
        // Re-check after sleeping: the pool may have started shutdown
        // or quarantined while we backed off.
        if s.sup.shutdown.load(Ordering::SeqCst)
            || s.sup.quarantined.load(Ordering::SeqCst)
        {
            if s.live.load(Ordering::SeqCst) == 0 {
                break;
            }
            continue;
        }
        s.live.fetch_add(1, Ordering::AcqRel);
        s.sup.respawns.fetch_add(1, Ordering::Relaxed);
        let handle = spawn_worker(WorkerCtx {
            w,
            backend: Arc::clone(&s.backend),
            queue: Arc::clone(&s.queue),
            max_batch: s.max_batch,
            counters: Arc::clone(&s.counters),
            worker_served: Arc::clone(&s.worker_served),
            live: Arc::clone(&s.live),
            sup: Arc::clone(&s.sup),
            death_tx: s.death_tx.clone(),
        });
        lock_recover(&s.workers).push(handle);
    }
}

/// `deadline` (if any) can still be met if service completes `us`
/// microseconds after `now`.
fn fits(deadline: Option<Deadline>, now: Instant, us: f64) -> bool {
    match deadline {
        None => true,
        Some(d) => now + Duration::from_secs_f64(us.max(0.0) / 1e6)
            <= d.instant(),
    }
}

fn worker_loop(ctx: WorkerCtx) {
    let _guard = ExitGuard {
        w: ctx.w,
        queue: Arc::clone(&ctx.queue),
        counters: Arc::clone(&ctx.counters),
        live: Arc::clone(&ctx.live),
        sup: Arc::clone(&ctx.sup),
        death_tx: ctx.death_tx.clone(),
    };
    // Sessions are minted on the worker thread (they are not Send).
    // A backend that cannot create sessions still drains the queue,
    // answering every request with the typed reason.
    let mut session: Option<Box<dyn Session>> = None;
    let mut session_err = String::new();
    match mint_session(&ctx.backend) {
        Ok(s) => session = Some(s),
        Err(e) => session_err = e,
    }
    let (in_dim, out_dim, granularity) = match &session {
        Some(s) => {
            let spec = s.spec();
            (spec.in_dim, spec.out_dim, spec.batch_granularity.max(1))
        }
        None => (0, 0, 1),
    };
    let backend_name = ctx.backend.name().to_string();

    // Per-worker moving average of measured per-request service time
    // (µs) — the batch-formation cost model. 0 until the first
    // measurement, which disables compatibility pruning exactly like
    // the old FIFO pool (nothing is known yet, and undeadlined
    // traffic never needs it).
    let mut est_us = 0.0f64;

    // Reused across batches: after warmup these hit their high-water
    // capacity and stop allocating.
    let mut xs: Vec<f32> = Vec::new();
    let mut out: Vec<f32> = Vec::new();
    let mut group: Vec<(Meta, Job)> = Vec::new();

    loop {
        group.clear();
        match ctx.queue.pop_wait() {
            Some(e) => group.push(e),
            None => return, // pool dropped: queue closed and drained
        }
        // Micro-batch formation: drain the queue's best entries while
        // (a) the batch has room and (b) the projected completion of
        // the *grown* batch still meets every member's deadline and
        // the candidate's own. The moment the best queued entry is
        // incompatible we stop — it will head its own group on the
        // next loop turn, never waiting out a batch it cannot afford.
        while group.len() < ctx.max_batch {
            let popped = if est_us > 0.0 {
                let projected = est_us * (group.len() + 1) as f64;
                let now = Instant::now();
                let group_deadline =
                    group.iter().filter_map(|(m, _)| m.deadline).min();
                if !fits(group_deadline, now, projected) {
                    break;
                }
                ctx.queue
                    .try_pop_if(|m| fits(m.deadline, now, projected))
            } else {
                ctx.queue.try_pop_if(|_| true)
            };
            match popped {
                Some(e) => group.push(e),
                None => break,
            }
        }

        // A quarantined backend is never touched again: answer fast
        // with the typed reason (surviving workers double as the
        // fail-fast path, so callers never hang on a broken model).
        if ctx.sup.quarantined.load(Ordering::SeqCst) {
            for (_, j) in group.drain(..) {
                ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(unavailable(
                    "backend quarantined after repeated panics",
                )));
            }
            continue;
        }

        // Take the session for this group; it is handed back at the
        // end unless a contained panic left it suspect.
        let Some(mut s) = session.take() else {
            for (_, j) in group.drain(..) {
                ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(unavailable(&session_err)));
            }
            continue;
        };

        // Shed expired requests (a deadline that passed while queued
        // is answered with the typed shed error, *never* served late)
        // and split off malformed ones so one bad client cannot
        // poison a whole batch.
        let now = Instant::now();
        let mut batch: Vec<Job> = Vec::with_capacity(group.len());
        for (meta, j) in group.drain(..) {
            match meta.deadline {
                Some(d) if d.expired_at(now) => {
                    ctx.counters.shed.fetch_add(1, Ordering::Relaxed);
                    let _ = j.resp.send(Err(
                        InferenceError::DeadlineExceeded {
                            stage: "queue",
                            late_us: d.late_by_us(now),
                        },
                    ));
                }
                _ if j.x.len() != in_dim => {
                    ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                    let _ =
                        j.resp.send(Err(InferenceError::ShapeMismatch {
                            what: "input",
                            expected: in_dim,
                            got: j.x.len(),
                        }));
                }
                _ => batch.push(j),
            }
        }
        if batch.is_empty() {
            session = Some(s);
            continue;
        }

        // Fixed-batch substrates (XLA with compiled_batch > 1) can
        // only execute multiples of their granularity: cut the largest
        // servable head chunk and answer the remainder with a typed
        // error up front — single requests are *unservable* there, so
        // holding them back would strand them, and submitting a ragged
        // batch would doom the whole group.
        let head = if granularity > 1 {
            let m = (batch.len() / granularity) * granularity;
            for j in batch.drain(m..) {
                ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                let _ = j.resp.send(Err(InferenceError::ShapeMismatch {
                    what: "batch rows (must be a multiple of the \
                           compiled batch)",
                    expected: granularity,
                    got: 1,
                }));
            }
            m
        } else {
            batch.len()
        };
        if head == 0 {
            session = Some(s);
            continue;
        }

        let n = batch.len();
        let t_serve = Instant::now();
        let mut group_served = 0u64;
        let mut group_done = false;
        // A contained panic retires this worker after the group: the
        // session (and any state the panic unwound through) is
        // suspect, so the supervisor restaffs with a fresh one.
        let mut panicked = false;
        if n > 1 || granularity > 1 {
            xs.clear();
            for j in &batch {
                xs.extend_from_slice(&j.x);
            }
            out.clear();
            out.resize(n * out_dim, 0.0);
            // Batch path; equivalence with sequential infer_into is
            // part of the Session contract. If a substrate refuses the
            // batch with a typed error, fall through to the
            // per-request path below. If it *panics*, the faulty
            // request is unknown — re-mint a session and isolate it on
            // the per-request path, so a panic never fails innocent
            // batchmates.
            match catch_unwind(AssertUnwindSafe(|| {
                s.infer_batch(&xs, &mut out)
            })) {
                Ok(Ok(())) => {
                    for (i, j) in batch.drain(..).enumerate() {
                        group_served += 1;
                        ctx.worker_served[ctx.w]
                            .fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Ok(
                            out[i * out_dim..(i + 1) * out_dim].to_vec()
                        ));
                    }
                    group_done = true;
                }
                Ok(Err(_)) => {}
                Err(p) => {
                    panicked = true;
                    ctx.sup.record_panic();
                    let msg = panic_message(p.as_ref());
                    match mint_session(&ctx.backend) {
                        Ok(ns) => s = ns,
                        Err(e) => {
                            // Cannot isolate without a session: the
                            // whole group reports the contained panic.
                            for j in batch.drain(..) {
                                ctx.counters
                                    .errors
                                    .fetch_add(1, Ordering::Relaxed);
                                let _ = j.resp.send(Err(
                                    InferenceError::BackendPanicked {
                                        backend: backend_name.clone(),
                                        message: msg.clone(),
                                    },
                                ));
                            }
                            session_err = e;
                            group_done = true;
                        }
                    }
                }
            }
        }
        if !group_done {
            let mut it = batch.into_iter();
            loop {
                let Some(j) = it.next() else { break };
                out.clear();
                out.resize(out_dim, 0.0);
                match catch_unwind(AssertUnwindSafe(|| {
                    s.infer_into(&j.x, &mut out)
                })) {
                    Ok(Ok(())) => {
                        group_served += 1;
                        ctx.worker_served[ctx.w]
                            .fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Ok(out.clone()));
                    }
                    Ok(Err(e)) => {
                        ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Err(e));
                    }
                    Err(p) => {
                        // The panic fails exactly this ticket; the
                        // rest of the group continues on a fresh
                        // session.
                        panicked = true;
                        ctx.sup.record_panic();
                        ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                        let _ = j.resp.send(Err(
                            InferenceError::BackendPanicked {
                                backend: backend_name.clone(),
                                message: panic_message(p.as_ref()),
                            },
                        ));
                        match mint_session(&ctx.backend) {
                            Ok(ns) => s = ns,
                            Err(e) => {
                                for rest in it {
                                    ctx.counters
                                        .errors
                                        .fetch_add(1, Ordering::Relaxed);
                                    let _ = rest
                                        .resp
                                        .send(Err(unavailable(&e)));
                                }
                                session_err = e;
                                break;
                            }
                        }
                    }
                }
            }
        }
        // One "batch" per drained group that served anything, whatever
        // path executed it — so served/batches is a true mean group
        // size even when a substrate forces per-request fallback.
        if group_served > 0 {
            ctx.counters
                .served
                .fetch_add(group_served, Ordering::Relaxed);
            ctx.counters.batches.fetch_add(1, Ordering::Relaxed);
            // Fold the measured per-request service time into the
            // batch-formation estimate (moving average, α = 0.4).
            let per_req_us =
                t_serve.elapsed().as_secs_f64() * 1e6 / group_served as f64;
            est_us = if est_us <= 0.0 {
                per_req_us
            } else {
                0.6 * est_us + 0.4 * per_req_us
            };
        }
        if panicked {
            // Retire: the ExitGuard reports the death and the
            // supervisor restaffs with backoff. The quarantine streak
            // survives in `Supervision`.
            return;
        }
        if group_served > 0 {
            // Any success breaks the consecutive-fault streak.
            ctx.sup.consecutive.store(0, Ordering::Release);
        }
        session = Some(s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, EngineBackend, ModelSpec};
    use crate::engine::{Act, Layer, Model};
    use crate::plc::HwProfile;
    use crate::serve::Priority;

    fn model() -> Model {
        Model::new(vec![
            Layer::dense(
                (0..8 * 6).map(|i| ((i % 11) as f32) * 0.1 - 0.5).collect(),
                vec![0.05; 6],
                8,
                Act::Relu,
            ),
            Layer::dense(
                (0..6 * 3).map(|i| 0.3 - (i % 4) as f32 * 0.1).collect(),
                vec![0.0; 3],
                6,
                Act::None,
            ),
        ])
    }

    #[test]
    fn pool_matches_sequential_session() {
        let backend = Arc::new(EngineBackend::new(model()));
        let mut reference = backend.session().unwrap();
        let inputs: Vec<Vec<f32>> = (0..40)
            .map(|i| {
                (0..8).map(|k| ((i * 8 + k) as f32 * 0.17).sin()).collect()
            })
            .collect();
        let want: Vec<Vec<f32>> =
            inputs.iter().map(|x| reference.infer(x).unwrap()).collect();

        let pool =
            Pool::new(backend, PoolConfig { workers: 3, max_batch: 4 });
        // Pipelined: all tickets in flight at once.
        let tickets: Vec<Ticket> =
            inputs.iter().map(|x| pool.submit(x)).collect();
        for (t, w) in tickets.into_iter().zip(&want) {
            let got = t.wait().unwrap();
            assert_eq!(&got, w, "pool result must be bit-identical");
        }
        assert_eq!(pool.served(), 40);
        assert_eq!(pool.errors(), 0);
        assert_eq!(pool.shed(), 0, "no-deadline load must never shed");
        assert!(pool.batches() <= 40, "batching must coalesce, not inflate");
        let per_worker = pool.worker_served();
        assert_eq!(per_worker.iter().sum::<u64>(), 40);
        let h = pool.health();
        assert!(h.is_healthy(), "healthy load leaves the pool healthy");
        assert_eq!(h.panics_contained, 0);
        assert_eq!(h.respawns, 0);
    }

    #[test]
    fn pool_reports_shape_mismatch_per_request() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool = Pool::new(backend, PoolConfig::default());
        match pool.infer(&[0.0; 3]) {
            Err(InferenceError::ShapeMismatch { expected: 8, got: 3, .. }) => {}
            other => panic!("want ShapeMismatch, got {other:?}"),
        }
        // Healthy traffic still flows afterwards.
        assert_eq!(pool.infer(&[0.1; 8]).unwrap().len(), 3);
    }

    #[test]
    fn ticket_probes_resolve_without_losing_the_result() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool = Pool::new(backend, PoolConfig::default());
        let mut t = pool.submit(&[0.1; 8]);
        // Probe until resolved (bounded), then confirm the result was
        // delivered through the probe path, not lost.
        let mut got = None;
        for _ in 0..600 {
            if let Some(r) = t.wait_timeout(Duration::from_millis(50)) {
                got = Some(r);
                break;
            }
        }
        assert_eq!(got.expect("ticket never resolved").unwrap().len(), 3);

        // A dead pool resolves probes with the typed error instead of
        // returning None forever.
        let backend = Arc::new(EngineBackend::new(model()));
        let pool2 = Pool::new(backend, PoolConfig::default());
        let mut t2 = pool2.submit(&[0.1; 8]);
        let _ = t2.wait_timeout(Duration::from_secs(30)).expect("served");
        drop(pool2); // joins workers: the serving side is gone for sure
        let again = t2.try_wait().expect("resolved tickets stay resolved");
        assert!(again.is_err(), "second take reports disconnection");
    }

    #[test]
    fn pool_shuts_down_cleanly() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool =
            Pool::new(backend, PoolConfig { workers: 2, max_batch: 2 });
        assert_eq!(pool.infer(&[0.2; 8]).unwrap().len(), 3);
        drop(pool); // joins workers; must not hang or panic
    }

    #[test]
    fn expired_deadline_is_shed_not_served() {
        let backend = Arc::new(EngineBackend::new(model()));
        let pool = Pool::new(backend, PoolConfig::default());
        let r = pool
            .submit_with(
                &[0.1; 8],
                SubmitOptions::new().deadline(Deadline::within_us(0.0)),
            )
            .unwrap()
            .wait();
        match r {
            Err(InferenceError::DeadlineExceeded { stage: "queue", .. }) => {}
            other => panic!("want queue shed, got {other:?}"),
        }
        assert_eq!(pool.shed(), 1);
        assert_eq!(pool.served(), 0, "a shed request is never served");
        // A generous deadline is served normally afterwards.
        let ok = pool
            .submit_with(
                &[0.1; 8],
                SubmitOptions::new()
                    .priority(Priority::Control)
                    .deadline(Deadline::within_us(30_000_000.0)),
            )
            .unwrap()
            .wait();
        assert_eq!(ok.unwrap().len(), 3);
    }

    #[test]
    fn admission_gate_rejects_infeasible_budget_at_submit() {
        let backend = Arc::new(EngineBackend::new(model()));
        // A deliberately absurd modeled cost: every deadlined request
        // is infeasible, undeadlined traffic is untouched.
        let pool = Pool::with_admission(
            backend,
            PoolConfig::default(),
            Admission::new(HwProfile::beaglebone(), 1e12),
        );
        match pool.submit_with(
            &[0.1; 8],
            SubmitOptions::new().deadline(Deadline::within_us(1_000.0)),
        ) {
            Err(InferenceError::DeadlineExceeded {
                stage: "admission", ..
            }) => {}
            other => panic!("want admission rejection, got {other:?}"),
        }
        assert_eq!(pool.shed(), 0, "rejected at ingress, not queued");
        assert_eq!(pool.infer(&[0.1; 8]).unwrap().len(), 3);
    }

    // -----------------------------------------------------------------
    // Supervision (contained panics, respawn, quarantine)
    // -----------------------------------------------------------------

    /// Panics on request tag `x[0] == 666`, serves everything else.
    struct SelectivePanicBackend {
        inner: EngineBackend,
    }

    impl SelectivePanicBackend {
        fn shared() -> SharedBackend {
            Arc::new(SelectivePanicBackend {
                inner: EngineBackend::new(model()),
            })
        }
    }

    impl Backend for SelectivePanicBackend {
        fn name(&self) -> &'static str {
            "selective-panic"
        }
        fn spec(&self) -> ModelSpec {
            self.inner.spec()
        }
        fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
            Ok(Box::new(SelectivePanicSession {
                inner: self.inner.session()?,
            }))
        }
    }

    struct SelectivePanicSession {
        inner: Box<dyn Session>,
    }

    impl Session for SelectivePanicSession {
        fn name(&self) -> &'static str {
            "selective-panic"
        }
        fn spec(&self) -> ModelSpec {
            self.inner.spec()
        }
        fn infer_into(
            &mut self,
            x: &[f32],
            out: &mut [f32],
        ) -> Result<(), InferenceError> {
            assert!(x[0] != 666.0, "synthetic poison request");
            self.inner.infer_into(x, out)
        }
    }

    fn tagged(tag: f32) -> Vec<f32> {
        let mut v = vec![0.25f32; 8];
        v[0] = tag;
        v
    }

    fn wait_healthy(pool: &Pool) -> PoolHealth {
        let t0 = Instant::now();
        loop {
            let h = pool.health();
            if h.is_healthy() {
                return h;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "pool never restaffed: {h:?}"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn a_panic_fails_only_its_own_ticket() {
        let pool = Pool::new(
            SelectivePanicBackend::shared(),
            // max_batch 1: each request is its own group, so the
            // panic's blast radius is exactly one ticket.
            PoolConfig { workers: 2, max_batch: 1 },
        );
        let reference = pool.infer(&tagged(1.0)).unwrap();

        let poison = pool.submit(&tagged(666.0));
        let healthy: Vec<Ticket> =
            (0..10).map(|_| pool.submit(&tagged(1.0))).collect();

        match poison.wait() {
            Err(InferenceError::BackendPanicked { backend, message }) => {
                assert_eq!(backend, "selective-panic");
                assert!(
                    message.contains("synthetic poison"),
                    "panic payload survives: {message}"
                );
            }
            other => panic!("want BackendPanicked, got {other:?}"),
        }
        for t in healthy {
            assert_eq!(
                t.wait().unwrap(),
                reference,
                "innocent requests are served bit-identically"
            );
        }
        let h = wait_healthy(&pool);
        assert_eq!(h.panics_contained, 1);
        assert!(h.respawns >= 1, "the dead worker was restaffed");
        assert!(!h.quarantined);
    }

    #[test]
    fn batch_path_panic_spares_innocent_batchmates() {
        let pool = Pool::new(
            SelectivePanicBackend::shared(),
            // One worker and a roomy batch: the poison request shares
            // a group with innocents.
            PoolConfig { workers: 1, max_batch: 8 },
        );
        let reference = pool.infer(&tagged(1.0)).unwrap();

        // Pipeline a mixed wave while the single worker is busy with
        // the first entry, so the rest coalesce into one batch.
        let mut tickets = Vec::new();
        tickets.push(pool.submit(&tagged(1.0)));
        tickets.push(pool.submit(&tagged(666.0)));
        for _ in 0..5 {
            tickets.push(pool.submit(&tagged(1.0)));
        }
        let mut panics = 0;
        for t in tickets {
            match t.wait() {
                Ok(y) => assert_eq!(y, reference),
                Err(InferenceError::BackendPanicked { .. }) => panics += 1,
                Err(other) => panic!("unexpected error: {other}"),
            }
        }
        assert_eq!(panics, 1, "exactly the poison ticket failed");
        let h = wait_healthy(&pool);
        assert!(h.panics_contained >= 1);
    }

    #[test]
    fn repeated_panics_quarantine_the_backend() {
        let pool = Pool::with_supervisor(
            SelectivePanicBackend::shared(),
            PoolConfig { workers: 1, max_batch: 1 },
            SupervisorConfig {
                quarantine_after: 3,
                respawn_backoff: Duration::from_micros(200),
                max_respawn_backoff: Duration::from_millis(5),
            },
        );
        // Three straight poison requests trip the quarantine.
        for _ in 0..3 {
            assert!(pool.infer(&tagged(666.0)).is_err());
        }
        let t0 = Instant::now();
        while !pool.health().quarantined {
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "quarantine never tripped: {:?}",
                pool.health()
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        // Quarantined: even healthy-looking requests fail fast with a
        // typed BackendUnavailable instead of touching the backend —
        // and nothing hangs. (With a lone worker the pool may already
        // have closed its queue, so any of the quarantine/exit/
        // disconnect reasons is acceptable; all are fail-fast.)
        match pool.infer(&tagged(1.0)) {
            Err(InferenceError::BackendUnavailable { backend, .. }) => {
                assert_eq!(backend, "pool");
            }
            other => panic!("want fail-fast unavailable, got {other:?}"),
        }
        let h = pool.health();
        assert!(h.quarantined);
        assert_eq!(h.panics_contained, 3);
    }

    #[test]
    fn successes_reset_the_quarantine_streak() {
        let pool = Pool::with_supervisor(
            SelectivePanicBackend::shared(),
            PoolConfig { workers: 1, max_batch: 1 },
            SupervisorConfig {
                quarantine_after: 3,
                respawn_backoff: Duration::from_micros(200),
                max_respawn_backoff: Duration::from_millis(5),
            },
        );
        // Alternate panic / success well past the quarantine
        // threshold: the streak keeps resetting, so the pool stays in
        // service.
        for round in 0..5 {
            assert!(
                pool.infer(&tagged(666.0)).is_err(),
                "round {round}: poison fails"
            );
            assert_eq!(
                pool.infer(&tagged(1.0)).unwrap().len(),
                3,
                "round {round}: healthy request served after respawn"
            );
        }
        let h = wait_healthy(&pool);
        assert!(!h.quarantined, "interleaved successes prevent quarantine");
        assert_eq!(h.panics_contained, 5);
        assert!(h.respawns >= 5);
    }
}
