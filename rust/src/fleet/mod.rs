//! Fleet-scale closed-loop simulation: hundreds-to-thousands of
//! independently seeded `msf` plant twins driving the serving tier
//! concurrently, with detector verdicts fed back into the sims as
//! defense responses (ROADMAP item 4 — the end-to-end "heavy
//! traffic, many scenarios" proof).
//!
//! Three parts:
//!
//! - [`scenario`] — the declarative attack corpus: PLC-taxonomy
//!   families (sensor spoofing, actuator manipulation, stealthy
//!   ramp, replay, multi-stage campaigns) compiled onto the seven
//!   `msf::attacks` primitives with deterministic per-plant seeding.
//! - [`driver`] — the traffic generator: every plant's scan readings
//!   become Control-class detection requests under scan-cycle
//!   deadlines (plus Defense-class confirmations from suspicious
//!   plants and Batch-class sweeps), multiplexed over in-process
//!   [`serve::Pool`](crate::serve::Pool)s or the
//!   [`netserve`](crate::netserve) client; verdicts feed back as a
//!   setpoint-clamp → actuator-lockout → operator-escalation ladder.
//! - [`slo`] — fleet-level SLOs: per-class deadline hit rate and
//!   latency percentiles, shed rate, per-family recall and
//!   time-to-detect, split into a deterministic
//!   [`FleetOutcome`](slo::FleetOutcome) (replay-comparable with
//!   `==`) and wall-clock [`FleetTiming`](slo::FleetTiming).
//!
//! The determinism contract: a [`FleetOutcome`] is a pure function
//! of the [`FleetConfig`] — identical seeds produce identical
//! outcomes across runs, transports, and build modes. `tests/fleet.rs`
//! and `benches/fleet.rs` pin this.
#![deny(missing_docs)]

pub mod driver;
pub mod scenario;
pub mod slo;

pub use driver::{detector_model, run_fleet, FleetConfig, FleetTarget};
pub use scenario::{plant_seed, AttackMix, Scenario, ScenarioFamily};
pub use slo::{
    ClassCounts, FamilyOutcome, FleetOutcome, FleetReport, FleetTiming,
    LatencyStats,
};
