//! Fleet-level SLO aggregation: per-class request accounting and
//! deadline hit rates, per-family detection recall / time-to-detect,
//! and wall-clock latency percentiles.
//!
//! The report is split along the determinism boundary:
//! [`FleetOutcome`] holds everything that is a pure function of the
//! fleet seed and configuration (counts, recall, time-to-detect in
//! *steps*, the trajectory digest) and implements `PartialEq` so
//! replay identity is one `assert_eq!`; [`FleetTiming`] holds the
//! wall-clock half (latency percentiles, run duration, transport
//! counters) which legitimately varies between runs and is excluded
//! from equality.

use super::scenario::ScenarioFamily;
use crate::serve::Priority;
use crate::util::json::Json;

/// Seconds per scan step (the 10 Hz scan cycle) — converts
/// time-to-detect from steps to seconds.
pub const STEP_SECONDS: f64 = 0.1;

/// Deterministic request accounting for one priority class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClassCounts {
    /// Requests handed to the transport (including ones refused at
    /// submit time).
    pub submitted: u64,
    /// Served with logits.
    pub served: u64,
    /// Shed with a typed `DeadlineExceeded`.
    pub shed: u64,
    /// Refused with a typed `Overloaded`.
    pub overloaded: u64,
    /// Resolved with any other typed error.
    pub failed: u64,
}

impl ClassCounts {
    /// Requests that reached *some* resolution (logits or typed
    /// error).
    pub fn resolved(&self) -> u64 {
        self.served + self.shed + self.overloaded + self.failed
    }

    /// Requests submitted but never resolved — zero in every healthy
    /// run (the acceptance invariant).
    pub fn unresolved(&self) -> u64 {
        self.submitted.saturating_sub(self.resolved())
    }

    /// Deadline hit rate: served / submitted (1.0 for an idle class).
    pub fn hit_rate(&self) -> f64 {
        if self.submitted == 0 {
            1.0
        } else {
            self.served as f64 / self.submitted as f64
        }
    }

    /// Element-wise sum (for whole-fleet totals).
    pub fn merged(&self, other: &ClassCounts) -> ClassCounts {
        ClassCounts {
            submitted: self.submitted + other.submitted,
            served: self.served + other.served,
            shed: self.shed + other.shed,
            overloaded: self.overloaded + other.overloaded,
            failed: self.failed + other.failed,
        }
    }
}

/// Wall-clock latency samples for one class (timing half of the
/// report; never part of replay equality).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<f64>,
}

impl LatencyStats {
    /// Record one request latency in microseconds.
    pub fn record(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    /// Number of samples recorded.
    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    /// Whether no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    /// Nearest-rank percentile in microseconds (0.0 with no samples).
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut xs = self.samples_us.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * xs.len() as f64).ceil() as usize;
        xs[rank.clamp(1, xs.len()) - 1]
    }

    /// Mean latency in microseconds (0.0 with no samples).
    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }
}

/// Detection outcome for one scenario family across the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct FamilyOutcome {
    /// The scenario family.
    pub family: ScenarioFamily,
    /// Plants assigned a campaign of this family.
    pub plants: u64,
    /// Plants whose campaign produced a debounced detection inside
    /// its window (plus slack).
    pub detected: u64,
    /// Time-to-detect in scan steps (campaign start → debounced
    /// detection), one entry per detected plant, ascending.
    pub detect_steps: Vec<u64>,
}

impl FamilyOutcome {
    /// Detection recall: detected / plants (1.0 for an empty family).
    pub fn recall(&self) -> f64 {
        if self.plants == 0 {
            1.0
        } else {
            self.detected as f64 / self.plants as f64
        }
    }

    /// Nearest-rank percentile of time-to-detect, in seconds (0.0
    /// with no detections).
    pub fn ttd_seconds(&self, p: f64) -> f64 {
        if self.detect_steps.is_empty() {
            return 0.0;
        }
        let n = self.detect_steps.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        self.detect_steps[rank.clamp(1, n) - 1] as f64 * STEP_SECONDS
    }
}

/// The deterministic half of a fleet run: a pure function of
/// `FleetConfig` (seed, mix, sizes, feedback flags). Two runs with
/// identical configs — across processes, transports, or build modes —
/// must compare equal.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetOutcome {
    /// Fleet size.
    pub plants: u64,
    /// Scan steps driven per plant.
    pub steps: u64,
    /// Fleet seed the run replays from.
    pub seed: u64,
    /// Whether detector verdicts fed back into the sims.
    pub feedback: bool,
    /// Per-class accounting, indexed by `Priority::band()`.
    pub per_class: [ClassCounts; 3],
    /// Per-family detection outcomes (families with ≥ 1 plant, in
    /// `ScenarioFamily::ALL` order).
    pub families: Vec<FamilyOutcome>,
    /// Debounced detections outside any campaign window.
    pub false_positives: u64,
    /// Setpoint-clamp responses applied (defense rung 1).
    pub clamps: u64,
    /// Actuator-lockout responses applied (defense rung 2).
    pub lockouts: u64,
    /// Operator escalations raised through `hitl::OperatorConsole`.
    pub escalations: u64,
    /// Mean |true Wd − setpoint| across all plants and post-warmup
    /// steps — the physical-damage metric feedback is supposed to
    /// shrink.
    pub mean_true_wd_dev: f64,
    /// FNV-1a digest over the final `(tb0, tbot, wd)` bit patterns of
    /// every plant — one u64 that pins every trajectory.
    pub trajectory_digest: u64,
}

impl FleetOutcome {
    /// Accounting for one priority class.
    pub fn class(&self, p: Priority) -> &ClassCounts {
        &self.per_class[p.band()]
    }

    /// Whole-fleet totals across classes.
    pub fn total(&self) -> ClassCounts {
        self.per_class
            .iter()
            .fold(ClassCounts::default(), |acc, c| acc.merged(c))
    }

    /// Submitted-but-never-resolved requests across all classes.
    pub fn unresolved(&self) -> u64 {
        self.per_class.iter().map(|c| c.unresolved()).sum()
    }

    /// Fraction of all requests shed or refused under load.
    pub fn shed_rate(&self) -> f64 {
        let t = self.total();
        if t.submitted == 0 {
            0.0
        } else {
            (t.shed + t.overloaded) as f64 / t.submitted as f64
        }
    }

    /// Outcome for one family, if any plant ran it.
    pub fn family(&self, f: ScenarioFamily) -> Option<&FamilyOutcome> {
        self.families.iter().find(|o| o.family == f)
    }
}

/// The wall-clock half of a fleet run: latency percentiles and
/// transport counters. Varies run to run; excluded from replay
/// equality.
#[derive(Debug, Clone, Default)]
pub struct FleetTiming {
    /// Wall-clock duration of the whole run, seconds.
    pub wall_secs: f64,
    /// Per-class request latency, indexed by `Priority::band()`.
    pub latency: [LatencyStats; 3],
    /// `Pool::served()` summed over pools (0 on the netserve path —
    /// the pools live in the server process).
    pub pool_served: u64,
    /// `Pool::shed()` summed over pools (0 on the netserve path).
    pub pool_shed: u64,
    /// `Pool::batches()` summed over pools (0 on the netserve path).
    pub pool_batches: u64,
}

/// A complete fleet run report: deterministic [`FleetOutcome`] plus
/// wall-clock [`FleetTiming`].
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// The replayable half (compare with `assert_eq!`).
    pub outcome: FleetOutcome,
    /// The wall-clock half.
    pub timing: FleetTiming,
}

impl FleetReport {
    /// Serialize the full report (both halves) as JSON — the
    /// `BENCH_fleet.json` `fleet{...}` shape documented in `API.md`.
    pub fn to_json(&self) -> Json {
        let o = &self.outcome;
        let classes = Priority::ALL
            .iter()
            .map(|p| {
                let c = o.class(*p);
                let l = &self.timing.latency[p.band()];
                Json::obj(vec![
                    ("class", Json::Str(p.name().to_string())),
                    ("submitted", Json::Num(c.submitted as f64)),
                    ("served", Json::Num(c.served as f64)),
                    ("shed", Json::Num(c.shed as f64)),
                    ("overloaded", Json::Num(c.overloaded as f64)),
                    ("failed", Json::Num(c.failed as f64)),
                    ("hit_rate", Json::Num(c.hit_rate())),
                    ("p50_us", Json::Num(l.percentile_us(50.0))),
                    ("p95_us", Json::Num(l.percentile_us(95.0))),
                    ("p99_us", Json::Num(l.percentile_us(99.0))),
                ])
            })
            .collect();
        let families = o
            .families
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("family", Json::Str(f.family.name().to_string())),
                    ("plants", Json::Num(f.plants as f64)),
                    ("detected", Json::Num(f.detected as f64)),
                    ("recall", Json::Num(f.recall())),
                    ("ttd_p50_s", Json::Num(f.ttd_seconds(50.0))),
                    ("ttd_p95_s", Json::Num(f.ttd_seconds(95.0))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("plants", Json::Num(o.plants as f64)),
            ("steps", Json::Num(o.steps as f64)),
            ("seed", Json::Num(o.seed as f64)),
            ("feedback", Json::Bool(o.feedback)),
            ("classes", Json::Arr(classes)),
            ("families", Json::Arr(families)),
            ("shed_rate", Json::Num(o.shed_rate())),
            ("unresolved", Json::Num(o.unresolved() as f64)),
            ("false_positives", Json::Num(o.false_positives as f64)),
            ("clamps", Json::Num(o.clamps as f64)),
            ("lockouts", Json::Num(o.lockouts as f64)),
            ("escalations", Json::Num(o.escalations as f64)),
            ("mean_true_wd_dev", Json::Num(o.mean_true_wd_dev)),
            (
                "trajectory_digest",
                Json::Str(format!("{:016x}", o.trajectory_digest)),
            ),
            ("wall_secs", Json::Num(self.timing.wall_secs)),
        ])
    }

    /// Print the human-readable summary (`icsml fleet` output).
    pub fn print_summary(&self) {
        let o = &self.outcome;
        println!(
            "fleet: {} plants x {} steps (seed {}, feedback {})",
            o.plants, o.steps, o.seed, o.feedback
        );
        println!(
            "  {:<8} {:>9} {:>9} {:>6} {:>10} {:>6} {:>8} {:>9} {:>9}",
            "class",
            "submitted",
            "served",
            "shed",
            "overloaded",
            "failed",
            "hit",
            "p50_us",
            "p99_us"
        );
        for p in Priority::ALL.iter() {
            let c = o.class(*p);
            let l = &self.timing.latency[p.band()];
            println!(
                "  {:<8} {:>9} {:>9} {:>6} {:>10} {:>6} {:>7.1}% {:>9.0} {:>9.0}",
                p.name(),
                c.submitted,
                c.served,
                c.shed,
                c.overloaded,
                c.failed,
                c.hit_rate() * 100.0,
                l.percentile_us(50.0),
                l.percentile_us(99.0),
            );
        }
        for f in &o.families {
            println!(
                "  {:<22} plants {:>4}  recall {:>5.1}%  ttd p50 {:>6.1}s p95 {:>6.1}s",
                f.family.name(),
                f.plants,
                f.recall() * 100.0,
                f.ttd_seconds(50.0),
                f.ttd_seconds(95.0),
            );
        }
        println!(
            "  defense: clamps {} lockouts {} escalations {} false_positives {}",
            o.clamps, o.lockouts, o.escalations, o.false_positives
        );
        println!(
            "  shed_rate {:.4}  unresolved {}  mean|wd-set| {:.5}  digest {:016x}  wall {:.2}s",
            o.shed_rate(),
            o.unresolved(),
            o.mean_true_wd_dev,
            o.trajectory_digest,
            self.timing.wall_secs
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_counts_invariants() {
        let c = ClassCounts {
            submitted: 10,
            served: 6,
            shed: 2,
            overloaded: 1,
            failed: 0,
        };
        assert_eq!(c.resolved(), 9);
        assert_eq!(c.unresolved(), 1);
        assert!((c.hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(ClassCounts::default().hit_rate(), 1.0);
        let m = c.merged(&c);
        assert_eq!(m.submitted, 20);
        assert_eq!(m.served, 12);
    }

    #[test]
    fn latency_percentiles_nearest_rank() {
        let mut l = LatencyStats::default();
        assert_eq!(l.percentile_us(50.0), 0.0);
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            l.record(v);
        }
        assert_eq!(l.len(), 5);
        assert!(!l.is_empty());
        assert_eq!(l.percentile_us(50.0), 3.0);
        assert_eq!(l.percentile_us(100.0), 5.0);
        assert_eq!(l.percentile_us(0.0), 1.0);
        assert!((l.mean_us() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn family_outcome_recall_and_ttd() {
        let f = FamilyOutcome {
            family: ScenarioFamily::Replay,
            plants: 4,
            detected: 3,
            detect_steps: vec![10, 20, 100],
        };
        assert!((f.recall() - 0.75).abs() < 1e-12);
        assert!((f.ttd_seconds(50.0) - 2.0).abs() < 1e-12);
        assert!((f.ttd_seconds(100.0) - 10.0).abs() < 1e-12);
    }
}
