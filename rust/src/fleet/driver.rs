//! The fleet traffic generator: multiplexes hundreds-to-thousands of
//! independently seeded plant sims over the serving tier — in-process
//! [`serve::Pool`](crate::serve::Pool) shards or the
//! [`netserve`](crate::netserve) client — open-loop on arrivals,
//! closed-loop on feedback.
//!
//! Per scan step, every plant: (1) steps its physics and pushes the
//! ADC readings into its sliding window; (2) once warm, submits a
//! Control-class detection request under the scan-cycle deadline
//! bridge (`Deadline::for_scan`); (3) if mid-debounce ("suspicious"),
//! submits an extra Defense-class confirmation request — this is how
//! attack waves turn into load spikes; (4) periodically, Batch-class
//! retraining-style sweeps ride along with no deadline.
//!
//! **Determinism.** Verdicts are applied in lock-step: the batch
//! submitted at step `t` is resolved (blocking) before the sims
//! advance past step `t + feedback_delay`, so the step at which a
//! defense response lands is a pure function of the logits, never of
//! wall-clock scheduling. Logits are bit-identical across runs (f32 arithmetic,
//! no fast-math), so the whole
//! [`FleetOutcome`](super::slo::FleetOutcome) replays exactly — even
//! across the pool and netserve transports.
//!
//! **Defense ladder.** Each debounced detection advances the plant
//! one rung: 1 → setpoint clamp, 2 → actuator lockout, ≥
//! `escalate_rung` → operator escalation through
//! [`hitl::OperatorConsole`](crate::hitl::OperatorConsole), whose
//! intervention ends the campaign after `operator_delay` steps.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::scenario::{plant_seed, AttackMix, Scenario, ScenarioFamily};
use super::slo::{
    ClassCounts, FamilyOutcome, FleetOutcome, FleetReport, FleetTiming,
    LatencyStats,
};
use crate::api::{EngineBackend, InferenceError, SharedBackend};
use crate::defense::{SlidingWindow, FEATURES, WINDOW};
use crate::engine::{Act, Layer, Model};
use crate::hitl::OperatorConsole;
use crate::msf::{Simulator, TB0_NOM, WD_SET};
use crate::netserve::Client;
use crate::netserve::NetOptions;
use crate::plc::{HwProfile, ScanCycle};
use crate::serve::{Deadline, Pool, PoolConfig, Priority, SubmitOptions, Ticket};
use crate::st::tasks::serve_priority;
use crate::st::{TaskScheduler, Value, Vm};

/// Wd-deviation band of the fleet detector (t/min beyond which the
/// window mean fires the attack logit). ~100σ above benign ADC+noise
/// jitter of the windowed mean, far below every scenario's effect.
pub const DETECT_WD_BAND: f64 = 0.05;

/// Tb0-deviation band of the fleet detector (°C).
pub const DETECT_TB0_BAND: f64 = 0.35;

/// Steps past a campaign's end during which a debounced firing still
/// counts as a detection (recovery transients), not a false positive.
pub const DETECT_SLACK: u64 = 400;

/// The fleet's hand-built two-logit detector: 400 → 4 (ReLU) → 2.
///
/// Layer 1 computes the window-mean deviation of each channel beyond
/// a band: `h0/h1` fire when mean(Wd) is above/below
/// `WD_SET ± DETECT_WD_BAND`, `h2/h3` when mean(Tb0) is beyond
/// `TB0_NOM ± DETECT_TB0_BAND`. Layer 2 sums the excesses with a
/// large gain against a fixed margin on the normal logit, so
/// `logits[1] > logits[0]` ⇔ some channel mean left its band by more
/// than 1/400. Same feature layout as
/// [`defense::SlidingWindow`](crate::defense::SlidingWindow)
/// (`[tb0 window | wd window]`).
pub fn detector_model() -> Model {
    let inv = 1.0f32 / WINDOW as f32;
    let mut w1 = vec![0.0f32; 4 * FEATURES];
    for i in 0..WINDOW {
        // Row layout is [neurons][inputs].
        w1[WINDOW + i] = inv; // h0: mean(wd) high
        w1[FEATURES + WINDOW + i] = -inv; // h1: mean(wd) low
        w1[2 * FEATURES + i] = inv; // h2: mean(tb0) high
        w1[3 * FEATURES + i] = -inv; // h3: mean(tb0) low
    }
    let b1 = vec![
        -((WD_SET + DETECT_WD_BAND) as f32),
        (WD_SET - DETECT_WD_BAND) as f32,
        -((TB0_NOM + DETECT_TB0_BAND) as f32),
        (TB0_NOM - DETECT_TB0_BAND) as f32,
    ];
    let gain = 400.0f32;
    let w2 = vec![0.0, 0.0, 0.0, 0.0, gain, gain, gain, gain];
    let b2 = vec![1.0f32, 0.0];
    Model::new(vec![
        Layer::dense(w1, b1, FEATURES, Act::Relu),
        Layer::dense(w2, b2, 4, Act::None),
    ])
}

/// The per-plant two-task IEC 61131-3 controller used when
/// [`FleetConfig::st_tasks`] is on: a priority-0 control task every
/// scan (100 ms, integrating a PI-style correction from the ADC
/// image) and a priority-1 detection task every third scan. The
/// driver feeds each plant's ADC readings into the globals, ticks the
/// plant's [`TaskScheduler`] once per simulator step, and only
/// submits a detection request on ticks where `t_detect` actually ran
/// — with the request class bridged from the task's IEC priority via
/// [`serve_priority`] (1 → `Defense`) and the deadline from
/// `Deadline::for_scan` as usual.
const ST_TASKS_SRC: &str = "\
VAR_GLOBAL
    g_tb0 : REAL;
    g_wd : REAL;
    g_mv : REAL;
    g_scans : DINT;
    g_det_runs : DINT;
    g_det_acc : REAL;
END_VAR
PROGRAM CtrlScan
VAR err : REAL; END_VAR
    err := 0.66 - g_wd;
    g_mv := g_mv + 0.4 * err;
    g_scans := g_scans + 1;
END_PROGRAM
PROGRAM DetectScan
    g_det_acc := g_det_acc + g_wd + g_tb0;
    g_det_runs := g_det_runs + 1;
END_PROGRAM
CONFIGURATION FleetPlant
    RESOURCE cpu ON plc
        TASK t_ctrl(INTERVAL := T#100ms, PRIORITY := 0);
        TASK t_detect(INTERVAL := T#300ms, PRIORITY := 1);
        PROGRAM pCtrl WITH t_ctrl : CtrlScan;
        PROGRAM pDet WITH t_detect : DetectScan;
    END_RESOURCE
END_CONFIGURATION
";

/// Fleet run parameters. Every field is an input to the deterministic
/// [`FleetOutcome`](super::slo::FleetOutcome).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of independently seeded plants.
    pub plants: usize,
    /// Scan steps to drive per plant (0.1 s each).
    pub steps: u64,
    /// Fleet seed; per-plant seeds derive via
    /// [`plant_seed`](super::scenario::plant_seed).
    pub seed: u64,
    /// Scenario mix assigned across the fleet by proportional
    /// striping.
    pub mix: AttackMix,
    /// Sensor noise on the sims.
    pub noise: bool,
    /// Feed detector verdicts back as defense responses.
    pub feedback: bool,
    /// Attach scan-cycle deadlines (`Deadline::for_scan`) to
    /// Control/Defense requests. Off ⇒ nothing sheds, which keeps
    /// served-counts deterministic; on ⇒ realistic shed behavior.
    pub deadline: bool,
    /// Scan period in µs used for the deadline bridge (the real scan
    /// is 100 ms; tighten this to put the serving tier under deadline
    /// pressure).
    pub period_us: f64,
    /// Control-task cost per scan in µs (the scan budget left for ML
    /// is `period − control_us`).
    pub control_us: f64,
    /// Lock-step pipeline depth: the step-`t` batch resolves once
    /// `feedback_delay` further step batches have been queued behind
    /// it.
    pub feedback_delay: u64,
    /// Consecutive positive verdicts required per debounced
    /// detection.
    pub debounce: u32,
    /// Defense rung at which the plant escalates to the operator.
    pub escalate_rung: u32,
    /// Operator response delay in steps (escalation → intervention).
    pub operator_delay: u64,
    /// Submit a Batch-class sweep burst every this many steps
    /// (0 disables sweeps).
    pub sweep_every: u64,
    /// Plants sampled per sweep burst.
    pub sweep_batch: usize,
    /// Run each plant's controller as a real two-task IEC 61131-3
    /// CONFIGURATION (`ST_TASKS_SRC` on the bytecode [`Vm`]):
    /// detection requests are then paced by the priority-1 `t_detect`
    /// task (every third scan) and submitted at the serve class its
    /// IEC priority bridges to (`Defense`), instead of every-scan
    /// `Control`-class submission.
    pub st_tasks: bool,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            plants: 16,
            steps: 2_000,
            seed: 1,
            mix: AttackMix::uniform(),
            noise: true,
            feedback: true,
            deadline: false,
            period_us: 100_000.0,
            control_us: 2.0,
            feedback_delay: 2,
            debounce: 5,
            escalate_rung: 3,
            operator_delay: 50,
            sweep_every: 100,
            sweep_batch: 4,
            st_tasks: false,
        }
    }
}

/// Where the fleet's inference traffic goes.
pub enum FleetTarget {
    /// In-process `serve::Pool` shards; plant `i` routes to shard
    /// `i % shards`.
    Pools(Vec<Pool>),
    /// A connected `netserve` client driving one named registry
    /// model over the pipelined wire protocol.
    Net {
        /// Connected client (use `Client::connect_with` + a
        /// `RetryPolicy` to survive reconnects).
        client: Client,
        /// Registry model name to drive.
        model: String,
    },
}

impl FleetTarget {
    /// Convenience in-process target: `shards` pools × `workers`
    /// workers each, all over one shared fleet-detector backend.
    pub fn pools(shards: usize, workers: usize, max_batch: usize) -> FleetTarget {
        let backend: SharedBackend = Arc::new(EngineBackend::new(detector_model()));
        let pools = (0..shards.max(1))
            .map(|_| {
                Pool::new(
                    Arc::clone(&backend),
                    PoolConfig { workers, max_batch },
                )
            })
            .collect();
        FleetTarget::Pools(pools)
    }
}

/// Unified submit/resolve over the two transports, keyed by request
/// id (pool path: a private counter over `Ticket`s; net path: the
/// wire id).
enum Lane {
    Pools {
        pools: Vec<Pool>,
        tickets: HashMap<u64, Ticket>,
        next_key: u64,
    },
    Net {
        client: Client,
        model: String,
        /// Replies received while waiting for a different id.
        done: HashMap<u64, Result<Vec<f32>, InferenceError>>,
        /// Set when the transport failed terminally; all later
        /// resolves short-circuit instead of re-timing-out.
        dead: Option<String>,
    },
}

impl Lane {
    fn new(target: FleetTarget) -> Lane {
        match target {
            FleetTarget::Pools(pools) => Lane::Pools {
                pools,
                tickets: HashMap::new(),
                next_key: 0,
            },
            FleetTarget::Net { mut client, model } => {
                // A stuck server must surface as a typed error, not a
                // hung fleet: bound every blocking recv.
                let _ = client.set_timeout(Some(Duration::from_secs(60)));
                Lane::Net {
                    client,
                    model,
                    done: HashMap::new(),
                    dead: None,
                }
            }
        }
    }

    fn submit(
        &mut self,
        plant: usize,
        x: &[f32],
        priority: Priority,
        budget: Option<(Deadline, f64)>,
    ) -> Result<u64, InferenceError> {
        match self {
            Lane::Pools {
                pools,
                tickets,
                next_key,
            } => {
                let pool = &pools[plant % pools.len()];
                let mut opts = SubmitOptions::new().priority(priority);
                if let Some((deadline, _)) = budget {
                    opts = opts.deadline(deadline);
                }
                let ticket = pool.submit_with(x, opts)?;
                let key = *next_key;
                *next_key += 1;
                tickets.insert(key, ticket);
                Ok(key)
            }
            Lane::Net { client, model, .. } => {
                let mut opts = NetOptions::new().priority(priority);
                if let Some((_, us)) = budget {
                    opts = opts.deadline_us(us);
                }
                client.submit(model, x, &opts).map_err(|e| {
                    InferenceError::BackendUnavailable {
                        backend: "netserve".to_string(),
                        reason: format!("submit failed: {e}"),
                    }
                })
            }
        }
    }

    fn resolve(&mut self, key: u64) -> Result<Vec<f32>, InferenceError> {
        match self {
            Lane::Pools { tickets, .. } => match tickets.remove(&key) {
                Some(t) => t.wait(),
                None => Err(InferenceError::BackendUnavailable {
                    backend: "fleet".to_string(),
                    reason: format!("unknown ticket {key}"),
                }),
            },
            Lane::Net {
                client, done, dead, ..
            } => {
                if let Some(r) = done.remove(&key) {
                    return r;
                }
                if let Some(reason) = dead {
                    return Err(InferenceError::BackendUnavailable {
                        backend: "netserve".to_string(),
                        reason: reason.clone(),
                    });
                }
                loop {
                    match client.recv_reconnecting() {
                        Ok(reply) => {
                            let res =
                                reply.result.map_err(|e| e.to_error());
                            if reply.id == key {
                                return res;
                            }
                            done.insert(reply.id, res);
                        }
                        Err(InferenceError::ConnectionLost {
                            lost_ids,
                            reason,
                        }) => {
                            // Distribute the loss over the individual
                            // requests so each resolves typed.
                            for id in &lost_ids {
                                done.insert(
                                    *id,
                                    Err(InferenceError::ConnectionLost {
                                        lost_ids: vec![*id],
                                        reason: reason.clone(),
                                    }),
                                );
                            }
                            if let Some(r) = done.remove(&key) {
                                return r;
                            }
                            return Err(InferenceError::ConnectionLost {
                                lost_ids: vec![key],
                                reason,
                            });
                        }
                        Err(e) => {
                            *dead = Some(e.to_string());
                            return Err(e);
                        }
                    }
                }
            }
        }
    }

    /// `(served, shed, batches)` summed over in-process pools (zeros
    /// on the net path — those counters live server-side).
    fn pool_counters(&self) -> (u64, u64, u64) {
        match self {
            Lane::Pools { pools, .. } => pools.iter().fold(
                (0, 0, 0),
                |(s, sh, b), p| {
                    (s + p.served(), sh + p.shed(), b + p.batches())
                },
            ),
            Lane::Net { .. } => (0, 0, 0),
        }
    }
}

/// Classify a typed resolution error into the shed/overloaded/failed
/// accounting buckets.
fn account_error(c: &mut ClassCounts, e: &InferenceError) {
    match e {
        InferenceError::DeadlineExceeded { .. } => c.shed += 1,
        InferenceError::Overloaded { .. } => c.overloaded += 1,
        _ => c.failed += 1,
    }
}

/// One plant's on-PLC task set: the compiled two-task configuration
/// running on the bytecode tier plus its cyclic executive, and the
/// resolved global slots / task index the driver pokes each step.
struct StTasks {
    vm: Vm,
    sched: TaskScheduler,
    g_tb0: usize,
    g_wd: usize,
    detect_task: usize,
    detect_class: Priority,
}

impl StTasks {
    fn new(unit: &crate::st::ir::Unit) -> StTasks {
        let g_tb0 = unit.find_global("g_tb0").expect("g_tb0 global");
        let g_wd = unit.find_global("g_wd").expect("g_wd global");
        let vm = Vm::new(unit.clone());
        let sched = TaskScheduler::for_runtime(&vm, HwProfile::beaglebone())
            .expect("fleet controller declares a CONFIGURATION");
        let detect_task =
            sched.model().find_task("t_detect").expect("t_detect task");
        let detect_class =
            serve_priority(sched.model().tasks[detect_task].priority);
        StTasks { vm, sched, g_tb0, g_wd, detect_task, detect_class }
    }

    /// Feed the scan's ADC image and run one scheduler tick; returns
    /// whether the detection task ran this scan.
    fn scan(&mut self, tb0_adc: f64, wd_adc: f64) -> bool {
        self.vm.globals[self.g_tb0] = Value::Real(tb0_adc as f32);
        self.vm.globals[self.g_wd] = Value::Real(wd_adc as f32);
        let report = self
            .sched
            .tick(&mut self.vm)
            .expect("fleet ST controller faulted");
        report.ran.contains(&self.detect_task)
    }
}

struct PlantRt {
    sim: Simulator,
    window: SlidingWindow,
    st: Option<StTasks>,
    scenario: Option<Scenario>,
    consecutive: u32,
    rung: u32,
    escalated: bool,
    first_detect: Option<u64>,
    false_positives: u64,
    intervene_at: Option<u64>,
    dev_accum: f64,
    dev_samples: u64,
}

struct PendingMeta {
    plant: usize,
    class: Priority,
    detect: bool,
    submitted: Instant,
}

struct FleetRun<'a> {
    cfg: &'a FleetConfig,
    lane: Lane,
    cycle: ScanCycle,
    plants: Vec<PlantRt>,
    console: OperatorConsole,
    counts: [ClassCounts; 3],
    latency: [LatencyStats; 3],
    pending: HashMap<u64, PendingMeta>,
    ring: VecDeque<Vec<u64>>,
    features: Vec<f32>,
    clamps: u64,
    lockouts: u64,
}

impl FleetRun<'_> {
    fn submit_one(&mut self, plant: usize, class: Priority, detect: bool) {
        let budget = if self.cfg.deadline && class != Priority::Batch {
            Some((
                Deadline::for_scan(&self.cycle, self.cfg.control_us),
                self.cycle.ml_budget_us(self.cfg.control_us),
            ))
        } else {
            None
        };
        self.counts[class.band()].submitted += 1;
        match self.lane.submit(plant, &self.features, class, budget) {
            Ok(key) => {
                self.pending.insert(
                    key,
                    PendingMeta {
                        plant,
                        class,
                        detect,
                        submitted: Instant::now(),
                    },
                );
                self.ring
                    .back_mut()
                    .expect("ring slot pushed per step")
                    .push(key);
            }
            Err(e) => account_error(&mut self.counts[class.band()], &e),
        }
    }

    fn resolve_batch(&mut self, keys: Vec<u64>, now_step: u64) {
        for key in keys {
            let meta = match self.pending.remove(&key) {
                Some(m) => m,
                None => continue,
            };
            let result = self.lane.resolve(key);
            let band = meta.class.band();
            match &result {
                Ok(_) => {
                    self.counts[band].served += 1;
                    self.latency[band]
                        .record(meta.submitted.elapsed().as_secs_f64() * 1e6);
                }
                Err(e) => account_error(&mut self.counts[band], e),
            }
            if meta.detect {
                self.apply_verdict(meta.plant, &result, now_step);
            }
        }
    }

    fn apply_verdict(
        &mut self,
        idx: usize,
        result: &Result<Vec<f32>, InferenceError>,
        now_step: u64,
    ) {
        let positive = match result {
            Ok(logits) => logits.len() >= 2 && logits[1] > logits[0],
            // A shed/errored request is a missed observation, not a
            // verdict: the debounce counter holds.
            Err(_) => return,
        };
        let p = &mut self.plants[idx];
        if !positive {
            p.consecutive = 0;
            return;
        }
        p.consecutive += 1;
        if p.consecutive % self.cfg.debounce.max(1) != 0 {
            return;
        }
        // A debounced detection event.
        let (in_window, before_window) = match &p.scenario {
            Some(s) => (
                now_step >= s.start_step
                    && now_step < s.end_step.saturating_add(DETECT_SLACK),
                now_step < s.start_step,
            ),
            None => (false, true),
        };
        if in_window {
            if p.first_detect.is_none() {
                p.first_detect = Some(now_step);
            }
        } else if before_window {
            p.false_positives += 1;
        }
        if !self.cfg.feedback {
            return;
        }
        // Escalation ladder: every debounced event advances one rung.
        p.rung += 1;
        if p.rung == 1 {
            p.sim.defense.clamp_setpoint = true;
            self.clamps += 1;
        } else if p.rung == 2 {
            p.sim.defense.lockout_actuators = true;
            self.lockouts += 1;
        }
        if p.rung >= self.cfg.escalate_rung && !p.escalated {
            p.escalated = true;
            p.intervene_at = Some(self.console.escalate(idx, now_step));
        }
    }

    fn step(&mut self, t: u64) {
        // Operator interventions due this step end the campaign: the
        // operator takes the plant to manual and clears the intruder.
        for p in self.plants.iter_mut() {
            if p.intervene_at == Some(t) {
                p.intervene_at = None;
                for a in p.sim.attacks.iter_mut() {
                    a.end_step = a.end_step.min(t);
                }
            }
        }
        self.ring.push_back(Vec::new());
        for i in 0..self.plants.len() {
            let r = self.plants[i].sim.step();
            if t >= WINDOW as u64 {
                let dev = (self.plants[i].sim.state.wd - WD_SET).abs();
                self.plants[i].dev_accum += dev;
                self.plants[i].dev_samples += 1;
            }
            let warm = self.plants[i].window.push(r.tb0_adc, r.wd_adc);
            // In st_tasks mode the plant's own task scheduler paces
            // detection: tick it every scan (whether or not the window
            // is warm — the schedule must stay aligned with plant
            // time) and only submit when `t_detect` ran, at the serve
            // class its IEC priority bridges to.
            let (detect_now, detect_class) =
                match self.plants[i].st.as_mut() {
                    Some(st) => {
                        let ran = st.scan(r.tb0_adc, r.wd_adc);
                        (ran, st.detect_class)
                    }
                    None => (true, Priority::Control),
                };
            if !warm {
                continue;
            }
            if detect_now || self.plants[i].consecutive > 0 {
                self.plants[i].window.fill_features(&mut self.features);
            }
            if detect_now {
                self.submit_one(i, detect_class, true);
            }
            if self.plants[i].consecutive > 0 {
                // Suspicious plants double-check at Defense class —
                // attack waves become load spikes.
                self.submit_one(i, Priority::Defense, false);
            }
        }
        // Batch-class retraining-style sweeps ride along periodically.
        if self.cfg.sweep_every > 0
            && t > 0
            && t % self.cfg.sweep_every == 0
            && !self.plants.is_empty()
        {
            for k in 0..self.cfg.sweep_batch {
                let i = (t as usize + k) % self.plants.len();
                if !self.plants[i].window.ready() {
                    continue;
                }
                self.plants[i].window.fill_features(&mut self.features);
                self.submit_one(i, Priority::Batch, false);
            }
        }
    }
}

/// Drive a full fleet run against `target` and build the report.
///
/// Every submitted request is resolved — logits or typed error —
/// before the report is built; nothing is left in flight. The
/// returned [`FleetOutcome`](super::slo::FleetOutcome) is a pure
/// function of `cfg` (see the module docs for the lock-step
/// determinism argument).
pub fn run_fleet(cfg: &FleetConfig, target: FleetTarget) -> FleetReport {
    let t0 = Instant::now();
    // One compile of the two-task controller, cloned per plant (each
    // plant owns its globals/meter; the source is fixed so the unit
    // is too).
    let st_unit = if cfg.st_tasks {
        Some(
            crate::st::compile(ST_TASKS_SRC)
                .expect("fleet two-task controller compiles"),
        )
    } else {
        None
    };
    let mut run = FleetRun {
        cfg,
        lane: Lane::new(target),
        cycle: ScanCycle::new(HwProfile::beaglebone(), cfg.period_us),
        plants: (0..cfg.plants)
            .map(|i| {
                let seed = plant_seed(cfg.seed, i);
                let scenario = cfg.mix.assign(i, cfg.plants).map(|fam| {
                    Scenario::generate(fam, seed ^ 0x00a7_7ac4, cfg.steps)
                });
                let attacks = scenario
                    .as_ref()
                    .map(|s| s.attacks.clone())
                    .unwrap_or_default();
                PlantRt {
                    sim: Simulator::new(seed, cfg.noise, attacks),
                    window: SlidingWindow::new(),
                    st: st_unit.as_ref().map(StTasks::new),
                    scenario,
                    consecutive: 0,
                    rung: 0,
                    escalated: false,
                    first_detect: None,
                    false_positives: 0,
                    intervene_at: None,
                    dev_accum: 0.0,
                    dev_samples: 0,
                }
            })
            .collect(),
        console: OperatorConsole::new(cfg.operator_delay),
        counts: [ClassCounts::default(); 3],
        latency: Default::default(),
        pending: HashMap::new(),
        ring: VecDeque::new(),
        features: vec![0.0f32; FEATURES],
        clamps: 0,
        lockouts: 0,
    };

    for t in 0..cfg.steps {
        // Lock-step feedback: resolve the batch from `feedback_delay`
        // steps back before stepping the sims.
        while run.ring.len() > cfg.feedback_delay as usize {
            let batch = run.ring.pop_front().expect("ring non-empty");
            run.resolve_batch(batch, t);
        }
        run.step(t);
    }
    // Drain everything still in flight.
    while let Some(batch) = run.ring.pop_front() {
        run.resolve_batch(batch, cfg.steps);
    }

    let mut families = Vec::new();
    for fam in ScenarioFamily::ALL {
        let mut fo = FamilyOutcome {
            family: fam,
            plants: 0,
            detected: 0,
            detect_steps: Vec::new(),
        };
        for p in &run.plants {
            let s = match &p.scenario {
                Some(s) if s.family == fam => s,
                _ => continue,
            };
            fo.plants += 1;
            if let Some(d) = p.first_detect {
                fo.detected += 1;
                fo.detect_steps.push(d.saturating_sub(s.start_step));
            }
        }
        fo.detect_steps.sort_unstable();
        if fo.plants > 0 {
            families.push(fo);
        }
    }

    let mut digest: u64 = 0xcbf2_9ce4_8422_2325;
    let mut dev_sum = 0.0;
    let mut dev_n: u64 = 0;
    let mut false_positives: u64 = 0;
    for p in &run.plants {
        for bits in [
            p.sim.state.tb0.to_bits(),
            p.sim.state.tbot.to_bits(),
            p.sim.state.wd.to_bits(),
        ] {
            for byte in bits.to_le_bytes() {
                digest ^= byte as u64;
                digest = digest.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        dev_sum += p.dev_accum;
        dev_n += p.dev_samples;
        false_positives += p.false_positives;
    }

    let (pool_served, pool_shed, pool_batches) = run.lane.pool_counters();
    FleetReport {
        outcome: FleetOutcome {
            plants: cfg.plants as u64,
            steps: cfg.steps,
            seed: cfg.seed,
            feedback: cfg.feedback,
            per_class: run.counts,
            families,
            false_positives,
            clamps: run.clamps,
            lockouts: run.lockouts,
            escalations: run.console.escalations.len() as u64,
            mean_true_wd_dev: if dev_n == 0 {
                0.0
            } else {
                dev_sum / dev_n as f64
            },
            trajectory_digest: digest,
        },
        timing: FleetTiming {
            wall_secs: t0.elapsed().as_secs_f64(),
            latency: run.latency,
            pool_served,
            pool_shed,
            pool_batches,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, Session as _};

    fn infer(model: &Model, x: &[f32]) -> Vec<f32> {
        let mut session = EngineBackend::new(model.clone()).session().unwrap();
        session.infer(x).unwrap()
    }

    #[test]
    fn detector_separates_nominal_from_deviated_windows() {
        let model = detector_model();
        let mut x = vec![0.0f32; FEATURES];
        for i in 0..WINDOW {
            x[i] = TB0_NOM as f32;
            x[WINDOW + i] = WD_SET as f32;
        }
        let nominal = infer(&model, &x);
        assert!(
            nominal[0] > nominal[1],
            "nominal window must read normal: {nominal:?}"
        );
        // Small jitter stays normal.
        let mut jit = x.clone();
        for (i, v) in jit.iter_mut().enumerate() {
            *v += if i % 2 == 0 { 0.002 } else { -0.002 };
        }
        let jittered = infer(&model, &jit);
        assert!(jittered[0] > jittered[1], "jitter fires: {jittered:?}");
        // Wd mean shifted past the band fires.
        let mut low = x.clone();
        for v in low.iter_mut().skip(WINDOW) {
            *v -= 0.1;
        }
        let fired = infer(&model, &low);
        assert!(fired[1] > fired[0], "wd shift must fire: {fired:?}");
        // Tb0 mean shifted past its band fires too.
        let mut hot = x;
        for v in hot.iter_mut().take(WINDOW) {
            *v += 1.0;
        }
        let fired = infer(&model, &hot);
        assert!(fired[1] > fired[0], "tb0 shift must fire: {fired:?}");
    }

    #[test]
    fn pool_fleet_runs_and_replays() {
        let cfg = FleetConfig {
            plants: 6,
            steps: 900,
            seed: 11,
            sweep_every: 50,
            ..FleetConfig::default()
        };
        let a = run_fleet(&cfg, FleetTarget::pools(2, 2, 8));
        let b = run_fleet(&cfg, FleetTarget::pools(1, 3, 4));
        assert_eq!(a.outcome.unresolved(), 0);
        assert_eq!(
            a.outcome, b.outcome,
            "outcome must not depend on pool topology"
        );
        assert!(a.outcome.class(Priority::Control).served > 0);
        assert!(a.outcome.class(Priority::Batch).served > 0);
        assert!(a.timing.pool_served > 0);
    }

    /// The two-task controller mode: detection is paced by the ST
    /// task scheduler (every third scan), submitted at the Defense
    /// class its IEC priority 1 bridges to, and the whole run still
    /// replays bit-identically across pool topologies.
    #[test]
    fn st_task_fleet_paces_detection_and_replays() {
        let cfg = FleetConfig {
            plants: 4,
            steps: 900,
            seed: 11,
            st_tasks: true,
            sweep_every: 0,
            ..FleetConfig::default()
        };
        let a = run_fleet(&cfg, FleetTarget::pools(2, 2, 8));
        let b = run_fleet(&cfg, FleetTarget::pools(1, 3, 4));
        assert_eq!(a.outcome.unresolved(), 0);
        assert_eq!(
            a.outcome, b.outcome,
            "task-paced outcome must not depend on pool topology"
        );
        // Detection requests ride the Defense band now; nothing is
        // submitted at Control class (no sweeps, no per-scan checks).
        let defense = a.outcome.class(Priority::Defense);
        assert!(defense.submitted > 0, "detect submits: {defense:?}");
        assert_eq!(a.outcome.class(Priority::Control).submitted, 0);
        // t_detect runs every third 100 ms scan, so per-plant detect
        // submissions are bounded by ~steps/3 (suspicion re-checks are
        // Defense-class too, hence <=, plus the warmup window).
        assert!(
            defense.submitted <= cfg.plants as u64 * (cfg.steps / 3 + 1) * 2,
            "detection must be task-paced: {defense:?}"
        );
        // The slower detection cadence still catches the campaigns.
        assert!(
            a.outcome.families.iter().any(|f| f.detected > 0),
            "attacks must still be detected: {:?}",
            a.outcome.families
        );
    }

    #[test]
    fn benign_fleet_has_no_false_positives() {
        let cfg = FleetConfig {
            plants: 4,
            steps: 800,
            seed: 3,
            mix: AttackMix::benign(),
            ..FleetConfig::default()
        };
        let r = run_fleet(&cfg, FleetTarget::pools(1, 2, 8));
        assert_eq!(r.outcome.false_positives, 0);
        assert_eq!(r.outcome.clamps, 0);
        assert_eq!(r.outcome.escalations, 0);
        assert!(r.outcome.families.is_empty());
        assert_eq!(r.outcome.unresolved(), 0);
    }
}
