//! Declarative attack-scenario corpus: the taxonomy families from the
//! PLC-security literature (*SoK: Security of Programmable Logic
//! Controllers*; the ICS cybersecurity surveys) compiled onto the
//! seven `msf::attacks` primitives, with deterministic per-plant
//! parameter draws so a fleet run replays exactly from its seed.
//!
//! A [`Scenario`] is a *campaign*: one or more timed [`Attack`]
//! windows generated from a family template plus a seeded RNG. The
//! same `(family, seed, horizon)` triple always generates the same
//! scenario — determinism is the contract the replay-identity tests
//! and the fleet bench rely on.

use crate::msf::attacks::{Attack, AttackFamily};
use crate::util::rng::SplitMix64;

/// Earliest step any scenario may begin: the detector's sliding
/// window (200 samples) plus settling margin, so every plant has a
/// warm window before its campaign starts.
pub const EARLIEST_ATTACK_STEP: u64 = crate::defense::WINDOW as u64 + 60;

/// Minimum campaign duration in scan steps (40 s at the 10 Hz scan
/// rate) — short enough to fit small test horizons, long enough for
/// the windowed detector to react.
pub const MIN_SCENARIO_STEPS: u64 = 400;

/// Taxonomy family of one plant's campaign. Families are *shapes*;
/// each compiles onto the low-level `msf::attacks` primitives with
/// seeded magnitudes and phase layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScenarioFamily {
    /// False data injection on a sensor channel (Tb0 bias or Wd
    /// scaling): the controller is fed lies and drives the real
    /// plant off its operating point.
    SensorSpoof,
    /// Direct actuator manipulation — steam-valve bias, recycle-flow
    /// reduction, or a tampered production setpoint.
    ActuatorManipulation,
    /// Slowly escalating recycle-flow reduction in eight magnitude
    /// stairs — each stair small, the sum large.
    StealthyRamp,
    /// Stale-operating-point replay: an actuator campaign masked by a
    /// sensor splice that replays the benign Wd level. The splice
    /// discontinuity (the lagged Wd sensor cannot be re-scaled
    /// seamlessly) is the classic detection opportunity.
    Replay,
    /// Multi-stage campaign: sub-threshold sensor recon, then an
    /// actuator foothold, then a combined strike.
    MultiStage,
}

impl ScenarioFamily {
    /// Every family, in a fixed order (report/striping order).
    pub const ALL: [ScenarioFamily; 5] = [
        ScenarioFamily::SensorSpoof,
        ScenarioFamily::ActuatorManipulation,
        ScenarioFamily::StealthyRamp,
        ScenarioFamily::Replay,
        ScenarioFamily::MultiStage,
    ];

    /// Canonical name (stable: used in reports, JSON, and CLI).
    pub fn name(self) -> &'static str {
        match self {
            ScenarioFamily::SensorSpoof => "sensor_spoof",
            ScenarioFamily::ActuatorManipulation => "actuator_manipulation",
            ScenarioFamily::StealthyRamp => "stealthy_ramp",
            ScenarioFamily::Replay => "replay",
            ScenarioFamily::MultiStage => "multi_stage",
        }
    }

    /// Parse a canonical name or CLI alias (`spoof`, `actuator`,
    /// `ramp`, `multistage`).
    pub fn from_name(name: &str) -> Option<ScenarioFamily> {
        match name {
            "sensor_spoof" | "spoof" => Some(ScenarioFamily::SensorSpoof),
            "actuator_manipulation" | "actuator" => {
                Some(ScenarioFamily::ActuatorManipulation)
            }
            "stealthy_ramp" | "ramp" => Some(ScenarioFamily::StealthyRamp),
            "replay" => Some(ScenarioFamily::Replay),
            "multi_stage" | "multistage" => Some(ScenarioFamily::MultiStage),
            _ => None,
        }
    }
}

/// One plant's campaign: the family it was generated from, the
/// compiled attack windows, and the overall campaign window
/// (`[start_step, end_step)`) used for recall/time-to-detect
/// accounting. Multi-phase campaigns have gaps inside the window.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Taxonomy family this campaign was generated from.
    pub family: ScenarioFamily,
    /// Compiled attack windows (what `msf::Simulator` executes).
    pub attacks: Vec<Attack>,
    /// First step of the campaign window.
    pub start_step: u64,
    /// One past the last step of the campaign window.
    pub end_step: u64,
}

impl Scenario {
    /// Generate the campaign for `family` over a run of `horizon`
    /// steps. Deterministic in `(family, seed, horizon)`.
    pub fn generate(family: ScenarioFamily, seed: u64, horizon: u64) -> Scenario {
        let mut rng = SplitMix64::new(seed);
        let h = horizon.max(EARLIEST_ATTACK_STEP + MIN_SCENARIO_STEPS + 200);
        let start = EARLIEST_ATTACK_STEP + rng.below(h / 6 + 1);
        let end = start + (h.saturating_sub(start) * 3 / 4).max(MIN_SCENARIO_STEPS);
        let attacks = match family {
            ScenarioFamily::SensorSpoof => {
                if rng.below(2) == 0 {
                    vec![Attack::new(
                        AttackFamily::Tb0Fdi,
                        rng.uniform(1.5, 3.5),
                        start,
                        end,
                    )]
                } else {
                    vec![Attack::new(
                        AttackFamily::WdFdi,
                        rng.uniform(0.08, 0.2),
                        start,
                        end,
                    )]
                }
            }
            ScenarioFamily::ActuatorManipulation => {
                let a = match rng.below(4) {
                    0 => Attack::new(
                        AttackFamily::SteamBias,
                        rng.uniform(0.25, 0.45),
                        start,
                        end,
                    ),
                    1 => Attack::new(
                        AttackFamily::RecycleReduction,
                        rng.uniform(0.15, 0.3),
                        start,
                        end,
                    ),
                    2 => Attack::new(
                        AttackFamily::SetpointTamper,
                        rng.uniform(0.8, 1.6),
                        start,
                        end,
                    ),
                    _ => Attack::new(
                        AttackFamily::Combined,
                        rng.uniform(0.35, 0.55),
                        start,
                        end,
                    ),
                };
                vec![a]
            }
            ScenarioFamily::StealthyRamp => {
                let m_max = rng.uniform(0.2, 0.35);
                let segments: u64 = 8;
                let span = (end - start) / segments;
                (0..segments)
                    .map(|i| {
                        let s0 = start + i * span;
                        let s1 = if i == segments - 1 {
                            end
                        } else {
                            start + (i + 1) * span
                        };
                        Attack::new(
                            AttackFamily::RecycleReduction,
                            m_max * (i + 1) as f64 / segments as f64,
                            s0,
                            s1,
                        )
                    })
                    .collect()
            }
            ScenarioFamily::Replay => {
                let cut = rng.uniform(0.2, 0.35);
                // Sensor splice replaying the benign Wd level: scale
                // the reading up so the steady-state spoofed value
                // matches the pre-attack operating point. `quality`
                // models how well the replayed segment is aligned.
                let quality = rng.uniform(0.85, 1.0);
                let wd_mask = 1.0 - quality / (1.0 - cut);
                vec![
                    Attack::new(AttackFamily::RecycleReduction, cut, start, end),
                    Attack::new(AttackFamily::WdFdi, wd_mask, start, end),
                ]
            }
            ScenarioFamily::MultiStage => {
                let dur = end - start;
                let p1_end = start + dur / 5;
                let p2_start = p1_end + dur / 10;
                let p2_end = p2_start + dur / 4;
                let p3_start = p2_end + dur / 10;
                vec![
                    // Phase 1: sub-threshold Wd-sensor recon probe
                    // (below the detector's deviation band).
                    Attack::new(
                        AttackFamily::WdFdi,
                        rng.uniform(0.0008, 0.0018),
                        start,
                        p1_end,
                    ),
                    // Phase 2: actuator foothold.
                    Attack::new(
                        AttackFamily::SteamBias,
                        rng.uniform(0.2, 0.35),
                        p2_start,
                        p2_end,
                    ),
                    // Phase 3: combined strike to the end.
                    Attack::new(
                        AttackFamily::Combined,
                        rng.uniform(0.4, 0.6),
                        p3_start,
                        end,
                    ),
                ]
            }
        };
        Scenario {
            family,
            attacks,
            start_step: start,
            end_step: end,
        }
    }

    /// Whether any attack window covers `step` (multi-phase campaigns
    /// have inactive gaps inside `[start_step, end_step)`).
    pub fn active(&self, step: u64) -> bool {
        self.attacks.iter().any(|a| a.active(step))
    }
}

/// Weighted mix of scenario families across a fleet, plus a benign
/// share. Plants are assigned families by deterministic proportional
/// striping (no RNG), so the same mix over the same fleet size always
/// yields the same per-plant assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct AttackMix {
    entries: Vec<(ScenarioFamily, f64)>,
    benign: f64,
}

impl AttackMix {
    /// Every family weighted 1.0, plus one benign share.
    pub fn uniform() -> AttackMix {
        AttackMix {
            entries: ScenarioFamily::ALL.iter().map(|f| (*f, 1.0)).collect(),
            benign: 1.0,
        }
    }

    /// All plants benign (control-run mix).
    pub fn benign() -> AttackMix {
        AttackMix {
            entries: Vec::new(),
            benign: 1.0,
        }
    }

    /// Parse a mix spec: comma-separated `family[=weight]` terms plus
    /// an optional `benign[=weight]` term; a bare name means weight
    /// 1. `"uniform"` (or empty) is [`AttackMix::uniform`]. Example:
    /// `"spoof=2,ramp,benign=1"`.
    pub fn parse(spec: &str) -> Result<AttackMix, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "uniform" {
            return Ok(AttackMix::uniform());
        }
        let mut entries = Vec::new();
        let mut benign = 0.0;
        for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (name, w) = match part.split_once('=') {
                Some((n, w)) => {
                    let w: f64 = w
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad weight in {part:?}"))?;
                    (n.trim(), w)
                }
                None => (part.trim(), 1.0),
            };
            if w < 0.0 || !w.is_finite() {
                return Err(format!("weight for {name:?} must be finite and >= 0"));
            }
            if name.eq_ignore_ascii_case("benign") {
                benign += w;
                continue;
            }
            let f = ScenarioFamily::from_name(name)
                .ok_or_else(|| format!("unknown scenario family {name:?}"))?;
            entries.push((f, w));
        }
        if entries.iter().map(|(_, w)| *w).sum::<f64>() + benign <= 0.0 {
            return Err("attack mix has zero total weight".to_string());
        }
        Ok(AttackMix { entries, benign })
    }

    /// Total weight (families + benign share).
    pub fn total_weight(&self) -> f64 {
        self.entries.iter().map(|(_, w)| *w).sum::<f64>() + self.benign
    }

    /// Deterministic proportional assignment: plant `i` of `total`
    /// maps to the family whose cumulative-weight bucket contains the
    /// stripe coordinate `(i + 0.5) / total`. Returns `None` for the
    /// benign tail.
    pub fn assign(&self, plant: usize, total: usize) -> Option<ScenarioFamily> {
        let w_total = self.total_weight();
        if w_total <= 0.0 || total == 0 {
            return None;
        }
        let x = (plant as f64 + 0.5) / total as f64 * w_total;
        let mut acc = 0.0;
        for (f, w) in &self.entries {
            acc += *w;
            if x < acc {
                return Some(*f);
            }
        }
        None
    }
}

/// Per-plant seed derivation: statistically independent streams for
/// each plant of a fleet, deterministic in `(fleet_seed, plant)`.
pub fn plant_seed(fleet_seed: u64, plant: usize) -> u64 {
    let mixed =
        fleet_seed ^ (plant as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    SplitMix64::new(mixed).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_names_round_trip_with_aliases() {
        for f in ScenarioFamily::ALL {
            assert_eq!(ScenarioFamily::from_name(f.name()), Some(f));
        }
        assert_eq!(
            ScenarioFamily::from_name("spoof"),
            Some(ScenarioFamily::SensorSpoof)
        );
        assert_eq!(
            ScenarioFamily::from_name("multistage"),
            Some(ScenarioFamily::MultiStage)
        );
        assert_eq!(ScenarioFamily::from_name("zeroday"), None);
    }

    #[test]
    fn generate_is_deterministic_and_well_formed() {
        for f in ScenarioFamily::ALL {
            let a = Scenario::generate(f, 1234, 3000);
            let b = Scenario::generate(f, 1234, 3000);
            assert_eq!(a, b, "{f:?} must replay from its seed");
            let c = Scenario::generate(f, 1235, 3000);
            assert_ne!(a, c, "{f:?} should vary with the seed");
            assert!(a.start_step >= EARLIEST_ATTACK_STEP);
            assert!(a.end_step >= a.start_step + MIN_SCENARIO_STEPS);
            assert!(!a.attacks.is_empty());
            for atk in &a.attacks {
                assert!(atk.start_step >= a.start_step);
                assert!(atk.end_step <= a.end_step);
                assert!(atk.magnitude.is_finite());
            }
            assert!(a.active(a.start_step), "{f:?} starts active");
        }
    }

    #[test]
    fn stealthy_ramp_magnitudes_ascend() {
        let s = Scenario::generate(ScenarioFamily::StealthyRamp, 7, 4000);
        assert_eq!(s.attacks.len(), 8);
        for w in s.attacks.windows(2) {
            assert!(w[1].magnitude > w[0].magnitude);
            assert_eq!(w[0].end_step, w[1].start_step, "segments abut");
        }
        assert_eq!(s.attacks.last().unwrap().end_step, s.end_step);
    }

    #[test]
    fn mix_parse_and_proportional_striping() {
        let mix = AttackMix::parse("spoof=2,ramp=1,benign=1").unwrap();
        let total = 400;
        let mut spoof = 0;
        let mut ramp = 0;
        let mut benign = 0;
        for i in 0..total {
            match mix.assign(i, total) {
                Some(ScenarioFamily::SensorSpoof) => spoof += 1,
                Some(ScenarioFamily::StealthyRamp) => ramp += 1,
                None => benign += 1,
                other => panic!("unexpected assignment {other:?}"),
            }
        }
        assert_eq!(spoof, 200);
        assert_eq!(ramp, 100);
        assert_eq!(benign, 100);
        assert!(AttackMix::parse("nonsense=1").is_err());
        assert!(AttackMix::parse("spoof=-1").is_err());
        assert!(AttackMix::parse("benign=0").is_err());
        assert_eq!(AttackMix::parse("uniform").unwrap(), AttackMix::uniform());
        let all_benign = AttackMix::parse("benign=3").unwrap();
        assert_eq!(all_benign.assign(0, 10), None);
    }

    #[test]
    fn plant_seeds_are_distinct() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..1000 {
            assert!(seen.insert(plant_seed(42, i)), "plant {i} seed collides");
        }
        assert_ne!(plant_seed(1, 0), plant_seed(2, 0));
        assert_eq!(plant_seed(1, 5), plant_seed(1, 5));
    }
}
