//! Multipart inference (paper §6.3): when the model does not fit the
//! scan cycle, split the computation across cycles. The scheduler
//! drives any partial-capable [`Session`]'s `begin`/`step`/`finish`
//! sub-API, charging each row its modeled on-PLC cost and stopping
//! when the cycle's ML budget is spent. Correctness invariant
//! (property-tested): any schedule yields the single-shot output
//! exactly.
//!
//! Since the Engine/Session split the coordinator holds a [`Session`],
//! not a whole backend — any number of multipart inferences can be in
//! flight over one shared backend, one per session.

use std::sync::Arc;

use crate::api::{Backend, EngineSession, InferenceError, Session};
use crate::engine::{Layer, Model};
use crate::plc::HwProfile;

/// ST-equivalent modeled cost per MAC on a profile, anchored to the
/// calibrated dense dot product (BBB: 455.2 µs / 4096 MACs ≈ 0.111 µs).
pub fn us_per_mac(profile: &HwProfile) -> f64 {
    // dense 64x64 anchor op mix per MAC (see timing_calibration.rs):
    // ~7.25 loads, 2.1 stores, 2.02 fp, 1.06 int, 1.05 branches.
    7.25 * profile.costs.load
        + 2.1 * profile.costs.store
        + 2.02 * profile.costs.fp_add
        + 1.06 * profile.costs.int_op
        + 1.05 * profile.costs.branch
}

/// Modeled cost (µs) of one output row costing `macs` MACs.
pub fn row_macs_cost_us(macs: f64, profile: &HwProfile) -> f64 {
    // per-row call overhead (method dispatch + epilogue)
    macs * us_per_mac(profile) + profile.costs.call
}

/// Modeled cost (µs) of one output row of an engine layer.
pub fn row_cost_us(layer: &Layer, profile: &HwProfile) -> f64 {
    let rows = layer.chunk_rows().max(1) as f64;
    row_macs_cost_us(layer.macs() as f64 / rows, profile)
}

/// Statistics from a multipart run.
#[derive(Debug, Clone, Default)]
pub struct MultipartStats {
    /// Scan cycles consumed by the last inference.
    pub cycles: u64,
    /// Modeled ML CPU time per cycle (µs), max over cycles.
    pub max_cycle_us: f64,
    /// Total modeled ML time (µs).
    pub total_us: f64,
}

/// A resumable inference scheduled over any capable session (engine,
/// ST bytecode VM, ...) — the §6.3 coordinator. It owns no concrete
/// model; all substrate access goes through the session's
/// [`crate::api::PartialSession`] sub-API.
pub struct MultipartSession {
    session: Box<dyn Session>,
    pub profile: HwProfile,
    out_buf: Vec<f32>,
    pub stats: MultipartStats,
}

impl MultipartSession {
    /// Engine-backed session (the common §6.3 configuration).
    pub fn new(model: Model, profile: HwProfile) -> MultipartSession {
        MultipartSession::with_session(
            Box::new(EngineSession::new(Arc::new(model))),
            profile,
        )
        .expect("engine sessions support partial inference")
    }

    /// Coordinator over a session minted from `backend` (checks the
    /// partial capability up front).
    pub fn over_backend(
        backend: &dyn Backend,
        profile: HwProfile,
    ) -> Result<MultipartSession, InferenceError> {
        MultipartSession::with_session(backend.session()?, profile)
    }

    /// Coordinator over an arbitrary session; typed error when the
    /// session's substrate cannot resume.
    pub fn with_session(
        mut session: Box<dyn Session>,
        profile: HwProfile,
    ) -> Result<MultipartSession, InferenceError> {
        if session.partial().is_none() {
            return Err(InferenceError::Unsupported {
                backend: session.name().to_string(),
                op: "partial (multipart) inference",
            });
        }
        let out_dim = session.spec().out_dim;
        Ok(MultipartSession {
            session,
            profile,
            out_buf: vec![0.0; out_dim],
            stats: MultipartStats::default(),
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.session.name()
    }

    /// Begin a new inference with input `x` (resets the session).
    pub fn begin(&mut self, x: &[f32]) -> Result<(), InferenceError> {
        self.session.partial().unwrap().begin(x)?;
        self.stats = MultipartStats::default();
        Ok(())
    }

    pub fn in_flight(&mut self) -> bool {
        self.session.partial().unwrap().in_flight()
    }

    /// Run one scan cycle's worth of work under `budget_us` of modeled
    /// CPU time. Returns the model output when the inference completes
    /// this cycle. Always makes progress (at least one row per cycle),
    /// matching the paper's behaviour where a single row is the minimum
    /// schedulable unit.
    pub fn step_cycle(
        &mut self,
        budget_us: f64,
    ) -> Result<Option<Vec<f32>>, InferenceError> {
        let mut spent = 0.0f64;
        let mut rows_done = 0usize;
        let mut step_err = None;
        let profile = self.profile.clone();
        let partial = self.session.partial().unwrap();
        while !partial.finished() {
            let cost = row_macs_cost_us(partial.next_row_macs(), &profile);
            if rows_done > 0 && spent + cost > budget_us {
                break;
            }
            match partial.step(1) {
                Ok(0) => break,
                Ok(consumed) => {
                    spent += cost;
                    rows_done += consumed;
                }
                Err(e) => {
                    step_err = Some(e);
                    break;
                }
            }
        }
        let finished = partial.finished() && step_err.is_none();
        let finish_result = if finished {
            Some(partial.finish(&mut self.out_buf))
        } else {
            None
        };
        // Charge the cycle before propagating any error: rows already
        // executed consumed real budget even if a later step faulted,
        // and a retried cycle does not re-step them.
        self.stats.cycles += 1;
        self.stats.total_us += spent;
        if spent > self.stats.max_cycle_us {
            self.stats.max_cycle_us = spent;
        }
        if let Some(e) = step_err {
            return Err(e);
        }
        match finish_result {
            Some(Ok(())) => Ok(Some(self.out_buf.clone())),
            Some(Err(e)) => Err(e),
            None => Ok(None),
        }
    }

    /// Run a whole inference under a fixed per-cycle budget; returns
    /// (output, cycles used), or `None` when `max_cycles` was not
    /// enough. Output latency = cycles × scan period.
    pub fn run_to_completion(
        &mut self,
        x: &[f32],
        budget_us: f64,
        max_cycles: u64,
    ) -> Result<Option<(Vec<f32>, u64)>, InferenceError> {
        self.begin(x)?;
        for cycle in 1..=max_cycles {
            if let Some(out) = self.step_cycle(budget_us)? {
                return Ok(Some((out, cycle)));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, RowPlan, StBackend};
    use crate::engine::Act;
    use crate::util::fixtures;
    use crate::util::prop::{prop_assert, prop_check};

    fn model() -> Model {
        Model::new(vec![
            Layer::Input { dim: 8 },
            Layer::dense(
                (0..8 * 16).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect(),
                vec![0.05; 16],
                8,
                Act::Relu,
            ),
            Layer::dense(
                (0..16 * 4).map(|i| 0.2 - (i % 5) as f32 * 0.06).collect(),
                vec![0.0; 4],
                16,
                Act::None,
            ),
        ])
    }

    #[test]
    fn multipart_output_equals_single_shot() {
        prop_check(40, |g| {
            let mut m = model();
            let x: Vec<f32> = (0..8).map(|_| g.f32_in(-1.5, 1.5)).collect();
            let want = m.infer(&x);
            let mut sess =
                MultipartSession::new(model(), HwProfile::beaglebone());
            let budget = g.f64_in(0.5, 50.0);
            let got = sess
                .run_to_completion(&x, budget, 10_000)
                .expect("no backend error")
                .expect("must finish");
            prop_assert(
                got.0 == want,
                format!("multipart {:?} != single {:?}", got.0, want),
            )?;
            prop_assert(got.1 >= 1, "at least one cycle")
        });
    }

    #[test]
    fn smaller_budget_takes_more_cycles() {
        let x = [0.3f32; 8];
        let mut s1 = MultipartSession::new(model(), HwProfile::beaglebone());
        let (_, fast) = s1.run_to_completion(&x, 1e9, 10).unwrap().unwrap();
        let mut s2 = MultipartSession::new(model(), HwProfile::beaglebone());
        let (_, slow) =
            s2.run_to_completion(&x, 1.0, 10_000).unwrap().unwrap();
        assert_eq!(fast, 1, "unlimited budget completes in one cycle");
        assert!(slow > fast, "tight budget spreads across cycles ({slow})");
    }

    #[test]
    fn budget_respected_beyond_first_row() {
        let mut sess = MultipartSession::new(model(), HwProfile::beaglebone());
        sess.begin(&[0.1; 8]).unwrap();
        let budget =
            2.0 * row_cost_us(&model().layers()[1], &HwProfile::beaglebone());
        while sess.step_cycle(budget).unwrap().is_none() {}
        // max cycle time may exceed budget by at most one row's cost
        // (minimum progress guarantee).
        let max_row = model()
            .layers()
            .iter()
            .map(|l| row_cost_us(l, &HwProfile::beaglebone()))
            .fold(0.0, f64::max);
        assert!(sess.stats.max_cycle_us <= budget + max_row + 1e-9);
    }

    #[test]
    fn wago_rows_cost_more_than_bbb() {
        let l = model().layers()[1].clone();
        assert!(
            row_cost_us(&l, &HwProfile::wago_pfc100())
                > row_cost_us(&l, &HwProfile::beaglebone())
        );
    }

    /// The shared 8-16-4 fixture as an ST backend (ported ICSML code +
    /// weights on disk, executing on the bytecode VM, with the real
    /// layer plan) and as an engine model.
    fn st_backend_and_reference(tag: &str) -> (StBackend, Model) {
        let (st, reference) = fixtures::ported_mlp_8_16_4(77, tag);
        let st = st.with_plan(RowPlan::from_layer_sizes(&fixtures::MLP_SIZES));
        (st, reference)
    }

    #[test]
    fn multipart_schedules_over_st_backend() {
        // The acceptance property of the backend-agnostic redesign: a
        // full §6.3 inference through a *non-engine* backend (the ST
        // PLC on the bytecode VM), schedule-invariant vs the
        // single-shot engine result for any per-cycle budget.
        let (st, mut reference) = st_backend_and_reference("invariance");
        assert!(st.spec().supports_partial);
        let mut sess =
            MultipartSession::over_backend(&st, HwProfile::beaglebone())
                .unwrap();
        assert_eq!(sess.backend_name(), "st");
        prop_check(10, |g| {
            let x: Vec<f32> = (0..8).map(|_| g.f32_in(-1.0, 1.0)).collect();
            let want = reference.infer(&x);
            let budget = g.f64_in(0.5, 30.0);
            let (got, cycles) = sess
                .run_to_completion(&x, budget, 10_000)
                .expect("no backend error")
                .expect("must finish");
            prop_assert(cycles >= 1, "at least one cycle")?;
            let dev = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            prop_assert(
                dev < 1e-5,
                format!("st multipart {got:?} != engine single {want:?}"),
            )
        });
    }

    #[test]
    fn st_tight_budget_spreads_across_cycles() {
        let (st, _) = st_backend_and_reference("budget");
        let mut sess =
            MultipartSession::over_backend(&st, HwProfile::beaglebone())
                .unwrap();
        let x = [0.25f32; 8];
        let (_, one) = sess.run_to_completion(&x, 1e9, 10).unwrap().unwrap();
        assert_eq!(one, 1);
        let (_, many) =
            sess.run_to_completion(&x, 1.0, 10_000).unwrap().unwrap();
        assert!(many > 1, "tight budget must take multiple cycles ({many})");
    }
}
