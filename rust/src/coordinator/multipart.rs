//! Multipart inference (paper §6.3): when the model does not fit the
//! scan cycle, split the computation across cycles. The scheduler
//! walks the engine model's (layer, row) chunks, charging each row its
//! modeled on-PLC cost and stopping when the cycle's ML budget is
//! spent. Correctness invariant (property-tested): any schedule yields
//! the single-shot output exactly.

use crate::engine::model::{Cursor, Model};
use crate::engine::Layer;
use crate::plc::HwProfile;

/// ST-equivalent modeled cost per MAC on a profile, anchored to the
/// calibrated dense dot product (BBB: 455.2 µs / 4096 MACs ≈ 0.111 µs).
pub fn us_per_mac(profile: &HwProfile) -> f64 {
    // dense 64x64 anchor op mix per MAC (see timing_calibration.rs):
    // ~7.25 loads, 2.1 stores, 2.02 fp, 1.06 int, 1.05 branches.
    7.25 * profile.costs.load
        + 2.1 * profile.costs.store
        + 2.02 * profile.costs.fp_add
        + 1.06 * profile.costs.int_op
        + 1.05 * profile.costs.branch
}

/// Modeled cost (µs) of one output row of a layer.
pub fn row_cost_us(layer: &Layer, profile: &HwProfile) -> f64 {
    let rows = layer.chunk_rows().max(1) as f64;
    let per_row_macs = layer.macs() as f64 / rows;
    // per-row call overhead (method dispatch + epilogue)
    per_row_macs * us_per_mac(profile) + profile.costs.call
}

/// Statistics from a multipart run.
#[derive(Debug, Clone, Default)]
pub struct MultipartStats {
    /// Scan cycles consumed by the last inference.
    pub cycles: u64,
    /// Modeled ML CPU time per cycle (µs), max over cycles.
    pub max_cycle_us: f64,
    /// Total modeled ML time (µs).
    pub total_us: f64,
}

/// A resumable inference session over an engine model.
pub struct MultipartSession {
    pub model: Model,
    pub profile: HwProfile,
    cursor: Cursor,
    input: Vec<f32>,
    pub stats: MultipartStats,
}

impl MultipartSession {
    pub fn new(model: Model, profile: HwProfile) -> MultipartSession {
        let in_dim = model.in_dim();
        MultipartSession {
            model,
            profile,
            cursor: Cursor::default(),
            input: vec![0.0; in_dim],
            stats: MultipartStats::default(),
        }
    }

    /// Begin a new inference with input `x` (resets the cursor).
    pub fn begin(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.input.len());
        self.input.copy_from_slice(x);
        self.cursor = Cursor::default();
        self.stats = MultipartStats::default();
    }

    pub fn in_flight(&self) -> bool {
        self.cursor != Cursor::default()
    }

    /// Run one scan cycle's worth of work under `budget_us` of modeled
    /// CPU time. Returns the model output when the inference completes
    /// this cycle. Always makes progress (at least one row per cycle),
    /// matching the paper's behaviour where a single row is the minimum
    /// schedulable unit.
    pub fn step_cycle(&mut self, budget_us: f64) -> Option<Vec<f32>> {
        let mut spent = 0.0f64;
        let mut rows_done = 0usize;
        let mut result = None;
        loop {
            if self.cursor.layer >= self.model.layers().len() {
                break;
            }
            let cost =
                row_cost_us(&self.model.layers()[self.cursor.layer], &self.profile);
            if rows_done > 0 && spent + cost > budget_us {
                break;
            }
            let (c, out) =
                self.model.infer_partial(&self.input, self.cursor, 1);
            self.cursor = c;
            spent += cost;
            rows_done += 1;
            if let Some(out) = out {
                result = Some(out);
                break;
            }
        }
        self.stats.cycles += 1;
        self.stats.total_us += spent;
        if spent > self.stats.max_cycle_us {
            self.stats.max_cycle_us = spent;
        }
        if result.is_some() {
            self.cursor = Cursor::default();
        }
        result
    }

    /// Run a whole inference under a fixed per-cycle budget; returns
    /// (output, cycles used). Output latency = cycles × scan period.
    pub fn run_to_completion(
        &mut self,
        x: &[f32],
        budget_us: f64,
        max_cycles: u64,
    ) -> Option<(Vec<f32>, u64)> {
        self.begin(x);
        for cycle in 1..=max_cycles {
            if let Some(out) = self.step_cycle(budget_us) {
                return Some((out, cycle));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Act, Layer};
    use crate::util::prop::{prop_assert, prop_check};

    fn model() -> Model {
        Model::new(vec![
            Layer::Input { dim: 8 },
            Layer::dense(
                (0..8 * 16).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect(),
                vec![0.05; 16],
                8,
                Act::Relu,
            ),
            Layer::dense(
                (0..16 * 4).map(|i| 0.2 - (i % 5) as f32 * 0.06).collect(),
                vec![0.0; 4],
                16,
                Act::None,
            ),
        ])
    }

    #[test]
    fn multipart_output_equals_single_shot() {
        prop_check(40, |g| {
            let mut m = model();
            let x: Vec<f32> = (0..8).map(|_| g.f32_in(-1.5, 1.5)).collect();
            let want = m.infer(&x);
            let mut sess =
                MultipartSession::new(model(), HwProfile::beaglebone());
            let budget = g.f64_in(0.5, 50.0);
            let got = sess
                .run_to_completion(&x, budget, 10_000)
                .expect("must finish");
            prop_assert(
                got.0 == want,
                format!("multipart {:?} != single {:?}", got.0, want),
            )?;
            prop_assert(got.1 >= 1, "at least one cycle")
        });
    }

    #[test]
    fn smaller_budget_takes_more_cycles() {
        let x = [0.3f32; 8];
        let mut s1 = MultipartSession::new(model(), HwProfile::beaglebone());
        let (_, fast) = s1.run_to_completion(&x, 1e9, 10).unwrap();
        let mut s2 = MultipartSession::new(model(), HwProfile::beaglebone());
        let (_, slow) = s2.run_to_completion(&x, 1.0, 10_000).unwrap();
        assert_eq!(fast, 1, "unlimited budget completes in one cycle");
        assert!(slow > fast, "tight budget spreads across cycles ({slow})");
    }

    #[test]
    fn budget_respected_beyond_first_row() {
        let mut sess = MultipartSession::new(model(), HwProfile::beaglebone());
        sess.begin(&[0.1; 8]);
        let budget = 2.0 * row_cost_us(&model().layers()[1], &HwProfile::beaglebone());
        while sess.step_cycle(budget).is_none() {}
        // max cycle time may exceed budget by at most one row's cost
        // (minimum progress guarantee).
        let max_row = model()
            .layers()
            .iter()
            .map(|l| row_cost_us(l, &HwProfile::beaglebone()))
            .fold(0.0, f64::max);
        assert!(sess.stats.max_cycle_us <= budget + max_row + 1e-9);
    }

    #[test]
    fn wago_rows_cost_more_than_bbb() {
        let l = model().layers()[1].clone();
        assert!(
            row_cost_us(&l, &HwProfile::wago_pfc100())
                > row_cost_us(&l, &HwProfile::beaglebone())
        );
    }
}
