//! Coordination layer over the [`crate::api`] inference contract: the
//! policy router (with error fallback + penalties) and the §6.3
//! multipart scheduler (splitting inference across scan cycles under a
//! per-cycle CPU budget, on any [`crate::api::PartialBackend`]).

pub mod multipart;
pub mod router;

pub use multipart::{MultipartSession, MultipartStats};
pub use router::{BackendStats, InferenceRouter, RoutePolicy, ERROR_PENALTY_US};
