//! Coordination layer: inference-backend router and the §6.3 multipart
//! scheduler (splitting inference across scan cycles under a per-cycle
//! CPU budget).

pub mod multipart;
pub mod router;

pub use multipart::{MultipartSession, MultipartStats};
pub use router::{InferenceRouter, RoutePolicy};
