//! Coordination layer over the [`crate::api`] inference contract: the
//! policy router (a shared control plane with per-caller
//! [`router::RouterSession`]s, error fallback + penalties) and the
//! §6.3 multipart scheduler (splitting inference across scan cycles
//! under a per-cycle CPU budget, on any partial-capable
//! [`crate::api::Session`]).

pub mod multipart;
pub mod router;

pub use multipart::{MultipartSession, MultipartStats};
pub use router::{
    BackendStats, InferenceRouter, RoutePolicy, RouterSession,
    ERROR_PENALTY_US,
};
