//! Inference-backend router: registers the available backends (ST
//! interpreter PLC, native engine, XLA/PJRT) and routes requests by
//! policy. On a real deployment the ST path *is* the PLC; the router
//! exists so the serving examples and benchmarks can exercise all
//! paths uniformly and fall back when a backend is unavailable.
//!
//! Resilience: a request only fails when *every* registered backend
//! fails. On a backend error the router records a latency penalty
//! against it (so `FastestObserved` stops re-picking a flaky-but-fast
//! backend) and retries the next-best candidate per policy.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::api::{Backend, InferenceError};

/// Modeled latency charged per error when ranking backends: one full
/// second — a flaky backend has to be *very* fast to stay attractive.
pub const ERROR_PENALTY_US: f64 = 1e6;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Prefer the named backend; fall back to the others (ranked by
    /// observed score) only when it fails.
    Pinned,
    /// Fastest observed mean latency (after a warmup per backend),
    /// errors penalized.
    FastestObserved,
}

/// Per-backend running statistics.
#[derive(Debug, Clone, Default)]
pub struct BackendStats {
    /// Successful requests.
    pub requests: u64,
    /// Total latency of successful requests (µs).
    pub total_us: f64,
    /// All errors, including caller-side shape bugs.
    pub errors: u64,
    /// Backend-fault errors ([`crate::api::InferenceError::is_backend_fault`]);
    /// only these carry penalty.
    pub faults: u64,
    /// Accumulated fault penalty (µs), [`ERROR_PENALTY_US`] per fault.
    pub penalty_us: f64,
}

impl BackendStats {
    /// Mean latency over *successful* requests.
    pub fn mean_us(&self) -> f64 {
        if self.requests == 0 {
            f64::INFINITY
        } else {
            self.total_us / self.requests as f64
        }
    }

    /// Ranking score: mean over successes + faults, with each fault
    /// charged [`ERROR_PENALTY_US`]. Caller-side errors don't count as
    /// attempts, so they neither boost nor demote. No signal → infinite
    /// (the exploration pass handles untried backends separately).
    pub fn score_us(&self) -> f64 {
        let attempts = self.requests + self.faults;
        if attempts == 0 {
            f64::INFINITY
        } else {
            (self.total_us + self.penalty_us) / attempts as f64
        }
    }

    /// Has latency signal (successes or penalized faults). Caller-side
    /// errors don't count — a backend that only ever saw malformed
    /// requests still deserves its exploration pass.
    fn tried(&self) -> bool {
        self.requests + self.faults > 0
    }
}

/// The router.
pub struct InferenceRouter {
    backends: BTreeMap<String, Box<dyn Backend>>,
    stats: BTreeMap<String, BackendStats>,
    pub policy: RoutePolicy,
    pub pinned: Option<String>,
}

impl InferenceRouter {
    pub fn new(policy: RoutePolicy) -> InferenceRouter {
        InferenceRouter {
            backends: BTreeMap::new(),
            stats: BTreeMap::new(),
            policy,
            pinned: None,
        }
    }

    pub fn register(&mut self, name: impl Into<String>, b: Box<dyn Backend>) {
        let name = name.into();
        self.stats.insert(name.clone(), BackendStats::default());
        self.backends.insert(name, b);
    }

    pub fn backend_names(&self) -> Vec<String> {
        self.backends.keys().cloned().collect()
    }

    pub fn stats(&self, name: &str) -> Option<&BackendStats> {
        self.stats.get(name)
    }

    /// Rank every registered backend per policy: the policy's first
    /// choice leads, the rest follow as fallbacks (best score first).
    fn ranked(&self) -> Result<Vec<String>, InferenceError> {
        if self.backends.is_empty() {
            return Err(InferenceError::NoBackends);
        }
        // Untried backends first (exploration, registration-name
        // order), then by score.
        let mut order: Vec<String> = Vec::with_capacity(self.backends.len());
        for (name, s) in &self.stats {
            if self.backends.contains_key(name) && !s.tried() {
                order.push(name.clone());
            }
        }
        let mut tried: Vec<&String> = self
            .stats
            .iter()
            .filter(|(n, s)| self.backends.contains_key(*n) && s.tried())
            .map(|(n, _)| n)
            .collect();
        tried.sort_by(|a, b| {
            self.stats[*a]
                .score_us()
                .partial_cmp(&self.stats[*b].score_us())
                .unwrap()
                .then_with(|| a.cmp(b))
        });
        order.extend(tried.into_iter().cloned());

        if self.policy == RoutePolicy::Pinned {
            // A pinned backend leads; an unset or unregistered pin is
            // a config error we tolerate by serving from the ranked
            // list — a request only fails when every backend fails.
            if let Some(pinned) = self
                .pinned
                .clone()
                .filter(|p| self.backends.contains_key(p))
            {
                order.retain(|n| *n != pinned);
                order.insert(0, pinned);
            }
        }
        Ok(order)
    }

    /// Record `n` served requests under one wall-clock measurement (a
    /// batch counts per row, so per-request means stay comparable
    /// between batch and single traffic).
    fn record_ok(&mut self, name: &str, t: Instant, n: u64) {
        let s = self.stats.get_mut(name).unwrap();
        s.requests += n;
        s.total_us += t.elapsed().as_secs_f64() * 1e6;
    }

    fn record_err(&mut self, name: &str, e: &InferenceError) {
        let s = self.stats.get_mut(name).unwrap();
        s.errors += 1;
        // Only backend faults skew the ranking: a caller-side shape
        // bug fails identically everywhere and says nothing about
        // this backend's health.
        if e.is_backend_fault() {
            s.faults += 1;
            s.penalty_us += ERROR_PENALTY_US;
        }
    }

    /// Route one inference into a caller-provided buffer; returns the
    /// backend that served it. Backends whose `out_dim` does not match
    /// `out.len()` are skipped as failures. (The zero-allocation
    /// contract applies to `Backend::infer_into`; the router's own
    /// ranking bookkeeping is control-plane and may allocate.)
    pub fn infer_into(
        &mut self,
        x: &[f32],
        out: &mut [f32],
    ) -> Result<String, InferenceError> {
        let mut failures = Vec::new();
        for name in self.ranked()? {
            let t = Instant::now();
            let backend = self.backends.get_mut(&name).unwrap();
            match backend.infer_into(x, out) {
                Ok(()) => {
                    self.record_ok(&name, t, 1);
                    return Ok(name);
                }
                Err(e) => {
                    self.record_err(&name, &e);
                    failures.push((name, e.to_string()));
                }
            }
        }
        Err(InferenceError::AllBackendsFailed { failures })
    }

    /// Route one inference request, allocating the output (sized per
    /// serving backend).
    pub fn infer(
        &mut self,
        x: &[f32],
    ) -> Result<(String, Vec<f32>), InferenceError> {
        let mut failures = Vec::new();
        let mut out = Vec::new();
        for name in self.ranked()? {
            let t = Instant::now();
            let backend = self.backends.get_mut(&name).unwrap();
            out.resize(backend.spec().out_dim, 0.0);
            match backend.infer_into(x, &mut out) {
                Ok(()) => {
                    self.record_ok(&name, t, 1);
                    return Ok((name, out));
                }
                Err(e) => {
                    self.record_err(&name, &e);
                    failures.push((name, e.to_string()));
                }
            }
        }
        Err(InferenceError::AllBackendsFailed { failures })
    }

    /// Route a batch (`n` row-major inputs → `n` outputs) through one
    /// backend, falling back per policy like [`InferenceRouter::infer`].
    pub fn infer_batch_into(
        &mut self,
        xs: &[f32],
        out: &mut [f32],
    ) -> Result<(String, usize), InferenceError> {
        let mut failures = Vec::new();
        for name in self.ranked()? {
            let t = Instant::now();
            let backend = self.backends.get_mut(&name).unwrap();
            match backend.infer_batch(xs, out) {
                Ok(n) => {
                    self.record_ok(&name, t, n as u64);
                    return Ok((name, n));
                }
                Err(e) => {
                    self.record_err(&name, &e);
                    failures.push((name, e.to_string()));
                }
            }
        }
        Err(InferenceError::AllBackendsFailed { failures })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{EngineBackend, ModelSpec};
    use crate::engine::{Act, Layer, Model};
    use crate::util::prop::{prop_assert, prop_check};

    fn tiny_model(scale: f32) -> Model {
        Model::new(vec![Layer::dense(
            vec![scale; 4],
            vec![0.0, 0.0],
            2,
            Act::None,
        )])
    }

    struct SlowBackend(EngineBackend, std::time::Duration);
    impl Backend for SlowBackend {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn spec(&self) -> ModelSpec {
            self.0.spec()
        }
        fn infer_into(
            &mut self,
            x: &[f32],
            out: &mut [f32],
        ) -> Result<(), InferenceError> {
            std::thread::sleep(self.1);
            self.0.infer_into(x, out)
        }
    }

    /// A backend that always fails mid-execution, instantly.
    struct FailingBackend;
    impl Backend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing"
        }
        fn spec(&self) -> ModelSpec {
            ModelSpec::dense_f32(2, 2)
        }
        fn infer_into(
            &mut self,
            _x: &[f32],
            _out: &mut [f32],
        ) -> Result<(), InferenceError> {
            Err(InferenceError::ExecutionFailed {
                backend: "failing".into(),
                source: anyhow::anyhow!("synthetic fault"),
            })
        }
    }

    #[test]
    fn pinned_policy_routes_to_pinned() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        r.register("a", Box::new(EngineBackend::new(tiny_model(1.0))));
        r.register("b", Box::new(EngineBackend::new(tiny_model(2.0))));
        r.pinned = Some("b".to_string());
        let (name, out) = r.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "b");
        assert_eq!(out, vec![4.0, 4.0]);
    }

    #[test]
    fn fastest_observed_explores_then_prefers_fast() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register(
            "slow",
            Box::new(SlowBackend(
                EngineBackend::new(tiny_model(1.0)),
                std::time::Duration::from_millis(8),
            )),
        );
        r.register("fast", Box::new(EngineBackend::new(tiny_model(1.0))));
        // Exploration touches both; afterwards all routes go fast.
        for _ in 0..6 {
            r.infer(&[1.0, 1.0]).unwrap();
        }
        let (name, _) = r.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "fast");
        assert!(r.stats("slow").unwrap().requests >= 1);
    }

    #[test]
    fn all_backends_agree_is_verifiable() {
        // Router invariant: identical models on different backends give
        // identical outputs for the same request.
        prop_check(30, |g| {
            let x = [g.f32_in(-2.0, 2.0), g.f32_in(-2.0, 2.0)];
            let mut a = EngineBackend::new(tiny_model(1.5));
            let mut b = EngineBackend::new(tiny_model(1.5));
            prop_assert(
                a.infer(&x).unwrap() == b.infer(&x).unwrap(),
                "backend divergence",
            )
        });
    }

    #[test]
    fn empty_router_errors() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        match r.infer(&[0.0]) {
            Err(InferenceError::NoBackends) => {}
            other => panic!("want NoBackends, got {other:?}"),
        }
    }

    #[test]
    fn errors_fall_back_to_next_backend() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("failing", Box::new(FailingBackend));
        r.register("good", Box::new(EngineBackend::new(tiny_model(1.0))));
        // Every request is served despite the failing backend; by
        // exploration order "failing" is tried (and penalized) first.
        for _ in 0..5 {
            let (name, out) = r.infer(&[1.0, 1.0]).unwrap();
            assert_eq!(name, "good");
            assert_eq!(out, vec![2.0, 2.0]);
        }
        assert!(r.stats("failing").unwrap().errors >= 1);
        assert_eq!(r.stats("good").unwrap().requests, 5);
    }

    #[test]
    fn pinned_unset_still_serves_from_ranked_list() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        r.register("good", Box::new(EngineBackend::new(tiny_model(1.0))));
        // pinned left at None: a config gap, not a request failure.
        let (name, _) = r.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "good");
    }

    #[test]
    fn pinned_falls_back_when_pinned_fails() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        r.register("failing", Box::new(FailingBackend));
        r.register("good", Box::new(EngineBackend::new(tiny_model(1.0))));
        r.pinned = Some("failing".to_string());
        let (name, _) = r.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "good");
        assert_eq!(r.stats("failing").unwrap().errors, 1);
    }

    #[test]
    fn error_penalty_demotes_flaky_fast_backend() {
        // A backend that fails instantly used to keep an untouched
        // (infinite→unset) mean and could be re-picked forever; with
        // the penalty its score is worse than any honest backend.
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("failing", Box::new(FailingBackend));
        r.register("good", Box::new(EngineBackend::new(tiny_model(1.0))));
        for _ in 0..3 {
            r.infer(&[1.0, 1.0]).unwrap();
        }
        let flaky = r.stats("failing").unwrap();
        let good = r.stats("good").unwrap();
        assert!(flaky.score_us() > good.score_us());
        assert!(flaky.score_us() >= ERROR_PENALTY_US);
        // Only the exploration pass touched it; afterwards ranking
        // keeps it behind "good" (but still available as fallback).
        assert_eq!(flaky.errors, 1);
    }

    #[test]
    fn caller_shape_bug_does_not_penalize_backends() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("good", Box::new(EngineBackend::new(tiny_model(1.0))));
        // Wrong input length: a caller bug, not a backend fault.
        assert!(r.infer(&[1.0, 2.0, 3.0]).is_err());
        let s = r.stats("good").unwrap();
        assert_eq!(s.errors, 1);
        assert_eq!(s.faults, 0);
        assert_eq!(s.penalty_us, 0.0, "ShapeMismatch must not add penalty");
        assert_eq!(
            s.score_us(),
            f64::INFINITY,
            "a caller bug is not a latency signal"
        );
        // The backend still serves and ranks normally afterwards.
        let (name, _) = r.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "good");
    }

    #[test]
    fn all_failing_reports_every_attempt() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("f1", Box::new(FailingBackend));
        r.register("f2", Box::new(FailingBackend));
        match r.infer(&[1.0, 1.0]) {
            Err(InferenceError::AllBackendsFailed { failures }) => {
                assert_eq!(failures.len(), 2);
            }
            other => panic!("want AllBackendsFailed, got {other:?}"),
        }
    }

    #[test]
    fn infer_into_routes_without_allocating_output() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("good", Box::new(EngineBackend::new(tiny_model(3.0))));
        let mut out = [0.0f32; 2];
        let name = r.infer_into(&[1.0, 1.0], &mut out).unwrap();
        assert_eq!(name, "good");
        assert_eq!(out, [6.0, 6.0]);
    }
}
