//! Inference-backend router: registers the available backends (ST
//! interpreter PLC, native engine, XLA/PJRT) and routes requests by
//! policy. On a real deployment the ST path *is* the PLC; the router
//! exists so the serving examples and benchmarks can exercise all
//! paths uniformly and fall back when a backend is unavailable.
//!
//! Shape (post Engine/Session split): the router itself is a **shared,
//! `Sync` control plane** — immutable backend handles plus ranking
//! statistics behind a `Mutex`. Serving state is per caller: each
//! caller mints a [`RouterSession`] ([`InferenceRouter::session`])
//! holding lazily-created per-backend [`Session`]s. Many router
//! sessions route concurrently over one router; the stats lock is
//! control-plane only and never held across an inference call.
//!
//! Resilience: a request only fails when *every* registered backend
//! fails. On a backend error the router records a latency penalty
//! against it (so `FastestObserved` stops re-picking a flaky-but-fast
//! backend) and retries the next-best candidate per policy.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::api::{Backend, InferenceError, Session, SharedBackend};
use crate::serve::Deadline;

/// Modeled latency charged per error when ranking backends: one full
/// second — a flaky backend has to be *very* fast to stay attractive.
pub const ERROR_PENALTY_US: f64 = 1e6;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Prefer the named backend; fall back to the others (ranked by
    /// observed score) only when it fails.
    Pinned,
    /// Fastest observed mean latency (after a warmup per backend),
    /// errors penalized.
    FastestObserved,
}

/// Per-backend running statistics.
#[derive(Debug, Clone, Default)]
pub struct BackendStats {
    /// Successful requests.
    pub requests: u64,
    /// Total latency of successful requests (µs).
    pub total_us: f64,
    /// All errors, including caller-side shape bugs.
    pub errors: u64,
    /// Backend-fault errors ([`crate::api::InferenceError::is_backend_fault`]);
    /// only these carry penalty.
    pub faults: u64,
    /// Accumulated fault penalty (µs), [`ERROR_PENALTY_US`] per fault.
    pub penalty_us: f64,
}

impl BackendStats {
    /// Mean latency over *successful* requests.
    pub fn mean_us(&self) -> f64 {
        if self.requests == 0 {
            f64::INFINITY
        } else {
            self.total_us / self.requests as f64
        }
    }

    /// Ranking score: mean over successes + faults, with each fault
    /// charged [`ERROR_PENALTY_US`]. Caller-side errors don't count as
    /// attempts, so they neither boost nor demote. No signal → infinite
    /// (the exploration pass handles untried backends separately).
    pub fn score_us(&self) -> f64 {
        let attempts = self.requests + self.faults;
        if attempts == 0 {
            f64::INFINITY
        } else {
            (self.total_us + self.penalty_us) / attempts as f64
        }
    }

    /// Has latency signal (successes or penalized faults). Caller-side
    /// errors don't count — a backend that only ever saw malformed
    /// requests still deserves its exploration pass.
    fn tried(&self) -> bool {
        self.requests + self.faults > 0
    }
}

/// The shared router: immutable backend handles + locked statistics.
/// Registration happens before sharing (`&mut self`); everything on
/// the serving path is `&self`, so one router serves any number of
/// threads (`tests/concurrency.rs` hammers exactly this).
pub struct InferenceRouter {
    backends: BTreeMap<String, SharedBackend>,
    stats: Mutex<BTreeMap<String, BackendStats>>,
    pub policy: RoutePolicy,
    pub pinned: Option<String>,
}

impl InferenceRouter {
    pub fn new(policy: RoutePolicy) -> InferenceRouter {
        InferenceRouter {
            backends: BTreeMap::new(),
            stats: Mutex::new(BTreeMap::new()),
            policy,
            pinned: None,
        }
    }

    pub fn register(&mut self, name: impl Into<String>, b: SharedBackend) {
        let name = name.into();
        self.stats
            .lock()
            .unwrap()
            .insert(name.clone(), BackendStats::default());
        self.backends.insert(name, b);
    }

    pub fn backend_names(&self) -> Vec<String> {
        self.backends.keys().cloned().collect()
    }

    /// Snapshot of one backend's statistics.
    pub fn stats(&self, name: &str) -> Option<BackendStats> {
        self.stats.lock().unwrap().get(name).cloned()
    }

    /// Mint a per-caller routing session. Backend sessions inside it
    /// are created lazily, the first time the ranking reaches each
    /// backend.
    pub fn session(&self) -> RouterSession<'_> {
        RouterSession { router: self, sessions: BTreeMap::new() }
    }

    /// Rank every registered backend per policy: the policy's first
    /// choice leads, the rest follow as fallbacks (best score first).
    fn ranked(&self) -> Result<Vec<String>, InferenceError> {
        if self.backends.is_empty() {
            return Err(InferenceError::NoBackends);
        }
        let stats = self.stats.lock().unwrap();
        // Untried backends first (exploration, registration-name
        // order), then by score.
        let mut order: Vec<String> = Vec::with_capacity(self.backends.len());
        for (name, s) in stats.iter() {
            if self.backends.contains_key(name) && !s.tried() {
                order.push(name.clone());
            }
        }
        let mut tried: Vec<&String> = stats
            .iter()
            .filter(|(n, s)| self.backends.contains_key(*n) && s.tried())
            .map(|(n, _)| n)
            .collect();
        tried.sort_by(|a, b| {
            stats[*a]
                .score_us()
                .partial_cmp(&stats[*b].score_us())
                .unwrap()
                .then_with(|| a.cmp(b))
        });
        order.extend(tried.into_iter().cloned());

        if self.policy == RoutePolicy::Pinned {
            // A pinned backend leads; an unset or unregistered pin is
            // a config error we tolerate by serving from the ranked
            // list — a request only fails when every backend fails.
            if let Some(pinned) = self
                .pinned
                .clone()
                .filter(|p| self.backends.contains_key(p))
            {
                order.retain(|n| *n != pinned);
                order.insert(0, pinned);
            }
        }
        Ok(order)
    }

    /// Record `n` served requests under one wall-clock measurement (a
    /// batch counts per row, so per-request means stay comparable
    /// between batch and single traffic).
    fn record_ok(&self, name: &str, t: Instant, n: u64) {
        let mut stats = self.stats.lock().unwrap();
        let s = stats.get_mut(name).unwrap();
        s.requests += n;
        s.total_us += t.elapsed().as_secs_f64() * 1e6;
    }

    fn record_err(&self, name: &str, e: &InferenceError) {
        let mut stats = self.stats.lock().unwrap();
        let s = stats.get_mut(name).unwrap();
        s.errors += 1;
        // Only backend faults skew the ranking: a caller-side shape
        // bug fails identically everywhere and says nothing about
        // this backend's health.
        if e.is_backend_fault() {
            s.faults += 1;
            s.penalty_us += ERROR_PENALTY_US;
        }
    }
}

/// One caller's routing state: lazily-created sessions over the shared
/// router's backends. Not `Sync` — every concurrent caller takes its
/// own (`router.session()`), which is exactly what makes the router
/// itself lock-free on the data plane.
pub struct RouterSession<'r> {
    router: &'r InferenceRouter,
    sessions: BTreeMap<String, Box<dyn Session>>,
}

impl RouterSession<'_> {
    /// Get-or-create the cached session for `name`.
    fn session_for(
        &mut self,
        name: &str,
    ) -> Result<&mut Box<dyn Session>, InferenceError> {
        if !self.sessions.contains_key(name) {
            let backend = self.router.backends.get(name).ok_or_else(|| {
                InferenceError::BackendUnavailable {
                    backend: name.to_string(),
                    reason: "unregistered".into(),
                }
            })?;
            let session = backend.session()?;
            self.sessions.insert(name.to_string(), session);
        }
        Ok(self.sessions.get_mut(name).unwrap())
    }

    /// After a backend fault the cached session may hold corrupted
    /// mid-request state — drop it so the next attempt starts fresh.
    fn retire_on_fault(&mut self, name: &str, e: &InferenceError) {
        if e.is_backend_fault() {
            self.sessions.remove(name);
        }
    }

    /// Route one inference into a caller-provided buffer; returns the
    /// backend that served it. Backends whose `out_dim` does not match
    /// `out.len()` are skipped as failures. (The zero-allocation
    /// contract applies to `Session::infer_into`; the router's own
    /// ranking bookkeeping is control-plane and may allocate.)
    pub fn infer_into(
        &mut self,
        x: &[f32],
        out: &mut [f32],
    ) -> Result<String, InferenceError> {
        self.route_into(x, out, None)
    }

    /// Deadline pass-through of [`RouterSession::infer_into`]: the
    /// caller's `serve`-layer deadline bounds the *whole* fallback
    /// chain, not each attempt — once it expires, remaining candidate
    /// backends are not tried and the request is shed with
    /// [`InferenceError::DeadlineExceeded`] (a late answer is
    /// worthless to a scan cycle, so burning more backends on it only
    /// steals time from live requests).
    pub fn infer_into_by(
        &mut self,
        x: &[f32],
        out: &mut [f32],
        deadline: Deadline,
    ) -> Result<String, InferenceError> {
        self.route_into(x, out, Some(deadline))
    }

    fn route_into(
        &mut self,
        x: &[f32],
        out: &mut [f32],
        deadline: Option<Deadline>,
    ) -> Result<String, InferenceError> {
        let mut failures = Vec::new();
        for name in self.router.ranked()? {
            if let Some(d) = deadline.filter(|d| d.expired()) {
                return Err(InferenceError::DeadlineExceeded {
                    stage: "router",
                    late_us: d.late_by_us(Instant::now()),
                });
            }
            // Start the clock only once the session exists: lazy
            // session minting (an ST image restore + first-scan weight
            // load can be milliseconds) must not skew the backend's
            // latency ranking.
            let mut t = Instant::now();
            let r = self.session_for(&name).and_then(|s| {
                t = Instant::now();
                s.infer_into(x, out)
            });
            match r {
                Ok(()) => {
                    self.router.record_ok(&name, t, 1);
                    return Ok(name);
                }
                Err(e) => {
                    self.router.record_err(&name, &e);
                    self.retire_on_fault(&name, &e);
                    failures.push((name, e.to_string()));
                }
            }
        }
        Err(InferenceError::AllBackendsFailed { failures })
    }

    /// Route one inference request, allocating the output (sized per
    /// serving backend).
    pub fn infer(
        &mut self,
        x: &[f32],
    ) -> Result<(String, Vec<f32>), InferenceError> {
        let mut failures = Vec::new();
        let mut out = Vec::new();
        for name in self.router.ranked()? {
            let mut t = Instant::now();
            let r = self.session_for(&name).and_then(|s| {
                out.resize(s.spec().out_dim, 0.0);
                t = Instant::now();
                s.infer_into(x, &mut out)
            });
            match r {
                Ok(()) => {
                    self.router.record_ok(&name, t, 1);
                    return Ok((name, out));
                }
                Err(e) => {
                    self.router.record_err(&name, &e);
                    self.retire_on_fault(&name, &e);
                    failures.push((name, e.to_string()));
                }
            }
        }
        Err(InferenceError::AllBackendsFailed { failures })
    }

    /// Route a batch (`n` row-major inputs → `n` outputs) through one
    /// backend, falling back per policy like [`RouterSession::infer`].
    pub fn infer_batch_into(
        &mut self,
        xs: &[f32],
        out: &mut [f32],
    ) -> Result<(String, usize), InferenceError> {
        let mut failures = Vec::new();
        for name in self.router.ranked()? {
            let mut t = Instant::now();
            let r = self.session_for(&name).and_then(|s| {
                t = Instant::now();
                s.infer_batch(xs, out)
            });
            match r {
                Ok(n) => {
                    self.router.record_ok(&name, t, n as u64);
                    return Ok((name, n));
                }
                Err(e) => {
                    self.router.record_err(&name, &e);
                    self.retire_on_fault(&name, &e);
                    failures.push((name, e.to_string()));
                }
            }
        }
        Err(InferenceError::AllBackendsFailed { failures })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::api::{Backend, EngineBackend, ModelSpec};
    use crate::engine::{Act, Layer, Model};
    use crate::util::prop::{prop_assert, prop_check};

    fn tiny_model(scale: f32) -> Model {
        Model::new(vec![Layer::dense(
            vec![scale; 4],
            vec![0.0, 0.0],
            2,
            Act::None,
        )])
    }

    /// A backend whose sessions sleep before serving.
    struct SlowBackend(EngineBackend, std::time::Duration);
    impl Backend for SlowBackend {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn spec(&self) -> ModelSpec {
            self.0.spec()
        }
        fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
            Ok(Box::new(SlowSession(self.0.session()?, self.1)))
        }
    }
    struct SlowSession(Box<dyn Session>, std::time::Duration);
    impl Session for SlowSession {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn spec(&self) -> ModelSpec {
            self.0.spec()
        }
        fn infer_into(
            &mut self,
            x: &[f32],
            out: &mut [f32],
        ) -> Result<(), InferenceError> {
            std::thread::sleep(self.1);
            self.0.infer_into(x, out)
        }
    }

    /// A backend whose sessions always fail mid-execution, instantly.
    struct FailingBackend;
    impl Backend for FailingBackend {
        fn name(&self) -> &'static str {
            "failing"
        }
        fn spec(&self) -> ModelSpec {
            ModelSpec::dense_f32(2, 2)
        }
        fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
            Ok(Box::new(FailingSession))
        }
    }
    struct FailingSession;
    impl Session for FailingSession {
        fn name(&self) -> &'static str {
            "failing"
        }
        fn spec(&self) -> ModelSpec {
            ModelSpec::dense_f32(2, 2)
        }
        fn infer_into(
            &mut self,
            _x: &[f32],
            _out: &mut [f32],
        ) -> Result<(), InferenceError> {
            Err(InferenceError::ExecutionFailed {
                backend: "failing".into(),
                source: anyhow::anyhow!("synthetic fault"),
            })
        }
    }

    #[test]
    fn pinned_policy_routes_to_pinned() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        r.register("a", Arc::new(EngineBackend::new(tiny_model(1.0))));
        r.register("b", Arc::new(EngineBackend::new(tiny_model(2.0))));
        r.pinned = Some("b".to_string());
        let mut sess = r.session();
        let (name, out) = sess.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "b");
        assert_eq!(out, vec![4.0, 4.0]);
    }

    #[test]
    fn fastest_observed_explores_then_prefers_fast() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register(
            "slow",
            Arc::new(SlowBackend(
                EngineBackend::new(tiny_model(1.0)),
                std::time::Duration::from_millis(8),
            )),
        );
        r.register("fast", Arc::new(EngineBackend::new(tiny_model(1.0))));
        let mut sess = r.session();
        // Exploration touches both; afterwards all routes go fast.
        for _ in 0..6 {
            sess.infer(&[1.0, 1.0]).unwrap();
        }
        let (name, _) = sess.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "fast");
        assert!(r.stats("slow").unwrap().requests >= 1);
    }

    #[test]
    fn all_backends_agree_is_verifiable() {
        // Router invariant: identical models on different backends give
        // identical outputs for the same request.
        prop_check(30, |g| {
            let x = [g.f32_in(-2.0, 2.0), g.f32_in(-2.0, 2.0)];
            let a = EngineBackend::new(tiny_model(1.5));
            let b = EngineBackend::new(tiny_model(1.5));
            prop_assert(
                a.session().unwrap().infer(&x).unwrap()
                    == b.session().unwrap().infer(&x).unwrap(),
                "backend divergence",
            )
        });
    }

    #[test]
    fn empty_router_errors() {
        let r = InferenceRouter::new(RoutePolicy::Pinned);
        let mut sess = r.session();
        match sess.infer(&[0.0]) {
            Err(InferenceError::NoBackends) => {}
            other => panic!("want NoBackends, got {other:?}"),
        }
    }

    #[test]
    fn errors_fall_back_to_next_backend() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("failing", Arc::new(FailingBackend));
        r.register("good", Arc::new(EngineBackend::new(tiny_model(1.0))));
        let mut sess = r.session();
        // Every request is served despite the failing backend; by
        // exploration order "failing" is tried (and penalized) first.
        for _ in 0..5 {
            let (name, out) = sess.infer(&[1.0, 1.0]).unwrap();
            assert_eq!(name, "good");
            assert_eq!(out, vec![2.0, 2.0]);
        }
        assert!(r.stats("failing").unwrap().errors >= 1);
        assert_eq!(r.stats("good").unwrap().requests, 5);
    }

    #[test]
    fn pinned_unset_still_serves_from_ranked_list() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        r.register("good", Arc::new(EngineBackend::new(tiny_model(1.0))));
        // pinned left at None: a config gap, not a request failure.
        let (name, _) = r.session().infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "good");
    }

    #[test]
    fn pinned_falls_back_when_pinned_fails() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        r.register("failing", Arc::new(FailingBackend));
        r.register("good", Arc::new(EngineBackend::new(tiny_model(1.0))));
        r.pinned = Some("failing".to_string());
        let (name, _) = r.session().infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "good");
        assert_eq!(r.stats("failing").unwrap().errors, 1);
    }

    #[test]
    fn error_penalty_demotes_flaky_fast_backend() {
        // A backend that fails instantly used to keep an untouched
        // (infinite→unset) mean and could be re-picked forever; with
        // the penalty its score is worse than any honest backend.
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("failing", Arc::new(FailingBackend));
        r.register("good", Arc::new(EngineBackend::new(tiny_model(1.0))));
        let mut sess = r.session();
        for _ in 0..3 {
            sess.infer(&[1.0, 1.0]).unwrap();
        }
        let flaky = r.stats("failing").unwrap();
        let good = r.stats("good").unwrap();
        assert!(flaky.score_us() > good.score_us());
        assert!(flaky.score_us() >= ERROR_PENALTY_US);
        // Only the exploration pass touched it; afterwards ranking
        // keeps it behind "good" (but still available as fallback).
        assert_eq!(flaky.errors, 1);
    }

    #[test]
    fn caller_shape_bug_does_not_penalize_backends() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("good", Arc::new(EngineBackend::new(tiny_model(1.0))));
        let mut sess = r.session();
        // Wrong input length: a caller bug, not a backend fault.
        assert!(sess.infer(&[1.0, 2.0, 3.0]).is_err());
        let s = r.stats("good").unwrap();
        assert_eq!(s.errors, 1);
        assert_eq!(s.faults, 0);
        assert_eq!(s.penalty_us, 0.0, "ShapeMismatch must not add penalty");
        assert_eq!(
            s.score_us(),
            f64::INFINITY,
            "a caller bug is not a latency signal"
        );
        // The backend still serves and ranks normally afterwards.
        let (name, _) = sess.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "good");
    }

    #[test]
    fn all_failing_reports_every_attempt() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("f1", Arc::new(FailingBackend));
        r.register("f2", Arc::new(FailingBackend));
        match r.session().infer(&[1.0, 1.0]) {
            Err(InferenceError::AllBackendsFailed { failures }) => {
                assert_eq!(failures.len(), 2);
            }
            other => panic!("want AllBackendsFailed, got {other:?}"),
        }
    }

    #[test]
    fn expired_deadline_stops_fallback_iteration() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("good", Arc::new(EngineBackend::new(tiny_model(1.0))));
        let mut sess = r.session();
        let mut out = [0.0f32; 2];
        // An already-expired deadline sheds before any backend is
        // tried — no stats recorded, no backend penalized.
        let d = crate::serve::Deadline::within_us(0.0);
        std::thread::sleep(std::time::Duration::from_millis(1));
        match sess.infer_into_by(&[1.0, 1.0], &mut out, d) {
            Err(InferenceError::DeadlineExceeded {
                stage: "router", ..
            }) => {}
            other => panic!("want router shed, got {other:?}"),
        }
        let s = r.stats("good").unwrap();
        assert_eq!(s.requests + s.errors, 0, "no backend was touched");
        // A live deadline routes normally.
        let d = crate::serve::Deadline::within_us(30e6);
        let name = sess.infer_into_by(&[1.0, 1.0], &mut out, d).unwrap();
        assert_eq!(name, "good");
        assert_eq!(out, [2.0, 2.0]);
    }

    #[test]
    fn infer_into_routes_without_allocating_output() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("good", Arc::new(EngineBackend::new(tiny_model(3.0))));
        let mut sess = r.session();
        let mut out = [0.0f32; 2];
        let name = sess.infer_into(&[1.0, 1.0], &mut out).unwrap();
        assert_eq!(name, "good");
        assert_eq!(out, [6.0, 6.0]);
    }

    #[test]
    fn concurrent_router_sessions_share_stats() {
        // Two sessions over one shared router: both serve, stats
        // aggregate under the lock. (The heavy multi-thread version
        // lives in tests/concurrency.rs.)
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register("good", Arc::new(EngineBackend::new(tiny_model(1.0))));
        let mut s1 = r.session();
        let mut s2 = r.session();
        for _ in 0..4 {
            s1.infer(&[1.0, 1.0]).unwrap();
            s2.infer(&[1.0, 1.0]).unwrap();
        }
        assert_eq!(r.stats("good").unwrap().requests, 8);
    }
}
