//! Inference-backend router: registers the available backends (ST
//! interpreter PLC, native engine, XLA/PJRT) and routes requests by
//! policy. On a real deployment the ST path *is* the PLC; the router
//! exists so the serving examples and benchmarks can exercise all
//! paths uniformly and fall back when a backend is unavailable.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::defense::Backend;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Always use the named backend.
    Pinned,
    /// Fastest observed mean latency (after a warmup per backend).
    FastestObserved,
}

/// Per-backend running statistics.
#[derive(Debug, Clone, Default)]
pub struct BackendStats {
    pub requests: u64,
    pub total_us: f64,
    pub errors: u64,
}

impl BackendStats {
    pub fn mean_us(&self) -> f64 {
        if self.requests == 0 {
            f64::INFINITY
        } else {
            self.total_us / self.requests as f64
        }
    }
}

/// The router.
pub struct InferenceRouter {
    backends: BTreeMap<String, Box<dyn Backend>>,
    stats: BTreeMap<String, BackendStats>,
    pub policy: RoutePolicy,
    pub pinned: Option<String>,
}

impl InferenceRouter {
    pub fn new(policy: RoutePolicy) -> InferenceRouter {
        InferenceRouter {
            backends: BTreeMap::new(),
            stats: BTreeMap::new(),
            policy,
            pinned: None,
        }
    }

    pub fn register(&mut self, name: impl Into<String>, b: Box<dyn Backend>) {
        let name = name.into();
        self.stats.insert(name.clone(), BackendStats::default());
        self.backends.insert(name, b);
    }

    pub fn backend_names(&self) -> Vec<String> {
        self.backends.keys().cloned().collect()
    }

    pub fn stats(&self, name: &str) -> Option<&BackendStats> {
        self.stats.get(name)
    }

    /// Pick a backend per policy.
    fn pick(&self) -> Result<String> {
        anyhow::ensure!(!self.backends.is_empty(), "no backends registered");
        match self.policy {
            RoutePolicy::Pinned => self
                .pinned
                .clone()
                .filter(|p| self.backends.contains_key(p))
                .ok_or_else(|| anyhow::anyhow!("pinned backend missing")),
            RoutePolicy::FastestObserved => {
                // Prefer any backend that has not been tried yet
                // (exploration), then the fastest mean.
                if let Some((name, _)) = self
                    .stats
                    .iter()
                    .find(|(_, s)| s.requests == 0)
                {
                    return Ok(name.clone());
                }
                Ok(self
                    .stats
                    .iter()
                    .min_by(|a, b| {
                        a.1.mean_us().partial_cmp(&b.1.mean_us()).unwrap()
                    })
                    .map(|(n, _)| n.clone())
                    .unwrap())
            }
        }
    }

    /// Route one inference request.
    pub fn infer(&mut self, x: &[f32]) -> Result<(String, Vec<f32>)> {
        let name = self.pick()?;
        let t = Instant::now();
        let backend = self.backends.get_mut(&name).unwrap();
        match backend.infer(x) {
            Ok(out) => {
                let s = self.stats.get_mut(&name).unwrap();
                s.requests += 1;
                s.total_us += t.elapsed().as_secs_f64() * 1e6;
                Ok((name, out))
            }
            Err(e) => {
                let s = self.stats.get_mut(&name).unwrap();
                s.errors += 1;
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::defense::EngineBackend;
    use crate::engine::{Act, Layer, Model};
    use crate::util::prop::{prop_assert, prop_check};

    fn tiny_model(scale: f32) -> Model {
        Model::new(vec![Layer::dense(
            vec![scale; 4],
            vec![0.0, 0.0],
            2,
            Act::None,
        )])
    }

    struct SlowBackend(EngineBackend, std::time::Duration);
    impl Backend for SlowBackend {
        fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>> {
            std::thread::sleep(self.1);
            self.0.infer(x)
        }
        fn name(&self) -> &'static str {
            "slow"
        }
    }

    #[test]
    fn pinned_policy_routes_to_pinned() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        r.register("a", Box::new(EngineBackend(tiny_model(1.0))));
        r.register("b", Box::new(EngineBackend(tiny_model(2.0))));
        r.pinned = Some("b".to_string());
        let (name, out) = r.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "b");
        assert_eq!(out, vec![4.0, 4.0]);
    }

    #[test]
    fn fastest_observed_explores_then_prefers_fast() {
        let mut r = InferenceRouter::new(RoutePolicy::FastestObserved);
        r.register(
            "slow",
            Box::new(SlowBackend(
                EngineBackend(tiny_model(1.0)),
                std::time::Duration::from_millis(8),
            )),
        );
        r.register("fast", Box::new(EngineBackend(tiny_model(1.0))));
        // Exploration touches both; afterwards all routes go fast.
        for _ in 0..6 {
            r.infer(&[1.0, 1.0]).unwrap();
        }
        let (name, _) = r.infer(&[1.0, 1.0]).unwrap();
        assert_eq!(name, "fast");
        assert!(r.stats("slow").unwrap().requests >= 1);
    }

    #[test]
    fn all_backends_agree_is_verifiable() {
        // Router invariant: identical models on different backends give
        // identical outputs for the same request.
        prop_check(30, |g| {
            let x = [g.f32_in(-2.0, 2.0), g.f32_in(-2.0, 2.0)];
            let mut a = EngineBackend(tiny_model(1.5));
            let mut b = EngineBackend(tiny_model(1.5));
            prop_assert(
                a.infer(&x).unwrap() == b.infer(&x).unwrap(),
                "backend divergence",
            )
        });
    }

    #[test]
    fn empty_router_errors() {
        let mut r = InferenceRouter::new(RoutePolicy::Pinned);
        assert!(r.infer(&[0.0]).is_err());
    }
}
