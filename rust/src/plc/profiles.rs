//! PLC hardware profiles.
//!
//! Two layers of data:
//!
//! 1. [`PLC_SPECS`] — the paper's **Table 1** (manufacturer, models,
//!    vendor-reported time per instruction, memory), reproduced as a
//!    static database for the `icsml table1` / Fig. 3 reports.
//! 2. [`HwProfile`] — executable timing models for the two benchmark
//!    devices (WAGO PFC100, BeagleBone Black). A profile maps the ST
//!    interpreter's abstract-op [`Meter`] to modeled CPU microseconds;
//!    the per-class costs are calibrated so the paper's anchor numbers
//!    are reproduced (DESIGN.md §9): BBB 64x64 dense dot ≈ 455.2 µs /
//!    activation ≈ 181.8 µs per layer, WAGO ≈ 696.4 / 248.3 µs,
//!    BINARR/ARRBIN fixed costs, etc.

use crate::st::Meter;

/// Modeled cost (µs) per abstract operation class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostVector {
    pub load: f64,
    pub store: f64,
    pub fp_add: f64,
    pub fp_mul: f64,
    pub fp_div: f64,
    pub fp_trans: f64,
    pub int_op: f64,
    pub cmp: f64,
    pub fp_cmp: f64,
    pub branch: f64,
    pub call: f64,
    pub convert: f64,
    pub copy_per_byte: f64,
    pub io_call: f64,
    pub io_per_byte: f64,
}

impl CostVector {
    /// Modeled CPU time for a metered op delta.
    pub fn time_us(&self, m: &Meter) -> f64 {
        m.loads as f64 * self.load
            + m.stores as f64 * self.store
            + m.fp_add as f64 * self.fp_add
            + m.fp_mul as f64 * self.fp_mul
            + m.fp_div as f64 * self.fp_div
            + m.fp_trans as f64 * self.fp_trans
            + m.int_ops as f64 * self.int_op
            + m.cmp as f64 * self.cmp
            + m.fp_cmp as f64 * self.fp_cmp
            + m.branches as f64 * self.branch
            + m.calls as f64 * self.call
            + m.converts as f64 * self.convert
            + m.copy_bytes as f64 * self.copy_per_byte
            + m.io_calls as f64 * self.io_call
            + m.io_bytes as f64 * self.io_per_byte
    }

    /// Uniform scaling (used to derive the WAGO profile from the BBB
    /// one — the devices differ mainly in clock speed, paper §5).
    pub fn scaled(&self, k: f64) -> CostVector {
        CostVector {
            load: self.load * k,
            store: self.store * k,
            fp_add: self.fp_add * k,
            fp_mul: self.fp_mul * k,
            fp_div: self.fp_div * k,
            fp_trans: self.fp_trans * k,
            int_op: self.int_op * k,
            cmp: self.cmp * k,
            fp_cmp: self.fp_cmp * k,
            branch: self.branch * k,
            call: self.call * k,
            convert: self.convert * k,
            copy_per_byte: self.copy_per_byte * k,
            io_call: self.io_call * k,
            io_per_byte: self.io_per_byte * k,
        }
    }
}

/// An executable device timing model.
#[derive(Debug, Clone)]
pub struct HwProfile {
    pub name: &'static str,
    pub cpu: &'static str,
    pub clock_mhz: u32,
    pub ram_bytes: u64,
    pub costs: CostVector,
    /// §5.4: the Codesys profiler's instrumentation roughly doubles
    /// execution time; models the "measured with profiler" mode.
    pub profiler_overhead: f64,
}

impl HwProfile {
    /// Modeled CPU time (µs) for a metered delta.
    pub fn time_us(&self, m: &Meter) -> f64 {
        self.costs.time_us(m)
    }

    /// Time with Codesys-profiler instrumentation enabled (§5.4).
    pub fn time_us_instrumented(&self, m: &Meter) -> f64 {
        self.costs.time_us(m) * self.profiler_overhead
    }

    /// Modeled CPU time as a wall-clock [`std::time::Duration`] — the
    /// budget→deadline bridge used by `serve::Deadline::for_meter`:
    /// the serving tier can commit to answering no later than this
    /// device would have computed the same metered workload.
    pub fn budget(&self, m: &Meter) -> std::time::Duration {
        std::time::Duration::from_secs_f64(self.time_us(m).max(0.0) / 1e6)
    }

    /// BeagleBone Black (1 GHz Cortex-A8, 512 MB) — Codesys soft-PLC.
    /// Per-class costs calibrated against the paper's §5.2 anchors.
    pub fn beaglebone() -> HwProfile {
        HwProfile {
            name: "BeagleBone Black",
            cpu: "ARM Cortex-A8 @ 1 GHz",
            clock_mhz: 1000,
            ram_bytes: 512 << 20,
            costs: BBB_COSTS,
            profiler_overhead: 2.0,
        }
    }

    /// WAGO PFC100 (600 MHz Cortex-A8, 256 MB). The paper's measured
    /// WAGO:BBB ratio is ≈1.5x (696.4/455.2 dot, 13.7/9.33 per-neuron).
    pub fn wago_pfc100() -> HwProfile {
        HwProfile {
            name: "WAGO PFC100",
            cpu: "ARM Cortex-A8 @ 600 MHz",
            clock_mhz: 600,
            ram_bytes: 256 << 20,
            costs: BBB_COSTS.scaled(1.53),
            profiler_overhead: 2.0,
        }
    }

    pub fn by_name(name: &str) -> Option<HwProfile> {
        match name.to_ascii_lowercase().as_str() {
            "bbb" | "beaglebone" => Some(HwProfile::beaglebone()),
            "wago" | "pfc100" => Some(HwProfile::wago_pfc100()),
            _ => None,
        }
    }
}

/// BBB per-class costs (µs). Calibrated in
/// `rust/tests/timing_calibration.rs` against the paper anchors.
// Solved from the §5.2 anchors using the metered op counts of the
// anchor workloads (see rust/tests/timing_calibration.rs):
//   dot(64x64):  29,708 loads, 8,585 stores, 8,256 fp, 4,353 int,
//                4,289 branches, 66 calls  → 455.2 µs
//   act(64):     130 calls dominate           → 181.8 µs
// The fp/int split follows the Cortex-A8's non-pipelined VFP (FP ops
// ~1.5 orders costlier than integer ALU ops) — this is what produces
// the paper's §6.1 quantization speedups (−59.7% SINT): the anchors
// only pin the totals, the microarchitecture pins the ratio.
const BBB_COSTS: CostVector = CostVector {
    load: 0.0015,
    store: 0.0015,
    fp_add: 0.0343,
    fp_mul: 0.0343,
    fp_div: 0.080,
    fp_trans: 0.45,
    int_op: 0.0015,
    cmp: 0.0015,
    fp_cmp: 0.075,
    branch: 0.004,
    call: 1.375,
    convert: 0.010,
    copy_per_byte: 0.003,
    io_call: 400.0,
    io_per_byte: 0.25,
};

/// One Table-1 row (vendor-reported specs).
#[derive(Debug, Clone, Copy)]
pub struct PlcSpec {
    pub manufacturer: &'static str,
    pub models: &'static str,
    pub time_per_instruction_us: &'static str,
    pub memory: &'static str,
}

/// Paper Table 1: PLC hardware specifications by manufacturer.
pub const PLC_SPECS: &[PlcSpec] = &[
    PlcSpec { manufacturer: "ABB", models: "AC500 PM57x/58x/59x/595/50xx/55x/56x", time_per_instruction_us: "FP:0.7/0.5/0.004/0.001/0.6/1200", memory: "128-512KB/512KB-1MB/2-4MB/16MB/256KB-1MB/128-512KB" },
    PlcSpec { manufacturer: "Allen Bradley", models: "Micro 810/20/30/50/70, CL 5380, 5560/70/80", time_per_instruction_us: "2.5/0.3/0.3/0.3/0.3, N/A, N/A", memory: "2/20/8-20/20/40KB, 600KB-10MB, 3-40/2-32/2-32MB" },
    PlcSpec { manufacturer: "Delta Electronics", models: "AS300, AH500", time_per_instruction_us: "1.6, 0.02 LD", memory: "N/A, 128KB-4MB" },
    PlcSpec { manufacturer: "Eaton", models: "XC152, XC300", time_per_instruction_us: "N/A, N/A", memory: "64MB, 512MB" },
    PlcSpec { manufacturer: "Emerson", models: "Micro CPUE05/001, RX3i CPE400/CPL410", time_per_instruction_us: "0.8 Bool/1.8, N/A", memory: "64/34KB, 64MB/2GB" },
    PlcSpec { manufacturer: "Fatek", models: "B1, B1z", time_per_instruction_us: "0.33, 0.33", memory: "31KB, 15KB" },
    PlcSpec { manufacturer: "Festo", models: "CECC-D/LK/S", time_per_instruction_us: "N/A", memory: "16/16/44MB" },
    PlcSpec { manufacturer: "Fuji Electric", models: "SPH5000M/H/D/3000D/300/2000/200", time_per_instruction_us: "FP:0.0253/0.066/0.088/0.08/0.27/5600", memory: "4/4/2/2/2MB/128KB" },
    PlcSpec { manufacturer: "Hitachi", models: "Micro EHV+, HX, EHV+", time_per_instruction_us: "N/A, 0.006 FP, 0.08", memory: "1MB, 16MB, 2MB" },
    PlcSpec { manufacturer: "Honeywell", models: "ControlEdge R170 PLC", time_per_instruction_us: "N/A", memory: "256MB ECC" },
    PlcSpec { manufacturer: "Mitsubishi Electric", models: "MELSEC iQ-R/Q/L", time_per_instruction_us: "0.0098 FP/0.0016 LD/0.065 LD", memory: "4MB/64-896KB/64K Steps" },
    PlcSpec { manufacturer: "Panasonic", models: "FP 7/2SH/0R/X0/0H", time_per_instruction_us: "0.011/0.03/0.08-0.58/0.08-0.58/0.01", memory: "1MB/20KB/64KB/16KB/64K Steps" },
    PlcSpec { manufacturer: "Rexroth (Bosch)", models: "XM21/22/42, VPB", time_per_instruction_us: "FP:0.026/0.013/0.02/0.02", memory: "0.5/0.5/2/16GB" },
    PlcSpec { manufacturer: "Schneider Electric", models: "Modicon M221/241/251/262", time_per_instruction_us: "0.3/0.3/0.022/0.005", memory: "256KB/64MB/64MB/32MB" },
    PlcSpec { manufacturer: "SIEMENS", models: "SIMATIC S7-1200/1500", time_per_instruction_us: "2.3/0.006-0.384", memory: "150KB/150KB-4MB" },
    PlcSpec { manufacturer: "WAGO", models: "PFC100/200", time_per_instruction_us: "N/A, N/A", memory: "256/512MB" },
];

/// Fig. 3 companion data: Keras Applications model sizes (millions of
/// 32-bit parameters), used to contrast with PLC memory.
pub const KERAS_MODEL_SIZES: &[(&str, f64)] = &[
    ("MobileNet (a=0.25)", 0.47),
    ("MobileNetV2", 3.5),
    ("MobileNet", 4.3),
    ("NASNetMobile", 5.3),
    ("DenseNet121", 8.1),
    ("EfficientNetB0", 5.3),
    ("EfficientNetB3", 12.3),
    ("DenseNet201", 20.2),
    ("ResNet50", 25.6),
    ("NASNetLarge", 88.9),
    ("ResNet152", 60.4),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_covers_all_manufacturers() {
        assert_eq!(PLC_SPECS.len(), 16);
        assert!(PLC_SPECS.iter().any(|s| s.manufacturer == "WAGO"));
        assert!(PLC_SPECS.iter().any(|s| s.manufacturer == "SIEMENS"));
    }

    #[test]
    fn wago_is_slower_than_bbb() {
        let bbb = HwProfile::beaglebone();
        let wago = HwProfile::wago_pfc100();
        let mut m = Meter::new();
        m.fp_mul = 1000;
        m.loads = 3000;
        let r = wago.time_us(&m) / bbb.time_us(&m);
        assert!((r - 1.53).abs() < 1e-9);
    }

    #[test]
    fn instrumented_mode_doubles() {
        let bbb = HwProfile::beaglebone();
        let mut m = Meter::new();
        m.fp_add = 100;
        assert!((bbb.time_us_instrumented(&m) - 2.0 * bbb.time_us(&m)).abs() < 1e-9);
    }

    #[test]
    fn by_name_lookup() {
        assert!(HwProfile::by_name("wago").is_some());
        assert!(HwProfile::by_name("BBB").is_some());
        assert!(HwProfile::by_name("cray").is_none());
    }

    #[test]
    fn budget_duration_matches_time_us() {
        let bbb = HwProfile::beaglebone();
        let mut m = Meter::new();
        m.fp_add = 1000;
        let us = bbb.time_us(&m);
        assert!((bbb.budget(&m).as_secs_f64() * 1e6 - us).abs() < 1e-6);
    }

    #[test]
    fn cost_vector_time_accumulates() {
        let c = HwProfile::beaglebone().costs;
        let mut m = Meter::new();
        m.io_calls = 1;
        m.io_bytes = 100;
        let t = c.time_us(&m);
        assert!((t - (c.io_call + 100.0 * c.io_per_byte)).abs() < 1e-9);
    }
}
