//! PLC simulator: hardware profiles (paper Table 1), the abstract-op →
//! CPU-time model calibrated on the paper's published anchors, the
//! scan-cycle executor, and memory accounting.

pub mod memory;
pub mod profiles;
pub mod scan;

pub use profiles::{CostVector, HwProfile, PlcSpec, PLC_SPECS};
pub use scan::{ScanCycle, ScanStats};
