//! Scan-cycle executor: the PLC's periodic read-inputs → run-tasks →
//! write-outputs loop (paper §2.1 / §3.3), with modeled per-cycle CPU
//! time and real-time overrun accounting.

use super::profiles::HwProfile;
use crate::st::Meter;

/// Scan-cycle bookkeeping for one PLC task set.
#[derive(Debug, Clone)]
pub struct ScanCycle {
    pub profile: HwProfile,
    /// Scan period in microseconds (paper case study: 100 ms).
    pub period_us: f64,
    pub stats: ScanStats,
}

/// Aggregated statistics over a run.
#[derive(Debug, Clone, Default)]
pub struct ScanStats {
    pub cycles: u64,
    /// Cycles whose modeled CPU time exceeded the period.
    pub overruns: u64,
    pub control_time_us: f64,
    pub ml_time_us: f64,
    pub max_cycle_us: f64,
}

impl ScanCycle {
    pub fn new(profile: HwProfile, period_us: f64) -> ScanCycle {
        ScanCycle { profile, period_us, stats: ScanStats::default() }
    }

    /// Record one completed cycle from metered deltas. `control` covers
    /// the control task (PID etc.), `ml` the inference task. Returns
    /// the cycle's modeled CPU time (µs).
    pub fn record(&mut self, control: &Meter, ml: &Meter) -> f64 {
        let c = self.profile.time_us(control);
        let m = self.profile.time_us(ml);
        let total = c + m;
        self.stats.cycles += 1;
        self.stats.control_time_us += c;
        self.stats.ml_time_us += m;
        if total > self.period_us {
            self.stats.overruns += 1;
        }
        if total > self.stats.max_cycle_us {
            self.stats.max_cycle_us = total;
        }
        total
    }

    /// Record a cycle from already-modeled times (for native-engine /
    /// XLA backends whose cost is estimated from MAC counts).
    pub fn record_times(&mut self, control_us: f64, ml_us: f64) -> f64 {
        let total = control_us + ml_us;
        self.stats.cycles += 1;
        self.stats.control_time_us += control_us;
        self.stats.ml_time_us += ml_us;
        if total > self.period_us {
            self.stats.overruns += 1;
        }
        if total > self.stats.max_cycle_us {
            self.stats.max_cycle_us = total;
        }
        total
    }

    /// Spare time per cycle after the control task, available for
    /// (multipart) inference.
    pub fn ml_budget_us(&self, control_us: f64) -> f64 {
        (self.period_us - control_us).max(0.0)
    }

    /// [`ScanCycle::ml_budget_us`] as a wall-clock duration — the
    /// budget→deadline bridge used by `serve::Deadline::for_scan` (an
    /// in-cycle inference answered after this much wall time has by
    /// definition overrun the cycle).
    pub fn ml_budget(&self, control_us: f64) -> std::time::Duration {
        std::time::Duration::from_secs_f64(
            self.ml_budget_us(control_us) / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meter(fp_mul: u64) -> Meter {
        Meter { fp_mul, ..Meter::default() }
    }

    #[test]
    fn overrun_detection() {
        let mut sc = ScanCycle::new(HwProfile::beaglebone(), 100.0);
        // Small cycle: no overrun.
        sc.record(&meter(10), &meter(10));
        assert_eq!(sc.stats.overruns, 0);
        // Huge ML load: overrun.
        sc.record(&meter(10), &meter(1_000_000));
        assert_eq!(sc.stats.overruns, 1);
        assert_eq!(sc.stats.cycles, 2);
        assert!(sc.stats.max_cycle_us > 100.0);
    }

    #[test]
    fn budget_never_negative() {
        let sc = ScanCycle::new(HwProfile::beaglebone(), 100.0);
        assert_eq!(sc.ml_budget_us(150.0), 0.0);
        assert_eq!(sc.ml_budget_us(40.0), 60.0);
    }

    #[test]
    fn ml_budget_duration_matches_us() {
        let sc = ScanCycle::new(HwProfile::beaglebone(), 100_000.0);
        assert_eq!(sc.ml_budget(40_000.0).as_micros(), 60_000);
        assert_eq!(sc.ml_budget(200_000.0), std::time::Duration::ZERO);
    }

    #[test]
    fn record_times_accumulates() {
        let mut sc = ScanCycle::new(HwProfile::wago_pfc100(), 1000.0);
        sc.record_times(100.0, 200.0);
        sc.record_times(100.0, 300.0);
        assert_eq!(sc.stats.control_time_us, 200.0);
        assert_eq!(sc.stats.ml_time_us, 500.0);
        assert_eq!(sc.stats.overruns, 0);
    }
}
