//! Scan-cycle executor: the PLC's periodic read-inputs → run-tasks →
//! write-outputs loop (paper §2.1 / §3.3), with modeled per-cycle CPU
//! time and real-time overrun accounting.

use super::profiles::HwProfile;
use crate::st::Meter;

/// Scan-cycle bookkeeping for one PLC task set.
#[derive(Debug, Clone)]
pub struct ScanCycle {
    pub profile: HwProfile,
    /// Scan period in microseconds (paper case study: 100 ms).
    pub period_us: f64,
    pub stats: ScanStats,
}

/// Aggregated statistics over a run.
#[derive(Debug, Clone, Default)]
pub struct ScanStats {
    pub cycles: u64,
    /// Cycles whose modeled CPU time exceeded the period.
    pub overruns: u64,
    pub control_time_us: f64,
    pub ml_time_us: f64,
    pub max_cycle_us: f64,
}

impl ScanCycle {
    pub fn new(profile: HwProfile, period_us: f64) -> ScanCycle {
        ScanCycle { profile, period_us, stats: ScanStats::default() }
    }

    /// Record one completed cycle from metered deltas. `control` covers
    /// the control task (PID etc.), `ml` the inference task. Returns
    /// the cycle's modeled CPU time (µs).
    pub fn record(&mut self, control: &Meter, ml: &Meter) -> f64 {
        let c = self.profile.time_us(control);
        let m = self.profile.time_us(ml);
        self.record_times(c, m)
    }

    /// Record a cycle from already-modeled times (for native-engine /
    /// XLA backends whose cost is estimated from MAC counts).
    ///
    /// A cycle consuming *exactly* the period is an overrun: the
    /// period must also cover the I/O image swap, so zero slack means
    /// the next cycle's inputs are already late (`>=`, not `>` — the
    /// boundary the zero-headroom tests pin).
    pub fn record_times(&mut self, control_us: f64, ml_us: f64) -> f64 {
        let total = control_us + ml_us;
        self.stats.cycles += 1;
        self.stats.control_time_us += control_us;
        self.stats.ml_time_us += ml_us;
        if total >= self.period_us {
            self.stats.overruns += 1;
        }
        if total > self.stats.max_cycle_us {
            self.stats.max_cycle_us = total;
        }
        total
    }

    /// Spare time per cycle after the control task, available for
    /// (multipart) inference.
    pub fn ml_budget_us(&self, control_us: f64) -> f64 {
        (self.period_us - control_us).max(0.0)
    }

    /// [`ScanCycle::ml_budget_us`] as a wall-clock duration — the
    /// budget→deadline bridge used by `serve::Deadline::for_scan` (an
    /// in-cycle inference answered after this much wall time has by
    /// definition overrun the cycle).
    pub fn ml_budget(&self, control_us: f64) -> std::time::Duration {
        std::time::Duration::from_secs_f64(
            self.ml_budget_us(control_us) / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meter(fp_mul: u64) -> Meter {
        Meter { fp_mul, ..Meter::default() }
    }

    #[test]
    fn overrun_detection() {
        let mut sc = ScanCycle::new(HwProfile::beaglebone(), 100.0);
        // Small cycle: no overrun.
        sc.record(&meter(10), &meter(10));
        assert_eq!(sc.stats.overruns, 0);
        // Huge ML load: overrun.
        sc.record(&meter(10), &meter(1_000_000));
        assert_eq!(sc.stats.overruns, 1);
        assert_eq!(sc.stats.cycles, 2);
        assert!(sc.stats.max_cycle_us > 100.0);
    }

    #[test]
    fn budget_never_negative() {
        let sc = ScanCycle::new(HwProfile::beaglebone(), 100.0);
        assert_eq!(sc.ml_budget_us(150.0), 0.0);
        assert_eq!(sc.ml_budget_us(40.0), 60.0);
    }

    #[test]
    fn ml_budget_duration_matches_us() {
        let sc = ScanCycle::new(HwProfile::beaglebone(), 100_000.0);
        assert_eq!(sc.ml_budget(40_000.0).as_micros(), 60_000);
        assert_eq!(sc.ml_budget(200_000.0), std::time::Duration::ZERO);
    }

    #[test]
    fn record_times_accumulates() {
        let mut sc = ScanCycle::new(HwProfile::wago_pfc100(), 1000.0);
        sc.record_times(100.0, 200.0);
        sc.record_times(100.0, 300.0);
        assert_eq!(sc.stats.control_time_us, 200.0);
        assert_eq!(sc.stats.ml_time_us, 500.0);
        assert_eq!(sc.stats.overruns, 0);
    }

    /// A cycle consuming exactly the period has zero slack left for
    /// the I/O image swap — that is an overrun, not a near miss.
    #[test]
    fn exact_period_cycle_is_an_overrun() {
        let mut sc = ScanCycle::new(HwProfile::beaglebone(), 300.0);
        sc.record_times(100.0, 200.0);
        assert_eq!(sc.stats.overruns, 1);
        // One modeled microsecond of slack: not an overrun.
        sc.record_times(100.0, 199.0);
        assert_eq!(sc.stats.overruns, 1);
        assert_eq!(sc.stats.cycles, 2);
    }

    /// Control alone filling the period leaves an ml_budget of exactly
    /// zero — not negative, and the duration bridge agrees.
    #[test]
    fn ml_budget_at_zero_headroom() {
        let sc = ScanCycle::new(HwProfile::beaglebone(), 250.0);
        assert_eq!(sc.ml_budget_us(250.0), 0.0);
        assert_eq!(sc.ml_budget(250.0), std::time::Duration::ZERO);
        // Infinitesimally under the period: budget is the remainder.
        assert!(sc.ml_budget_us(249.5) > 0.0);
    }

    /// Period shorter than the fixed control cost: every cycle
    /// overruns, the ML budget is pinned at zero, and the stats stay
    /// coherent (no negative or NaN accumulation).
    #[test]
    fn period_shorter_than_control_cost() {
        let mut sc = ScanCycle::new(HwProfile::beaglebone(), 50.0);
        for _ in 0..4 {
            sc.record_times(80.0, 0.0);
        }
        assert_eq!(sc.stats.cycles, 4);
        assert_eq!(sc.stats.overruns, 4);
        assert_eq!(sc.stats.control_time_us, 320.0);
        assert_eq!(sc.stats.max_cycle_us, 80.0);
        assert_eq!(sc.ml_budget_us(80.0), 0.0);
    }

    /// Stats accumulate across a mixed run of overrunning and healthy
    /// cycles; max_cycle_us tracks the single worst cycle.
    #[test]
    fn stats_accumulate_across_overruns() {
        let mut sc = ScanCycle::new(HwProfile::wago_pfc100(), 100.0);
        let times = [(10.0, 20.0), (50.0, 80.0), (10.0, 10.0), (60.0, 40.0)];
        for (c, m) in times {
            sc.record_times(c, m);
        }
        assert_eq!(sc.stats.cycles, 4);
        // 130 and exactly-100 overrun; 30 and 20 do not.
        assert_eq!(sc.stats.overruns, 2);
        assert_eq!(sc.stats.control_time_us, 130.0);
        assert_eq!(sc.stats.ml_time_us, 150.0);
        assert_eq!(sc.stats.max_cycle_us, 130.0);
    }

    /// The metered `record` path and the pre-modeled `record_times`
    /// path agree on the same workload (record is a thin pricing
    /// wrapper — a drift between them would double-count cycles).
    #[test]
    fn record_meter_and_times_paths_agree() {
        let profile = HwProfile::beaglebone();
        let m = meter(1_000);
        let us = profile.time_us(&m);
        let mut a = ScanCycle::new(profile.clone(), 100.0);
        let mut b = ScanCycle::new(profile, 100.0);
        let ta = a.record(&m, &meter(0));
        let tb = b.record_times(us, 0.0);
        assert_eq!(ta, tb);
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.stats.overruns, b.stats.overruns);
        assert_eq!(a.stats.control_time_us, b.stats.control_time_us);
    }
}
