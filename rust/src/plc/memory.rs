//! PLC memory accounting (paper §3.2, §4.2.1, Fig. 3).
//!
//! Computes a model's resident footprint on the PLC and checks it —
//! including the transient `VAR_INPUT` duplication the paper warns
//! about (the MELSEC iQ-R example: passing a 512-neuron layer's
//! weights+biases by value ≈ 2 MB, overflowing a 4 MB device).

use crate::engine::{Layer, Model};
use crate::quant::{memory_requirements, Scheme};

/// Resident bytes of one engine layer (weights + biases + scales +
/// output buffer), ICSML allocation style.
pub fn layer_bytes(l: &Layer) -> u64 {
    let out_buf = 4 * l.out_dim() as u64;
    match l {
        Layer::Input { .. } | Layer::Activation { .. } => out_buf,
        Layer::Scale { channels, .. } => out_buf + 8 * *channels as u64,
        Layer::Dense { inputs, neurons, .. } => {
            memory_requirements(*inputs as u64, *neurons as u64, None).total
                + out_buf
        }
        Layer::QuantDense { inputs, neurons, scheme, .. } => {
            memory_requirements(*inputs as u64, *neurons as u64, Some(*scheme))
                .total
                + out_buf
        }
        Layer::Conv2D { w, b, .. } | Layer::ConvDW { w, b, .. } => {
            4 * (w.len() + b.len()) as u64 + out_buf
        }
    }
}

/// Resident bytes of a whole model (the Fig. 3 comparison quantity).
pub fn model_bytes(m: &Model) -> u64 {
    m.layers().iter().map(layer_bytes).sum()
}

/// Worst-case transient bytes if a layer's weights+biases were passed
/// by `VAR_INPUT` (call-by-value duplication, §4.2.1) instead of
/// through `dataMem` pointers.
pub fn var_input_copy_bytes(inputs: u64, neurons: u64, scheme: Option<Scheme>) -> u64 {
    let r = memory_requirements(inputs, neurons, scheme);
    r.weights + r.biases
}

/// Does a model fit a device, optionally including the VAR_INPUT
/// duplication transient? Reserves 25% of RAM for the runtime + control
/// application (Codesys-style).
pub fn fits(
    model_resident: u64,
    transient_copies: u64,
    ram_bytes: u64,
) -> bool {
    let budget = ram_bytes - ram_bytes / 4;
    model_resident + transient_copies <= budget
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Act;

    #[test]
    fn melsec_iqr_varinput_overflow_scenario() {
        // Paper §4.2.1: MELSEC iQ-R has 4 MB; a 512-neuron dense layer
        // with 512 inputs ≈ 2 MB of weights+biases. Passing them
        // VAR_INPUT duplicates that 2 MB: model + copy > 4 MB budget,
        // while the dataMem (pointer) approach fits.
        let ram = 4 << 20;
        // three-layer MNIST model resident size
        let resident: u64 = [(784u64, 512u64), (512, 512), (512, 10)]
            .iter()
            .map(|(i, n)| memory_requirements(*i, *n, None).total + 4 * n)
            .sum();
        // (the paper quotes ≈2 MB for its example configuration; a
        // 512x512 layer's weights+biases are ≈1 MB — either transient
        // overflows the 4 MB device once the model is resident)
        let copy = var_input_copy_bytes(512, 512, None);
        assert!(copy > 1 << 20, "copy ≈ 1MB, got {copy}");
        assert!(
            !fits(resident, copy, ram),
            "VAR_INPUT duplication must overflow the iQ-R"
        );
        assert!(fits(resident, 0, ram), "dataMem approach must fit");
    }

    #[test]
    fn layer_bytes_dense() {
        let l = Layer::dense(vec![0.0; 64 * 64], vec![0.0; 64], 64, Act::Relu);
        // 64*64*4 weights + 64*4 biases + 64*4 out buffer
        assert_eq!(layer_bytes(&l), 16_384 + 256 + 256);
    }

    #[test]
    fn model_bytes_sums() {
        let m = Model::new(vec![
            Layer::Input { dim: 4 },
            Layer::dense(vec![0.0; 8], vec![0.0; 2], 4, Act::None),
        ]);
        assert_eq!(model_bytes(&m), 16 + (32 + 8 + 8));
    }

    #[test]
    fn entry_level_plc_cannot_fit_classifier() {
        // Allen Bradley Micro 810: 2 KB — even the §7 classifier
        // (≈115 KB) is far beyond it (the Fig. 3 story).
        let resident: u64 = [(400u64, 64u64), (64, 32), (32, 16), (16, 2)]
            .iter()
            .map(|(i, n)| memory_requirements(*i, *n, None).total + 4 * n)
            .sum();
        assert!(!fits(resident, 0, 2 * 1024));
        // WAGO PFC100 (256 MB) fits it trivially.
        assert!(fits(resident, 0, 256 << 20));
    }
}
