//! The §7 on-PLC anomaly-detection application: sliding window over
//! (TB0, Wd) ADC readings → 400-feature vector → classifier →
//! debounced detection, behind a pluggable inference backend.

use std::collections::VecDeque;

use crate::engine::Model;
use crate::st::{Interp, Meter, Value};

/// Window length per feature (paper: 10 Hz x 20 s).
pub const WINDOW: usize = 200;
/// Total classifier inputs (2 features x WINDOW).
pub const FEATURES: usize = 2 * WINDOW;

/// An inference backend the detector can run on.
pub trait Backend {
    /// Classifier logits for one feature vector.
    fn infer(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>>;
    fn name(&self) -> &'static str;
    /// Metered ST ops for the last inference (ST backend only).
    fn last_meter(&self) -> Option<Meter> {
        None
    }
}

/// Native-engine backend.
pub struct EngineBackend(pub Model);

impl Backend for EngineBackend {
    fn infer(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        Ok(self.0.infer(x))
    }
    fn name(&self) -> &'static str {
        "engine"
    }
}

/// ST-interpreter backend: the ported ICSML program running on the
/// simulated PLC. Feeds the program's `inputs` array, runs one scan of
/// the inference POU, reads `outputs`.
pub struct StBackend {
    pub interp: Interp,
    pub program: String,
    last: Meter,
}

impl StBackend {
    pub fn new(interp: Interp, program: impl Into<String>) -> StBackend {
        StBackend { interp, program: program.into(), last: Meter::new() }
    }
}

impl Backend for StBackend {
    fn infer(&mut self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        let inst = self
            .interp
            .program_instance(&self.program)
            .ok_or_else(|| anyhow::anyhow!("no program {}", self.program))?;
        match self.interp.instance_field(inst, "inputs") {
            Some(Value::ArrF32(a)) => {
                anyhow::ensure!(a.borrow().len() == x.len(), "input size");
                a.borrow_mut().copy_from_slice(x);
            }
            other => anyhow::bail!("bad inputs field: {other:?}"),
        }
        let before = self.interp.meter.clone();
        self.interp
            .run_program(&self.program)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        self.last = self.interp.meter.since(&before);
        match self.interp.instance_field(inst, "outputs") {
            Some(Value::ArrF32(a)) => Ok(a.borrow().clone()),
            other => anyhow::bail!("bad outputs field: {other:?}"),
        }
    }

    fn name(&self) -> &'static str {
        "st"
    }

    fn last_meter(&self) -> Option<Meter> {
        Some(self.last.clone())
    }
}

/// Sliding-window feature extractor. Layout matches training
/// (`train.window_matrix`): `[tb0 oldest..newest | wd oldest..newest]`.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    tb0: VecDeque<f32>,
    wd: VecDeque<f32>,
}

impl Default for SlidingWindow {
    fn default() -> Self {
        Self::new()
    }
}

impl SlidingWindow {
    pub fn new() -> SlidingWindow {
        SlidingWindow {
            tb0: VecDeque::with_capacity(WINDOW),
            wd: VecDeque::with_capacity(WINDOW),
        }
    }

    /// Push one scan's readings. Returns true once the window is full.
    pub fn push(&mut self, tb0: f64, wd: f64) -> bool {
        if self.tb0.len() == WINDOW {
            self.tb0.pop_front();
            self.wd.pop_front();
        }
        self.tb0.push_back(tb0 as f32);
        self.wd.push_back(wd as f32);
        self.tb0.len() == WINDOW
    }

    pub fn ready(&self) -> bool {
        self.tb0.len() == WINDOW
    }

    /// Materialize the 400-feature vector into `out`.
    pub fn fill_features(&self, out: &mut [f32]) {
        assert!(self.ready());
        assert_eq!(out.len(), FEATURES);
        for (i, v) in self.tb0.iter().enumerate() {
            out[i] = *v;
        }
        for (i, v) in self.wd.iter().enumerate() {
            out[WINDOW + i] = *v;
        }
    }
}

/// Debounced detector: fires after `threshold` consecutive positive
/// classifications (a window-based model needs several malicious
/// samples before flagging — the paper's ~5 s detection latency).
pub struct Detector {
    pub backend: Box<dyn Backend>,
    pub window: SlidingWindow,
    pub threshold: u32,
    consecutive: u32,
    features: Vec<f32>,
}

impl Detector {
    pub fn new(backend: Box<dyn Backend>, threshold: u32) -> Detector {
        Detector {
            backend,
            window: SlidingWindow::new(),
            threshold,
            consecutive: 0,
            features: vec![0.0; FEATURES],
        }
    }

    /// Feed one scan's readings; returns `Some(positive)` once the
    /// window is warm (positive = attack detected this cycle after
    /// debounce).
    pub fn observe(&mut self, tb0: f64, wd: f64) -> anyhow::Result<Option<bool>> {
        if !self.window.push(tb0, wd) {
            return Ok(None);
        }
        self.window.fill_features(&mut self.features);
        let logits = self.backend.infer(&self.features)?;
        let attack = logits[1] > logits[0];
        if attack {
            self.consecutive += 1;
        } else {
            self.consecutive = 0;
        }
        Ok(Some(self.consecutive >= self.threshold))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Act, Layer};

    #[test]
    fn window_layout_matches_training() {
        let mut w = SlidingWindow::new();
        for i in 0..WINDOW + 5 {
            w.push(i as f64, 10_000.0 + i as f64);
        }
        let mut f = vec![0.0; FEATURES];
        w.fill_features(&mut f);
        // Oldest tb0 kept = 5, newest = 204.
        assert_eq!(f[0], 5.0);
        assert_eq!(f[WINDOW - 1], (WINDOW + 4) as f32);
        assert_eq!(f[WINDOW], 10_005.0);
        assert_eq!(f[FEATURES - 1], (10_000 + WINDOW + 4) as f32);
    }

    #[test]
    fn window_not_ready_before_full() {
        let mut w = SlidingWindow::new();
        for _ in 0..WINDOW - 1 {
            assert!(!w.push(0.0, 0.0));
        }
        assert!(w.push(0.0, 0.0));
    }

    /// A hand-built "detector" that fires when mean(wd window) < 10:
    /// w = [0;200 tb0 | -1/200;200 wd], b = 10 on the attack logit.
    fn threshold_model() -> Model {
        let mut w = vec![0.0f32; FEATURES * 2];
        for i in 0..WINDOW {
            // logit1 (attack) gets -mean(wd) + 10 - i.e. fires when
            // mean < 10.  Weight layout: [in][out] col? engine uses
            // dense rows [neurons][inputs]: row0 = logit0 (zeros),
            // row1 = attack logit.
            w[FEATURES + WINDOW + i] = -1.0 / WINDOW as f32;
        }
        let b = vec![0.0f32, 10.0];
        Model::new(vec![Layer::dense(w, b, FEATURES, Act::None)])
    }

    #[test]
    fn detector_debounce_and_fire() {
        let mut det =
            Detector::new(Box::new(EngineBackend(threshold_model())), 3);
        // Warm the window with wd = 20 (mean 20 > 10: benign).
        let mut fired = false;
        for _ in 0..WINDOW + 10 {
            if let Some(f) = det.observe(90.0, 20.0).unwrap() {
                fired |= f;
            }
        }
        assert!(!fired, "no detection under benign data");
        // Attack: wd collapses to 0 — after enough samples the window
        // mean crosses and debounce counts 3 consecutive positives.
        let mut detect_at = None;
        for i in 0..WINDOW + 10 {
            if det.observe(90.0, 0.0).unwrap() == Some(true) {
                detect_at = Some(i);
                break;
            }
        }
        let at = detect_at.expect("must detect");
        assert!(at >= 2, "debounce needs >= threshold cycles, got {at}");
    }
}
