//! The §7 on-PLC anomaly-detection application: sliding window over
//! (TB0, Wd) ADC readings → 400-feature vector → classifier →
//! debounced detection, behind the pluggable [`crate::api::Session`]
//! inference contract.
//!
//! This module is a pure *consumer* of the inference API — the traits
//! and the backend adapters live in [`crate::api`] (historically they
//! were defined here; see `API.md` for migration notes). A detector
//! owns one [`Session`]; many detectors can watch many streams over
//! one shared backend.

use std::collections::VecDeque;

use crate::api::{InferenceError, Session};

/// Window length per feature (paper: 10 Hz x 20 s).
pub const WINDOW: usize = 200;
/// Total classifier inputs (2 features x WINDOW).
pub const FEATURES: usize = 2 * WINDOW;

/// Sliding-window feature extractor. Layout matches training
/// (`train.window_matrix`): `[tb0 oldest..newest | wd oldest..newest]`.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    tb0: VecDeque<f32>,
    wd: VecDeque<f32>,
}

impl Default for SlidingWindow {
    fn default() -> Self {
        Self::new()
    }
}

impl SlidingWindow {
    pub fn new() -> SlidingWindow {
        SlidingWindow {
            tb0: VecDeque::with_capacity(WINDOW),
            wd: VecDeque::with_capacity(WINDOW),
        }
    }

    /// Push one scan's readings. Returns true once the window is full.
    pub fn push(&mut self, tb0: f64, wd: f64) -> bool {
        if self.tb0.len() == WINDOW {
            self.tb0.pop_front();
            self.wd.pop_front();
        }
        self.tb0.push_back(tb0 as f32);
        self.wd.push_back(wd as f32);
        self.tb0.len() == WINDOW
    }

    pub fn ready(&self) -> bool {
        self.tb0.len() == WINDOW
    }

    /// Materialize the 400-feature vector into `out`.
    pub fn fill_features(&self, out: &mut [f32]) {
        assert!(self.ready());
        assert_eq!(out.len(), FEATURES);
        for (i, v) in self.tb0.iter().enumerate() {
            out[i] = *v;
        }
        for (i, v) in self.wd.iter().enumerate() {
            out[WINDOW + i] = *v;
        }
    }
}

/// Debounced detector: fires after `threshold` consecutive positive
/// classifications (a window-based model needs several malicious
/// samples before flagging — the paper's ~5 s detection latency).
pub struct Detector {
    pub session: Box<dyn Session>,
    pub window: SlidingWindow,
    pub threshold: u32,
    consecutive: u32,
    features: Vec<f32>,
    /// Preallocated logit buffer sized to the model's `out_dim`
    /// (`observe` is on the scan-cycle hot path: no per-call
    /// allocation).
    logits: Vec<f32>,
}

impl Detector {
    /// Detector over one inference session (mint it from a shared
    /// backend: `Detector::new(backend.session()?, 5)`).
    pub fn new(session: Box<dyn Session>, threshold: u32) -> Detector {
        let out_dim = session.spec().out_dim;
        Detector {
            session,
            window: SlidingWindow::new(),
            threshold,
            consecutive: 0,
            features: vec![0.0; FEATURES],
            logits: vec![0.0; out_dim],
        }
    }

    /// Feed one scan's readings; returns `Some(positive)` once the
    /// window is warm (positive = attack detected this cycle after
    /// debounce). A model with fewer than 2 logits is a typed
    /// [`InferenceError::ShapeMismatch`], not a panic.
    pub fn observe(
        &mut self,
        tb0: f64,
        wd: f64,
    ) -> Result<Option<bool>, InferenceError> {
        // Fail on the first call, not after WINDOW warm-up cycles:
        // the logit count is fixed at construction.
        if self.logits.len() < 2 {
            return Err(InferenceError::ShapeMismatch {
                what: "detector logits",
                expected: 2,
                got: self.logits.len(),
            });
        }
        if !self.window.push(tb0, wd) {
            return Ok(None);
        }
        self.window.fill_features(&mut self.features);
        self.session.infer_into(&self.features, &mut self.logits)?;
        let attack = self.logits[1] > self.logits[0];
        if attack {
            self.consecutive += 1;
        } else {
            self.consecutive = 0;
        }
        Ok(Some(self.consecutive >= self.threshold))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, EngineBackend};
    use crate::engine::{Act, Layer, Model};

    #[test]
    fn window_layout_matches_training() {
        let mut w = SlidingWindow::new();
        for i in 0..WINDOW + 5 {
            w.push(i as f64, 10_000.0 + i as f64);
        }
        let mut f = vec![0.0; FEATURES];
        w.fill_features(&mut f);
        // Oldest tb0 kept = 5, newest = 204.
        assert_eq!(f[0], 5.0);
        assert_eq!(f[WINDOW - 1], (WINDOW + 4) as f32);
        assert_eq!(f[WINDOW], 10_005.0);
        assert_eq!(f[FEATURES - 1], (10_000 + WINDOW + 4) as f32);
    }

    #[test]
    fn window_not_ready_before_full() {
        let mut w = SlidingWindow::new();
        for _ in 0..WINDOW - 1 {
            assert!(!w.push(0.0, 0.0));
        }
        assert!(w.push(0.0, 0.0));
    }

    /// A hand-built "detector" that fires when mean(wd window) < 10:
    /// w = [0;200 tb0 | -1/200;200 wd], b = 10 on the attack logit.
    fn threshold_model() -> Model {
        let mut w = vec![0.0f32; FEATURES * 2];
        for i in 0..WINDOW {
            // logit1 (attack) gets -mean(wd) + 10 - i.e. fires when
            // mean < 10.  Weight layout: [in][out] col? engine uses
            // dense rows [neurons][inputs]: row0 = logit0 (zeros),
            // row1 = attack logit.
            w[FEATURES + WINDOW + i] = -1.0 / WINDOW as f32;
        }
        let b = vec![0.0f32, 10.0];
        Model::new(vec![Layer::dense(w, b, FEATURES, Act::None)])
    }

    #[test]
    fn detector_debounce_and_fire() {
        let mut det = Detector::new(
            EngineBackend::new(threshold_model()).session().unwrap(),
            3,
        );
        // Warm the window with wd = 20 (mean 20 > 10: benign).
        let mut fired = false;
        for _ in 0..WINDOW + 10 {
            if let Some(f) = det.observe(90.0, 20.0).unwrap() {
                fired |= f;
            }
        }
        assert!(!fired, "no detection under benign data");
        // Attack: wd collapses to 0 — after enough samples the window
        // mean crosses and debounce counts 3 consecutive positives.
        let mut detect_at = None;
        for i in 0..WINDOW + 10 {
            if det.observe(90.0, 0.0).unwrap() == Some(true) {
                detect_at = Some(i);
                break;
            }
        }
        let at = detect_at.expect("must detect");
        assert!(at >= 2, "debounce needs >= threshold cycles, got {at}");
    }

    #[test]
    fn single_logit_model_is_typed_error_not_panic() {
        // One-logit model: the old code indexed logits[1] and panicked.
        let m = Model::new(vec![Layer::dense(
            vec![0.0f32; FEATURES],
            vec![0.0f32],
            FEATURES,
            Act::None,
        )]);
        let mut det =
            Detector::new(EngineBackend::new(m).session().unwrap(), 3);
        // Misconfiguration surfaces on the very first observation, not
        // after the window warms up.
        match det.observe(1.0, 1.0) {
            Err(InferenceError::ShapeMismatch { expected: 2, got: 1, .. }) => {}
            other => panic!("want ShapeMismatch, got {other:?}"),
        }
    }
}
