//! Network front door: async TCP ingress + multi-model registry.
//!
//! Everything below this module serves requests that originate *in
//! process*. This layer opens the stack to the network — the
//! deployment shape an ICS detection service actually runs in: many
//! per-plant / per-PLC-class models behind one endpoint, thousands of
//! concurrent in-flight requests, a fixed thread budget.
//!
//! Four pieces, composed left to right on the request path:
//!
//! * [`proto`] — a length-prefixed, versioned binary wire protocol
//!   carrying model name, priority class, deadline budget and f32
//!   payload, with typed request/response/error frames and an
//!   incremental, non-panicking decoder.
//! * [`Client`] — the blocking caller side: connect, pipeline
//!   submissions, match replies by id, reconstruct typed
//!   [`InferenceError`](crate::api::InferenceError)s from error
//!   frames; with a [`RetryPolicy`], survive a dead transport by
//!   reconnecting (address failover, jittered backoff) and surface
//!   the unrecoverable in-flight replies as typed
//!   [`ConnectionLost`](crate::api::InferenceError::ConnectionLost)
//!   errors.
//! * [`ModelRegistry`] — named engines loaded lazily from manifest
//!   roots (or injected by tests via [`StaticLoader`]), each behind
//!   its own [`serve::Pool`](crate::serve::Pool), cached under an
//!   LRU byte/engine budget.
//! * [`NetServer`] — a single-threaded poll reactor (std only, no new
//!   deps) that parses frames, routes them through the registry into
//!   pools, and completes responses from ticket readiness
//!   ([`serve::Ticket::try_wait`](crate::serve::Ticket::try_wait)) —
//!   O(workers) threads however many requests are in flight.
//!
//! See `docs/ARCHITECTURE.md` ("life of a networked query") and the
//! "Network serving & model registry" section of `API.md`.

#![deny(missing_docs)]

pub mod client;
pub mod proto;
pub mod registry;
pub mod server;

pub use client::{Client, NetOptions, NetReply, RetryPolicy};
pub use registry::{
    LoadedModel, ManifestLoader, ModelEntry, ModelLoader, ModelRegistry,
    RegistryConfig, StaticLoader,
};
pub use server::{NetServer, ServerConfig, ServerStats};
