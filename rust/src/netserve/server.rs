//! Nonblocking TCP front door over the model registry.
//!
//! One reactor thread owns every connection: it accepts, reads,
//! parses frames, routes requests into per-model [`Pool`]s through
//! the [`ModelRegistry`], polls outstanding [`Ticket`]s with
//! [`Ticket::try_wait`], and flushes replies — all without ever
//! blocking on a single request. Thread budget is **O(workers)**: the
//! reactor plus the pool workers, regardless of how many thousands of
//! requests are in flight. That is the property ROADMAP item 1 asks
//! for; a thread-per-request design melts exactly when an ICS
//! detection service is needed most (alarm storms).
//!
//! The loop is a minimal poll-style reactor on `std` only — no mio,
//! no epoll binding, no new dependencies. Every socket is
//! nonblocking; when a full pass makes no progress (no bytes moved,
//! no ticket completed, no connection accepted) the reactor sleeps
//! [`ServerConfig::idle_sleep`] before the next pass, trading a
//! bounded sliver of idle latency for zero busy-spin.
//!
//! Failure containment: a malformed or hostile stream gets a typed
//! [`ErrorCode::Protocol`](super::proto::ErrorCode::Protocol) error
//! frame and a close — it never panics the reactor, wedges the loop,
//! or affects other connections. Per-request failures (unknown model,
//! shed deadline, shape mismatch) travel back as error frames on a
//! healthy connection.
//!
//! Resource exhaustion is refused, not absorbed: per-connection and
//! whole-server in-flight caps answer excess requests with a typed
//! [`InferenceError::Overloaded`] frame carrying a retry-after hint
//! (the connection stays healthy — overload is the *caller's* signal
//! to back off, not a reason to cut them off), a stalled partial
//! frame trips [`ServerConfig::read_timeout`], a connection holding
//! no work for [`ServerConfig::idle_timeout`] is reclaimed, and a
//! peer that stops draining its replies is dropped once
//! [`ServerConfig::max_wbuf`] bytes back up. Shutdown has a graceful
//! gear: [`NetServer::shutdown_drain`] stops accepting, lets
//! in-flight requests finish within a grace budget, then joins the
//! reactor.
//!
//! [`Pool`]: crate::serve::Pool

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::InferenceError;
use crate::serve::{Deadline, SubmitOptions, Ticket};

use super::proto::{
    self, Decoded, ErrorFrame, Frame, ResponseFrame, DEFAULT_MAX_FRAME,
};
use super::registry::ModelRegistry;

/// Reactor sizing and robustness knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Largest accepted frame body, in bytes
    /// ([`DEFAULT_MAX_FRAME`]). Bigger prefixes mark the stream
    /// corrupt.
    pub max_frame: usize,
    /// Max simultaneously-open connections; beyond this, new peers
    /// wait in the OS accept backlog.
    pub max_conns: usize,
    /// How long the reactor sleeps after a pass that made no
    /// progress.
    pub idle_sleep: Duration,
    /// Max in-flight requests per connection; excess requests are
    /// answered with a typed [`InferenceError::Overloaded`] frame
    /// (scope `"connection"`) and the connection stays open.
    pub max_inflight_per_conn: usize,
    /// Max in-flight requests across *all* connections; excess
    /// requests get an [`InferenceError::Overloaded`] frame (scope
    /// `"server"`). This bounds reactor memory no matter how many
    /// peers pile on.
    pub max_inflight_total: usize,
    /// A connection with no in-flight work and no traffic for this
    /// long is reclaimed (silent close — the peer walked away).
    pub idle_timeout: Duration,
    /// A partially-received frame older than this marks the stream
    /// stalled: typed protocol error, then close. Bounds how long a
    /// trickling (or wedged) peer can hold a connection's buffer.
    pub read_timeout: Duration,
    /// Max bytes of encoded replies allowed to back up for a peer
    /// that is not reading; beyond this the connection is dropped
    /// (a slow consumer must not grow server memory unboundedly).
    pub max_wbuf: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_frame: DEFAULT_MAX_FRAME,
            max_conns: 1024,
            idle_sleep: Duration::from_micros(200),
            max_inflight_per_conn: 1024,
            max_inflight_total: 4096,
            idle_timeout: Duration::from_secs(60),
            read_timeout: Duration::from_secs(10),
            max_wbuf: 16 << 20,
        }
    }
}

/// Monotonic counters the reactor publishes (all `Relaxed`; read
/// them for monitoring, not for synchronization).
#[derive(Default)]
pub struct ServerStats {
    accepted: AtomicU64,
    requests: AtomicU64,
    responses: AtomicU64,
    error_frames: AtomicU64,
    protocol_errors: AtomicU64,
    overloaded: AtomicU64,
}

impl ServerStats {
    /// Connections accepted since bind.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Request frames parsed.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Successful response frames sent.
    pub fn responses(&self) -> u64 {
        self.responses.load(Ordering::Relaxed)
    }

    /// Typed error frames sent (per-request failures *and* protocol
    /// errors).
    pub fn error_frames(&self) -> u64 {
        self.error_frames.load(Ordering::Relaxed)
    }

    /// Corrupt-stream events (each also closes its connection).
    pub fn protocol_errors(&self) -> u64 {
        self.protocol_errors.load(Ordering::Relaxed)
    }

    /// Requests refused at an in-flight cap
    /// ([`InferenceError::Overloaded`] frames sent; also counted in
    /// [`ServerStats::error_frames`]).
    pub fn overloaded(&self) -> u64 {
        self.overloaded.load(Ordering::Relaxed)
    }
}

/// Handle to a running network server. Dropping it stops the reactor
/// and joins its thread; in-flight pool work is abandoned (tickets
/// dropped), pool workers themselves are owned by the registry.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    thread: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (use port 0 for an OS-assigned port) and start the
    /// reactor thread serving `registry`.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        registry: Arc<ModelRegistry>,
        cfg: ServerConfig,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let drain = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let thread = {
            let stop = Arc::clone(&stop);
            let drain = Arc::clone(&drain);
            let stats = Arc::clone(&stats);
            std::thread::Builder::new()
                .name("netserve-reactor".into())
                .spawn(move || {
                    reactor(listener, registry, cfg, stop, drain, stats)
                })?
        };
        Ok(NetServer { addr, stop, drain, stats, thread: Some(thread) })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The reactor's monitoring counters.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// An owned handle to the counters that outlives the server —
    /// for reading the final totals after a consuming
    /// [`NetServer::shutdown_drain`].
    pub fn stats_handle(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Stop the reactor and join its thread. (Dropping the server
    /// does the same; this just names the intent.) In-flight requests
    /// are abandoned — use [`NetServer::shutdown_drain`] to let them
    /// finish.
    pub fn shutdown(mut self) {
        self.halt();
    }

    /// Graceful shutdown: stop accepting new connections and new
    /// bytes, let already-received requests complete and their replies
    /// flush, then stop the reactor. If draining takes longer than
    /// `grace`, fall back to a hard stop so shutdown is always
    /// bounded. The `icsml listen` subcommand routes SIGINT/SIGTERM
    /// here.
    pub fn shutdown_drain(mut self, grace: Duration) {
        self.drain.store(true, Ordering::SeqCst);
        let t0 = Instant::now();
        while t0.elapsed() < grace {
            match &self.thread {
                Some(t) if !t.is_finished() => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                _ => break,
            }
        }
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// One client connection's state, owned by the reactor.
struct Conn {
    stream: TcpStream,
    /// Unparsed inbound bytes.
    rbuf: Vec<u8>,
    /// Encoded outbound bytes not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// How much of `wbuf` the socket has taken.
    wpos: usize,
    /// In-flight requests: (wire id, pool ticket).
    pending: Vec<(u64, Ticket)>,
    /// Peer half-closed its write side; serve what's pending, then
    /// close.
    eof: bool,
    /// Stream is corrupt: stop parsing, close once `wbuf` drains.
    close_after_flush: bool,
    dead: bool,
    /// Last pass that moved bytes or completed a ticket for this
    /// connection (drives [`ServerConfig::idle_timeout`]).
    last_activity: Instant,
    /// When the currently-buffered *partial* frame started waiting
    /// for its remainder (drives [`ServerConfig::read_timeout`]).
    partial_since: Option<Instant>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: Vec::new(),
            eof: false,
            close_after_flush: false,
            dead: false,
            last_activity: Instant::now(),
            partial_since: None,
        }
    }

    fn send(&mut self, frame: &Frame) {
        frame.encode(&mut self.wbuf);
    }
}

fn reactor(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let draining = drain.load(Ordering::SeqCst);
        let mut progress = false;
        while !draining && conns.len() < cfg.max_conns {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    stats.accepted.fetch_add(1, Ordering::Relaxed);
                    conns.push(Conn::new(stream));
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
        // The global in-flight count: seeded from the per-connection
        // truth each pass, kept exact across this pass's submissions
        // and completions by `service`/`dispatch`.
        let mut total: usize =
            conns.iter().map(|c| c.pending.len()).sum();
        for conn in conns.iter_mut() {
            if draining {
                // Drain mode: stop reading new bytes; what is already
                // buffered or in flight still completes and flushes.
                conn.eof = true;
            }
            progress |= service(conn, &registry, &cfg, &stats, &mut total);
        }
        conns.retain(|c| !c.dead);
        if draining && conns.is_empty() {
            return; // drained dry: a graceful exit
        }
        if !progress {
            std::thread::sleep(cfg.idle_sleep);
        }
    }
}

/// One nonblocking pass over a connection:
/// read → parse/dispatch → poll tickets → flush. Returns whether any
/// progress was made.
fn service(
    conn: &mut Conn,
    registry: &ModelRegistry,
    cfg: &ServerConfig,
    stats: &ServerStats,
    total: &mut usize,
) -> bool {
    let mut progress = false;

    // Read until the socket runs dry.
    if !conn.eof && !conn.close_after_flush {
        let mut buf = [0u8; 16384];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.eof = true;
                    progress = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&buf[..n]);
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return true;
                }
            }
        }
    }

    // Parse every complete frame buffered so far.
    let mut consumed = 0;
    while !conn.close_after_flush {
        match proto::decode(&conn.rbuf[consumed..], cfg.max_frame) {
            Decoded::Frame(frame, used) => {
                consumed += used;
                progress = true;
                dispatch(conn, frame, registry, cfg, stats, total);
            }
            Decoded::Incomplete => break,
            Decoded::Corrupt(msg) => {
                stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                stats.error_frames.fetch_add(1, Ordering::Relaxed);
                conn.send(&Frame::Error(ErrorFrame::protocol(0, msg)));
                conn.close_after_flush = true;
                conn.rbuf.clear();
                consumed = 0;
                progress = true;
                break;
            }
        }
    }
    if consumed > 0 {
        conn.rbuf.drain(..consumed);
    }
    // Anything left over is a partial frame: start (or keep) its
    // stall clock. A complete drain resets it.
    if conn.rbuf.is_empty() || conn.close_after_flush {
        conn.partial_since = None;
    } else if conn.partial_since.is_none() {
        conn.partial_since = Some(Instant::now());
    }

    // Complete whatever the pool has finished, without blocking.
    let mut i = 0;
    while i < conn.pending.len() {
        match conn.pending[i].1.try_wait() {
            Some(result) => {
                let (id, _) = conn.pending.swap_remove(i);
                *total = total.saturating_sub(1);
                progress = true;
                match result {
                    Ok(payload) => {
                        stats.responses.fetch_add(1, Ordering::Relaxed);
                        conn.send(&Frame::Response(ResponseFrame {
                            id,
                            payload,
                        }));
                    }
                    Err(e) => {
                        stats
                            .error_frames
                            .fetch_add(1, Ordering::Relaxed);
                        conn.send(&Frame::Error(ErrorFrame::from_error(
                            id, &e,
                        )));
                    }
                }
            }
            None => i += 1,
        }
    }

    // Flush until the socket pushes back.
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                return true;
            }
            Ok(n) => {
                conn.wpos += n;
                progress = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return true;
            }
        }
    }
    if conn.wpos == conn.wbuf.len() && conn.wpos > 0 {
        conn.wbuf.clear();
        conn.wpos = 0;
    }
    // A peer that stops reading its replies must not grow server
    // memory without bound: drop the connection once the backlog of
    // encoded-but-unsent bytes exceeds the cap.
    if conn.wbuf.len() - conn.wpos > cfg.max_wbuf {
        conn.dead = true;
        return true;
    }

    if progress {
        conn.last_activity = Instant::now();
    } else if !conn.close_after_flush {
        // A frame header arrived but its body never followed: the
        // stream is stalled (trickling or wedged peer). Typed error,
        // then close — same containment as a corrupt stream.
        if let Some(t0) = conn.partial_since {
            if t0.elapsed() > cfg.read_timeout {
                stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                stats.error_frames.fetch_add(1, Ordering::Relaxed);
                conn.send(&Frame::Error(ErrorFrame::protocol(
                    0,
                    "read timed out mid-frame",
                )));
                conn.close_after_flush = true;
                conn.rbuf.clear();
                conn.partial_since = None;
                progress = true;
            }
        }
        // A connection holding no work and moving no bytes for the
        // idle budget is reclaimed silently — the peer walked away.
        if conn.pending.is_empty()
            && conn.wbuf.is_empty()
            && conn.rbuf.is_empty()
            && !conn.eof
            && conn.last_activity.elapsed() > cfg.idle_timeout
        {
            conn.dead = true;
            return true;
        }
    }

    let flushed = conn.wbuf.is_empty();
    if conn.close_after_flush && flushed {
        conn.dead = true;
    }
    if conn.eof && flushed && conn.pending.is_empty() {
        conn.dead = true;
    }
    progress
}

/// Route one parsed frame. Requests go through the registry into the
/// model's pool; anything else from a client is a protocol violation.
fn dispatch(
    conn: &mut Conn,
    frame: Frame,
    registry: &ModelRegistry,
    cfg: &ServerConfig,
    stats: &ServerStats,
    total: &mut usize,
) {
    let req = match frame {
        Frame::Request(r) => r,
        other => {
            stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
            stats.error_frames.fetch_add(1, Ordering::Relaxed);
            conn.send(&Frame::Error(ErrorFrame::protocol(
                other.id(),
                "clients may only send request frames",
            )));
            conn.close_after_flush = true;
            return;
        }
    };
    stats.requests.fetch_add(1, Ordering::Relaxed);
    // In-flight caps: refuse with a typed Overloaded frame instead of
    // queueing unboundedly. The connection stays healthy — overload
    // tells the caller to back off, it is not the caller's fault. The
    // retry hints are deliberately coarse: one idle-ish beat for a
    // per-connection bump, several for whole-server saturation.
    let over = if conn.pending.len() >= cfg.max_inflight_per_conn {
        Some(("connection", 500.0))
    } else if *total >= cfg.max_inflight_total {
        Some(("server", 2_000.0))
    } else {
        None
    };
    if let Some((scope, retry_after_us)) = over {
        stats.overloaded.fetch_add(1, Ordering::Relaxed);
        stats.error_frames.fetch_add(1, Ordering::Relaxed);
        let e = InferenceError::Overloaded { scope, retry_after_us };
        conn.send(&Frame::Error(ErrorFrame::from_error(req.id, &e)));
        return;
    }
    let entry = match registry.get_or_load(&req.model) {
        Ok(e) => e,
        Err(e) => {
            stats.error_frames.fetch_add(1, Ordering::Relaxed);
            conn.send(&Frame::Error(ErrorFrame::from_error(req.id, &e)));
            return;
        }
    };
    let mut opts = SubmitOptions::new().priority(req.priority);
    if let Some(us) = req.deadline_us {
        opts = opts.deadline(Deadline::within_us(us));
    }
    match entry.pool().submit_with(&req.payload, opts) {
        Ok(ticket) => {
            conn.pending.push((req.id, ticket));
            *total += 1;
        }
        Err(e) => {
            stats.error_frames.fetch_add(1, Ordering::Relaxed);
            conn.send(&Frame::Error(ErrorFrame::from_error(req.id, &e)));
        }
    }
}
