//! Nonblocking TCP front door over the model registry.
//!
//! One reactor thread owns every connection: it accepts, reads,
//! parses frames, routes requests into per-model [`Pool`]s through
//! the [`ModelRegistry`], polls outstanding [`Ticket`]s with
//! [`Ticket::try_wait`], and flushes replies — all without ever
//! blocking on a single request. Thread budget is **O(workers)**: the
//! reactor plus the pool workers, regardless of how many thousands of
//! requests are in flight. That is the property ROADMAP item 1 asks
//! for; a thread-per-request design melts exactly when an ICS
//! detection service is needed most (alarm storms).
//!
//! The loop is a minimal poll-style reactor on `std` only — no mio,
//! no epoll binding, no new dependencies. Every socket is
//! nonblocking; when a full pass makes no progress (no bytes moved,
//! no ticket completed, no connection accepted) the reactor sleeps
//! [`ServerConfig::idle_sleep`] before the next pass, trading a
//! bounded sliver of idle latency for zero busy-spin.
//!
//! Failure containment: a malformed or hostile stream gets a typed
//! [`ErrorCode::Protocol`](super::proto::ErrorCode::Protocol) error
//! frame and a close — it never panics the reactor, wedges the loop,
//! or affects other connections. Per-request failures (unknown model,
//! shed deadline, shape mismatch) travel back as error frames on a
//! healthy connection.
//!
//! [`Pool`]: crate::serve::Pool

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::serve::{Deadline, SubmitOptions, Ticket};

use super::proto::{
    self, Decoded, ErrorFrame, Frame, ResponseFrame, DEFAULT_MAX_FRAME,
};
use super::registry::ModelRegistry;

/// Reactor sizing and robustness knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Largest accepted frame body, in bytes
    /// ([`DEFAULT_MAX_FRAME`]). Bigger prefixes mark the stream
    /// corrupt.
    pub max_frame: usize,
    /// Max simultaneously-open connections; beyond this, new peers
    /// wait in the OS accept backlog.
    pub max_conns: usize,
    /// How long the reactor sleeps after a pass that made no
    /// progress.
    pub idle_sleep: Duration,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_frame: DEFAULT_MAX_FRAME,
            max_conns: 1024,
            idle_sleep: Duration::from_micros(200),
        }
    }
}

/// Monotonic counters the reactor publishes (all `Relaxed`; read
/// them for monitoring, not for synchronization).
#[derive(Default)]
pub struct ServerStats {
    accepted: AtomicU64,
    requests: AtomicU64,
    responses: AtomicU64,
    error_frames: AtomicU64,
    protocol_errors: AtomicU64,
}

impl ServerStats {
    /// Connections accepted since bind.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Request frames parsed.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Successful response frames sent.
    pub fn responses(&self) -> u64 {
        self.responses.load(Ordering::Relaxed)
    }

    /// Typed error frames sent (per-request failures *and* protocol
    /// errors).
    pub fn error_frames(&self) -> u64 {
        self.error_frames.load(Ordering::Relaxed)
    }

    /// Corrupt-stream events (each also closes its connection).
    pub fn protocol_errors(&self) -> u64 {
        self.protocol_errors.load(Ordering::Relaxed)
    }
}

/// Handle to a running network server. Dropping it stops the reactor
/// and joins its thread; in-flight pool work is abandoned (tickets
/// dropped), pool workers themselves are owned by the registry.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
    thread: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (use port 0 for an OS-assigned port) and start the
    /// reactor thread serving `registry`.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        registry: Arc<ModelRegistry>,
        cfg: ServerConfig,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let thread = {
            let stop = Arc::clone(&stop);
            let stats = Arc::clone(&stats);
            std::thread::Builder::new()
                .name("netserve-reactor".into())
                .spawn(move || {
                    reactor(listener, registry, cfg, stop, stats)
                })?
        };
        Ok(NetServer { addr, stop, stats, thread: Some(thread) })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The reactor's monitoring counters.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Stop the reactor and join its thread. (Dropping the server
    /// does the same; this just names the intent.)
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// One client connection's state, owned by the reactor.
struct Conn {
    stream: TcpStream,
    /// Unparsed inbound bytes.
    rbuf: Vec<u8>,
    /// Encoded outbound bytes not yet accepted by the socket.
    wbuf: Vec<u8>,
    /// How much of `wbuf` the socket has taken.
    wpos: usize,
    /// In-flight requests: (wire id, pool ticket).
    pending: Vec<(u64, Ticket)>,
    /// Peer half-closed its write side; serve what's pending, then
    /// close.
    eof: bool,
    /// Stream is corrupt: stop parsing, close once `wbuf` drains.
    close_after_flush: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            pending: Vec::new(),
            eof: false,
            close_after_flush: false,
            dead: false,
        }
    }

    fn send(&mut self, frame: &Frame) {
        frame.encode(&mut self.wbuf);
    }
}

fn reactor(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        let mut progress = false;
        while conns.len() < cfg.max_conns {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    stats.accepted.fetch_add(1, Ordering::Relaxed);
                    conns.push(Conn::new(stream));
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
        for conn in conns.iter_mut() {
            progress |= service(conn, &registry, &cfg, &stats);
        }
        conns.retain(|c| !c.dead);
        if !progress {
            std::thread::sleep(cfg.idle_sleep);
        }
    }
}

/// One nonblocking pass over a connection:
/// read → parse/dispatch → poll tickets → flush. Returns whether any
/// progress was made.
fn service(
    conn: &mut Conn,
    registry: &ModelRegistry,
    cfg: &ServerConfig,
    stats: &ServerStats,
) -> bool {
    let mut progress = false;

    // Read until the socket runs dry.
    if !conn.eof && !conn.close_after_flush {
        let mut buf = [0u8; 16384];
        loop {
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    conn.eof = true;
                    progress = true;
                    break;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&buf[..n]);
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return true;
                }
            }
        }
    }

    // Parse every complete frame buffered so far.
    let mut consumed = 0;
    while !conn.close_after_flush {
        match proto::decode(&conn.rbuf[consumed..], cfg.max_frame) {
            Decoded::Frame(frame, used) => {
                consumed += used;
                progress = true;
                dispatch(conn, frame, registry, stats);
            }
            Decoded::Incomplete => break,
            Decoded::Corrupt(msg) => {
                stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                stats.error_frames.fetch_add(1, Ordering::Relaxed);
                conn.send(&Frame::Error(ErrorFrame::protocol(0, msg)));
                conn.close_after_flush = true;
                conn.rbuf.clear();
                consumed = 0;
                progress = true;
                break;
            }
        }
    }
    if consumed > 0 {
        conn.rbuf.drain(..consumed);
    }

    // Complete whatever the pool has finished, without blocking.
    let mut i = 0;
    while i < conn.pending.len() {
        match conn.pending[i].1.try_wait() {
            Some(result) => {
                let (id, _) = conn.pending.swap_remove(i);
                progress = true;
                match result {
                    Ok(payload) => {
                        stats.responses.fetch_add(1, Ordering::Relaxed);
                        conn.send(&Frame::Response(ResponseFrame {
                            id,
                            payload,
                        }));
                    }
                    Err(e) => {
                        stats
                            .error_frames
                            .fetch_add(1, Ordering::Relaxed);
                        conn.send(&Frame::Error(ErrorFrame::from_error(
                            id, &e,
                        )));
                    }
                }
            }
            None => i += 1,
        }
    }

    // Flush until the socket pushes back.
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                return true;
            }
            Ok(n) => {
                conn.wpos += n;
                progress = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return true;
            }
        }
    }
    if conn.wpos == conn.wbuf.len() && conn.wpos > 0 {
        conn.wbuf.clear();
        conn.wpos = 0;
    }

    let flushed = conn.wbuf.is_empty();
    if conn.close_after_flush && flushed {
        conn.dead = true;
    }
    if conn.eof && flushed && conn.pending.is_empty() {
        conn.dead = true;
    }
    progress
}

/// Route one parsed frame. Requests go through the registry into the
/// model's pool; anything else from a client is a protocol violation.
fn dispatch(
    conn: &mut Conn,
    frame: Frame,
    registry: &ModelRegistry,
    stats: &ServerStats,
) {
    let req = match frame {
        Frame::Request(r) => r,
        other => {
            stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
            stats.error_frames.fetch_add(1, Ordering::Relaxed);
            conn.send(&Frame::Error(ErrorFrame::protocol(
                other.id(),
                "clients may only send request frames",
            )));
            conn.close_after_flush = true;
            return;
        }
    };
    stats.requests.fetch_add(1, Ordering::Relaxed);
    let entry = match registry.get_or_load(&req.model) {
        Ok(e) => e,
        Err(e) => {
            stats.error_frames.fetch_add(1, Ordering::Relaxed);
            conn.send(&Frame::Error(ErrorFrame::from_error(req.id, &e)));
            return;
        }
    };
    let mut opts = SubmitOptions::new().priority(req.priority);
    if let Some(us) = req.deadline_us {
        opts = opts.deadline(Deadline::within_us(us));
    }
    match entry.pool().submit_with(&req.payload, opts) {
        Ok(ticket) => conn.pending.push((req.id, ticket)),
        Err(e) => {
            stats.error_frames.fetch_add(1, Ordering::Relaxed);
            conn.send(&Frame::Error(ErrorFrame::from_error(req.id, &e)));
        }
    }
}
