//! Blocking client for the netserve wire protocol.
//!
//! The client is deliberately simple: a blocking `TcpStream`, an
//! incremental decode buffer, and three verbs — [`Client::submit`]
//! (fire a request, get its wire id back), [`Client::recv`] (block
//! for the next reply, whichever request it answers), and
//! [`Client::infer`] (submit + wait, the one-liner). Pipelining is
//! first-class: submit any number of requests before receiving, and
//! match replies to requests by id — the server answers in completion
//! order, not submission order.
//!
//! Flaky peers are survivable, not fatal: a client built with
//! [`Client::connect_with`] owns a [`RetryPolicy`] and the full
//! resolved address list. When the transport dies it reconnects under
//! jittered exponential backoff, cycling through the addresses
//! (failover). What *cannot* be recovered — replies to requests that
//! were in flight when the connection died — is surfaced honestly:
//! [`Client::recv_reconnecting`] returns a typed
//! [`InferenceError::ConnectionLost`] naming the lost wire ids, and
//! [`Client::infer`] (a self-contained, idempotent one-shot)
//! resubmits itself after the reconnect instead.

use std::io::{self, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::api::InferenceError;
use crate::serve::Priority;
use crate::util::rng::SplitMix64;

use super::proto::{
    decode, Decoded, ErrorFrame, Frame, RequestFrame, DEFAULT_MAX_FRAME,
};

/// Reconnect knobs for a [`Client`] that should survive transport
/// failures ([`Client::connect_with`]).
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Connection attempts per recovery (each attempt tries every
    /// resolved address before counting as failed). Clamped to ≥ 1.
    pub max_reconnects: usize,
    /// Delay after the first failed attempt; doubles per failure
    /// (capped), with up to 50% random jitter so a fleet of
    /// reconnecting clients never thunders in lockstep.
    pub backoff: Duration,
    /// Upper bound on the (pre-jitter) delay.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_reconnects: 5,
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// The default policy: 5 attempts, 10 ms → 500 ms backoff.
    pub fn new() -> RetryPolicy {
        RetryPolicy::default()
    }
}

/// Per-request options carried on the wire (the client-side mirror of
/// [`SubmitOptions`](crate::serve::SubmitOptions)).
#[derive(Debug, Clone, Copy)]
pub struct NetOptions {
    /// Priority class the server schedules the request in.
    pub priority: Priority,
    /// Deadline budget in microseconds from submission, if any. The
    /// server converts it to an absolute deadline on receipt;
    /// expired requests are shed with
    /// [`InferenceError::DeadlineExceeded`], never answered late.
    pub deadline_us: Option<f64>,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions { priority: Priority::Batch, deadline_us: None }
    }
}

impl NetOptions {
    /// Batch priority, no deadline.
    pub fn new() -> NetOptions {
        NetOptions::default()
    }

    /// Set the priority class.
    pub fn priority(mut self, p: Priority) -> NetOptions {
        self.priority = p;
        self
    }

    /// Set the deadline budget, in microseconds from submission.
    pub fn deadline_us(mut self, us: f64) -> NetOptions {
        self.deadline_us = Some(us);
        self
    }
}

/// One reply off the wire, matched to its request by `id`.
#[derive(Debug)]
pub struct NetReply {
    /// The wire id of the request this answers.
    pub id: u64,
    /// The model output, or the server's typed error frame.
    pub result: Result<Vec<f32>, ErrorFrame>,
}

/// Blocking connection to a [`NetServer`](super::NetServer).
pub struct Client {
    stream: TcpStream,
    rbuf: Vec<u8>,
    next_id: u64,
    /// Every address the connect string resolved to — the failover
    /// list reconnects cycle through.
    addrs: Vec<SocketAddr>,
    /// Mirror of the socket's read timeout, reapplied on reconnect.
    timeout: Option<Duration>,
    policy: Option<RetryPolicy>,
    /// Wire ids submitted but not yet answered — the casualties a
    /// dead connection is reported with.
    pending_ids: Vec<u64>,
    /// Backoff jitter stream (deterministic seed: reproducible tests,
    /// and two clients still diverge after their first backoff).
    rng: SplitMix64,
}

/// Try each resolved address in order; first success wins.
fn connect_any(addrs: &[SocketAddr]) -> io::Result<TcpStream> {
    let mut last: Option<io::Error> = None;
    for addr in addrs {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true)?;
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        io::Error::new(
            ErrorKind::InvalidInput,
            "connect string resolved to no addresses",
        )
    }))
}

/// The error kinds that mean "the transport is gone" (reconnectable),
/// as opposed to timeouts or decode problems (the connection is still
/// standing).
fn is_disconnect(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        ErrorKind::UnexpectedEof
            | ErrorKind::ConnectionReset
            | ErrorKind::ConnectionAborted
            | ErrorKind::ConnectionRefused
            | ErrorKind::BrokenPipe
            | ErrorKind::NotConnected
    )
}

impl Client {
    /// Connect to a server. A connect string that resolves to several
    /// addresses doubles as a failover list for
    /// [`Client::connect_with`]'s reconnect machinery.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let addrs: Vec<SocketAddr> = addr.to_socket_addrs()?.collect();
        let stream = connect_any(&addrs)?;
        Ok(Client {
            stream,
            rbuf: Vec::new(),
            next_id: 0,
            addrs,
            timeout: None,
            policy: None,
            pending_ids: Vec::new(),
            rng: SplitMix64::new(0xc11e_27_5eed),
        })
    }

    /// Like [`Client::connect`], with a [`RetryPolicy`]: when the
    /// transport later dies, the client reconnects (cycling the
    /// resolved addresses under jittered exponential backoff) instead
    /// of staying dead — see [`Client::recv_reconnecting`] and
    /// [`Client::infer`].
    pub fn connect_with<A: ToSocketAddrs>(
        addr: A,
        policy: RetryPolicy,
    ) -> io::Result<Client> {
        let mut c = Client::connect(addr)?;
        c.policy = Some(policy);
        Ok(c)
    }

    /// Bound how long [`Client::recv`] blocks (`None` = forever). A
    /// timed-out `recv` returns the underlying io error; the
    /// connection stays usable. The bound survives reconnects.
    pub fn set_timeout(
        &mut self,
        timeout: Option<Duration>,
    ) -> io::Result<()> {
        self.timeout = timeout;
        self.stream.set_read_timeout(timeout)
    }

    /// A second handle over the same connection, with its own decode
    /// buffer. Intended for the split sender/receiver shape (one
    /// thread submits, another receives): exactly **one** handle may
    /// call [`Client::recv`], and exactly one may call
    /// [`Client::submit`] — two readers would tear frames apart, and
    /// two writers would interleave ids. The clone does **not** carry
    /// the [`RetryPolicy`]: two handles reconnecting the same logical
    /// client independently would race; recovery belongs to the
    /// original.
    pub fn try_clone(&self) -> io::Result<Client> {
        Ok(Client {
            stream: self.stream.try_clone()?,
            rbuf: Vec::new(),
            next_id: self.next_id,
            addrs: self.addrs.clone(),
            timeout: self.timeout,
            policy: None,
            pending_ids: Vec::new(),
            rng: SplitMix64::new(0xc11e_27_5eed ^ self.next_id),
        })
    }

    /// Wire ids submitted on this handle that have not been answered
    /// yet (what [`InferenceError::ConnectionLost`] would report if
    /// the transport died now).
    pub fn pending_ids(&self) -> &[u64] {
        &self.pending_ids
    }

    /// Tear down and re-establish the transport under the configured
    /// [`RetryPolicy`], cycling through every resolved address with
    /// jittered exponential backoff between attempts. The decode
    /// buffer is reset; in-flight ids stay in [`Client::pending_ids`]
    /// for the caller (or [`Client::recv_reconnecting`]) to account
    /// for. Errors when no policy is configured or every attempt
    /// failed.
    pub fn reconnect(&mut self) -> io::Result<()> {
        let policy = self.policy.clone().ok_or_else(|| {
            io::Error::new(
                ErrorKind::NotConnected,
                "connection lost and no retry policy configured",
            )
        })?;
        let mut delay = policy.backoff;
        let mut last: Option<io::Error> = None;
        for attempt in 0..policy.max_reconnects.max(1) {
            if attempt > 0 {
                let jitter = Duration::from_secs_f64(
                    delay.as_secs_f64() * 0.5 * self.rng.next_f64(),
                );
                std::thread::sleep(delay + jitter);
                delay = (delay * 2).min(policy.max_backoff);
            }
            match connect_any(&self.addrs) {
                Ok(s) => {
                    s.set_read_timeout(self.timeout)?;
                    self.stream = s;
                    self.rbuf.clear();
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            io::Error::new(ErrorKind::NotConnected, "reconnect failed")
        }))
    }

    /// Send one request and return the wire id its reply will carry.
    /// Does not wait for the reply — pipeline as many as you like.
    pub fn submit(
        &mut self,
        model: &str,
        x: &[f32],
        opts: &NetOptions,
    ) -> io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let mut wire = Vec::with_capacity(64 + 4 * x.len());
        Frame::Request(RequestFrame {
            id,
            priority: opts.priority,
            deadline_us: opts.deadline_us,
            model: model.to_string(),
            payload: x.to_vec(),
        })
        .encode(&mut wire);
        self.stream.write_all(&wire)?;
        self.pending_ids.push(id);
        Ok(id)
    }

    /// Block for the next reply (success or typed error), in server
    /// completion order.
    pub fn recv(&mut self) -> io::Result<NetReply> {
        loop {
            match decode(&self.rbuf, DEFAULT_MAX_FRAME) {
                Decoded::Frame(frame, used) => {
                    self.rbuf.drain(..used);
                    let reply = match frame {
                        Frame::Response(r) => NetReply {
                            id: r.id,
                            result: Ok(r.payload),
                        },
                        Frame::Error(e) => NetReply {
                            id: e.id,
                            result: Err(e),
                        },
                        Frame::Request(_) => {
                            return Err(io::Error::new(
                                io::ErrorKind::InvalidData,
                                "server sent a request frame",
                            ))
                        }
                    };
                    self.pending_ids.retain(|&p| p != reply.id);
                    return Ok(reply);
                }
                Decoded::Incomplete => {
                    let mut buf = [0u8; 16384];
                    let n = self.stream.read(&mut buf)?;
                    if n == 0 {
                        return Err(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            "server closed the connection",
                        ));
                    }
                    self.rbuf.extend_from_slice(&buf[..n]);
                }
                Decoded::Corrupt(msg) => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        msg,
                    ));
                }
            }
        }
    }

    /// Like [`Client::recv`], but a dead transport is survived: the
    /// client reconnects under its [`RetryPolicy`] and the call
    /// returns a typed [`InferenceError::ConnectionLost`] naming the
    /// wire ids whose replies died with the old connection — the
    /// server answers over the connection a request arrived on, so
    /// those replies are unrecoverable and a robust caller must
    /// decide which to resubmit. After the error the client is
    /// connected again and *subsequent* traffic flows normally.
    /// Timeouts and decode errors pass through untouched (the
    /// connection is still standing); with no policy configured the
    /// io error is surfaced as `BackendUnavailable`, exactly like
    /// [`Client::recv`] callers would.
    pub fn recv_reconnecting(&mut self) -> Result<NetReply, InferenceError> {
        match self.recv() {
            Ok(r) => Ok(r),
            Err(e) if is_disconnect(&e) && self.policy.is_some() => {
                let reason = e.to_string();
                let lost_ids = std::mem::take(&mut self.pending_ids);
                self.reconnect().map_err(io_unavailable)?;
                Err(InferenceError::ConnectionLost { lost_ids, reason })
            }
            Err(e) => Err(io_unavailable(e)),
        }
    }

    /// Blocking convenience: submit one request and wait for *its*
    /// reply, reconstructing the typed error on failure. Replies to
    /// other pipelined requests that arrive first are discarded — use
    /// [`Client::submit`]/[`Client::recv`] directly when pipelining.
    ///
    /// Under a [`RetryPolicy`], a transport death is survived by
    /// reconnecting and *resubmitting* — a one-shot infer is
    /// idempotent, so retrying it is always safe. Requests pipelined
    /// via [`Client::submit`] that were still in flight are dropped
    /// without a report here; don't mix manual pipelining with
    /// `infer` across failures — pipeline with
    /// [`Client::recv_reconnecting`], which accounts for every id.
    pub fn infer(
        &mut self,
        model: &str,
        x: &[f32],
        opts: &NetOptions,
    ) -> Result<Vec<f32>, InferenceError> {
        let mut reconnects_left =
            self.policy.as_ref().map_or(0, |p| p.max_reconnects.max(1));
        'attempt: loop {
            let id = match self.submit(model, x, opts) {
                Ok(id) => id,
                Err(e) if reconnects_left > 0 && is_disconnect(&e) => {
                    reconnects_left -= 1;
                    self.pending_ids.clear();
                    self.reconnect().map_err(io_unavailable)?;
                    continue 'attempt;
                }
                Err(e) => return Err(io_unavailable(e)),
            };
            loop {
                let reply = match self.recv() {
                    Ok(r) => r,
                    Err(e)
                        if reconnects_left > 0 && is_disconnect(&e) =>
                    {
                        reconnects_left -= 1;
                        self.pending_ids.clear();
                        self.reconnect().map_err(io_unavailable)?;
                        continue 'attempt; // resubmit the one-shot
                    }
                    Err(e) => return Err(io_unavailable(e)),
                };
                if reply.id != id {
                    continue;
                }
                return match reply.result {
                    Ok(y) => Ok(y),
                    Err(e) => Err(e.to_error()),
                };
            }
        }
    }
}

fn io_unavailable(e: io::Error) -> InferenceError {
    InferenceError::BackendUnavailable {
        backend: "netserve".into(),
        reason: e.to_string(),
    }
}
