//! Blocking client for the netserve wire protocol.
//!
//! The client is deliberately simple: a blocking `TcpStream`, an
//! incremental decode buffer, and three verbs — [`Client::submit`]
//! (fire a request, get its wire id back), [`Client::recv`] (block
//! for the next reply, whichever request it answers), and
//! [`Client::infer`] (submit + wait, the one-liner). Pipelining is
//! first-class: submit any number of requests before receiving, and
//! match replies to requests by id — the server answers in completion
//! order, not submission order.

use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::api::InferenceError;
use crate::serve::Priority;

use super::proto::{
    decode, Decoded, ErrorFrame, Frame, RequestFrame, DEFAULT_MAX_FRAME,
};

/// Per-request options carried on the wire (the client-side mirror of
/// [`SubmitOptions`](crate::serve::SubmitOptions)).
#[derive(Debug, Clone, Copy)]
pub struct NetOptions {
    /// Priority class the server schedules the request in.
    pub priority: Priority,
    /// Deadline budget in microseconds from submission, if any. The
    /// server converts it to an absolute deadline on receipt;
    /// expired requests are shed with
    /// [`InferenceError::DeadlineExceeded`], never answered late.
    pub deadline_us: Option<f64>,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions { priority: Priority::Batch, deadline_us: None }
    }
}

impl NetOptions {
    /// Batch priority, no deadline.
    pub fn new() -> NetOptions {
        NetOptions::default()
    }

    /// Set the priority class.
    pub fn priority(mut self, p: Priority) -> NetOptions {
        self.priority = p;
        self
    }

    /// Set the deadline budget, in microseconds from submission.
    pub fn deadline_us(mut self, us: f64) -> NetOptions {
        self.deadline_us = Some(us);
        self
    }
}

/// One reply off the wire, matched to its request by `id`.
#[derive(Debug)]
pub struct NetReply {
    /// The wire id of the request this answers.
    pub id: u64,
    /// The model output, or the server's typed error frame.
    pub result: Result<Vec<f32>, ErrorFrame>,
}

/// Blocking connection to a [`NetServer`](super::NetServer).
pub struct Client {
    stream: TcpStream,
    rbuf: Vec<u8>,
    next_id: u64,
}

impl Client {
    /// Connect to a server.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, rbuf: Vec::new(), next_id: 0 })
    }

    /// Bound how long [`Client::recv`] blocks (`None` = forever). A
    /// timed-out `recv` returns the underlying io error; the
    /// connection stays usable.
    pub fn set_timeout(
        &mut self,
        timeout: Option<Duration>,
    ) -> io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }

    /// A second handle over the same connection, with its own decode
    /// buffer. Intended for the split sender/receiver shape (one
    /// thread submits, another receives): exactly **one** handle may
    /// call [`Client::recv`], and exactly one may call
    /// [`Client::submit`] — two readers would tear frames apart, and
    /// two writers would interleave ids.
    pub fn try_clone(&self) -> io::Result<Client> {
        Ok(Client {
            stream: self.stream.try_clone()?,
            rbuf: Vec::new(),
            next_id: self.next_id,
        })
    }

    /// Send one request and return the wire id its reply will carry.
    /// Does not wait for the reply — pipeline as many as you like.
    pub fn submit(
        &mut self,
        model: &str,
        x: &[f32],
        opts: &NetOptions,
    ) -> io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let mut wire = Vec::with_capacity(64 + 4 * x.len());
        Frame::Request(RequestFrame {
            id,
            priority: opts.priority,
            deadline_us: opts.deadline_us,
            model: model.to_string(),
            payload: x.to_vec(),
        })
        .encode(&mut wire);
        self.stream.write_all(&wire)?;
        Ok(id)
    }

    /// Block for the next reply (success or typed error), in server
    /// completion order.
    pub fn recv(&mut self) -> io::Result<NetReply> {
        loop {
            match decode(&self.rbuf, DEFAULT_MAX_FRAME) {
                Decoded::Frame(frame, used) => {
                    self.rbuf.drain(..used);
                    return match frame {
                        Frame::Response(r) => Ok(NetReply {
                            id: r.id,
                            result: Ok(r.payload),
                        }),
                        Frame::Error(e) => Ok(NetReply {
                            id: e.id,
                            result: Err(e),
                        }),
                        Frame::Request(_) => Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "server sent a request frame",
                        )),
                    };
                }
                Decoded::Incomplete => {
                    let mut buf = [0u8; 16384];
                    let n = self.stream.read(&mut buf)?;
                    if n == 0 {
                        return Err(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            "server closed the connection",
                        ));
                    }
                    self.rbuf.extend_from_slice(&buf[..n]);
                }
                Decoded::Corrupt(msg) => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        msg,
                    ));
                }
            }
        }
    }

    /// Blocking convenience: submit one request and wait for *its*
    /// reply, reconstructing the typed error on failure. Replies to
    /// other pipelined requests that arrive first are discarded — use
    /// [`Client::submit`]/[`Client::recv`] directly when pipelining.
    pub fn infer(
        &mut self,
        model: &str,
        x: &[f32],
        opts: &NetOptions,
    ) -> Result<Vec<f32>, InferenceError> {
        let id = self.submit(model, x, opts).map_err(io_unavailable)?;
        loop {
            let reply = self.recv().map_err(io_unavailable)?;
            if reply.id != id {
                continue;
            }
            return match reply.result {
                Ok(y) => Ok(y),
                Err(e) => Err(e.to_error()),
            };
        }
    }
}

fn io_unavailable(e: io::Error) -> InferenceError {
    InferenceError::BackendUnavailable {
        backend: "netserve".into(),
        reason: e.to_string(),
    }
}
