//! Lazy multi-model registry: named engines behind one server.
//!
//! The realistic ICS deployment shape is one detection service
//! fronting *many* models — per-plant, per-PLC-class, per-sensor —
//! far more than fit in memory at once on an edge box. The
//! [`ModelRegistry`] owns that working set: it loads a named engine
//! on first use (through a pluggable [`ModelLoader`]), wraps it in
//! its own [`Pool`] of workers, caches the result behind an `Arc`,
//! and evicts least-recently-used entries when a configurable
//! engine-count or byte budget is exceeded.
//!
//! Concurrency contract:
//!
//! * `get_or_load` for an already-resident model is a short
//!   mutex-protected map hit.
//! * A cold load runs *outside* the registry lock; concurrent callers
//!   asking for the same name park on a condvar and share the single
//!   load (the loader is invoked exactly once per residency).
//! * Eviction only drops the registry's own `Arc`. In-flight requests
//!   holding a [`ModelEntry`] keep its pool alive until they finish;
//!   the worker threads of an evicted pool are joined by whichever
//!   thread drops the last reference, never under the registry lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::api::{EngineBackend, InferenceError, SharedBackend};
use crate::porting::load_engine_model;
use crate::porting::manifest::ManifestSet;
use crate::serve::{Pool, PoolConfig};
use crate::util::lock::{lock_recover, wait_recover};

/// A backend produced by a [`ModelLoader`], plus its residency cost.
#[derive(Clone)]
pub struct LoadedModel {
    /// The engine, ready to serve.
    pub backend: SharedBackend,
    /// Bytes this model holds resident (weights + activations); the
    /// unit the registry's byte budget is charged in.
    pub bytes: u64,
}

/// Source of named models for a [`ModelRegistry`].
///
/// `load` may be slow (disk reads, weight parsing); the registry
/// guarantees it is called outside the registry lock and at most once
/// per residency of a given name.
pub trait ModelLoader: Send + Sync {
    /// Produce the backend for `name`, or a typed error —
    /// [`InferenceError::ModelNotFound`] when no such model exists.
    fn load(&self, name: &str) -> Result<LoadedModel, InferenceError>;

    /// Every name this loader can produce (sorted, for display).
    fn names(&self) -> Vec<String>;
}

/// In-memory [`ModelLoader`] over pre-built backends — the fixture
/// loader used by tests and benches, and the simplest way to serve
/// models that never touch disk.
#[derive(Default)]
pub struct StaticLoader {
    models: HashMap<String, LoadedModel>,
    loads: AtomicU64,
}

impl StaticLoader {
    /// An empty loader; add models with [`StaticLoader::insert`].
    pub fn new() -> StaticLoader {
        StaticLoader::default()
    }

    /// Register `backend` under `name`, charging `bytes` against the
    /// registry budget.
    pub fn insert(
        &mut self,
        name: impl Into<String>,
        backend: SharedBackend,
        bytes: u64,
    ) {
        self.models
            .insert(name.into(), LoadedModel { backend, bytes });
    }

    /// How many times `load` has succeeded — lets tests assert the
    /// registry's load-exactly-once contract.
    pub fn loads(&self) -> u64 {
        self.loads.load(Ordering::Relaxed)
    }
}

impl ModelLoader for StaticLoader {
    fn load(&self, name: &str) -> Result<LoadedModel, InferenceError> {
        match self.models.get(name) {
            Some(m) => {
                self.loads.fetch_add(1, Ordering::Relaxed);
                Ok(m.clone())
            }
            None => Err(InferenceError::ModelNotFound {
                model: name.to_string(),
            }),
        }
    }

    fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

/// [`ModelLoader`] over exported artifact manifests: resolves a name
/// through a [`ManifestSet`] (first root wins), reads the weights
/// from disk, and builds a native [`EngineBackend`].
pub struct ManifestLoader {
    set: ManifestSet,
}

impl ManifestLoader {
    /// Serve every model the manifest roots export.
    pub fn new(set: ManifestSet) -> ManifestLoader {
        ManifestLoader { set }
    }

    /// Residency estimate for a manifest model: weights + biases as
    /// f32s. Deliberately ignores the per-session activation scratch,
    /// which is bounded and small next to the weights.
    fn estimate_bytes(spec: &crate::porting::manifest::ModelSpec) -> u64 {
        spec.layers
            .iter()
            .map(|l| 4 * (l.inputs as u64 * l.neurons as u64 + l.neurons as u64))
            .sum()
    }
}

impl ModelLoader for ManifestLoader {
    fn load(&self, name: &str) -> Result<LoadedModel, InferenceError> {
        let (manifest, spec) = self.set.model(name).map_err(|_| {
            InferenceError::ModelNotFound {
                model: name.to_string(),
            }
        })?;
        let model = load_engine_model(&manifest.root, spec).map_err(
            |e| InferenceError::BackendUnavailable {
                backend: "registry".into(),
                reason: format!("loading {name}: {e:#}"),
            },
        )?;
        Ok(LoadedModel {
            backend: Arc::new(EngineBackend::new(model)),
            bytes: ManifestLoader::estimate_bytes(spec),
        })
    }

    fn names(&self) -> Vec<String> {
        self.set.names()
    }
}

/// Registry sizing knobs.
#[derive(Debug, Clone)]
pub struct RegistryConfig {
    /// Max resident models; the LRU entry is evicted beyond this.
    pub max_models: usize,
    /// Max total resident bytes across models (as charged by the
    /// loader); LRU entries are evicted until the new model fits.
    pub max_bytes: u64,
    /// Pool sizing applied to every per-model worker pool.
    pub pool: PoolConfig,
}

impl Default for RegistryConfig {
    fn default() -> RegistryConfig {
        RegistryConfig {
            max_models: usize::MAX,
            max_bytes: u64::MAX,
            pool: PoolConfig { workers: 2, max_batch: 8 },
        }
    }
}

/// A resident model: its serving pool plus bookkeeping. Handed out as
/// `Arc<ModelEntry>` so eviction can never yank a pool out from under
/// an in-flight request.
pub struct ModelEntry {
    name: String,
    pool: Pool,
    bytes: u64,
}

impl ModelEntry {
    /// Registry name this entry serves.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The model's worker pool; submit requests here.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// Bytes charged against the registry budget.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

enum Slot {
    /// Another thread is running the loader; park on the condvar.
    Loading,
    /// Resident. `last_used` is the registry tick of the most recent
    /// `get_or_load` hit — the LRU ordering key.
    Ready { entry: Arc<ModelEntry>, last_used: u64 },
}

struct Inner {
    slots: HashMap<String, Slot>,
    tick: u64,
    resident_bytes: u64,
}

/// Lazily-loading, LRU-evicting cache of named model pools.
pub struct ModelRegistry {
    loader: Box<dyn ModelLoader>,
    cfg: RegistryConfig,
    inner: Mutex<Inner>,
    cv: Condvar,
    loads: AtomicU64,
    evictions: AtomicU64,
}

impl ModelRegistry {
    /// A registry over `loader` with the given budgets.
    pub fn new(
        loader: Box<dyn ModelLoader>,
        cfg: RegistryConfig,
    ) -> ModelRegistry {
        ModelRegistry {
            loader,
            cfg,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
            }),
            cv: Condvar::new(),
            loads: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The resident entry for `name`, loading it first if necessary.
    ///
    /// Concurrent calls for the same cold name share one load. Errors
    /// are typed: [`InferenceError::ModelNotFound`] for unknown names,
    /// [`InferenceError::Evicted`] when the model alone exceeds the
    /// whole byte budget, loader failures as reported.
    pub fn get_or_load(
        &self,
        name: &str,
    ) -> Result<Arc<ModelEntry>, InferenceError> {
        let mut inner = lock_recover(&self.inner);
        loop {
            match inner.slots.get(name) {
                Some(Slot::Loading) => {
                    inner = wait_recover(&self.cv, inner);
                }
                Some(Slot::Ready { .. }) => {
                    inner.tick += 1;
                    let tick = inner.tick;
                    if let Some(Slot::Ready { entry, last_used }) =
                        inner.slots.get_mut(name)
                    {
                        *last_used = tick;
                        return Ok(Arc::clone(entry));
                    }
                }
                None => break,
            }
        }

        // Claim the load and run it without the lock.
        inner.slots.insert(name.to_string(), Slot::Loading);
        drop(inner);
        let loaded = self.loader.load(name);

        let mut inner = lock_recover(&self.inner);
        let loaded = match loaded {
            Ok(l) => l,
            Err(e) => {
                inner.slots.remove(name);
                self.cv.notify_all();
                return Err(e);
            }
        };
        if loaded.bytes > self.cfg.max_bytes {
            inner.slots.remove(name);
            self.cv.notify_all();
            return Err(InferenceError::Evicted {
                model: name.to_string(),
            });
        }

        // Evict LRU entries until the newcomer fits both budgets.
        // Collect the dropped Arcs and release them *after* the lock:
        // dropping the last reference joins the pool's workers.
        let mut dropped: Vec<Arc<ModelEntry>> = Vec::new();
        loop {
            let ready = inner
                .slots
                .values()
                .filter(|s| matches!(s, Slot::Ready { .. }))
                .count();
            let over_count = ready + 1 > self.cfg.max_models;
            let over_bytes = ready > 0
                && inner.resident_bytes + loaded.bytes
                    > self.cfg.max_bytes;
            if !over_count && !over_bytes {
                break;
            }
            let victim = inner
                .slots
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready { last_used, .. } => {
                        Some((*last_used, k.clone()))
                    }
                    Slot::Loading => None,
                })
                .min()
                .map(|(_, k)| k);
            let Some(victim) = victim else { break };
            if let Some(Slot::Ready { entry, .. }) =
                inner.slots.remove(&victim)
            {
                inner.resident_bytes =
                    inner.resident_bytes.saturating_sub(entry.bytes);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                dropped.push(entry);
            }
        }

        self.loads.fetch_add(1, Ordering::Relaxed);
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            pool: Pool::new(loaded.backend, self.cfg.pool.clone()),
            bytes: loaded.bytes,
        });
        inner.tick += 1;
        let tick = inner.tick;
        inner.resident_bytes += loaded.bytes;
        inner.slots.insert(
            name.to_string(),
            Slot::Ready { entry: Arc::clone(&entry), last_used: tick },
        );
        self.cv.notify_all();
        drop(inner);
        drop(dropped);
        Ok(entry)
    }

    /// Models currently resident.
    pub fn resident(&self) -> usize {
        lock_recover(&self.inner)
            .slots
            .values()
            .filter(|s| matches!(s, Slot::Ready { .. }))
            .count()
    }

    /// Bytes currently charged against the byte budget.
    pub fn resident_bytes(&self) -> u64 {
        lock_recover(&self.inner).resident_bytes
    }

    /// Successful loads since construction.
    pub fn loads(&self) -> u64 {
        self.loads.load(Ordering::Relaxed)
    }

    /// Entries evicted since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Every name the underlying loader can serve.
    pub fn names(&self) -> Vec<String> {
        self.loader.names()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, ModelSpec, Session};
    use crate::util::fixtures;

    fn fixture_loader(names: &[(&str, u64)]) -> StaticLoader {
        let mut l = StaticLoader::new();
        for (i, (name, bytes)) in names.iter().enumerate() {
            let backend: SharedBackend = Arc::new(EngineBackend::new(
                fixtures::mlp_8_16_4(1 + i as u64),
            ));
            l.insert(*name, backend, *bytes);
        }
        l
    }

    fn registry(
        loader: StaticLoader,
        max_models: usize,
        max_bytes: u64,
    ) -> ModelRegistry {
        ModelRegistry::new(
            Box::new(loader),
            RegistryConfig {
                max_models,
                max_bytes,
                pool: PoolConfig { workers: 1, max_batch: 4 },
            },
        )
    }

    #[test]
    fn lru_eviction_respects_touch_order() {
        let reg = registry(
            fixture_loader(&[("a", 1), ("b", 1), ("c", 1)]),
            2,
            u64::MAX,
        );
        reg.get_or_load("a").unwrap();
        reg.get_or_load("b").unwrap();
        reg.get_or_load("a").unwrap(); // touch: b is now LRU
        reg.get_or_load("c").unwrap(); // evicts b, not a
        assert_eq!(reg.resident(), 2);
        assert_eq!(reg.evictions(), 1);
        // a and c are hot: hitting them must not reload.
        let before = reg.loads();
        reg.get_or_load("a").unwrap();
        reg.get_or_load("c").unwrap();
        assert_eq!(reg.loads(), before);
        // b was evicted: hitting it reloads.
        reg.get_or_load("b").unwrap();
        assert_eq!(reg.loads(), before + 1);
    }

    #[test]
    fn byte_budget_evicts_until_the_newcomer_fits() {
        let reg = registry(
            fixture_loader(&[("a", 40), ("b", 40), ("c", 40)]),
            usize::MAX,
            100,
        );
        reg.get_or_load("a").unwrap();
        reg.get_or_load("b").unwrap();
        assert_eq!(reg.resident_bytes(), 80);
        reg.get_or_load("c").unwrap(); // 80 + 40 > 100: evicts a
        assert_eq!(reg.resident(), 2);
        assert_eq!(reg.resident_bytes(), 80);
        assert_eq!(reg.evictions(), 1);
    }

    #[test]
    fn model_larger_than_whole_budget_is_a_typed_evicted_error() {
        let reg =
            registry(fixture_loader(&[("big", 1000)]), usize::MAX, 100);
        match reg.get_or_load("big") {
            Err(InferenceError::Evicted { model }) => {
                assert_eq!(model, "big");
            }
            other => panic!("expected Evicted, got {other:?}"),
        }
        // The failed load must not leave a wedged Loading slot.
        assert_eq!(reg.resident(), 0);
        assert!(matches!(
            reg.get_or_load("big"),
            Err(InferenceError::Evicted { .. })
        ));
    }

    #[test]
    fn unknown_model_is_model_not_found() {
        let reg =
            registry(fixture_loader(&[("a", 1)]), usize::MAX, u64::MAX);
        match reg.get_or_load("ghost") {
            Err(InferenceError::ModelNotFound { model }) => {
                assert_eq!(model, "ghost");
            }
            other => panic!("expected ModelNotFound, got {other:?}"),
        }
        // And the name is retryable (no stuck Loading slot).
        assert!(reg.get_or_load("ghost").is_err());
        assert!(reg.get_or_load("a").is_ok());
    }

    #[test]
    fn concurrent_get_or_load_loads_exactly_once() {
        let reg = Arc::new(registry(
            fixture_loader(&[("m", 1)]),
            usize::MAX,
            u64::MAX,
        ));
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let reg = Arc::clone(&reg);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    let entry = reg.get_or_load("m").unwrap();
                    entry.pool().infer(&[0.0; 8]).unwrap()
                })
            })
            .collect();
        let outputs: Vec<Vec<f32>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(reg.loads(), 1, "8 racers share a single load");
        for o in &outputs {
            assert_eq!(o, &outputs[0], "same model, same answer");
        }
    }

    #[test]
    fn eviction_does_not_break_inflight_holders() {
        let reg = registry(
            fixture_loader(&[("a", 1), ("b", 1)]),
            1,
            u64::MAX,
        );
        let held = reg.get_or_load("a").unwrap();
        reg.get_or_load("b").unwrap(); // evicts a from the registry
        assert_eq!(reg.evictions(), 1);
        // The held Arc keeps a's pool fully serviceable.
        let y = held.pool().infer(&[0.5; 8]).unwrap();
        assert_eq!(y.len(), 4);
        assert_eq!(held.name(), "a");
        assert_eq!(held.bytes(), 1);
    }

    /// Backend wrapper whose drop is observable — lets the churn test
    /// prove each residency's pool is torn down exactly once, and
    /// never while a holder still uses it.
    struct DropCounting {
        inner: EngineBackend,
        drops: Arc<AtomicU64>,
    }

    impl Backend for DropCounting {
        fn name(&self) -> &'static str {
            "dropcount"
        }
        fn spec(&self) -> ModelSpec {
            self.inner.spec()
        }
        fn session(
            &self,
        ) -> Result<Box<dyn Session>, InferenceError> {
            self.inner.session()
        }
    }

    impl Drop for DropCounting {
        fn drop(&mut self) {
            self.drops.fetch_add(1, Ordering::Relaxed);
        }
    }

    struct ChurnLoader {
        loads: Arc<AtomicU64>,
        drops: Arc<AtomicU64>,
    }

    impl ModelLoader for ChurnLoader {
        fn load(&self, name: &str) -> Result<LoadedModel, InferenceError> {
            let seed = match name {
                "a" => 1,
                "b" => 2,
                _ => {
                    return Err(InferenceError::ModelNotFound {
                        model: name.to_string(),
                    })
                }
            };
            self.loads.fetch_add(1, Ordering::Relaxed);
            Ok(LoadedModel {
                backend: Arc::new(DropCounting {
                    inner: EngineBackend::new(fixtures::mlp_8_16_4(seed)),
                    drops: Arc::clone(&self.drops),
                }),
                // 60 bytes each under a 100-byte budget: "a" and "b"
                // can never be resident together, so every alternation
                // forces an eviction.
                bytes: 60,
            })
        }

        fn names(&self) -> Vec<String> {
            vec!["a".into(), "b".into()]
        }
    }

    #[test]
    fn concurrent_get_and_evict_under_byte_pressure() {
        let loads = Arc::new(AtomicU64::new(0));
        let drops = Arc::new(AtomicU64::new(0));
        let reg = Arc::new(ModelRegistry::new(
            Box::new(ChurnLoader {
                loads: Arc::clone(&loads),
                drops: Arc::clone(&drops),
            }),
            RegistryConfig {
                max_models: usize::MAX,
                max_bytes: 100,
                pool: PoolConfig { workers: 1, max_batch: 4 },
            },
        ));
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let reg = Arc::clone(&reg);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    for i in 0..25u64 {
                        // Two threads per model, phase-shifted: gets
                        // and evictions of the same names race
                        // constantly.
                        let name =
                            if (t + i) % 2 == 0 { "a" } else { "b" };
                        let entry = reg.get_or_load(name).unwrap();
                        // The held Arc must stay serviceable even if
                        // another thread evicts this entry right now.
                        let y =
                            entry.pool().infer(&[0.25; 8]).unwrap();
                        assert_eq!(y.len(), 4);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("no churn thread may panic or deadlock");
        }
        assert!(
            reg.evictions() >= 1,
            "the byte budget forced eviction churn"
        );
        assert!(reg.resident_bytes() <= 100, "budget never overshot");
        // Every residency allocated exactly one backend; evicted ones
        // are already dropped, the survivor goes with the registry.
        // drops == loads proves each pool tore down exactly once and
        // nothing leaked or double-freed.
        let total_loads = loads.load(Ordering::Relaxed);
        drop(reg);
        assert_eq!(drops.load(Ordering::Relaxed), total_loads);
    }
}
