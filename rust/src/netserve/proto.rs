//! Length-prefixed binary wire protocol for the network front door.
//!
//! Every frame on the wire is a `u32` little-endian length prefix
//! (counting the *body only*, not the prefix itself) followed by the
//! body:
//!
//! ```text
//! [len: u32 LE] [magic: u16 LE] [version: u8] [kind: u8] [id: u64 LE] [rest…]
//! ```
//!
//! `rest` depends on `kind`:
//!
//! * **Request** (`kind = 1`): `priority: u8` (band, `0 = Control`,
//!   `1 = Defense`, `2 = Batch`), `has_deadline: u8`,
//!   `deadline_us: f64 LE` (budget *relative to receipt*, in
//!   microseconds; ignored unless `has_deadline != 0`),
//!   `model_len: u16 LE` + UTF-8 model name, `n: u32 LE` + `n` f32 LE
//!   input features. Deadlines travel as relative budgets because the
//!   client and server clocks are unrelated; the server converts to an
//!   absolute [`Deadline`](crate::serve::Deadline) on arrival.
//! * **Response** (`kind = 2`): `n: u32 LE` + `n` f32 LE outputs.
//! * **Error** (`kind = 3`): `code: u16 LE`, `late_us: f64 LE`,
//!   `expected: u32 LE`, `got: u32 LE`, `model_len: u16 LE` + model
//!   name, `msg_len: u16 LE` + human-readable message. The fixed
//!   fields carry the machine-readable payload of the matching
//!   [`InferenceError`] variant so a client can reconstruct a typed
//!   error (see [`ErrorFrame::to_error`]); fields that don't apply to
//!   a given code are zero/empty.
//!
//! Decoding is incremental and non-panicking: [`decode`] looks at a
//! byte buffer and reports a complete frame, "need more bytes", or a
//! corrupt stream — never indexes out of bounds, and bounds every
//! allocation by the validated length prefix. That is what lets the
//! server's event loop feed it straight from nonblocking reads.

use crate::api::InferenceError;
use crate::serve::Priority;

/// First two body bytes of every frame — rejects non-protocol peers
/// (an HTTP probe, a port scanner) before any field is trusted.
pub const MAGIC: u16 = 0x4e53; // "NS"

/// Wire protocol version this build speaks.
pub const VERSION: u8 = 1;

/// Default cap on a single frame body, in bytes (16 MiB). A length
/// prefix above the cap marks the stream corrupt instead of letting a
/// hostile peer make the server reserve gigabytes.
pub const DEFAULT_MAX_FRAME: usize = 1 << 24;

/// Bytes of the fixed body header shared by every kind:
/// magic (2) + version (1) + kind (1) + id (8).
const HEADER: usize = 12;

const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;
const KIND_ERROR: u8 = 3;

/// Machine-readable error category carried by an error frame — the
/// wire image of [`InferenceError`]'s variants, plus
/// [`ErrorCode::Protocol`] for failures of the conversation itself
/// (malformed frame, unsupported version).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum ErrorCode {
    /// The frame or stream was malformed; the server closes the
    /// connection after sending this.
    Protocol = 1,
    /// Input length did not match the model
    /// ([`InferenceError::ShapeMismatch`]).
    ShapeMismatch = 2,
    /// The serving stack refused ([`InferenceError::BackendUnavailable`]).
    BackendUnavailable = 3,
    /// Operation not implemented ([`InferenceError::Unsupported`]).
    Unsupported = 4,
    /// Execution failed mid-flight ([`InferenceError::ExecutionFailed`]).
    ExecutionFailed = 5,
    /// Session-state misuse ([`InferenceError::SessionState`]).
    SessionState = 6,
    /// The request was shed ([`InferenceError::DeadlineExceeded`]).
    DeadlineExceeded = 7,
    /// No backends registered ([`InferenceError::NoBackends`]).
    NoBackends = 8,
    /// Every backend failed ([`InferenceError::AllBackendsFailed`]).
    AllBackendsFailed = 9,
    /// Unknown model name ([`InferenceError::ModelNotFound`]).
    ModelNotFound = 10,
    /// Model cannot be resident under the registry budget
    /// ([`InferenceError::Evicted`]).
    Evicted = 11,
    /// The server is at an in-flight cap and refused the request
    /// ([`InferenceError::Overloaded`]); `late_us` carries the
    /// retry-after hint in microseconds and `model` the cap scope
    /// (`"connection"` or `"server"`).
    Overloaded = 12,
    /// The backend panicked and the pool contained it
    /// ([`InferenceError::BackendPanicked`]); `model` carries the
    /// backend name.
    BackendPanicked = 13,
    /// The transport died with requests in flight
    /// ([`InferenceError::ConnectionLost`]); `model` carries the lost
    /// wire ids as a comma-separated list (wire v1 reuses the existing
    /// field set — see [`ErrorFrame::from_error`]).
    ConnectionLost = 14,
}

impl ErrorCode {
    /// Decode a wire value; `None` for codes this build doesn't know.
    pub fn from_u16(v: u16) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::Protocol,
            2 => ErrorCode::ShapeMismatch,
            3 => ErrorCode::BackendUnavailable,
            4 => ErrorCode::Unsupported,
            5 => ErrorCode::ExecutionFailed,
            6 => ErrorCode::SessionState,
            7 => ErrorCode::DeadlineExceeded,
            8 => ErrorCode::NoBackends,
            9 => ErrorCode::AllBackendsFailed,
            10 => ErrorCode::ModelNotFound,
            11 => ErrorCode::Evicted,
            12 => ErrorCode::Overloaded,
            13 => ErrorCode::BackendPanicked,
            14 => ErrorCode::ConnectionLost,
            _ => return None,
        })
    }
}

/// An inference request as it travels client → server.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    /// Caller-chosen correlation id, echoed verbatim in the reply.
    /// Replies may arrive out of submission order.
    pub id: u64,
    /// Priority class the request schedules in.
    pub priority: Priority,
    /// Remaining deadline budget in microseconds at send time, if any.
    pub deadline_us: Option<f64>,
    /// Registry name of the model to run.
    pub model: String,
    /// Flattened f32 input features.
    pub payload: Vec<f32>,
}

/// A successful reply, server → client.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    /// The request id this answers.
    pub id: u64,
    /// Flattened f32 model outputs.
    pub payload: Vec<f32>,
}

/// A typed failure reply, server → client.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorFrame {
    /// The request id this answers; `0` when the failure is not
    /// attributable to any single request (corrupt stream).
    pub id: u64,
    /// Machine-readable category.
    pub code: ErrorCode,
    /// Microseconds late, for [`ErrorCode::DeadlineExceeded`]; else 0.
    pub late_us: f64,
    /// Expected length, for [`ErrorCode::ShapeMismatch`]; else 0.
    pub expected: u32,
    /// Supplied length, for [`ErrorCode::ShapeMismatch`]; else 0.
    pub got: u32,
    /// Model name, for registry errors; else empty.
    pub model: String,
    /// Human-readable description (always safe to log).
    pub msg: String,
}

/// Any frame the protocol can carry.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server inference request.
    Request(RequestFrame),
    /// Server → client success.
    Response(ResponseFrame),
    /// Server → client typed failure.
    Error(ErrorFrame),
}

/// Outcome of one [`decode`] attempt over a byte buffer.
#[derive(Debug)]
pub enum Decoded {
    /// A complete frame, and how many buffer bytes it consumed
    /// (prefix + body) — the caller drains that many and tries again.
    Frame(Frame, usize),
    /// The buffer holds only part of a frame; read more bytes.
    Incomplete,
    /// The stream is not speaking this protocol (bad magic/version,
    /// oversized or impossible length, malformed fields). The
    /// connection cannot be resynchronized and must be closed.
    Corrupt(String),
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append `s` as `u16` length + UTF-8 bytes, truncating at `u16::MAX`
/// (registry names and error messages are far shorter in practice).
fn put_str(out: &mut Vec<u8>, s: &str) {
    let mut end = s.len().min(u16::MAX as usize);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    put_u16(out, end as u16);
    out.extend_from_slice(&s.as_bytes()[..end]);
}

/// Incremental field reader over one frame body. All methods are
/// bounds-checked; `None` means the body ended early (a corrupt frame,
/// since the length prefix promised more).
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let s = self.buf.get(self.pos..end)?;
        self.pos = end;
        Some(s)
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    fn u16(&mut self) -> Option<u16> {
        self.take(2).map(|b| u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| {
            u64::from_le_bytes([
                b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
            ])
        })
    }

    fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }

    fn f32s(&mut self) -> Option<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4)?)?;
        Some(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        )
    }

    fn str(&mut self) -> Option<String> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).ok()
    }

    fn exhausted(&self) -> bool {
        self.pos == self.buf.len()
    }
}

impl Frame {
    /// Correlation id of the request this frame belongs to.
    pub fn id(&self) -> u64 {
        match self {
            Frame::Request(r) => r.id,
            Frame::Response(r) => r.id,
            Frame::Error(e) => e.id,
        }
    }

    /// Append the length-prefixed wire image of `self` to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let mut body = Vec::with_capacity(HEADER + 32);
        put_u16(&mut body, MAGIC);
        body.push(VERSION);
        match self {
            Frame::Request(r) => {
                body.push(KIND_REQUEST);
                put_u64(&mut body, r.id);
                body.push(r.priority.band() as u8);
                body.push(u8::from(r.deadline_us.is_some()));
                put_f64(&mut body, r.deadline_us.unwrap_or(0.0));
                put_str(&mut body, &r.model);
                put_f32s(&mut body, &r.payload);
            }
            Frame::Response(r) => {
                body.push(KIND_RESPONSE);
                put_u64(&mut body, r.id);
                put_f32s(&mut body, &r.payload);
            }
            Frame::Error(e) => {
                body.push(KIND_ERROR);
                put_u64(&mut body, e.id);
                put_u16(&mut body, e.code as u16);
                put_f64(&mut body, e.late_us);
                put_u32(&mut body, e.expected);
                put_u32(&mut body, e.got);
                put_str(&mut body, &e.model);
                put_str(&mut body, &e.msg);
            }
        }
        put_u32(out, body.len() as u32);
        out.extend_from_slice(&body);
    }
}

/// Try to decode one frame from the front of `buf`.
///
/// `max_frame` caps the accepted body length ([`DEFAULT_MAX_FRAME`]
/// for both sides of this repo). Never panics, never reads past
/// `buf`, and never allocates more than the validated prefix allows.
pub fn decode(buf: &[u8], max_frame: usize) -> Decoded {
    if buf.len() < 4 {
        return Decoded::Incomplete;
    }
    let len =
        u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len > max_frame {
        return Decoded::Corrupt(format!(
            "frame length {len} exceeds cap {max_frame}"
        ));
    }
    if len < HEADER {
        return Decoded::Corrupt(format!(
            "frame length {len} below minimum header {HEADER}"
        ));
    }
    if buf.len() < 4 + len {
        return Decoded::Incomplete;
    }
    let mut c = Cursor::new(&buf[4..4 + len]);
    // The header reads cannot fail (len >= HEADER), but stay on the
    // checked path anyway.
    let (magic, version, kind, id) =
        match (c.u16(), c.u8(), c.u8(), c.u64()) {
            (Some(m), Some(v), Some(k), Some(i)) => (m, v, k, i),
            _ => return Decoded::Corrupt("truncated header".into()),
        };
    if magic != MAGIC {
        return Decoded::Corrupt(format!("bad magic {magic:#06x}"));
    }
    if version != VERSION {
        return Decoded::Corrupt(format!(
            "unsupported protocol version {version} (this build speaks {VERSION})"
        ));
    }
    let frame = match kind {
        KIND_REQUEST => decode_request(&mut c, id),
        KIND_RESPONSE => decode_response(&mut c, id),
        KIND_ERROR => decode_error(&mut c, id),
        other => {
            return Decoded::Corrupt(format!("unknown frame kind {other}"))
        }
    };
    match frame {
        Some(f) => Decoded::Frame(f, 4 + len),
        None => Decoded::Corrupt(format!(
            "malformed kind-{kind} body (id {id})"
        )),
    }
}

fn decode_request(c: &mut Cursor<'_>, id: u64) -> Option<Frame> {
    let band = c.u8()?;
    let priority =
        Priority::ALL.into_iter().find(|p| p.band() as u8 == band)?;
    let has_deadline = c.u8()? != 0;
    let budget = c.f64()?;
    let deadline_us = if has_deadline {
        if !budget.is_finite() {
            return None;
        }
        Some(budget)
    } else {
        None
    };
    let model = c.str()?;
    let payload = c.f32s()?;
    c.exhausted().then_some(Frame::Request(RequestFrame {
        id,
        priority,
        deadline_us,
        model,
        payload,
    }))
}

fn decode_response(c: &mut Cursor<'_>, id: u64) -> Option<Frame> {
    let payload = c.f32s()?;
    c.exhausted()
        .then_some(Frame::Response(ResponseFrame { id, payload }))
}

fn decode_error(c: &mut Cursor<'_>, id: u64) -> Option<Frame> {
    let code = ErrorCode::from_u16(c.u16()?)?;
    let late_us = c.f64()?;
    let expected = c.u32()?;
    let got = c.u32()?;
    let model = c.str()?;
    let msg = c.str()?;
    c.exhausted().then_some(Frame::Error(ErrorFrame {
        id,
        code,
        late_us,
        expected,
        got,
        model,
        msg,
    }))
}

impl ErrorFrame {
    /// A protocol-level failure (malformed stream, version mismatch),
    /// not tied to any [`InferenceError`].
    pub fn protocol(id: u64, msg: impl Into<String>) -> ErrorFrame {
        ErrorFrame {
            id,
            code: ErrorCode::Protocol,
            late_us: 0.0,
            expected: 0,
            got: 0,
            model: String::new(),
            msg: msg.into(),
        }
    }

    /// The wire image of a typed serving error, keeping the fields a
    /// client needs to reconstruct the variant.
    pub fn from_error(id: u64, err: &InferenceError) -> ErrorFrame {
        let mut f = ErrorFrame {
            id,
            code: ErrorCode::ExecutionFailed,
            late_us: 0.0,
            expected: 0,
            got: 0,
            model: String::new(),
            msg: err.to_string(),
        };
        match err {
            InferenceError::ShapeMismatch { expected, got, .. } => {
                f.code = ErrorCode::ShapeMismatch;
                f.expected = *expected as u32;
                f.got = *got as u32;
            }
            InferenceError::BackendUnavailable { .. } => {
                f.code = ErrorCode::BackendUnavailable;
            }
            InferenceError::Unsupported { .. } => {
                f.code = ErrorCode::Unsupported;
            }
            InferenceError::ExecutionFailed { .. } => {
                f.code = ErrorCode::ExecutionFailed;
            }
            InferenceError::SessionState { .. } => {
                f.code = ErrorCode::SessionState;
            }
            InferenceError::DeadlineExceeded { late_us, .. } => {
                f.code = ErrorCode::DeadlineExceeded;
                f.late_us = *late_us;
            }
            InferenceError::ModelNotFound { model } => {
                f.code = ErrorCode::ModelNotFound;
                f.model = model.clone();
            }
            InferenceError::Evicted { model } => {
                f.code = ErrorCode::Evicted;
                f.model = model.clone();
            }
            InferenceError::NoBackends => {
                f.code = ErrorCode::NoBackends;
            }
            InferenceError::AllBackendsFailed { .. } => {
                f.code = ErrorCode::AllBackendsFailed;
            }
            // The three robustness variants reuse the v1 field set so
            // the wire version does not bump: `late_us` doubles as the
            // retry-after hint, `model` as the scope / backend name /
            // lost-id list.
            InferenceError::Overloaded { scope, retry_after_us } => {
                f.code = ErrorCode::Overloaded;
                f.late_us = *retry_after_us;
                f.model = (*scope).to_string();
            }
            InferenceError::BackendPanicked { backend, message } => {
                f.code = ErrorCode::BackendPanicked;
                f.model = backend.clone();
                f.msg = message.clone();
            }
            InferenceError::ConnectionLost { lost_ids, reason } => {
                f.code = ErrorCode::ConnectionLost;
                f.got = lost_ids.len() as u32;
                f.model = lost_ids
                    .iter()
                    .map(u64::to_string)
                    .collect::<Vec<_>>()
                    .join(",");
                f.msg = reason.clone();
            }
        }
        f
    }

    /// Best-effort reconstruction of the typed error on the client
    /// side. Variants whose payload doesn't fully survive the wire
    /// (error sources, static strs) come back with the preserved
    /// machine fields and the human-readable message.
    pub fn to_error(&self) -> InferenceError {
        match self.code {
            ErrorCode::ShapeMismatch => InferenceError::ShapeMismatch {
                what: "input",
                expected: self.expected as usize,
                got: self.got as usize,
            },
            ErrorCode::DeadlineExceeded => {
                InferenceError::DeadlineExceeded {
                    stage: "remote",
                    late_us: self.late_us,
                }
            }
            ErrorCode::ModelNotFound => InferenceError::ModelNotFound {
                model: self.model.clone(),
            },
            ErrorCode::Evicted => InferenceError::Evicted {
                model: self.model.clone(),
            },
            ErrorCode::NoBackends => InferenceError::NoBackends,
            ErrorCode::AllBackendsFailed => {
                InferenceError::AllBackendsFailed {
                    failures: vec![("remote".into(), self.msg.clone())],
                }
            }
            ErrorCode::Unsupported => InferenceError::Unsupported {
                backend: "netserve".into(),
                op: "remote operation",
            },
            ErrorCode::SessionState => InferenceError::SessionState {
                backend: "netserve".into(),
                expected: "remote session state",
            },
            ErrorCode::ExecutionFailed => InferenceError::ExecutionFailed {
                backend: "netserve".into(),
                source: anyhow::anyhow!("{}", self.msg),
            },
            ErrorCode::Overloaded => InferenceError::Overloaded {
                scope: if self.model == "connection" {
                    "connection"
                } else {
                    "server"
                },
                retry_after_us: self.late_us,
            },
            ErrorCode::BackendPanicked => {
                InferenceError::BackendPanicked {
                    backend: if self.model.is_empty() {
                        "netserve".into()
                    } else {
                        self.model.clone()
                    },
                    message: self.msg.clone(),
                }
            }
            ErrorCode::ConnectionLost => InferenceError::ConnectionLost {
                lost_ids: self
                    .model
                    .split(',')
                    .filter_map(|s| s.parse::<u64>().ok())
                    .collect(),
                reason: self.msg.clone(),
            },
            ErrorCode::Protocol | ErrorCode::BackendUnavailable => {
                InferenceError::BackendUnavailable {
                    backend: "netserve".into(),
                    reason: self.msg.clone(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode_one(f: &Frame) -> Vec<u8> {
        let mut out = Vec::new();
        f.encode(&mut out);
        out
    }

    fn sample_request() -> Frame {
        Frame::Request(RequestFrame {
            id: 7,
            priority: Priority::Control,
            deadline_us: Some(1500.0),
            model: "classifier".into(),
            payload: vec![0.25, -1.0, 3.5],
        })
    }

    #[test]
    fn frames_roundtrip() {
        let frames = [
            sample_request(),
            Frame::Request(RequestFrame {
                id: 8,
                priority: Priority::Batch,
                deadline_us: None,
                model: "m".into(),
                payload: vec![],
            }),
            Frame::Response(ResponseFrame {
                id: 7,
                payload: vec![1.0, 2.0],
            }),
            Frame::Error(ErrorFrame {
                id: 9,
                code: ErrorCode::ModelNotFound,
                late_us: 0.0,
                expected: 0,
                got: 0,
                model: "ghost".into(),
                msg: "model \"ghost\" is not in the registry".into(),
            }),
        ];
        for f in &frames {
            let wire = encode_one(f);
            match decode(&wire, DEFAULT_MAX_FRAME) {
                Decoded::Frame(back, used) => {
                    assert_eq!(&back, f);
                    assert_eq!(used, wire.len());
                }
                other => panic!("expected frame, got {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_frames_are_incomplete_not_corrupt() {
        let wire = encode_one(&sample_request());
        for cut in 0..wire.len() {
            match decode(&wire[..cut], DEFAULT_MAX_FRAME) {
                Decoded::Incomplete => {}
                other => panic!("cut at {cut}: expected Incomplete, got {other:?}"),
            }
        }
    }

    #[test]
    fn decode_consumes_exactly_one_frame() {
        let mut wire = encode_one(&sample_request());
        let first_len = wire.len();
        Frame::Response(ResponseFrame { id: 1, payload: vec![9.0] })
            .encode(&mut wire);
        match decode(&wire, DEFAULT_MAX_FRAME) {
            Decoded::Frame(Frame::Request(_), used) => {
                assert_eq!(used, first_len);
                match decode(&wire[used..], DEFAULT_MAX_FRAME) {
                    Decoded::Frame(Frame::Response(r), _) => {
                        assert_eq!(r.payload, vec![9.0]);
                    }
                    other => panic!("expected response, got {other:?}"),
                }
            }
            other => panic!("expected request, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_corrupt() {
        let mut wire = Vec::new();
        put_u32(&mut wire, (DEFAULT_MAX_FRAME as u32) + 1);
        assert!(matches!(
            decode(&wire, DEFAULT_MAX_FRAME),
            Decoded::Corrupt(_)
        ));
    }

    #[test]
    fn runt_length_prefix_is_corrupt() {
        let mut wire = Vec::new();
        put_u32(&mut wire, 3); // below the 12-byte header
        wire.extend_from_slice(&[0, 0, 0]);
        assert!(matches!(
            decode(&wire, DEFAULT_MAX_FRAME),
            Decoded::Corrupt(_)
        ));
    }

    #[test]
    fn bad_magic_and_version_are_corrupt() {
        let mut wire = encode_one(&sample_request());
        wire[4] ^= 0xff; // magic low byte
        assert!(matches!(
            decode(&wire, DEFAULT_MAX_FRAME),
            Decoded::Corrupt(_)
        ));

        let mut wire = encode_one(&sample_request());
        wire[6] = VERSION + 1;
        match decode(&wire, DEFAULT_MAX_FRAME) {
            Decoded::Corrupt(msg) => assert!(msg.contains("version")),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn payload_count_mismatch_is_corrupt() {
        // A response whose f32 count promises more floats than the
        // frame carries: shrink the body but keep the count.
        let mut wire = encode_one(&Frame::Response(ResponseFrame {
            id: 1,
            payload: vec![1.0, 2.0, 3.0],
        }));
        // Drop the last float and fix up the length prefix; the inner
        // count still says 3.
        let len = wire.len() - 4;
        wire.truncate(len);
        let body_len = (len - 8) as u32;
        wire[..4].copy_from_slice(&body_len.to_le_bytes());
        assert!(matches!(
            decode(&wire, DEFAULT_MAX_FRAME),
            Decoded::Corrupt(_)
        ));
    }

    #[test]
    fn error_frames_reconstruct_typed_errors() {
        let cases: Vec<InferenceError> = vec![
            InferenceError::ShapeMismatch {
                what: "input",
                expected: 8,
                got: 3,
            },
            InferenceError::DeadlineExceeded {
                stage: "queue",
                late_us: 42.5,
            },
            InferenceError::ModelNotFound { model: "ghost".into() },
            InferenceError::Evicted { model: "big".into() },
            InferenceError::Overloaded {
                scope: "connection",
                retry_after_us: 750.0,
            },
            InferenceError::BackendPanicked {
                backend: "engine".into(),
                message: "synthetic".into(),
            },
            InferenceError::ConnectionLost {
                lost_ids: vec![3, 17, 255],
                reason: "peer reset".into(),
            },
        ];
        for err in &cases {
            let wire = encode_one(&Frame::Error(ErrorFrame::from_error(3, err)));
            let back = match decode(&wire, DEFAULT_MAX_FRAME) {
                Decoded::Frame(Frame::Error(e), _) => e.to_error(),
                other => panic!("expected error frame, got {other:?}"),
            };
            match (err, &back) {
                (
                    InferenceError::ShapeMismatch { expected, got, .. },
                    InferenceError::ShapeMismatch {
                        expected: e2,
                        got: g2,
                        ..
                    },
                ) => assert_eq!((expected, got), (e2, g2)),
                (
                    InferenceError::DeadlineExceeded { late_us, .. },
                    InferenceError::DeadlineExceeded { late_us: l2, .. },
                ) => assert_eq!(late_us, l2),
                (
                    InferenceError::ModelNotFound { model },
                    InferenceError::ModelNotFound { model: m2 },
                ) => assert_eq!(model, m2),
                (
                    InferenceError::Evicted { model },
                    InferenceError::Evicted { model: m2 },
                ) => assert_eq!(model, m2),
                (
                    InferenceError::Overloaded {
                        scope,
                        retry_after_us,
                    },
                    InferenceError::Overloaded {
                        scope: s2,
                        retry_after_us: r2,
                    },
                ) => assert_eq!((scope, retry_after_us), (s2, r2)),
                (
                    InferenceError::BackendPanicked { backend, message },
                    InferenceError::BackendPanicked {
                        backend: b2,
                        message: m2,
                    },
                ) => assert_eq!((backend, message), (b2, m2)),
                (
                    InferenceError::ConnectionLost { lost_ids, reason },
                    InferenceError::ConnectionLost {
                        lost_ids: l2,
                        reason: r2,
                    },
                ) => assert_eq!((lost_ids, reason), (l2, r2)),
                (a, b) => panic!("variant mismatch: {a:?} vs {b:?}"),
            }
            assert!(!back.is_backend_fault() || err.is_backend_fault());
        }
    }

    #[test]
    fn non_finite_deadline_is_corrupt() {
        let mut wire = Vec::new();
        Frame::Request(RequestFrame {
            id: 1,
            priority: Priority::Batch,
            deadline_us: Some(f64::NAN),
            model: "m".into(),
            payload: vec![],
        })
        .encode(&mut wire);
        assert!(matches!(
            decode(&wire, DEFAULT_MAX_FRAME),
            Decoded::Corrupt(_)
        ));
    }
}
