//! Integer quantization (paper §6.1): SINT/INT/DINT schemes, the
//! per-neuron symmetric quantizer, Table 2's memory calculator, and the
//! §6.1 arithmetic-operation analysis.

/// IEC 61131-3 integer quantization schemes (Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// 8-bit.
    Sint,
    /// 16-bit.
    Int,
    /// 32-bit.
    Dint,
}

impl Scheme {
    pub const ALL: [Scheme; 3] = [Scheme::Sint, Scheme::Int, Scheme::Dint];

    pub fn name(self) -> &'static str {
        match self {
            Scheme::Sint => "SINT",
            Scheme::Int => "INT",
            Scheme::Dint => "DINT",
        }
    }

    pub fn bytes(self) -> usize {
        match self {
            Scheme::Sint => 1,
            Scheme::Int => 2,
            Scheme::Dint => 4,
        }
    }

    /// Max magnitude representable (symmetric range).
    pub fn qmax(self) -> f64 {
        match self {
            Scheme::Sint => 127.0,
            Scheme::Int => 32_767.0,
            Scheme::Dint => 2_147_483_647.0,
        }
    }

    pub fn from_name(name: &str) -> Option<Scheme> {
        Some(match name.to_ascii_uppercase().as_str() {
            "SINT" => Scheme::Sint,
            "INT" => Scheme::Int,
            "DINT" => Scheme::Dint,
            _ => return None,
        })
    }
}

/// Quantize a dense layer's weights (`[neurons][inputs]` row-major)
/// symmetrically, one scale per output neuron — the paper's scheme
/// (Table 2: one REAL scaling factor per neuron + one for the input).
///
/// Returns `(w_q, s_w)` with `w ≈ w_q * s_w[neuron]`.
pub fn quantize_weights(
    w: &[f32],
    inputs: usize,
    neurons: usize,
    scheme: Scheme,
) -> (Vec<i32>, Vec<f32>) {
    assert_eq!(w.len(), inputs * neurons);
    let qmax = scheme.qmax();
    let mut w_q = Vec::with_capacity(w.len());
    let mut s_w = Vec::with_capacity(neurons);
    for n in 0..neurons {
        let row = &w[n * inputs..(n + 1) * inputs];
        let absmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
        let scale = absmax as f64 / qmax;
        s_w.push(scale as f32);
        for v in row {
            let q = (*v as f64 / scale).round().clamp(-qmax, qmax);
            w_q.push(q as i32);
        }
    }
    (w_q, s_w)
}

/// Pick the input scale factor for a known input range.
pub fn input_scale(abs_max: f32, scheme: Scheme) -> f32 {
    (abs_max.max(1e-12) as f64 / scheme.qmax()) as f32
}

/// One row of the paper's Table 2: memory requirements in bytes of a
/// fully connected layer under a quantization scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryRow {
    pub weights: u64,
    pub biases: u64,
    /// Per-neuron scales + the input scale, as REALs. 0 for the f32
    /// baseline.
    pub scaling: u64,
    pub total: u64,
}

/// Table 2 calculator. `scheme = None` is the REAL (f32) baseline row.
pub fn memory_requirements(
    inputs: u64,
    neurons: u64,
    scheme: Option<Scheme>,
) -> MemoryRow {
    let weights = inputs * neurons * scheme.map_or(4, |s| s.bytes() as u64);
    let biases = neurons * 4;
    let scaling = match scheme {
        Some(_) => (neurons + 1) * 4,
        None => 0,
    };
    MemoryRow { weights, biases, scaling, total: weights + biases + scaling }
}

/// §6.1 operation counts for one dense-layer inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpCounts {
    pub fp_mul: u64,
    pub fp_add: u64,
    pub int_mul: u64,
    pub int_add: u64,
}

/// Operation analysis: float layer vs integer-quantized layer (the
/// paper's example: 512x512 → 262,144 FP mul + 262,656 FP add vs
/// 1,024 FP mul + 512 FP add + 262,144 int mul + 262,144 int add).
pub fn op_counts(inputs: u64, neurons: u64, quantized: bool) -> OpCounts {
    if quantized {
        OpCounts {
            // input quantization: 1 divide (counted as mul) per input;
            // dequantization: 1 mul per neuron with the combined
            // s_x*s_w[n] scale precomputed — 1024 total for 512x512,
            // exactly the paper's figure.
            fp_mul: inputs + neurons,
            fp_add: neurons, // bias adds
            int_mul: inputs * neurons,
            int_add: inputs * neurons,
        }
    } else {
        OpCounts {
            fp_mul: inputs * neurons,
            // dot-product adds + bias adds
            fp_add: inputs * neurons + neurons,
            int_mul: 0,
            int_add: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{prop_assert, prop_check};

    #[test]
    fn table2_rows_match_paper() {
        // Paper Table 2 for 512 inputs x 512 neurons.
        let sint = memory_requirements(512, 512, Some(Scheme::Sint));
        assert_eq!(sint.weights, 262_144);
        assert_eq!(sint.biases, 2_048);
        assert_eq!(sint.scaling, 2_052);
        assert_eq!(sint.total, 266_244);

        let int = memory_requirements(512, 512, Some(Scheme::Int));
        assert_eq!(int.total, 528_388);

        let dint = memory_requirements(512, 512, Some(Scheme::Dint));
        assert_eq!(dint.total, 1_052_676);

        let real = memory_requirements(512, 512, None);
        assert_eq!(real.total, 1_050_624);
    }

    #[test]
    fn compression_percentages_match_paper() {
        // §6.1: SINT −74.66%, INT −49.71% vs REAL.
        let real = memory_requirements(512, 512, None).total as f64;
        let sint = memory_requirements(512, 512, Some(Scheme::Sint)).total as f64;
        let int = memory_requirements(512, 512, Some(Scheme::Int)).total as f64;
        assert!(((1.0 - sint / real) * 100.0 - 74.66).abs() < 0.01);
        assert!(((1.0 - int / real) * 100.0 - 49.71).abs() < 0.01);
    }

    #[test]
    fn op_counts_match_paper() {
        // §6.1 for the 512x512 layer.
        let f = op_counts(512, 512, false);
        assert_eq!(f.fp_mul, 262_144);
        assert_eq!(f.fp_add, 262_656);
        let q = op_counts(512, 512, true);
        assert_eq!(q.int_mul, 262_144);
        assert_eq!(q.int_add, 262_144);
        assert_eq!(q.fp_mul, 1_024); // 512 input divides + 512 dequant muls
        assert_eq!(q.fp_add, 512);
    }

    #[test]
    fn quantize_round_trip_error_bounded() {
        prop_check(40, |g| {
            let inputs = g.usize_in(1..=32);
            let neurons = g.usize_in(1..=16);
            let w = g.vec_f32((inputs * neurons)..=(inputs * neurons), -2.0, 2.0);
            for scheme in [Scheme::Sint, Scheme::Int] {
                let (wq, sw) = quantize_weights(&w, inputs, neurons, scheme);
                for n in 0..neurons {
                    for i in 0..inputs {
                        let orig = w[n * inputs + i];
                        let deq = wq[n * inputs + i] as f32 * sw[n];
                        let lsb = sw[n];
                        prop_assert(
                            (orig - deq).abs() <= 0.5 * lsb + 1e-6,
                            format!("{scheme:?}: {orig} vs {deq} (lsb {lsb})"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantized_values_in_range() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.37).collect();
        let (wq, _) = quantize_weights(&w, 16, 4, Scheme::Sint);
        assert!(wq.iter().all(|q| (-127..=127).contains(q)));
    }

    #[test]
    fn scheme_metadata() {
        assert_eq!(Scheme::Sint.bytes(), 1);
        assert_eq!(Scheme::Int.bytes(), 2);
        assert_eq!(Scheme::Dint.bytes(), 4);
        assert_eq!(Scheme::from_name("sint"), Some(Scheme::Sint));
        assert_eq!(Scheme::from_name("REAL"), None);
    }
}
