//! Cascaded PID controller (the PLC's §7 control task): outer loop
//! maps the Wd error to a TB0 setpoint, inner loop maps the TB0 error
//! to the steam-flow command Ws. Twin of `python/compile/plant.py`'s
//! `pid_step` (same clamps, same evaluation order).

use super::*;

/// Integrator state for the two loops.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PidState {
    pub outer_i: f64,
    pub inner_i: f64,
}

impl PidState {
    /// One control step (runs once per scan cycle). Returns the Ws
    /// command. Anti-windup: integrators clamped alongside outputs.
    pub fn step(&mut self, tb0_meas: f64, wd_meas: f64, wd_set: f64) -> f64 {
        let e_outer = wd_set - wd_meas;
        self.outer_i += e_outer * DT;
        self.outer_i = self.outer_i.clamp(-20.0, 20.0);
        let tb0_set = TB0_NOM + OUTER_KP * e_outer + OUTER_KI * self.outer_i;
        let tb0_set = tb0_set.clamp(TB0_SET_MIN, TB0_SET_MAX);

        let e_inner = tb0_set - tb0_meas;
        self.inner_i += e_inner * DT;
        self.inner_i = self.inner_i.clamp(-30.0, 30.0);
        let ws = WS_NOM + INNER_KP * e_inner + INNER_KI * self.inner_i;
        ws.clamp(WS_MIN, WS_MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_outputs_nominal_steam() {
        let mut pid = PidState::default();
        let ws = pid.step(TB0_NOM, WD_SET, WD_SET);
        assert!((ws - WS_NOM).abs() < 1e-9);
    }

    #[test]
    fn low_production_raises_steam_command() {
        let mut pid = PidState::default();
        let ws = pid.step(TB0_NOM, WD_SET - 2.0, WD_SET);
        assert!(ws > WS_NOM);
    }

    #[test]
    fn anti_windup_clamps_integrators() {
        let mut pid = PidState::default();
        for _ in 0..200_000 {
            pid.step(150.0, 40.0, WD_SET);
        }
        assert!(pid.inner_i >= -30.0 && pid.inner_i <= 30.0);
        assert!(pid.outer_i >= -20.0 && pid.outer_i <= 20.0);
    }

    #[test]
    fn output_saturates_at_limits() {
        let mut pid = PidState::default();
        // Massive positive error -> saturate at WS_MAX.
        let mut ws = 0.0;
        for _ in 0..10_000 {
            ws = pid.step(0.0, 0.0, WD_SET);
        }
        assert_eq!(ws, WS_MAX);
    }
}
