//! Reduced-order MSF flash-plant dynamics + the PLC ADC model.
//! Twin of `python/compile/plant.py` (normative evaluation order).

use super::*;

/// Plant state (top brine temperature, reject-section temperature,
/// distillate production with first-order lag).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlantState {
    pub tb0: f64,
    pub tbot: f64,
    pub wd: f64,
}

impl Default for PlantState {
    fn default() -> Self {
        PlantState { tb0: TB0_NOM, tbot: TBOT_NOM, wd: WD_SET }
    }
}

/// One Euler step of the plant ODEs. The arithmetic mirrors the Python
/// twin term-for-term:
///
/// ```text
/// t_in       = tbot + R_RECOV * (tb0 - tbot)
/// d tb0 /dt  = (LAMBDA_S * ws - wr * CP * (tb0 - t_in)) / C_H
/// flash_heat = wr * CP * (tb0 - tbot)
/// d tbot/dt  = (F_FLASH * flash_heat - wrej * CP * (tbot - T_SEA)) / C_B
/// wd_inst    = flash_heat / LAMBDA_V
/// d wd  /dt  = (wd_inst - wd) / TAU_D
/// ```
pub fn plant_step(s: PlantState, ws: f64, wr: f64, wrej: f64) -> PlantState {
    let t_in = s.tbot + R_RECOV * (s.tb0 - s.tbot);
    let d_tb0 = (LAMBDA_S * ws - wr * CP * (s.tb0 - t_in)) / C_H;
    let flash_heat = wr * CP * (s.tb0 - s.tbot);
    let d_tbot = (F_FLASH * flash_heat - wrej * CP * (s.tbot - T_SEA)) / C_B;
    let wd_inst = flash_heat / LAMBDA_V;
    let d_wd = (wd_inst - s.wd) / TAU_D;
    PlantState {
        tb0: s.tb0 + DT * d_tb0,
        tbot: s.tbot + DT * d_tbot,
        wd: s.wd + DT * d_wd,
    }
}

/// 14-bit ADC quantization over `[lo, hi]` (paper §7.1's visible
/// quantization steps). Matches the Python twin's float arithmetic.
pub fn adc(value: f64, lo: f64, hi: f64) -> f64 {
    let v = value.clamp(lo, hi);
    let code = ((v - lo) / (hi - lo) * ADC_LEVELS + 0.5).floor();
    lo + code * (hi - lo) / ADC_LEVELS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_point_is_fixed() {
        let s = PlantState::default();
        let s2 = plant_step(s, WS_NOM, WR_NOM, WREJ_NOM);
        assert!((s2.tb0 - s.tb0).abs() < 1e-9);
        assert!((s2.tbot - s.tbot).abs() < 1e-9);
        assert!((s2.wd - s.wd).abs() < 1e-9);
    }

    #[test]
    fn more_steam_raises_brine_temperature() {
        let mut s = PlantState::default();
        for _ in 0..600 {
            s = plant_step(s, WS_NOM * 1.2, WR_NOM, WREJ_NOM);
        }
        assert!(s.tb0 > TB0_NOM + 0.5);
        assert!(s.wd > WD_SET);
    }

    #[test]
    fn adc_grid_and_clamp() {
        let v = adc(19.1837, WD_ADC_LO, WD_ADC_HI);
        let lsb = (WD_ADC_HI - WD_ADC_LO) / ADC_LEVELS;
        assert!((v / lsb - (v / lsb).round()).abs() < 1e-6);
        assert!((v - 19.1837).abs() <= lsb / 2.0 + 1e-9);
        assert_eq!(adc(-5.0, WD_ADC_LO, WD_ADC_HI), 0.0);
        assert_eq!(adc(99.0, WD_ADC_LO, WD_ADC_HI), WD_ADC_HI);
    }

    #[test]
    fn mass_energy_sanity() {
        // Distillate production must track flash heat / latent heat.
        let s = PlantState { tb0: 92.0, tbot: 41.0, wd: 19.0 };
        let s2 = plant_step(s, WS_NOM, WR_NOM, WREJ_NOM);
        let wd_inst = WR_NOM * CP * (92.0 - 41.0) / LAMBDA_V;
        assert!(s2.wd > s.wd && s2.wd < wd_inst, "wd relaxes toward wd_inst");
    }
}
