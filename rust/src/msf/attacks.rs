//! Process-aware attack injector: the 7 parameterized families
//! substituting the Rajput et al. 2019 thermal-desalination attacks
//! (DESIGN.md §2). Effects are applied to actuators (flow scaling),
//! sensors (false data injection) or the controller setpoint.

/// The seven attack families (matches `plant.ATTACK_FAMILIES` in the
/// Python twin — order matters for dataset parity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttackFamily {
    /// 1. Ws actuator scaling.
    SteamBias,
    /// 2. Recycle brine flow cut.
    RecycleReduction,
    /// 3. Reject seawater flow scaling.
    RejectManipulation,
    /// 4. False data injection on the TB0 sensor.
    Tb0Fdi,
    /// 5. False data injection on the Wd sensor.
    WdFdi,
    /// 6. Wd setpoint tampering.
    SetpointTamper,
    /// 7. Combined brine + steam + reject manipulation (Fig. 7).
    Combined,
}

impl AttackFamily {
    pub const ALL: [AttackFamily; 7] = [
        AttackFamily::SteamBias,
        AttackFamily::RecycleReduction,
        AttackFamily::RejectManipulation,
        AttackFamily::Tb0Fdi,
        AttackFamily::WdFdi,
        AttackFamily::SetpointTamper,
        AttackFamily::Combined,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AttackFamily::SteamBias => "steam_bias",
            AttackFamily::RecycleReduction => "recycle_reduction",
            AttackFamily::RejectManipulation => "reject_manipulation",
            AttackFamily::Tb0Fdi => "tb0_fdi",
            AttackFamily::WdFdi => "wd_fdi",
            AttackFamily::SetpointTamper => "setpoint_tamper",
            AttackFamily::Combined => "combined",
        }
    }

    pub fn from_name(name: &str) -> Option<AttackFamily> {
        AttackFamily::ALL.into_iter().find(|f| f.name() == name)
    }
}

/// One attack instance: family + magnitude + active step window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Attack {
    pub family: AttackFamily,
    pub magnitude: f64,
    pub start_step: u64,
    pub end_step: u64,
}

impl Attack {
    pub fn new(
        family: AttackFamily,
        magnitude: f64,
        start_step: u64,
        end_step: u64,
    ) -> Attack {
        Attack { family, magnitude, start_step, end_step }
    }

    pub fn active(&self, step: u64) -> bool {
        step >= self.start_step && step < self.end_step
    }
}

/// Folded actuator/sensor/setpoint effects of all active attacks
/// (mirrors the Python twin's `_attack_params`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttackEffects {
    pub wr: f64,
    pub wrej: f64,
    pub ws_scale: f64,
    pub tb0_bias: f64,
    pub wd_scale: f64,
    pub wd_set: f64,
    pub active: bool,
}

impl AttackEffects {
    pub fn fold(attacks: &[Attack], step: u64) -> AttackEffects {
        use super::{WD_SET, WREJ_NOM, WR_NOM};
        let mut e = AttackEffects {
            wr: WR_NOM,
            wrej: WREJ_NOM,
            ws_scale: 1.0,
            tb0_bias: 0.0,
            wd_scale: 1.0,
            wd_set: WD_SET,
            active: false,
        };
        for a in attacks {
            if !a.active(step) {
                continue;
            }
            e.active = true;
            let m = a.magnitude;
            match a.family {
                AttackFamily::SteamBias => e.ws_scale *= 1.0 + m,
                AttackFamily::RecycleReduction => e.wr *= 1.0 - m,
                AttackFamily::RejectManipulation => e.wrej *= 1.0 + m,
                AttackFamily::Tb0Fdi => e.tb0_bias += m,
                AttackFamily::WdFdi => e.wd_scale *= 1.0 - m,
                AttackFamily::SetpointTamper => e.wd_set = WD_SET + m,
                AttackFamily::Combined => {
                    e.wr *= 1.0 - 0.6 * m;
                    e.ws_scale *= 1.0 + 0.4 * m;
                    e.wrej *= 1.0 - 0.8 * m;
                }
            }
        }
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_bounds_half_open() {
        let a = Attack::new(AttackFamily::Combined, 0.5, 10, 20);
        assert!(!a.active(9));
        assert!(a.active(10));
        assert!(a.active(19));
        assert!(!a.active(20));
    }

    #[test]
    fn fold_no_attacks_is_nominal() {
        let e = AttackEffects::fold(&[], 0);
        assert_eq!(e.wr, super::super::WR_NOM);
        assert_eq!(e.ws_scale, 1.0);
        assert!(!e.active);
    }

    #[test]
    fn fold_combined_matches_python_twin_formula() {
        let a = Attack::new(AttackFamily::Combined, 0.5, 0, 10);
        let e = AttackEffects::fold(&[a], 5);
        assert!((e.wr - super::super::WR_NOM * 0.7).abs() < 1e-12);
        assert!((e.ws_scale - 1.2).abs() < 1e-12);
        assert!((e.wrej - super::super::WREJ_NOM * 0.6).abs() < 1e-12);
        assert!(e.active);
    }

    #[test]
    fn multiple_attacks_compose() {
        let list = [
            Attack::new(AttackFamily::SteamBias, 0.1, 0, 10),
            Attack::new(AttackFamily::SteamBias, 0.1, 0, 10),
            Attack::new(AttackFamily::Tb0Fdi, 2.0, 0, 10),
        ];
        let e = AttackEffects::fold(&list, 1);
        assert!((e.ws_scale - 1.21).abs() < 1e-12);
        assert_eq!(e.tb0_bias, 2.0);
    }

    #[test]
    fn family_names_round_trip() {
        for f in AttackFamily::ALL {
            assert_eq!(AttackFamily::from_name(f.name()), Some(f));
        }
    }

    #[test]
    fn zero_length_and_extreme_windows_never_activate_wrongly() {
        // `[start, end)` with start == end is empty: never active.
        let z = Attack::new(AttackFamily::SteamBias, 0.1, 5, 5);
        assert!(!z.active(4));
        assert!(!z.active(5));
        assert!(!z.active(6));
        // Inverted window (end < start) is also empty.
        let inv = Attack::new(AttackFamily::SteamBias, 0.1, 10, 3);
        assert!(!inv.active(5));
        // An effectively-unbounded window covers everything below
        // u64::MAX (the exclusive end itself is outside).
        let open = Attack::new(AttackFamily::SteamBias, 0.1, 0, u64::MAX);
        assert!(open.active(0));
        assert!(open.active(u64::MAX - 1));
        assert!(!open.active(u64::MAX));
        // Unknown names don't parse.
        assert_eq!(AttackFamily::from_name("not_a_family"), None);
    }

    #[test]
    fn fold_applies_in_list_order_setpoint_last_wins() {
        // SetpointTamper *overwrites* wd_set, so when two tampers
        // overlap the same step the last one in declaration order
        // wins. This pins fold order = list order.
        let a = Attack::new(AttackFamily::SetpointTamper, 1.0, 0, 10);
        let b = Attack::new(AttackFamily::SetpointTamper, 2.0, 0, 10);
        let e_ab = AttackEffects::fold(&[a, b], 5);
        let e_ba = AttackEffects::fold(&[b, a], 5);
        assert_eq!(e_ab.wd_set, super::super::WD_SET + 2.0);
        assert_eq!(e_ba.wd_set, super::super::WD_SET + 1.0);
    }

    #[test]
    fn fold_scaling_effects_commute_on_shared_signals() {
        // Multiplicative effects (ws_scale/wr/wrej/wd_scale) compose
        // order-independently even when two families touch the same
        // signal — only overwriting effects are order-sensitive.
        let a = Attack::new(AttackFamily::RecycleReduction, 0.2, 0, 10);
        let b = Attack::new(AttackFamily::Combined, 0.5, 0, 10);
        let ab = AttackEffects::fold(&[a, b], 0);
        let ba = AttackEffects::fold(&[b, a], 0);
        assert!((ab.wr - super::super::WR_NOM * 0.8 * 0.7).abs() < 1e-9);
        assert!((ab.wr - ba.wr).abs() < 1e-9);
        assert!((ab.ws_scale - ba.ws_scale).abs() < 1e-9);
        assert!((ab.wrej - ba.wrej).abs() < 1e-9);
        // Only windows covering the step participate in the fold.
        let late = Attack::new(AttackFamily::RecycleReduction, 0.2, 5, 10);
        let e = AttackEffects::fold(&[late, b], 0);
        assert!((e.wr - super::super::WR_NOM * 0.7).abs() < 1e-9);
    }
}
