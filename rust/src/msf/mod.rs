//! MSF desalination plant simulator + cascaded PID + attack injector —
//! the runtime twin of the MATLAB-Simulink HITL setup the paper uses
//! (§7), substituted per DESIGN.md §2.
//!
//! **Normative spec**: `python/compile/plant.py`. Every equation here
//! replicates the Python twin's arithmetic in the *same evaluation
//! order* so the two integrate bit-for-bit (IEEE-754 f64); the
//! golden-trace test (`rust/tests/plant_golden.rs`) pins them to 1e-9.

pub mod attacks;
pub mod pid;
pub mod plant;
pub mod sim;

pub use attacks::{Attack, AttackFamily};
pub use pid::PidState;
pub use plant::{adc, plant_step, PlantState};
pub use sim::{DefensePosture, ScanReading, Simulator, SETPOINT_CLAMP_BAND};

// ------------------------------------------------------------ constants
// (mirrors python/compile/plant.py — keep both in sync)
/// Scan period: 100 ms, in minutes.
pub const DT: f64 = 0.1 / 60.0;
pub const T_SEA: f64 = 35.0;
pub const LAMBDA_S: f64 = 550.0;
pub const LAMBDA_V: f64 = 550.0;
pub const CP: f64 = 1.0;
pub const R_RECOV: f64 = 0.7;
pub const F_FLASH: f64 = 0.1;
pub const C_H: f64 = 800.0;
pub const C_B: f64 = 1500.0;
pub const TAU_D: f64 = 0.5;

pub const WR_NOM: f64 = 211.0;
pub const WREJ_NOM: f64 = 211.0;
pub const WS_NOM: f64 = 3165.0 / 550.0;
pub const WS_MAX: f64 = 12.0;
pub const WS_MIN: f64 = 0.0;
pub const TB0_NOM: f64 = 90.0;
pub const TBOT_NOM: f64 = 40.0;
/// 19.1818... tons/min (paper Fig. 8 mean: 19.18).
pub const WD_SET: f64 = 211.0 * 50.0 / 550.0;

pub const OUTER_KP: f64 = 2.0;
pub const OUTER_KI: f64 = 0.8;
pub const TB0_SET_MIN: f64 = 75.0;
pub const TB0_SET_MAX: f64 = 95.0;
pub const INNER_KP: f64 = 0.6;
pub const INNER_KI: f64 = 0.35;

pub const TB0_ADC_LO: f64 = 0.0;
pub const TB0_ADC_HI: f64 = 150.0;
pub const WD_ADC_LO: f64 = 0.0;
pub const WD_ADC_HI: f64 = 40.0;
pub const ADC_LEVELS: f64 = 16383.0;
pub const TB0_NOISE: f64 = 0.02;
pub const WD_NOISE: f64 = 0.0005;
