//! Closed-loop HITL twin: plant + ADC + cascaded PID + attack injector.
//! Step-for-step mirror of `python/compile/plant.py::Simulator` —
//! golden-trace-pinned.

use super::attacks::{Attack, AttackEffects};
use super::pid::PidState;
use super::plant::{adc, plant_step, PlantState};
use super::*;
use crate::util::rng::SplitMix64;

/// What the PLC sees on one scan cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScanReading {
    pub tb0_adc: f64,
    pub wd_adc: f64,
    pub ws_cmd: f64,
    pub attack_active: bool,
}

/// Closed-loop defense actuation applied between the attack fold and
/// the controller (set by the fleet driver when the detector fires;
/// see `fleet::driver`). The default posture is fully inactive and
/// leaves `step()` arithmetic bit-identical to the golden trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DefensePosture {
    /// Clamp the effective Wd setpoint to
    /// `WD_SET ± SETPOINT_CLAMP_BAND` (neutralizes setpoint tampering
    /// and bounds FDI-driven setpoint drift).
    pub clamp_setpoint: bool,
    /// Manual-fallback mode: actuators are driven at nominal flows,
    /// bypassing attack scaling on Ws/Wr/Wrej. Sensors may still be
    /// spoofed — lockout contains actuator damage, not FDI.
    pub lockout_actuators: bool,
}

/// Width of the setpoint clamp band (t/min) applied under
/// [`DefensePosture::clamp_setpoint`].
pub const SETPOINT_CLAMP_BAND: f64 = 0.25;

/// The closed-loop simulator.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub state: PlantState,
    pub pid: PidState,
    pub attacks: Vec<Attack>,
    /// Active defense posture (all-off by default; when off, `step()`
    /// is bit-identical to the pre-defense simulator).
    pub defense: DefensePosture,
    pub step_idx: u64,
    pub noise: bool,
    rng: SplitMix64,
}

impl Simulator {
    pub fn new(seed: u64, noise: bool, attacks: Vec<Attack>) -> Simulator {
        Simulator {
            state: PlantState::default(),
            pid: PidState::default(),
            attacks,
            defense: DefensePosture::default(),
            step_idx: 0,
            noise,
            rng: SplitMix64::new(seed),
        }
    }

    /// One 100 ms scan cycle: sensors (FDI → noise → ADC) → PID →
    /// actuators (attack scaling) → plant integration. Defense
    /// postures intercept the folded attack effects before they reach
    /// the controller/actuators.
    pub fn step(&mut self) -> ScanReading {
        let mut e = AttackEffects::fold(&self.attacks, self.step_idx);
        if self.defense.clamp_setpoint {
            e.wd_set = e
                .wd_set
                .clamp(WD_SET - SETPOINT_CLAMP_BAND, WD_SET + SETPOINT_CLAMP_BAND);
        }
        if self.defense.lockout_actuators {
            e.ws_scale = 1.0;
            e.wr = WR_NOM;
            e.wrej = WREJ_NOM;
        }

        let mut tb0_s = self.state.tb0 + e.tb0_bias;
        let mut wd_s = self.state.wd * e.wd_scale;
        if self.noise {
            tb0_s += TB0_NOISE * self.rng.normal();
            wd_s += WD_NOISE * self.rng.normal();
        }
        let tb0_adc = adc(tb0_s, TB0_ADC_LO, TB0_ADC_HI);
        let wd_adc = adc(wd_s, WD_ADC_LO, WD_ADC_HI);

        let ws_cmd = self.pid.step(tb0_adc, wd_adc, e.wd_set);
        let ws_applied = (ws_cmd * e.ws_scale).clamp(WS_MIN, WS_MAX);

        self.state = plant_step(self.state, ws_applied, e.wr, e.wrej);
        self.step_idx += 1;
        ScanReading {
            tb0_adc,
            wd_adc,
            ws_cmd,
            attack_active: e.active,
        }
    }

    /// Convenience: run `n` steps, returning the final reading.
    pub fn run(&mut self, n: u64) -> ScanReading {
        let mut last = self.step();
        for _ in 1..n {
            last = self.step();
        }
        last
    }

    /// Run `n` steps collecting every intermediate reading (the fleet
    /// driver's feed). Executes the identical `step()` sequence as
    /// `run(n)` — the collected trace is bit-for-bit the step-by-step
    /// trace (pinned by `tests/plant_golden.rs`).
    pub fn run_collect(&mut self, n: u64) -> Vec<ScanReading> {
        let mut out = Vec::with_capacity(n as usize);
        for _ in 0..n {
            out.push(self.step());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_setpoint_without_noise() {
        let mut sim = Simulator::new(1, false, vec![]);
        sim.run(24_000);
        assert!((sim.state.wd - WD_SET).abs() < 0.01);
        assert!((sim.state.tb0 - TB0_NOM).abs() < 0.5);
    }

    #[test]
    fn wd_statistics_match_paper_scale() {
        // Fig. 8: mean 19.18, σ ≈ 9.5e-4 on the measured Wd series.
        let mut sim = Simulator::new(3, true, vec![]);
        let mut xs = Vec::new();
        for i in 0..12_000u64 {
            let r = sim.step();
            if i >= 6_000 {
                xs.push(r.wd_adc);
            }
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!((mean - 19.18).abs() < 0.01, "mean {mean}");
        let std = var.sqrt();
        assert!((2e-4..5e-3).contains(&std), "std {std}");
    }

    #[test]
    fn every_family_perturbs_observables() {
        for family in crate::msf::attacks::AttackFamily::ALL {
            let mag = match family {
                crate::msf::attacks::AttackFamily::Tb0Fdi => 3.0,
                crate::msf::attacks::AttackFamily::SetpointTamper => 2.0,
                _ => 0.3,
            };
            let mut base = Simulator::new(2, false, vec![]);
            let mut attacked = Simulator::new(
                2,
                false,
                vec![Attack::new(family, mag, 1000, 9000)],
            );
            let mut dev: f64 = 0.0;
            for i in 0..9000 {
                let b = base.step();
                let a = attacked.step();
                if i > 2000 {
                    dev = dev.max(
                        (a.tb0_adc - b.tb0_adc).abs() / 90.0
                            + (a.wd_adc - b.wd_adc).abs() / 19.0,
                    );
                }
            }
            assert!(dev > 0.002, "{family:?}: max deviation {dev}");
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Simulator::new(9, true, vec![]);
        let mut b = Simulator::new(9, true, vec![]);
        for _ in 0..500 {
            assert_eq!(a.step(), b.step());
        }
    }

    #[test]
    fn run_collect_matches_step_by_step_bit_for_bit() {
        let attacks = vec![Attack::new(
            crate::msf::attacks::AttackFamily::Combined,
            0.5,
            100,
            400,
        )];
        let mut collected = Simulator::new(5, true, attacks.clone());
        let mut stepped = Simulator::new(5, true, attacks);
        let trace = collected.run_collect(600);
        assert_eq!(trace.len(), 600);
        for (i, r) in trace.iter().enumerate() {
            assert_eq!(*r, stepped.step(), "step {i}");
        }
        assert_eq!(collected.step_idx, stepped.step_idx);
        assert_eq!(collected.state.tb0.to_bits(), stepped.state.tb0.to_bits());
        assert_eq!(collected.state.tbot.to_bits(), stepped.state.tbot.to_bits());
        assert_eq!(collected.state.wd.to_bits(), stepped.state.wd.to_bits());
    }

    #[test]
    fn lockout_neutralizes_actuator_attack_bit_for_bit() {
        // With actuators locked to nominal flows, an actuator-side
        // campaign has zero effect on the physics or the (unspoofed)
        // sensors — the attacked run matches the benign run exactly.
        let mut benign = Simulator::new(4, true, vec![]);
        let mut attacked = Simulator::new(
            4,
            true,
            vec![Attack::new(
                crate::msf::attacks::AttackFamily::SteamBias,
                0.4,
                0,
                10_000,
            )],
        );
        attacked.defense.lockout_actuators = true;
        for i in 0..3_000 {
            let b = benign.step();
            let a = attacked.step();
            assert_eq!(a.tb0_adc.to_bits(), b.tb0_adc.to_bits(), "step {i}");
            assert_eq!(a.wd_adc.to_bits(), b.wd_adc.to_bits(), "step {i}");
            assert_eq!(a.ws_cmd.to_bits(), b.ws_cmd.to_bits(), "step {i}");
            assert!(a.attack_active);
        }
        assert_eq!(attacked.state.wd.to_bits(), benign.state.wd.to_bits());
    }

    #[test]
    fn setpoint_clamp_bounds_tampering() {
        let tamper = vec![Attack::new(
            crate::msf::attacks::AttackFamily::SetpointTamper,
            2.0,
            0,
            30_000,
        )];
        let mut undefended = Simulator::new(6, false, tamper.clone());
        undefended.run(30_000);
        let mut clamped = Simulator::new(6, false, tamper);
        clamped.defense.clamp_setpoint = true;
        clamped.run(30_000);
        let dev_undef = (undefended.state.wd - WD_SET).abs();
        let dev_clamp = (clamped.state.wd - WD_SET).abs();
        assert!(dev_undef > 1.5, "tamper should move wd: {dev_undef}");
        assert!(
            dev_clamp < SETPOINT_CLAMP_BAND + 0.05,
            "clamp should bound wd drift: {dev_clamp}"
        );
    }

    #[test]
    fn default_posture_is_inactive() {
        let mut plain = Simulator::new(7, true, vec![]);
        let mut defended = Simulator::new(7, true, vec![]);
        defended.defense = DefensePosture::default();
        for _ in 0..200 {
            assert_eq!(plain.step(), defended.step());
        }
    }

    #[test]
    fn pid_recovers_after_transient_attack() {
        let mut sim = Simulator::new(
            1,
            false,
            vec![Attack::new(
                crate::msf::attacks::AttackFamily::RecycleReduction,
                0.1,
                1000,
                4000,
            )],
        );
        sim.run(30_000);
        assert!((sim.state.wd - WD_SET).abs() < 0.05);
    }
}
