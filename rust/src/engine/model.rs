//! Sequential model executor with preallocated buffers (no allocation
//! on the inference hot path) and chunk-resumable evaluation for §6.3
//! multipart inference.
//!
//! The weights ([`Model`]) and the mutable evaluation scratch
//! ([`Activations`]) are split: every inference entry point has a
//! `&self` variant taking external activations
//! ([`Model::infer_with`] / [`Model::infer_partial_with`]), so one
//! `Arc<Model>` serves any number of concurrent sessions, each owning
//! its own `Activations`. The historical `&mut self` methods remain as
//! thin wrappers over a model-owned scratch for single-threaded use.

use super::layers::Layer;

/// The mutable evaluation state of one in-flight model evaluation:
/// ping-pong activation buffers + the quantization scratch. Per
/// session/thread; the model itself stays immutable and shared.
#[derive(Debug, Clone, Default)]
pub struct Activations {
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
    scratch: Vec<i32>,
}

impl Activations {
    /// Activations pre-sized for `model` (the zero-alloc hot path
    /// requires the buffers to be grown before the first call).
    pub fn for_model(model: &Model) -> Activations {
        let mut a = Activations::default();
        a.ensure(model.max_dim);
        a
    }

    #[inline]
    fn ensure(&mut self, dim: usize) {
        if self.buf_a.len() < dim {
            self.buf_a.resize(dim, 0.0);
            self.buf_b.resize(dim, 0.0);
        }
    }
}

/// A sequential ICSML model on the native engine. Weights are
/// immutable after construction (`&self` inference via
/// [`Model::infer_with`]); the lazily-populated scratch only backs the
/// `&mut self` convenience wrappers, so a shared `Arc<Model>` that is
/// only ever used through sessions carries no per-call buffers.
#[derive(Debug, Clone)]
pub struct Model {
    layers: Vec<Layer>,
    max_dim: usize,
    /// `None` until the first `&mut self` inference call; sessions
    /// never touch it (they own their [`Activations`]).
    acts: Option<Activations>,
}

/// A resumable position inside a model evaluation: `(layer, next_row)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Cursor {
    pub layer: usize,
    pub row: usize,
}

impl Model {
    pub fn new(layers: Vec<Layer>) -> Model {
        assert!(!layers.is_empty(), "model needs at least one layer");
        for (i, pair) in layers.windows(2).enumerate() {
            assert_eq!(
                pair[0].out_dim(),
                pair[1].in_dim(),
                "layer {i} out_dim != layer {} in_dim",
                i + 1
            );
        }
        let max_dim = layers
            .iter()
            .flat_map(|l| [l.in_dim(), l.out_dim()])
            .max()
            .unwrap();
        Model { layers, max_dim, acts: None }
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn in_dim(&self) -> usize {
        self.layers[0].in_dim()
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim()
    }

    /// Total multiply-accumulate count (timing-model input).
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Single-shot inference into a caller-provided buffer using
    /// caller-owned [`Activations`] — the allocation-free, `&self`
    /// (thread-shareable) hot path (`out.len()` must equal
    /// [`Model::out_dim`]).
    pub fn infer_with(
        &self,
        acts: &mut Activations,
        x: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), self.in_dim());
        assert_eq!(out.len(), self.out_dim());
        acts.ensure(self.max_dim);
        acts.buf_a[..x.len()].copy_from_slice(x);
        let mut cur_len = x.len();
        let n_layers = self.layers.len();
        for i in 0..n_layers {
            let l = &self.layers[i];
            let out_len = l.out_dim();
            let (src, dst) = if i % 2 == 0 {
                (&acts.buf_a, &mut acts.buf_b)
            } else {
                (&acts.buf_b, &mut acts.buf_a)
            };
            l.eval_rows(
                0,
                l.chunk_rows(),
                &src[..cur_len],
                &mut dst[..out_len],
                &mut acts.scratch,
            );
            cur_len = out_len;
        }
        let src = if n_layers % 2 == 0 { &acts.buf_a } else { &acts.buf_b };
        out.copy_from_slice(&src[..cur_len]);
    }

    /// Single-shot inference via the model-owned scratch (convenience
    /// for single-threaded callers; sessions use [`Model::infer_with`]).
    /// The scratch is created on the first call and reused afterwards,
    /// so steady-state calls stay allocation-free.
    pub fn infer_into(&mut self, x: &[f32], out: &mut [f32]) {
        let mut acts = self.acts.take().unwrap_or_default();
        self.infer_with(&mut acts, x, out);
        self.acts = Some(acts);
    }

    /// Single-shot inference (allocating wrapper over
    /// [`Model::infer_into`]).
    pub fn infer(&mut self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.out_dim()];
        self.infer_into(x, &mut out);
        out
    }

    /// Resumable inference: advance from `cursor` by at most
    /// `row_budget` output rows. Returns the new cursor and, when the
    /// model is finished, the output. The input `x` must be identical
    /// across the parts of one inference.
    ///
    /// This is the mechanism behind the paper's §6.3 multipart
    /// inference — the coordinator sizes `row_budget` to the scan
    /// cycle's spare time.
    pub fn infer_partial(
        &mut self,
        x: &[f32],
        cursor: Cursor,
        row_budget: usize,
    ) -> (Cursor, Option<Vec<f32>>) {
        let mut out = vec![0.0f32; self.out_dim()];
        let (c, done) = self.infer_partial_into(x, cursor, row_budget, &mut out);
        (c, done.then_some(out))
    }

    /// [`Model::infer_partial`] writing the completed output into a
    /// caller-provided buffer (no allocation); returns the new cursor
    /// and whether the inference completed this call.
    pub fn infer_partial_into(
        &mut self,
        x: &[f32],
        cursor: Cursor,
        row_budget: usize,
        out: &mut [f32],
    ) -> (Cursor, bool) {
        let mut acts = self.acts.take().unwrap_or_default();
        let r = self.infer_partial_with(&mut acts, x, cursor, row_budget, out);
        self.acts = Some(acts);
        r
    }

    /// Resumable inference over caller-owned [`Activations`] — the
    /// `&self` session variant of [`Model::infer_partial_into`]. The
    /// suspended state between calls lives entirely in `acts`, so
    /// independent sessions over one shared model never interfere.
    pub fn infer_partial_with(
        &self,
        acts: &mut Activations,
        x: &[f32],
        mut cursor: Cursor,
        mut row_budget: usize,
        out: &mut [f32],
    ) -> (Cursor, bool) {
        assert_eq!(x.len(), self.in_dim());
        assert_eq!(out.len(), self.out_dim());
        acts.ensure(self.max_dim);
        if cursor.layer == 0 && cursor.row == 0 {
            acts.buf_a[..x.len()].copy_from_slice(x);
        }
        let n_layers = self.layers.len();
        while cursor.layer < n_layers && row_budget > 0 {
            let i = cursor.layer;
            let l = &self.layers[i];
            let rows = l.chunk_rows();
            let take = row_budget.min(rows - cursor.row);
            let cur_len = l.in_dim();
            let out_len = l.out_dim();
            let (src, dst) = if i % 2 == 0 {
                (&acts.buf_a, &mut acts.buf_b)
            } else {
                (&acts.buf_b, &mut acts.buf_a)
            };
            l.eval_rows(
                cursor.row,
                cursor.row + take,
                &src[..cur_len],
                &mut dst[..out_len],
                &mut acts.scratch,
            );
            cursor.row += take;
            row_budget -= take;
            if cursor.row == rows {
                cursor.layer += 1;
                cursor.row = 0;
            }
        }
        if cursor.layer == n_layers {
            let cur_len = self.out_dim();
            let src =
                if n_layers % 2 == 0 { &acts.buf_a } else { &acts.buf_b };
            out.copy_from_slice(&src[..cur_len]);
            (cursor, true)
        } else {
            (cursor, false)
        }
    }

    /// Total chunk rows across all layers (for budgeting).
    pub fn total_rows(&self) -> usize {
        self.layers.iter().map(Layer::chunk_rows).sum()
    }

    /// Rows left from `cursor` to the end of the model.
    pub fn remaining_rows(&self, cursor: Cursor) -> usize {
        if cursor.layer >= self.layers.len() {
            return 0;
        }
        let rest: usize = self.layers[cursor.layer..]
            .iter()
            .map(Layer::chunk_rows)
            .sum();
        rest - cursor.row
    }
}

#[cfg(test)]
mod tests {
    use super::super::layers::Act;
    use super::*;
    use crate::util::prop::{prop_assert, prop_check};

    fn toy_model() -> Model {
        Model::new(vec![
            Layer::Input { dim: 4 },
            Layer::dense(
                (0..12).map(|i| (i as f32) * 0.1 - 0.6).collect(),
                vec![0.1, -0.1, 0.2],
                4,
                Act::Relu,
            ),
            Layer::dense(
                (0..6).map(|i| 0.3 - (i as f32) * 0.07).collect(),
                vec![0.05, -0.3],
                3,
                Act::None,
            ),
        ])
    }

    #[test]
    fn infer_shapes() {
        let mut m = toy_model();
        let y = m.infer(&[0.5, -0.25, 1.0, 2.0]);
        assert_eq!(y.len(), 2);
        assert_eq!(m.in_dim(), 4);
        assert_eq!(m.out_dim(), 2);
    }

    #[test]
    #[should_panic(expected = "out_dim != layer")]
    fn mismatched_layers_rejected() {
        Model::new(vec![
            Layer::Input { dim: 4 },
            Layer::dense(vec![0.0; 10], vec![0.0; 2], 5, Act::None),
        ]);
    }

    #[test]
    fn partial_inference_matches_single_shot() {
        // Property: any chunking schedule produces the single-shot
        // output exactly (the §6.3 correctness invariant).
        prop_check(60, |g| {
            let mut m = toy_model();
            let x: Vec<f32> = (0..4).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let want = m.infer(&x);
            let mut cursor = Cursor::default();
            let mut result = None;
            let mut steps = 0;
            while result.is_none() {
                let budget = g.usize_in(1..=3);
                let (c, r) = m.infer_partial(&x, cursor, budget);
                cursor = c;
                result = r;
                steps += 1;
                prop_assert(steps < 100, "did not converge")?;
            }
            prop_assert(
                result.as_deref() == Some(&want[..]),
                format!("partial {result:?} != full {want:?}"),
            )
        });
    }

    #[test]
    fn total_rows_budget_completes_in_one_call() {
        let mut m = toy_model();
        let x = [1.0, 2.0, 3.0, 4.0];
        let want = m.infer(&x);
        let budget = m.total_rows();
        let (c, out) = m.infer_partial(&x, Cursor::default(), budget);
        assert_eq!(c.layer, m.layers().len());
        assert_eq!(out.unwrap(), want);
    }

    #[test]
    fn macs_sum() {
        let m = toy_model();
        assert_eq!(m.macs(), 4 + 12 + 6);
    }

    #[test]
    fn infer_into_matches_infer() {
        let mut m = toy_model();
        let x = [0.5, -0.25, 1.0, 2.0];
        let want = m.infer(&x);
        let mut out = [0.0f32; 2];
        m.infer_into(&x, &mut out);
        assert_eq!(out.to_vec(), want);
    }

    #[test]
    fn infer_with_matches_infer_into_and_sessions_are_independent() {
        let mut m = toy_model();
        let xa = [0.5, -0.25, 1.0, 2.0];
        let xb = [-1.0, 0.75, 0.1, -0.4];
        let want_a = m.infer(&xa);
        let want_b = m.infer(&xb);
        // Two activation sets over the same immutable model, with an
        // interleaved partial evaluation in one of them: neither may
        // observe the other.
        let mut acts1 = Activations::for_model(&m);
        let mut acts2 = Activations::for_model(&m);
        let mut out_a = [0.0f32; 2];
        let mut out_b = [0.0f32; 2];
        let (c, done) = m.infer_partial_with(
            &mut acts1,
            &xa,
            Cursor::default(),
            2,
            &mut out_a,
        );
        assert!(!done);
        m.infer_with(&mut acts2, &xb, &mut out_b);
        assert_eq!(out_b.to_vec(), want_b);
        // Resume the suspended session; it must be unharmed.
        let total = m.total_rows();
        let (_, done) =
            m.infer_partial_with(&mut acts1, &xa, c, total, &mut out_a);
        assert!(done);
        assert_eq!(out_a.to_vec(), want_a);
    }

    #[test]
    fn remaining_rows_counts_down() {
        let mut m = toy_model();
        let total = m.total_rows();
        assert_eq!(m.remaining_rows(Cursor::default()), total);
        let (c, _) = m.infer_partial(&[0.0; 4], Cursor::default(), 3);
        assert_eq!(m.remaining_rows(c), total - 3);
        let (c, done) = m.infer_partial(&[0.0; 4], c, total);
        assert!(done.is_some());
        assert_eq!(m.remaining_rows(c), 0);
    }
}
