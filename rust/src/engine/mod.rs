//! Native-Rust ICSML engine.
//!
//! Semantically identical to the ST framework in [`crate::icsml_st`]
//! (same layer set, same math, same weight layout), compiled with full
//! optimization. It serves three roles (DESIGN.md §3):
//!
//! 1. the paper's §5.4 comparator ("we faithfully reimplemented ICSML
//!    in C++ ... -O3 ran ~4x faster");
//! 2. the resumable executor behind §6.3 multipart inference (layers
//!    can be evaluated in output-row chunks across scan cycles);
//! 3. a cross-check between the ST interpreter and the XLA runtime.

pub mod layers;
pub mod model;

pub use layers::{Act, Layer};
pub use model::{Activations, Cursor, Model};
