//! Layer definitions + kernels for the native engine.
//!
//! Weight layouts match the ICSML ST framework exactly:
//! dense `[neurons][inputs]` row-major; conv `[outC][inC][kh][kw]`;
//! depthwise `[C][kh][kw]`; CHW activations.

use crate::quant::Scheme;

/// Activation functions (paper §4.1 set). Codes match the ST
/// framework's ACT_* constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    LeakyRelu,
    Elu,
    Sigmoid,
    Tanh,
    Swish,
    BinaryStep,
    Softmax,
}

impl Act {
    /// ST framework activation code.
    pub fn code(self) -> i64 {
        match self {
            Act::None => 0,
            Act::Relu => 1,
            Act::LeakyRelu => 2,
            Act::Elu => 3,
            Act::Sigmoid => 4,
            Act::Tanh => 5,
            Act::Swish => 6,
            Act::BinaryStep => 7,
            Act::Softmax => 8,
        }
    }

    pub fn from_name(name: &str) -> Option<Act> {
        Some(match name {
            "linear" | "none" => Act::None,
            "relu" => Act::Relu,
            "leaky_relu" => Act::LeakyRelu,
            "elu" => Act::Elu,
            "sigmoid" => Act::Sigmoid,
            "tanh" => Act::Tanh,
            "swish" => Act::Swish,
            "binary_step" => Act::BinaryStep,
            "softmax" => Act::Softmax,
            _ => return None,
        })
    }

    /// Scalar application (softmax handled at the vector level).
    #[inline]
    pub fn apply(self, v: f32, alpha: f32) -> f32 {
        match self {
            Act::None | Act::Softmax => v,
            Act::Relu => v.max(0.0),
            Act::LeakyRelu => {
                if v >= 0.0 {
                    v
                } else {
                    alpha * v
                }
            }
            Act::Elu => {
                if v >= 0.0 {
                    v
                } else {
                    alpha * (v.exp() - 1.0)
                }
            }
            Act::Sigmoid => 1.0 / (1.0 + (-v).exp()),
            Act::Tanh => v.tanh(),
            Act::Swish => v / (1.0 + (-v).exp()),
            Act::BinaryStep => {
                if v >= 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Vector application (handles softmax).
    pub fn apply_vec(self, data: &mut [f32], alpha: f32) {
        if self == Act::Softmax {
            let m = data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in data.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            for v in data.iter_mut() {
                *v /= sum;
            }
            return;
        }
        for v in data.iter_mut() {
            *v = self.apply(*v, alpha);
        }
    }
}

/// One model layer. `in_dim`/`out_dim` are flat element counts.
#[derive(Debug, Clone)]
pub enum Layer {
    /// Copy layer (the paper's benchmark input layer).
    Input { dim: usize },
    Dense {
        /// `[neurons][inputs]` row-major (ICSML layout).
        w: Vec<f32>,
        b: Vec<f32>,
        inputs: usize,
        neurons: usize,
        act: Act,
        alpha: f32,
        /// §6.2 zero-weight skipping.
        pruned: bool,
    },
    Activation { dim: usize, act: Act, alpha: f32 },
    QuantDense {
        /// Quantized weights widened to i32 storage (scheme gives the
        /// on-PLC width for memory accounting + ST codegen).
        wq: Vec<i32>,
        s_w: Vec<f32>,
        b: Vec<f32>,
        s_x: f32,
        scheme: Scheme,
        inputs: usize,
        neurons: usize,
        act: Act,
        alpha: f32,
        skip_zero_w: bool,
        skip_zero_x: bool,
    },
    Conv2D {
        w: Vec<f32>,
        b: Vec<f32>,
        in_c: usize,
        in_h: usize,
        in_w: usize,
        out_c: usize,
        k_h: usize,
        k_w: usize,
        stride: usize,
        act: Act,
        alpha: f32,
    },
    ConvDW {
        w: Vec<f32>,
        b: Vec<f32>,
        chans: usize,
        in_h: usize,
        in_w: usize,
        k_h: usize,
        k_w: usize,
        stride: usize,
        act: Act,
        alpha: f32,
    },
    /// Per-channel affine (inference-folded BatchNorm), CHW layout.
    Scale {
        scales: Vec<f32>,
        shifts: Vec<f32>,
        channels: usize,
        dim: usize,
        act: Act,
        alpha: f32,
    },
}

impl Layer {
    pub fn dense(w: Vec<f32>, b: Vec<f32>, inputs: usize, act: Act) -> Layer {
        let neurons = b.len();
        assert_eq!(w.len(), inputs * neurons, "dense weight shape");
        Layer::Dense { w, b, inputs, neurons, act, alpha: 0.01, pruned: false }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            Layer::Input { dim } => *dim,
            Layer::Dense { inputs, .. } => *inputs,
            Layer::Activation { dim, .. } => *dim,
            Layer::QuantDense { inputs, .. } => *inputs,
            Layer::Conv2D { in_c, in_h, in_w, .. } => in_c * in_h * in_w,
            Layer::ConvDW { chans, in_h, in_w, .. } => chans * in_h * in_w,
            Layer::Scale { dim, .. } => *dim,
        }
    }

    pub fn out_dim(&self) -> usize {
        match self {
            Layer::Input { dim } => *dim,
            Layer::Dense { neurons, .. } => *neurons,
            Layer::Activation { dim, .. } => *dim,
            Layer::QuantDense { neurons, .. } => *neurons,
            Layer::Conv2D { out_c, .. } => {
                let (oh, ow) = self.conv_out_hw();
                out_c * oh * ow
            }
            Layer::ConvDW { chans, .. } => {
                let (oh, ow) = self.conv_out_hw();
                chans * oh * ow
            }
            Layer::Scale { dim, .. } => *dim,
        }
    }

    /// Output spatial size for conv layers.
    pub fn conv_out_hw(&self) -> (usize, usize) {
        match self {
            Layer::Conv2D { in_h, in_w, k_h, k_w, stride, .. }
            | Layer::ConvDW { in_h, in_w, k_h, k_w, stride, .. } => {
                ((in_h - k_h) / stride + 1, (in_w - k_w) / stride + 1)
            }
            _ => (0, 0),
        }
    }

    /// Number of independent output "rows" for chunked (multipart)
    /// evaluation: dense/quant → neurons; conv → out-channel rows;
    /// element-wise layers → 1 chunk.
    pub fn chunk_rows(&self) -> usize {
        match self {
            Layer::Dense { neurons, .. } | Layer::QuantDense { neurons, .. } => {
                *neurons
            }
            Layer::Conv2D { out_c, .. } => *out_c,
            Layer::ConvDW { chans, .. } => *chans,
            _ => 1,
        }
    }

    /// Evaluate output rows `[row0, row1)` from `x` into `out`.
    /// `eval_rows(0, chunk_rows(), ..)` is a full evaluation. Softmax /
    /// input-quantization pre-passes run on the first chunk.
    pub fn eval_rows(&self, row0: usize, row1: usize, x: &[f32], out: &mut [f32],
                     scratch: &mut Vec<i32>) {
        debug_assert_eq!(x.len(), self.in_dim());
        debug_assert_eq!(out.len(), self.out_dim());
        match self {
            Layer::Input { dim } => {
                out[..*dim].copy_from_slice(&x[..*dim]);
            }
            Layer::Activation { act, alpha, .. } => {
                out.copy_from_slice(x);
                act.apply_vec(out, *alpha);
            }
            Layer::Scale { scales, shifts, channels, dim, act, alpha } => {
                let per = dim / channels;
                for i in 0..*dim {
                    let c = i / per;
                    out[i] = act.apply(x[i] * scales[c] + shifts[c], *alpha);
                }
            }
            Layer::Dense { w, b, inputs, act, alpha, pruned, .. } => {
                for n in row0..row1 {
                    let row = &w[n * inputs..(n + 1) * inputs];
                    let mut s = 0.0f32;
                    if *pruned {
                        for (wi, xi) in row.iter().zip(x) {
                            if *wi != 0.0 {
                                s += wi * xi;
                            }
                        }
                    } else {
                        for (wi, xi) in row.iter().zip(x) {
                            s += wi * xi;
                        }
                    }
                    out[n] = act.apply(s + b[n], *alpha);
                }
                if *act == Act::Softmax && row1 == self.chunk_rows() {
                    Act::Softmax.apply_vec(out, *alpha);
                }
            }
            Layer::QuantDense {
                wq, s_w, b, s_x, inputs, act, alpha,
                skip_zero_w, skip_zero_x, ..
            } => {
                if row0 == 0 {
                    // quantize the input vector once per inference
                    scratch.clear();
                    scratch.extend(x.iter().map(|v| {
                        let q = v / s_x;
                        // IEC round-half-away-from-zero
                        (if q >= 0.0 {
                            (q + 0.5).floor()
                        } else {
                            (q - 0.5).ceil()
                        }) as i32
                    }));
                }
                let xq = &scratch[..];
                for n in row0..row1 {
                    let row = &wq[n * inputs..(n + 1) * inputs];
                    let mut acc: i32 = 0;
                    match (skip_zero_w, skip_zero_x) {
                        (true, true) => {
                            for (wi, xi) in row.iter().zip(xq) {
                                if *wi != 0 && *xi != 0 {
                                    acc = acc.wrapping_add(wi.wrapping_mul(*xi));
                                }
                            }
                        }
                        (true, false) => {
                            for (wi, xi) in row.iter().zip(xq) {
                                if *wi != 0 {
                                    acc = acc.wrapping_add(wi.wrapping_mul(*xi));
                                }
                            }
                        }
                        _ => {
                            for (wi, xi) in row.iter().zip(xq) {
                                acc = acc.wrapping_add(wi.wrapping_mul(*xi));
                            }
                        }
                    }
                    let v = acc as f32 * (s_x * s_w[n]) + b[n];
                    out[n] = act.apply(v, *alpha);
                }
            }
            Layer::Conv2D {
                w, b, in_c, in_h, in_w, out_c: _, k_h, k_w, stride, act, alpha,
            } => {
                let (oh, ow) = self.conv_out_hw();
                for oc in row0..row1 {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut s = b[oc];
                            for ic in 0..*in_c {
                                let wbase = ((oc * in_c) + ic) * k_h * k_w;
                                for ky in 0..*k_h {
                                    let xrow = (ic * in_h + oy * stride + ky)
                                        * in_w
                                        + ox * stride;
                                    for kx in 0..*k_w {
                                        s += w[wbase + ky * k_w + kx]
                                            * x[xrow + kx];
                                    }
                                }
                            }
                            out[(oc * oh + oy) * ow + ox] =
                                act.apply(s, *alpha);
                        }
                    }
                }
            }
            Layer::ConvDW {
                w, b, chans: _, in_h, in_w, k_h, k_w, stride, act, alpha,
            } => {
                let (oh, ow) = self.conv_out_hw();
                for c in row0..row1 {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut s = b[c];
                            for ky in 0..*k_h {
                                for kx in 0..*k_w {
                                    s += w[(c * k_h + ky) * k_w + kx]
                                        * x[(c * in_h + oy * stride + ky)
                                            * in_w
                                            + ox * stride
                                            + kx];
                                }
                            }
                            out[(c * oh + oy) * ow + ox] = act.apply(s, *alpha);
                        }
                    }
                }
            }
        }
    }

    /// Abstract multiply-accumulate count for one full evaluation (used
    /// by the PLC timing model for layers run on the native engine).
    pub fn macs(&self) -> u64 {
        match self {
            Layer::Input { dim } | Layer::Activation { dim, .. } => *dim as u64,
            Layer::Scale { dim, .. } => 2 * *dim as u64,
            Layer::Dense { inputs, neurons, .. }
            | Layer::QuantDense { inputs, neurons, .. } => {
                (*inputs * *neurons) as u64
            }
            Layer::Conv2D { in_c, out_c, k_h, k_w, .. } => {
                let (oh, ow) = self.conv_out_hw();
                (in_c * out_c * k_h * k_w * oh * ow) as u64
            }
            Layer::ConvDW { chans, k_h, k_w, .. } => {
                let (oh, ow) = self.conv_out_hw();
                (chans * k_h * k_w * oh * ow) as u64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn act_codes_match_st_framework() {
        assert_eq!(Act::None.code(), 0);
        assert_eq!(Act::Relu.code(), 1);
        assert_eq!(Act::Softmax.code(), 8);
        assert_eq!(Act::from_name("relu"), Some(Act::Relu));
        assert_eq!(Act::from_name("linear"), Some(Act::None));
        assert_eq!(Act::from_name("nope"), None);
    }

    #[test]
    fn dense_known_values() {
        let l = Layer::dense(
            vec![1.0, 2.0, 3.0, 4.0],
            vec![0.5, -10.0],
            2,
            Act::Relu,
        );
        let mut out = vec![0.0; 2];
        let mut scratch = Vec::new();
        l.eval_rows(0, 2, &[1.0, 2.0], &mut out, &mut scratch);
        assert_eq!(out, vec![5.5, 1.0]);
    }

    #[test]
    fn dense_chunked_equals_full() {
        let w: Vec<f32> = (0..12).map(|i| (i as f32) * 0.1 - 0.5).collect();
        let b = vec![0.1, -0.2, 0.3];
        let l = Layer::dense(w, b, 4, Act::Sigmoid);
        let x = [0.5, -1.0, 2.0, 0.25];
        let mut full = vec![0.0; 3];
        let mut chunked = vec![0.0; 3];
        let mut s = Vec::new();
        l.eval_rows(0, 3, &x, &mut full, &mut s);
        l.eval_rows(0, 1, &x, &mut chunked, &mut s);
        l.eval_rows(1, 2, &x, &mut chunked, &mut s);
        l.eval_rows(2, 3, &x, &mut chunked, &mut s);
        assert_eq!(full, chunked);
    }

    #[test]
    fn pruned_dense_matches_unpruned_on_sparse_weights() {
        let w = vec![0.0, 2.0, 0.0, 4.0, 0.0, 0.0];
        let b = vec![1.0, 2.0];
        let mut dense = Layer::dense(w.clone(), b.clone(), 3, Act::None);
        let x = [1.0, 2.0, 3.0];
        let mut out_a = vec![0.0; 2];
        let mut s = Vec::new();
        dense.eval_rows(0, 2, &x, &mut out_a, &mut s);
        if let Layer::Dense { pruned, .. } = &mut dense {
            *pruned = true;
        }
        let mut out_b = vec![0.0; 2];
        dense.eval_rows(0, 2, &x, &mut out_b, &mut s);
        assert_eq!(out_a, out_b);
    }

    #[test]
    fn softmax_vec() {
        let mut v = vec![1.0f32, 2.0, 3.0];
        Act::Softmax.apply_vec(&mut v, 0.0);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((v[2] - 0.66524).abs() < 1e-4);
    }

    #[test]
    fn conv2d_matches_st_test_vector() {
        let l = Layer::Conv2D {
            w: vec![1.0; 4],
            b: vec![1.0],
            in_c: 1,
            in_h: 3,
            in_w: 3,
            out_c: 1,
            k_h: 2,
            k_w: 2,
            stride: 1,
            act: Act::None,
            alpha: 0.0,
        };
        let x: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let mut out = vec![0.0; 4];
        let mut s = Vec::new();
        l.eval_rows(0, 1, &x, &mut out, &mut s);
        assert_eq!(out, vec![13.0, 17.0, 25.0, 29.0]);
    }

    #[test]
    fn macs_counts() {
        let l = Layer::dense(vec![0.0; 512 * 512], vec![0.0; 512], 512, Act::None);
        assert_eq!(l.macs(), 262_144); // the paper's §6.1 op count
    }
}
