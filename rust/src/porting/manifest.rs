//! `artifacts/manifest.json` schema (the contract with
//! `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One dense layer's export record.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub inputs: usize,
    pub neurons: usize,
    /// File names relative to `weights_dir`.
    pub weights: String,
    pub biases: String,
}

/// One exported model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub sizes: Vec<usize>,
    pub activations: Vec<String>,
    pub weights_dir: String,
    pub layers: Vec<LayerSpec>,
    /// Training report (accuracy etc.), kept as raw JSON.
    pub report: Json,
}

impl ModelSpec {
    /// Flattened input feature count, from the manifest's layer sizes.
    /// (`Manifest::load` validates `sizes` is non-empty, so consumers
    /// never hardcode dims like the old `x.len() / 400`.)
    pub fn in_dim(&self) -> usize {
        self.sizes.first().copied().unwrap_or(0)
    }

    /// Flattened output (logit) count.
    pub fn out_dim(&self) -> usize {
        self.sizes.last().copied().unwrap_or(0)
    }
}

/// Parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    /// HLO artifact name -> path relative to root.
    pub hlo: BTreeMap<String, String>,
    pub dataset: Json,
    pub plant: Json,
    pub golden_trace: String,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).context("parse manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in j.expect("models").as_obj().unwrap() {
            let sizes: Vec<usize> = m
                .expect("sizes")
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect();
            let activations: Vec<String> = m
                .expect("activations")
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_str().unwrap().to_string())
                .collect();
            let layers: Vec<LayerSpec> = m
                .expect("layers")
                .as_arr()
                .unwrap()
                .iter()
                .map(|l| LayerSpec {
                    inputs: l.expect("inputs").as_usize().unwrap(),
                    neurons: l.expect("neurons").as_usize().unwrap(),
                    weights: l.expect("weights").as_str().unwrap().to_string(),
                    biases: l.expect("biases").as_str().unwrap().to_string(),
                })
                .collect();
            anyhow::ensure!(
                !sizes.is_empty()
                    && layers.len() + 1 == sizes.len()
                    && activations.len() == layers.len(),
                "model {name}: inconsistent manifest"
            );
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    sizes,
                    activations,
                    weights_dir: m
                        .expect("weights_dir")
                        .as_str()
                        .unwrap()
                        .to_string(),
                    layers,
                    report: m.expect("report").clone(),
                },
            );
        }

        let hlo = j
            .expect("hlo")
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap().to_string()))
            .collect();

        Ok(Manifest {
            root: root.to_path_buf(),
            models,
            hlo,
            dataset: j.expect("dataset").clone(),
            plant: j.expect("plant").clone(),
            golden_trace: j
                .expect("golden_trace")
                .as_str()
                .unwrap()
                .to_string(),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("manifest has no model {name}"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        self.hlo
            .get(name)
            .map(|rel| self.root.join(rel))
            .ok_or_else(|| anyhow::anyhow!("manifest has no HLO {name}"))
    }

    /// Resolve a `dataset` entry (e.g. `"eval_windows"`) to an
    /// absolute path — a typed error on a malformed manifest, where
    /// the old `m.dataset.expect(key).as_str().unwrap()` call sites
    /// panicked.
    pub fn dataset_path(&self, key: &str) -> Result<PathBuf> {
        let entry = self.dataset.get(key).ok_or_else(|| {
            anyhow::anyhow!("manifest dataset has no entry {key:?}")
        })?;
        let rel = entry.as_str().ok_or_else(|| {
            anyhow::anyhow!("manifest dataset entry {key:?} is not a path")
        })?;
        Ok(self.root.join(rel))
    }
}

/// Several artifact roots acting as one multi-model namespace — the
/// deployment shape `netserve::ModelRegistry` loads from: one serving
/// process fronting many exported model sets (per-plant manifests,
/// per-PLC-class manifests, ...). Lookup is first-root-wins, so
/// earlier roots shadow later ones on name collisions.
#[derive(Debug, Clone)]
pub struct ManifestSet {
    manifests: Vec<Manifest>,
}

impl ManifestSet {
    /// Load `manifest.json` from each root, in order. Errors if any
    /// root fails to load, or no roots are given.
    pub fn load_roots(roots: &[PathBuf]) -> Result<ManifestSet> {
        anyhow::ensure!(!roots.is_empty(), "no manifest roots given");
        let manifests = roots
            .iter()
            .map(|r| Manifest::load(r))
            .collect::<Result<Vec<_>>>()?;
        Ok(ManifestSet { manifests })
    }

    /// Discover manifest roots under `dir`: the directory itself when
    /// it holds a `manifest.json`, otherwise every immediate
    /// subdirectory that does (sorted by name for determinism).
    pub fn discover(dir: &Path) -> Result<ManifestSet> {
        if dir.join("manifest.json").exists() {
            return ManifestSet::load_roots(&[dir.to_path_buf()]);
        }
        let mut roots: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("scan {}", dir.display()))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.join("manifest.json").exists())
            .collect();
        roots.sort();
        anyhow::ensure!(
            !roots.is_empty(),
            "no manifest.json under {} or its subdirectories",
            dir.display()
        );
        ManifestSet::load_roots(&roots)
    }

    /// The spec for `name` plus the manifest (root) that owns it —
    /// first root wins when several export the same name.
    pub fn model(&self, name: &str) -> Result<(&Manifest, &ModelSpec)> {
        self.manifests
            .iter()
            .find_map(|m| m.models.get(name).map(|s| (m, s)))
            .ok_or_else(|| {
                anyhow::anyhow!("no manifest root has model {name}")
            })
    }

    /// Every exported model name across the roots, sorted + deduped.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .manifests
            .iter()
            .flat_map(|m| m.models.keys().cloned())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// The loaded manifests, in root order.
    pub fn manifests(&self) -> &[Manifest] {
        &self.manifests
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_fast_artifacts_if_present() {
        let root = crate::artifacts_dir();
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let m = Manifest::load(&root).unwrap();
        let clf = m.model("classifier").unwrap();
        assert_eq!(clf.sizes, vec![400, 64, 32, 16, 2]);
        assert_eq!(clf.layers.len(), 4);
        assert!(m.hlo_path("classifier_b1").unwrap().exists());
        let mn = m.model("mnist512").unwrap();
        assert_eq!(mn.sizes, vec![784, 512, 512, 10]);
    }

    #[test]
    fn missing_model_is_error() {
        let root = crate::artifacts_dir();
        if !root.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&root).unwrap();
        assert!(m.model("nope").is_err());
        assert!(m.hlo_path("nope").is_err());
    }
}
