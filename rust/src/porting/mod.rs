//! Model porting toolchain (paper §4.3 + the §8.2 "future work"
//! model-to-model transformation, implemented).
//!
//! Reads `artifacts/manifest.json` (written by `python/compile/aot.py`
//! after training) and materializes the model three ways:
//!
//! * [`codegen::generate_st_program`] — ICSML **ST source code** plus
//!   `BINARR` weight loading, the paper's porting flow;
//! * [`load_engine_model`] — the same model on the native engine;
//! * the HLO artifacts referenced by the manifest feed
//!   [`crate::runtime`] directly (the compiled comparator).

pub mod codegen;
pub mod manifest;

pub use codegen::generate_st_program;
pub use manifest::{LayerSpec, Manifest, ModelSpec};

use std::path::Path;

use anyhow::Result;

use crate::engine::{Act, Layer, Model};
use crate::util::binio;

/// Build a native-engine model from a manifest model spec.
pub fn load_engine_model(root: &Path, spec: &ModelSpec) -> Result<Model> {
    let mut layers = Vec::new();
    for (i, l) in spec.layers.iter().enumerate() {
        let dir = root.join(&spec.weights_dir);
        let w = binio::read_f32(&dir.join(&l.weights))?;
        let b = binio::read_f32(&dir.join(&l.biases))?;
        anyhow::ensure!(
            w.len() == l.inputs * l.neurons && b.len() == l.neurons,
            "layer {i}: weight/bias sizes do not match the manifest"
        );
        let act = Act::from_name(&spec.activations[i]).ok_or_else(|| {
            anyhow::anyhow!("unknown activation {:?}", spec.activations[i])
        })?;
        layers.push(Layer::dense(w, b, l.inputs, act));
    }
    Ok(Model::new(layers))
}
