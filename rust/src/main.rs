//! `icsml` — CLI for the ICSML reproduction.
//!
//! Subcommands:
//! * `table1`  — print the paper's Table 1 (PLC hardware specs).
//! * `fig3`    — PLC memory vs Keras model sizes (Fig. 3 data).
//! * `table2`  — quantization memory requirements (Table 2).
//! * `port`    — generate ICSML ST code for a manifest model (§4.3).
//! * `infer`   — classify one eval window on a chosen backend.
//! * `hitl`    — run the §7 HITL case study (short form; the full
//!               driver is `examples/desalination_defense.rs`).
//! * `serve`   — serve eval windows through a `serve::Pool` (shared
//!               backend, per-worker sessions, deadline-aware
//!               micro-batching): `--requests N --workers W --batch B
//!               [--xla] [--deadline-us D] [--class
//!               control|defense|batch] [--admit bbb|wago]`.
//! * `listen`  — network front door: bind a `netserve::NetServer`
//!               over a lazily-loading model registry: `--addr A
//!               [--roots DIR,DIR,...] [--workers W] [--batch B]
//!               [--max-models N] [--max-mb MB] [--for-secs S]`.
//! * `client`  — drive a running `listen` server over TCP:
//!               `--addr A --model NAME --requests N [--class C]
//!               [--deadline-us D] [--dim K]`.
//! * `fleet`   — closed-loop fleet simulation: N independently seeded
//!               plants drive the hand-built deviation detector
//!               through a netserve front door, with detector
//!               verdicts fed back as defense responses:
//!               `--plants N --duration SECS --attack-mix MIX
//!               [--seed X] [--workers W] [--batch B] [--addr A]
//!               [--deadline] [--no-feedback]`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use icsml::api::{Backend, EngineBackend, Session as _, SharedBackend,
                 StBackend};
use icsml::defense::Detector;
use icsml::fleet::{
    detector_model, run_fleet, AttackMix, FleetConfig, FleetTarget,
};
use icsml::hitl::HitlRunner;
use icsml::msf::{Attack, AttackFamily};
use icsml::netserve::{
    proto::ErrorCode, Client, ManifestLoader, ModelRegistry, NetOptions,
    NetServer, RegistryConfig, RetryPolicy, ServerConfig, StaticLoader,
};
use icsml::plc::{profiles::KERAS_MODEL_SIZES, HwProfile, PLC_SPECS};
use icsml::porting::manifest::ManifestSet;
use icsml::porting::{self, codegen::CodegenOptions, Manifest};
use icsml::quant::{memory_requirements, Scheme};
use icsml::runtime::{Runtime, XlaBackend};
use icsml::serve::{
    Admission, Deadline, Pool, PoolConfig, Priority, SubmitOptions,
};
use icsml::util::bench::Table;
use icsml::util::binio;
use icsml::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&[
        "no-fused", "st", "engine", "xla", "deadline", "no-feedback",
        "st-tasks",
    ]);
    match args.subcommand.as_deref() {
        Some("table1") => table1(),
        Some("fig3") => fig3(),
        Some("table2") => table2(),
        Some("port") => port(&args),
        Some("infer") => infer(&args),
        Some("hitl") => hitl(&args),
        Some("serve") => serve(&args),
        Some("listen") => listen(&args),
        Some("client") => client(&args),
        Some("fleet") => fleet(&args),
        Some("tasks") => tasks(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            } else {
                eprintln!("missing subcommand\n");
            }
            usage();
            // An unrecognized invocation must fail the process (exit
            // code 1), not report success to the calling shell.
            std::process::exit(1);
        }
    }
}

/// The complete operator surface: every subcommand with its options.
fn usage() {
    eprintln!(
        "usage: icsml <subcommand> [options]\n\
         \n\
         subcommands:\n  \
         table1  print the paper's Table 1 (PLC hardware specs)\n  \
         fig3    PLC memory vs Keras model sizes (Fig. 3 data)\n  \
         table2  quantization memory requirements (Table 2)\n  \
         port    --model classifier [--program MAIN] [--out FILE] \
         [--no-fused]\n  \
         infer   --index N [--st|--engine|--xla]\n  \
         hitl    --steps N --attack combined --magnitude 0.5 \
         [--start N]\n  \
         serve   --requests N --workers W --batch B [--xla] \
         [--deadline-us D] [--class control|defense|batch] \
         [--admit bbb|wago]\n  \
         listen  --addr 127.0.0.1:9470 [--roots DIR,DIR] [--workers W] \
         [--batch B] [--max-models N] [--max-mb MB] [--for-secs S]\n  \
         client  --addr 127.0.0.1:9470 --model classifier --requests N \
         [--class C] [--deadline-us D] [--dim K]\n  \
         fleet   --plants N --duration SECS \
         [--attack-mix uniform|benign|fam=w,...] [--seed X] \
         [--workers W] [--batch B] [--addr A] [--deadline] \
         [--no-feedback] [--st-tasks]\n  \
         tasks   --file PROGRAM.st  (dump the parsed §2.7 TaskModel \
         as a table)"
    );
}

/// `icsml tasks --file prog.st` — compile an ST source and print its
/// CONFIGURATION → RESOURCE → TASK model.
fn tasks(args: &Args) -> Result<()> {
    let path = args
        .opt("file")
        .ok_or_else(|| anyhow::anyhow!("tasks needs --file PROGRAM.st"))?;
    let src = std::fs::read_to_string(path)?;
    let unit =
        icsml::st::compile(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
    let model = match &unit.tasks {
        Some(m) => m,
        None => {
            println!(
                "{path}: no CONFIGURATION block ({} program(s) would \
                 freewheel on the implicit scan cycle)",
                unit.programs.len()
            );
            return Ok(());
        }
    };
    println!(
        "CONFIGURATION {} / RESOURCE {} ON {}",
        model.config_name, model.resource_name, model.processor
    );
    let mut t = Table::new(&[
        "Task",
        "Trigger",
        "Priority",
        "Serve band",
        "Programs",
    ]);
    for task in &model.tasks {
        let trigger = match task.trigger {
            icsml::st::Trigger::Cyclic { interval_us } => {
                format!("cyclic every {interval_us} us")
            }
            icsml::st::Trigger::Single { global } => {
                format!("single on {}", unit.globals[global].name)
            }
            icsml::st::Trigger::Freewheeling => "freewheeling".to_string(),
        };
        let programs = task
            .programs
            .iter()
            .map(|b| {
                format!(
                    "{} : {}",
                    b.instance, unit.programs[b.program].name
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        let priority = if task.priority == u32::MAX {
            "lowest".to_string()
        } else {
            task.priority.to_string()
        };
        t.row(&[
            task.name.clone(),
            trigger,
            priority,
            icsml::st::tasks::serve_priority(task.priority)
                .name()
                .to_string(),
            programs,
        ]);
    }
    t.print();
    Ok(())
}

fn table1() -> Result<()> {
    let mut t = Table::new(&[
        "Manufacturer",
        "Models",
        "Avg Time/Instruction (us)",
        "Memory / RAM",
    ]);
    for s in PLC_SPECS {
        t.row(&[
            s.manufacturer.to_string(),
            s.models.to_string(),
            s.time_per_instruction_us.to_string(),
            s.memory.to_string(),
        ]);
    }
    println!("Table 1: PLC hardware specifications by manufacturer");
    t.print();
    Ok(())
}

fn fig3() -> Result<()> {
    println!("Fig. 3 (upper): PLCs and their memory (MB)");
    let mut t = Table::new(&["PLC", "RAM (MB)"]);
    for (name, mb) in [
        ("Allen Bradley Micro 810", 0.002),
        ("Fatek B1", 0.031),
        ("Emerson Micro CPUE05", 0.064),
        ("Siemens S7-1200", 0.15),
        ("Schneider M221", 0.25),
        ("Mitsubishi iQ-R", 4.0),
        ("Fuji SPH5000M", 4.0),
        ("Hitachi HX", 16.0),
        ("Festo CECC-S", 44.0),
        ("Eaton XC152", 64.0),
        ("WAGO PFC100", 256.0),
        ("Honeywell R170", 256.0),
        ("WAGO PFC200", 512.0),
        ("Eaton XC300", 512.0),
    ] {
        t.row(&[name.to_string(), format!("{mb}")]);
    }
    t.print();
    println!("\nFig. 3 (lower): Keras models, millions of f32 parameters");
    let mut t2 = Table::new(&["Model", "Params (M)", "Size (MB, f32)"]);
    for (name, m) in KERAS_MODEL_SIZES {
        t2.row(&[
            name.to_string(),
            format!("{m}"),
            format!("{:.1}", m * 4.0),
        ]);
    }
    t2.print();
    println!(
        "\n=> most PLCs can only hold the smallest models; memory-efficient \
         deployment is mandatory (paper §5.1)."
    );
    Ok(())
}

fn table2() -> Result<()> {
    println!(
        "Table 2: memory requirements (bytes) of a 512-neuron dense layer \
         with 512 inputs"
    );
    let mut t =
        Table::new(&["Scheme", "Weights", "Biases", "Scaling", "Total"]);
    for (name, scheme) in [
        ("SINT (8-bit)", Some(Scheme::Sint)),
        ("INT (16-bit)", Some(Scheme::Int)),
        ("DINT (32-bit)", Some(Scheme::Dint)),
        ("REAL (32-bit)", None),
    ] {
        let r = memory_requirements(512, 512, scheme);
        t.row(&[
            name.to_string(),
            r.weights.to_string(),
            r.biases.to_string(),
            if scheme.is_some() { r.scaling.to_string() } else { "N/A".into() },
            r.total.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn port(args: &Args) -> Result<()> {
    let m = Manifest::load(&icsml::artifacts_dir())?;
    let model = args.opt_or("model", "classifier");
    let spec = m.model(&model)?;
    let src = porting::generate_st_program(
        spec,
        &CodegenOptions {
            program: args.opt_or("program", "MAIN"),
            fused_activations: !args.has("no-fused"),
        },
    );
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &src)?;
            eprintln!("wrote {path} ({} bytes)", src.len());
        }
        None => print!("{src}"),
    }
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let m = Manifest::load(&icsml::artifacts_dir())?;
    let spec = m.model("classifier")?;
    let (in_dim, out_dim) = (spec.in_dim(), spec.out_dim());
    anyhow::ensure!(out_dim >= 2, "classifier needs >= 2 logits");
    let idx = args.opt_usize("index", 0);
    let x = binio::read_f32(&m.dataset_path("eval_windows")?)?;
    anyhow::ensure!(
        (idx + 1) * in_dim <= x.len(),
        "window {idx} out of range ({} windows in dataset)",
        x.len() / in_dim.max(1)
    );
    let xi = &x[idx * in_dim..(idx + 1) * in_dim];

    let (name, out): (&str, Vec<f32>) = if args.has("st") {
        let src = porting::generate_st_program(spec, &CodegenOptions::default());
        let mut it =
            icsml::icsml_st::load(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
        it.io_dir = m.root.join(&spec.weights_dir);
        let b = StBackend::new(it, "MAIN")?;
        ("st", b.session()?.infer(xi)?)
    } else if args.has("xla") {
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo(&m.hlo_path("classifier_b1")?)?;
        let b = XlaBackend::new(exe, in_dim, out_dim);
        ("xla", b.session()?.infer(xi)?)
    } else {
        let b =
            EngineBackend::new(porting::load_engine_model(&m.root, spec)?);
        ("engine", b.session()?.infer(xi)?)
    };
    let verdict = if out[1] > out[0] { "ATTACK" } else { "normal" };
    println!("backend={name} window={idx} logits={out:?} -> {verdict}");
    Ok(())
}

fn hitl(args: &Args) -> Result<()> {
    let m = Manifest::load(&icsml::artifacts_dir())?;
    let spec = m.model("classifier")?;
    let steps = args.opt_usize("steps", 9000) as u64;
    let family = AttackFamily::from_name(&args.opt_or("attack", "combined"))
        .ok_or_else(|| anyhow::anyhow!("unknown attack family"))?;
    let magnitude = args.opt_f64("magnitude", 0.5);
    let start = args.opt_usize("start", 4360) as u64;

    let engine = porting::load_engine_model(&m.root, spec)?;
    let detector =
        Detector::new(EngineBackend::new(engine).session()?, 5);
    let runner = HitlRunner::new(
        7,
        true,
        vec![Attack::new(family, magnitude, start, steps)],
        Some(detector),
        HwProfile::beaglebone(),
        100_000.0,
    );
    let report = runner.run(steps)?;
    let (mean, std) = report.wd_stats();
    println!(
        "HITL: {} cycles, attack {} injected @{start}",
        steps,
        family.name()
    );
    match report.detections.first() {
        Some((s, d)) => println!(
            "  detected @{d} ({}+{} cycles = {:.1} s after injection)",
            s,
            d - s,
            (d - s) as f64 * 0.1
        ),
        None => println!("  NOT detected"),
    }
    println!("  false positives: {}", report.false_positives);
    println!("  Wd mean {mean:.2} t/min, sigma {std:.2e}");
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let m = Manifest::load(&icsml::artifacts_dir())?;
    let spec = m.model("classifier")?;
    // Dims come from the manifest spec — nothing is hardcoded to the
    // 400-feature classifier any more.
    let (in_dim, out_dim) = (spec.in_dim(), spec.out_dim());
    anyhow::ensure!(out_dim >= 2, "classifier needs >= 2 logits");
    let n = args.opt_usize("requests", 100);
    let workers = args.opt_usize("workers", 4);
    let batch = args.opt_usize("batch", 8);
    // Deadline-aware options (PR 4): a per-request wall-clock budget,
    // a priority class, and an optional admission profile that gates
    // ingress on the PLC cost model.
    let deadline_us = args.opt_f64("deadline-us", 0.0);
    let class = args.opt_or("class", "batch");
    let priority = Priority::from_name(&class)
        .ok_or_else(|| anyhow::anyhow!("unknown priority class {class:?}"))?;
    let admission = match args.opt("admit") {
        Some(name) => {
            let profile = HwProfile::by_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown hardware profile {name:?}")
            })?;
            // Coarse per-request MAC estimate from the manifest's
            // layer sizes.
            let macs: usize =
                spec.sizes.windows(2).map(|w| w[0] * w[1]).sum();
            Some(Admission::from_macs(profile, macs as f64))
        }
        None => None,
    };
    let x = binio::read_f32(&m.dataset_path("eval_windows")?)?;
    anyhow::ensure!(
        x.len() >= in_dim,
        "eval dataset smaller than one input window"
    );
    let total = x.len() / in_dim;

    let backend: SharedBackend = if args.has("xla") {
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo(&m.hlo_path("classifier_b1")?)?;
        Arc::new(XlaBackend::new(exe, in_dim, out_dim))
    } else {
        Arc::new(EngineBackend::new(porting::load_engine_model(
            &m.root, spec,
        )?))
    };
    println!(
        "serving {n} requests on backend '{}' — {workers} workers, \
         micro-batch {batch}, class {}{}",
        backend.name(),
        priority.name(),
        if deadline_us > 0.0 {
            format!(", deadline {deadline_us} us/request")
        } else {
            String::new()
        }
    );

    let cfg = PoolConfig { workers, max_batch: batch };
    let pool = match admission {
        Some(a) => {
            println!(
                "  admission gate on {} (modeled {:.1} us/request)",
                a.profile().name,
                a.estimate_us()
            );
            Pool::with_admission(backend, cfg, a)
        }
        None => Pool::new(backend, cfg),
    };
    let t0 = std::time::Instant::now();
    // Pipelined submission: all tickets in flight keeps every worker
    // busy and gives micro-batching something to coalesce. With a
    // deadline, each request's budget starts at its own submit
    // instant; admission rejections surface as shed tickets here.
    let mut rejected = 0u64;
    let tickets: Vec<_> = (0..n)
        .map(|i| {
            let w = i % total;
            let window = &x[w * in_dim..(w + 1) * in_dim];
            // Class (and admission, when a deadline is set) apply to
            // every request; without --deadline-us the requests are
            // undeadlined but still scheduled in their band.
            let mut opts = SubmitOptions::new().priority(priority);
            if deadline_us > 0.0 {
                opts = opts.deadline(Deadline::within_us(deadline_us));
            }
            match pool.submit_with(window, opts) {
                Ok(t) => Some(t),
                Err(_) => {
                    rejected += 1;
                    None
                }
            }
        })
        .collect();
    let mut attacks = 0u64;
    let mut shed = 0u64;
    let mut answered = 0u64;
    for t in tickets.into_iter().flatten() {
        match t.wait() {
            Ok(out) => {
                answered += 1;
                if out[1] > out[0] {
                    attacks += 1;
                }
            }
            Err(icsml::api::InferenceError::DeadlineExceeded { .. }) => {
                shed += 1;
            }
            Err(e) => return Err(e.into()),
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "served {answered}/{n} requests in {secs:.3} s ({:.0} req/s): \
         {attacks} flagged as attacks",
        answered as f64 / secs.max(1e-9)
    );
    if deadline_us > 0.0 {
        println!(
            "  deadline hit rate {:.1}% — {shed} shed in queue, \
             {rejected} rejected at admission",
            100.0 * answered as f64 / (n as f64).max(1.0)
        );
    }
    println!(
        "  {} batch calls (mean batch {:.2}); per-worker shares: {:?}",
        pool.batches(),
        pool.served() as f64 / pool.batches().max(1) as f64,
        pool.worker_served()
    );
    Ok(())
}

/// Set by the SIGINT/SIGTERM handler; polled by `listen`'s stats
/// loop to turn the signal into a graceful drain shutdown.
static STOP_REQUESTED: AtomicBool = AtomicBool::new(false);

/// Route SIGINT and SIGTERM into [`STOP_REQUESTED`]. Raw `signal(2)`
/// through the C ABI — no new dependencies, and storing a flag is
/// async-signal-safe. On non-unix targets this is a no-op (ctrl-C
/// falls back to the default abort).
#[cfg(unix)]
fn install_stop_signals() {
    extern "C" fn on_stop(_sig: i32) {
        STOP_REQUESTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_stop);
        signal(SIGTERM, on_stop);
    }
}

#[cfg(not(unix))]
fn install_stop_signals() {}

fn listen(args: &Args) -> Result<()> {
    let addr = args.opt_or("addr", "127.0.0.1:9470");
    let workers = args.opt_usize("workers", 4);
    let batch = args.opt_usize("batch", 8);
    let max_models = args.opt_usize("max-models", 0);
    let max_mb = args.opt_f64("max-mb", 0.0);
    let for_secs = args.opt_f64("for-secs", 0.0);
    let roots: Vec<std::path::PathBuf> = match args.opt("roots") {
        Some(list) => list
            .split(',')
            .filter(|s| !s.is_empty())
            .map(std::path::PathBuf::from)
            .collect(),
        None => vec![icsml::artifacts_dir()],
    };
    let set = ManifestSet::load_roots(&roots)?;
    let names = set.names();
    let cfg = RegistryConfig {
        max_models: if max_models == 0 { usize::MAX } else { max_models },
        max_bytes: if max_mb <= 0.0 {
            u64::MAX
        } else {
            (max_mb * 1024.0 * 1024.0) as u64
        },
        pool: PoolConfig { workers, max_batch: batch },
    };
    let registry = Arc::new(ModelRegistry::new(
        Box::new(ManifestLoader::new(set)),
        cfg,
    ));
    let server = NetServer::bind(
        addr.as_str(),
        Arc::clone(&registry),
        ServerConfig::default(),
    )?;
    println!(
        "listening on {} — {} model(s) {:?}, {workers} workers x \
         micro-batch {batch} per model",
        server.local_addr(),
        names.len(),
        names
    );
    install_stop_signals();
    let stats = server.stats_handle();
    let started = std::time::Instant::now();
    let tick = if for_secs > 0.0 {
        std::time::Duration::from_secs_f64(for_secs.min(5.0))
    } else {
        std::time::Duration::from_secs(5)
    };
    'run: loop {
        // Sleep in small slices so a SIGINT/SIGTERM turns into a
        // drain within ~50 ms instead of waiting out a full tick.
        let slept = std::time::Instant::now();
        while slept.elapsed() < tick {
            if STOP_REQUESTED.load(Ordering::SeqCst) {
                break 'run;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        println!(
            "[{:>7.1}s] conns {} requests {} ok {} errors {} \
             (resident models {} / {:.1} MiB)",
            started.elapsed().as_secs_f64(),
            stats.accepted(),
            stats.requests(),
            stats.responses(),
            stats.error_frames(),
            registry.resident(),
            registry.resident_bytes() as f64 / (1024.0 * 1024.0),
        );
        if for_secs > 0.0 && started.elapsed().as_secs_f64() >= for_secs {
            break;
        }
    }
    // Graceful exit either way (signal or --for-secs): stop accepting,
    // let in-flight requests finish and flush, bounded by the grace
    // period, then report the final totals.
    if STOP_REQUESTED.load(Ordering::SeqCst) {
        println!("signal received — draining");
    }
    server.shutdown_drain(Duration::from_secs(5));
    println!(
        "final: conns {} requests {} ok {} errors {} overloaded {} \
         protocol-errors {}",
        stats.accepted(),
        stats.requests(),
        stats.responses(),
        stats.error_frames(),
        stats.overloaded(),
        stats.protocol_errors(),
    );
    println!("shut down cleanly");
    Ok(())
}

fn client(args: &Args) -> Result<()> {
    let addr = args.opt_or("addr", "127.0.0.1:9470");
    let model = args.opt_or("model", "classifier");
    let n = args.opt_usize("requests", 100);
    let class = args.opt_or("class", "batch");
    let priority = Priority::from_name(&class)
        .ok_or_else(|| anyhow::anyhow!("unknown priority class {class:?}"))?;
    let deadline_us = args.opt_f64("deadline-us", 0.0);
    let dim = args.opt_usize("dim", 0);
    // Inputs: either synthetic windows of --dim features, or the
    // local manifest's eval windows for the named model.
    let (x, in_dim) = if dim > 0 {
        let x: Vec<f32> =
            (0..dim * 16).map(|i| (i % 17) as f32 / 17.0).collect();
        (x, dim)
    } else {
        let m = Manifest::load(&icsml::artifacts_dir())?;
        let spec = m.model(&model)?;
        let x = binio::read_f32(&m.dataset_path("eval_windows")?)?;
        (x, spec.in_dim())
    };
    anyhow::ensure!(x.len() >= in_dim, "need at least one input window");
    let total = x.len() / in_dim;

    let mut c = Client::connect(addr.as_str())?;
    let mut opts = NetOptions::new().priority(priority);
    if deadline_us > 0.0 {
        opts = opts.deadline_us(deadline_us);
    }
    println!(
        "driving {n} requests for model {model:?} at {addr} \
         (class {}{})",
        priority.name(),
        if deadline_us > 0.0 {
            format!(", deadline {deadline_us} us")
        } else {
            String::new()
        }
    );
    let t0 = std::time::Instant::now();
    // Pipeline: submit everything, then drain replies by id.
    for i in 0..n {
        let w = i % total;
        c.submit(&model, &x[w * in_dim..(w + 1) * in_dim], &opts)?;
    }
    // Typed outcome accounting for the driven class: deadline sheds
    // and server-overload refusals are expected operating modes, not
    // failures, and are reported per class alongside served counts.
    let (mut ok, mut shed, mut overloaded, mut failed) =
        (0u64, 0u64, 0u64, 0u64);
    for _ in 0..n {
        let reply = c.recv()?;
        match reply.result {
            Ok(_) => ok += 1,
            Err(e) if e.code == ErrorCode::DeadlineExceeded => shed += 1,
            Err(e) if e.code == ErrorCode::Overloaded => overloaded += 1,
            Err(e) => {
                failed += 1;
                eprintln!("request {}: {}", reply.id, e.msg);
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{ok}/{n} answered in {secs:.3} s ({:.0} req/s)",
        ok as f64 / secs.max(1e-9)
    );
    println!(
        "  class {:<8} served {ok:>6}  shed {shed:>6}  overloaded \
         {overloaded:>6}  failed {failed:>6}",
        priority.name()
    );
    Ok(())
}

fn fleet(args: &Args) -> Result<()> {
    let plants = args.opt_usize("plants", 64);
    let duration = args.opt_f64("duration", 120.0);
    anyhow::ensure!(plants > 0, "--plants must be positive");
    anyhow::ensure!(duration > 0.0, "--duration must be positive");
    // The plant scan period is 100 ms: one second of plant time is
    // ten simulator steps.
    let steps = (duration * 10.0).round() as u64;
    let mix = AttackMix::parse(&args.opt_or("attack-mix", "uniform"))
        .map_err(|e| anyhow::anyhow!("--attack-mix: {e}"))?;
    let workers = args.opt_usize("workers", 4);
    let batch = args.opt_usize("batch", 8);
    let cfg = FleetConfig {
        plants,
        steps,
        seed: args.opt_usize("seed", 1) as u64,
        mix,
        deadline: args.has("deadline"),
        feedback: !args.has("no-feedback"),
        st_tasks: args.has("st-tasks"),
        ..FleetConfig::default()
    };
    println!(
        "fleet: {plants} plants x {steps} steps ({duration} s of plant \
         time), seed {}, feedback {}, deadlines {}, controller {}",
        cfg.seed,
        if cfg.feedback { "on" } else { "off" },
        if cfg.deadline { "on" } else { "off" },
        if cfg.st_tasks {
            "two-task ST configuration"
        } else {
            "native detector loop"
        },
    );

    // With --addr the fleet drives an external `listen` server (which
    // must expose a model named --model with the detector's 400->2
    // shape). Otherwise spawn a loopback front door over the
    // hand-built deviation detector so the command is self-contained
    // while still exercising the full network path.
    let (report, local) = match args.opt("addr") {
        Some(addr) => {
            println!("  driving external server at {addr}");
            let client = Client::connect_with(addr, RetryPolicy::new())?;
            let target = FleetTarget::Net {
                client,
                model: args.opt_or("model", "detector"),
            };
            (run_fleet(&cfg, target), None)
        }
        None => {
            let mut loader = StaticLoader::new();
            let backend: SharedBackend =
                Arc::new(EngineBackend::new(detector_model()));
            loader.insert("detector", backend, 1);
            let registry = Arc::new(ModelRegistry::new(
                Box::new(loader),
                RegistryConfig {
                    max_models: usize::MAX,
                    max_bytes: u64::MAX,
                    pool: PoolConfig { workers, max_batch: batch },
                },
            ));
            // Large fleets keep up to three lock-step batches in
            // flight on the single client connection; lift the
            // per-connection cap so connection-overload refusals
            // (timing-dependent) can't creep into the outcome.
            let server = NetServer::bind(
                "127.0.0.1:0",
                registry,
                ServerConfig {
                    max_inflight_per_conn: 4096,
                    ..ServerConfig::default()
                },
            )?;
            println!(
                "  loopback server at {} — {workers} workers x \
                 micro-batch {batch}",
                server.local_addr()
            );
            let client = Client::connect_with(
                server.local_addr(),
                RetryPolicy::new(),
            )?;
            let target = FleetTarget::Net {
                client,
                model: "detector".to_string(),
            };
            (run_fleet(&cfg, target), Some(server))
        }
    };

    report.print_summary();
    if let Some(server) = local {
        let stats = server.stats_handle();
        server.shutdown();
        println!(
            "server: conns {} requests {} ok {} errors {} overloaded {} \
             protocol-errors {}",
            stats.accepted(),
            stats.requests(),
            stats.responses(),
            stats.error_frames(),
            stats.overloaded(),
            stats.protocol_errors(),
        );
    }
    Ok(())
}
