//! HITL harness (paper §7): the closed loop of plant twin ↔ simulated
//! PLC, with the cascaded-PID control task and (optionally) the ICSML
//! defense running inside the scan cycle, modeled CPU accounting, and
//! series recording for the Fig. 7 / Fig. 8 reports.

use anyhow::Result;

use crate::api::Session as _;
use crate::defense::Detector;
use crate::msf::{Attack, Simulator};
use crate::plc::{HwProfile, ScanCycle};
use crate::st::Meter;

/// One recorded scan cycle.
#[derive(Debug, Clone, Copy)]
pub struct Record {
    pub step: u64,
    pub tb0_adc: f64,
    pub wd_adc: f64,
    pub ws_cmd: f64,
    pub attack_active: bool,
    pub detected: bool,
}

/// Run summary.
#[derive(Debug)]
pub struct HitlReport {
    pub records: Vec<Record>,
    /// First cycle at which each attack window was detected.
    pub detections: Vec<(u64, u64)>, // (attack start, detection cycle)
    pub false_positives: u64,
    pub scan: ScanCycle,
}

impl HitlReport {
    /// Mean/σ of the recorded Wd series (the Fig. 8 statistic).
    pub fn wd_stats(&self) -> (f64, f64) {
        let xs: Vec<f64> = self.records.iter().map(|r| r.wd_adc).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        (mean, var.sqrt())
    }
}

/// The HITL loop driver.
pub struct HitlRunner {
    pub sim: Simulator,
    pub detector: Option<Detector>,
    pub scan: ScanCycle,
    /// Modeled cost of the control task per cycle (µs); the cascaded
    /// PID is a few dozen FP ops — ~2 µs class on the BBB.
    pub control_us: f64,
}

impl HitlRunner {
    pub fn new(
        seed: u64,
        noise: bool,
        attacks: Vec<Attack>,
        detector: Option<Detector>,
        profile: HwProfile,
        period_us: f64,
    ) -> HitlRunner {
        HitlRunner {
            sim: Simulator::new(seed, noise, attacks),
            detector,
            scan: ScanCycle::new(profile, period_us),
            control_us: 2.0,
        }
    }

    /// Run `steps` scan cycles, recording everything.
    pub fn run(mut self, steps: u64) -> Result<HitlReport> {
        let mut records = Vec::with_capacity(steps as usize);
        let mut detections = Vec::new();
        let mut false_positives = 0u64;
        let mut pending_attack: Option<u64> = None;

        for step in 0..steps {
            let r = self.sim.step();
            let mut detected = false;
            let mut ml_meter = Meter::new();
            if let Some(det) = self.detector.as_mut() {
                if let Some(fire) = det.observe(r.tb0_adc, r.wd_adc)? {
                    detected = fire;
                    if let Some(m) = det.session.last_meter() {
                        ml_meter = m;
                    }
                }
            }
            self.scan.record(
                &Meter::default(), // control metered via record_times below
                &ml_meter,
            );
            self.scan.stats.control_time_us += self.control_us;

            // Detection bookkeeping per attack window.
            if r.attack_active {
                if pending_attack.is_none() {
                    pending_attack = Some(step);
                }
                if detected {
                    if let Some(start) = pending_attack.take() {
                        detections.push((start, step));
                        // Mark window as handled: use sentinel so later
                        // positives in the same window are not re-counted.
                        pending_attack = Some(u64::MAX);
                    }
                }
            } else {
                if detected {
                    false_positives += 1;
                }
                pending_attack = None;
            }

            records.push(Record {
                step,
                tb0_adc: r.tb0_adc,
                wd_adc: r.wd_adc,
                ws_cmd: r.ws_cmd,
                attack_active: r.attack_active,
                detected,
            });
        }
        Ok(HitlReport {
            records,
            detections: detections
                .into_iter()
                .filter(|(s, _)| *s != u64::MAX)
                .collect(),
            false_positives,
            scan: self.scan,
        })
    }
}

/// One escalation raised to the (simulated) plant operator by the
/// closed-loop defense ladder: which plant, when it fired, and when
/// the operator's manual intervention lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Escalation {
    /// Fleet index of the escalating plant.
    pub plant: usize,
    /// Scan step the defense escalated at.
    pub step: u64,
    /// Scan step the operator's intervention takes effect
    /// (`step + response_delay`).
    pub intervene_step: u64,
}

/// Deterministic stand-in for the human operator in the paper's §7
/// loop at fleet scale: escalations are acknowledged after a fixed
/// response delay (no wall clock involved, so fleet runs replay
/// exactly), and every escalation is kept for the run report.
#[derive(Debug, Clone, Default)]
pub struct OperatorConsole {
    /// Scan steps between an escalation and the operator's
    /// intervention (human reaction time; 50 steps ≈ 5 s at the
    /// 10 Hz scan rate).
    pub response_delay: u64,
    /// Every escalation raised, in arrival order.
    pub escalations: Vec<Escalation>,
}

impl OperatorConsole {
    /// Console with the given response delay (in scan steps).
    pub fn new(response_delay: u64) -> OperatorConsole {
        OperatorConsole {
            response_delay,
            escalations: Vec::new(),
        }
    }

    /// Record an escalation; returns the step at which the operator's
    /// intervention lands (the caller applies it to the sim then).
    pub fn escalate(&mut self, plant: usize, step: u64) -> u64 {
        let intervene_step = step + self.response_delay;
        self.escalations.push(Escalation {
            plant,
            step,
            intervene_step,
        });
        intervene_step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Backend, EngineBackend};
    use crate::defense::{Detector, FEATURES, WINDOW};
    use crate::engine::{Act, Layer, Model};
    use crate::msf::AttackFamily;

    #[test]
    fn operator_console_records_and_schedules() {
        let mut console = OperatorConsole::new(50);
        assert_eq!(console.escalate(3, 100), 150);
        assert_eq!(console.escalate(7, 200), 250);
        assert_eq!(console.escalations.len(), 2);
        assert_eq!(
            console.escalations[0],
            Escalation {
                plant: 3,
                step: 100,
                intervene_step: 150
            }
        );
    }

    /// Hand-built mean-threshold detector (fires when mean Wd over the
    /// window drops below 17).
    fn threshold_detector() -> Detector {
        let mut w = vec![0.0f32; FEATURES * 2];
        for i in 0..WINDOW {
            w[FEATURES + WINDOW + i] = -1.0 / WINDOW as f32;
        }
        let b = vec![0.0f32, 17.0];
        let m = Model::new(vec![Layer::dense(w, b, FEATURES, Act::None)]);
        Detector::new(EngineBackend::new(m).session().unwrap(), 5)
    }

    #[test]
    fn detects_combined_attack_with_latency() {
        let runner = HitlRunner::new(
            7,
            true,
            vec![Attack::new(AttackFamily::Combined, 0.6, 3000, 9000)],
            Some(threshold_detector()),
            HwProfile::beaglebone(),
            100_000.0,
        );
        let report = runner.run(9000).unwrap();
        assert_eq!(report.detections.len(), 1, "one attack window");
        let (start, at) = report.detections[0];
        assert_eq!(start, 3000);
        assert!(at > start, "detection after injection");
        assert!(
            at < start + 3000,
            "combined 0.6 attack detected within 5 min (at {at})"
        );
        assert_eq!(report.false_positives, 0);
    }

    #[test]
    fn no_detection_without_attack() {
        let runner = HitlRunner::new(
            3,
            true,
            vec![],
            Some(threshold_detector()),
            HwProfile::beaglebone(),
            100_000.0,
        );
        let report = runner.run(4000).unwrap();
        assert!(report.detections.is_empty());
        assert_eq!(report.false_positives, 0);
        let (mean, std) = report.wd_stats();
        assert!((mean - 19.18).abs() < 0.02);
        assert!(std < 0.01);
    }

    #[test]
    fn runs_without_detector() {
        let runner = HitlRunner::new(
            1,
            false,
            vec![],
            None,
            HwProfile::wago_pfc100(),
            100_000.0,
        );
        let report = runner.run(500).unwrap();
        assert_eq!(report.records.len(), 500);
        assert_eq!(report.scan.stats.cycles, 500);
    }
}
