//! PJRT runtime: loads the AOT-lowered JAX/Pallas models (HLO text
//! emitted by `python/compile/aot.py`) and executes them from the Rust
//! hot path. This is the repo's "TensorFlow Lite" comparator — the
//! same math as the ICSML model through an optimizing compiled runtime
//! (paper §5.2's TFLite baseline; see DESIGN.md §2).
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example).

use std::path::Path;

use anyhow::{Context, Result};

use crate::defense::Backend;

/// PJRT CPU client wrapper. Create once; compile many executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// One compiled model variant (weights embedded as constants at AOT
/// time — the runtime feeds only the input tensor).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with one f32 input tensor; returns the flattened f32
    /// output (AOT lowering uses `return_tuple=True`, so the result is
    /// a 1-tuple).
    pub fn run_f32(&self, input: &[f32], shape: &[usize]) -> Result<Vec<f32>> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == input.len(), "input length vs shape");
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute with two f32 inputs (used by the smoke artifact).
    pub fn run_f32x2(
        &self,
        a: (&[f32], &[usize]),
        b: (&[f32], &[usize]),
    ) -> Result<Vec<f32>> {
        let da: Vec<i64> = a.1.iter().map(|&d| d as i64).collect();
        let db: Vec<i64> = b.1.iter().map(|&d| d as i64).collect();
        let la = xla::Literal::vec1(a.0).reshape(&da)?;
        let lb = xla::Literal::vec1(b.0).reshape(&db)?;
        let result = self.exe.execute::<xla::Literal>(&[la, lb])?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }
}

/// Defense backend running the AOT classifier through PJRT.
pub struct XlaBackend {
    pub exe: Executable,
    pub in_dim: usize,
}

impl Backend for XlaBackend {
    fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.exe.run_f32(x, &[1, self.in_dim])
    }
    fn name(&self) -> &'static str {
        "xla"
    }
}
