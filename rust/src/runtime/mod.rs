//! PJRT runtime: loads the AOT-lowered JAX/Pallas models (HLO text
//! emitted by `python/compile/aot.py`) and executes them from the Rust
//! hot path. This is the repo's "TensorFlow Lite" comparator — the
//! same math as the ICSML model through an optimizing compiled runtime
//! (paper §5.2's TFLite baseline; see DESIGN.md §2).
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes
//! HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example).

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::api::{Backend, InferenceError, ModelSpec, Session};

/// PJRT CPU client wrapper. Create once; compile many executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// One compiled model variant (weights embedded as constants at AOT
/// time — the runtime feeds only the input tensor).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

// SAFETY: a loaded PJRT executable is immutable after compilation and
// the PJRT C API is documented thread-safe for execution; the binding
// wraps a C++ shared_ptr with no Rust-side interior mutability. The
// Rust binding simply does not declare the markers. Sharing an
// `Arc<Executable>` across `XlaSession`s matches how PJRT is used from
// multi-threaded C++ serving code.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with one f32 input tensor; returns the flattened f32
    /// output (AOT lowering uses `return_tuple=True`, so the result is
    /// a 1-tuple).
    pub fn run_f32(&self, input: &[f32], shape: &[usize]) -> Result<Vec<f32>> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(n == input.len(), "input length vs shape");
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute with two f32 inputs (used by the smoke artifact).
    pub fn run_f32x2(
        &self,
        a: (&[f32], &[usize]),
        b: (&[f32], &[usize]),
    ) -> Result<Vec<f32>> {
        let da: Vec<i64> = a.1.iter().map(|&d| d as i64).collect();
        let db: Vec<i64> = b.1.iter().map(|&d| d as i64).collect();
        let la = xla::Literal::vec1(a.0).reshape(&da)?;
        let lb = xla::Literal::vec1(b.0).reshape(&db)?;
        let result = self.exe.execute::<xla::Literal>(&[la, lb])?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }
}

/// Inference backend running an AOT classifier through PJRT: an
/// immutable handle to the compiled executable (shared by every
/// session via `Arc`).
///
/// The executable's leading dimension is its compiled batch size
/// (`classifier_b1` → 1) and is **fixed at AOT time** — PJRT rejects
/// any other shape. [`XlaSession::infer_batch`] overrides the trait's
/// per-row default with true batched execution: whole
/// `compiled_batch`-sized chunks go through XLA in single calls, and
/// batches that are not a multiple of it are rejected up front (no
/// per-row fallback exists on a fixed-batch executable). Likewise,
/// single-request `infer_into` is `Unsupported` when
/// `compiled_batch > 1`.
pub struct XlaBackend {
    exe: Arc<Executable>,
    in_dim: usize,
    out_dim: usize,
    compiled_batch: usize,
}

impl XlaBackend {
    pub fn new(exe: Executable, in_dim: usize, out_dim: usize) -> XlaBackend {
        XlaBackend { exe: Arc::new(exe), in_dim, out_dim, compiled_batch: 1 }
    }

    /// Declare the executable's compiled batch dimension (an artifact
    /// lowered with `batch=n` serves n rows per XLA call).
    pub fn with_compiled_batch(mut self, n: usize) -> XlaBackend {
        self.compiled_batch = n.max(1);
        self
    }

    /// The shared executable.
    pub fn executable(&self) -> &Arc<Executable> {
        &self.exe
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn spec(&self) -> ModelSpec {
        ModelSpec {
            batch_granularity: self.compiled_batch,
            ..ModelSpec::dense_f32(self.in_dim, self.out_dim)
        }
    }

    fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
        Ok(Box::new(XlaSession {
            exe: Arc::clone(&self.exe),
            in_dim: self.in_dim,
            out_dim: self.out_dim,
            compiled_batch: self.compiled_batch,
        }))
    }
}

/// One caller's XLA session. PJRT owns all execution state device-side
/// per call, so the session is a thin cursor over the shared
/// executable — it exists so XLA serves through the same
/// session-shaped API as every other substrate.
pub struct XlaSession {
    exe: Arc<Executable>,
    in_dim: usize,
    out_dim: usize,
    compiled_batch: usize,
}

impl XlaSession {
    fn run_rows(
        &mut self,
        rows: usize,
        xs: &[f32],
        out: &mut [f32],
    ) -> Result<(), InferenceError> {
        let got = self.exe.run_f32(xs, &[rows, self.in_dim]).map_err(|e| {
            InferenceError::ExecutionFailed { backend: "xla".into(), source: e }
        })?;
        // A wrong-sized result is the backend misbehaving, not a
        // caller shape bug — classify as a (penalizable) fault.
        if got.len() != out.len() {
            return Err(InferenceError::ExecutionFailed {
                backend: "xla".into(),
                source: anyhow::anyhow!(
                    "executable returned {} values, expected {}",
                    got.len(),
                    out.len()
                ),
            });
        }
        out.copy_from_slice(&got);
        Ok(())
    }
}

impl Session for XlaSession {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn spec(&self) -> ModelSpec {
        ModelSpec {
            batch_granularity: self.compiled_batch,
            ..ModelSpec::dense_f32(self.in_dim, self.out_dim)
        }
    }

    fn infer_into(&mut self, x: &[f32], out: &mut [f32]) -> Result<(), InferenceError> {
        if self.compiled_batch != 1 {
            return Err(InferenceError::Unsupported {
                backend: "xla".into(),
                op: "single-request inference on a fixed-batch executable",
            });
        }
        crate::api::backend::check_shapes(&self.spec(), x, out)?;
        self.run_rows(1, x, out)
    }

    fn infer_batch(&mut self, xs: &[f32], out: &mut [f32]) -> Result<usize, InferenceError> {
        let n = crate::api::backend::check_batch_shapes(&self.spec(), xs, out)?;
        // Whole compiled-batch chunks execute in one XLA call each.
        // The executable's batch dimension is fixed at AOT time, so a
        // ragged tail cannot run — reject it rather than produce a
        // partial batch.
        let b = self.compiled_batch;
        if n % b != 0 {
            return Err(InferenceError::ShapeMismatch {
                what: "batch rows (must be a multiple of the compiled batch)",
                expected: b,
                got: n,
            });
        }
        let mut row = 0usize;
        while row < n {
            let (i0, i1) = (row * self.in_dim, (row + b) * self.in_dim);
            let (o0, o1) = (row * self.out_dim, (row + b) * self.out_dim);
            self.run_rows(b, &xs[i0..i1], &mut out[o0..o1])?;
            row += b;
        }
        Ok(n)
    }
}
