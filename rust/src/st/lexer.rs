//! Structured Text lexer.
//!
//! IEC 61131-3 notes honored here:
//! * Keywords and identifiers are **case-insensitive** (normalized to
//!   upper-case for keywords; identifiers keep their spelling but compare
//!   case-insensitively downstream).
//! * Comments: `(* ... *)` (nesting allowed) and `//` line comments.
//! * Literals: `123`, `16#FF`, `2#1010`, `1.5`, `1.0E-3`, typed literals
//!   `REAL#1.5` / `INT#-4`, strings `'...'` with `$` escapes, `TRUE` /
//!   `FALSE`.

use std::fmt;

/// Token kinds. Keywords arrive as `Kw(&'static str)` (upper-case).
#[derive(Debug, Clone, PartialEq)]
pub enum TokenKind {
    Ident(String),
    Kw(&'static str),
    Int(i64),
    Real(f64),
    /// `TYPE#literal` — (type name upper-cased, raw literal text).
    Typed(String, String),
    Str(String),
    // punctuation / operators
    Assign,     // :=
    Arrow,      // =>
    Range,      // ..
    Plus, Minus, Star, Slash, Power, // **
    Eq, Neq, Lt, Gt, Le, Ge,
    LParen, RParen, LBracket, RBracket,
    Comma, Semi, Colon, Dot, Caret, Hash,
}

/// Token with 1-based line/column for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    pub line: u32,
    pub col: u32,
}

/// All reserved words we recognize (upper-case).
pub const KEYWORDS: &[&str] = &[
    "PROGRAM", "END_PROGRAM", "FUNCTION", "END_FUNCTION", "FUNCTION_BLOCK",
    "END_FUNCTION_BLOCK", "METHOD", "END_METHOD", "INTERFACE",
    "END_INTERFACE", "IMPLEMENTS", "EXTENDS", "TYPE", "END_TYPE", "STRUCT",
    "END_STRUCT", "VAR", "VAR_INPUT", "VAR_OUTPUT", "VAR_IN_OUT",
    "VAR_GLOBAL", "VAR_TEMP", "END_VAR", "CONSTANT", "RETAIN", "AT",
    "ARRAY", "OF", "POINTER", "TO", "STRING",
    "IF", "THEN", "ELSIF", "ELSE", "END_IF", "CASE", "END_CASE",
    "FOR", "BY", "DO", "END_FOR", "WHILE", "END_WHILE", "REPEAT",
    "UNTIL", "END_REPEAT", "EXIT", "RETURN", "CONTINUE",
    "AND", "OR", "XOR", "NOT", "MOD",
    "TRUE", "FALSE", "NULL",
    "BOOL", "SINT", "INT", "DINT", "LINT", "USINT", "UINT", "UDINT",
    "ULINT", "BYTE", "WORD", "DWORD", "LWORD", "REAL", "LREAL", "TIME",
    // §2.7 task model (CONFIGURATION / RESOURCE / TASK declarations).
    "CONFIGURATION", "END_CONFIGURATION", "RESOURCE", "END_RESOURCE",
    "TASK", "ON", "WITH",
];

/// Lex failure with position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexError {
    pub line: u32,
    pub col: u32,
    pub message: String,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}", self.line, self.col, self.message)
    }
}

impl std::error::Error for LexError {}

struct Lexer<'a> {
    src: &'a [u8],
    i: usize,
    line: u32,
    col: u32,
}

/// Tokenize ST source.
pub fn lex(source: &str) -> Result<Vec<Token>, LexError> {
    let mut lx = Lexer { src: source.as_bytes(), i: 0, line: 1, col: 1 };
    let mut out = Vec::new();
    loop {
        lx.skip_trivia()?;
        if lx.i >= lx.src.len() {
            return Ok(out);
        }
        let (line, col) = (lx.line, lx.col);
        let kind = lx.token()?;
        out.push(Token { kind, line, col });
    }
}

impl<'a> Lexer<'a> {
    fn err(&self, msg: impl Into<String>) -> LexError {
        LexError { line: self.line, col: self.col, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.i).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.src.get(self.i + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn skip_trivia(&mut self) -> Result<(), LexError> {
        loop {
            match self.peek() {
                Some(c) if c.is_ascii_whitespace() => {
                    self.bump();
                }
                Some(b'/') if self.peek2() == Some(b'/') => {
                    while let Some(c) = self.peek() {
                        if c == b'\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                Some(b'(') if self.peek2() == Some(b'*') => {
                    self.bump();
                    self.bump();
                    let mut depth = 1usize;
                    loop {
                        match (self.peek(), self.peek2()) {
                            (Some(b'*'), Some(b')')) => {
                                self.bump();
                                self.bump();
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            (Some(b'('), Some(b'*')) => {
                                self.bump();
                                self.bump();
                                depth += 1;
                            }
                            (Some(_), _) => {
                                self.bump();
                            }
                            (None, _) => {
                                return Err(self.err("unterminated comment"));
                            }
                        }
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    fn token(&mut self) -> Result<TokenKind, LexError> {
        let c = self.peek().unwrap();
        match c {
            b'A'..=b'Z' | b'a'..=b'z' | b'_' => self.word(),
            b'0'..=b'9' => self.number(),
            b'\'' => self.string(),
            _ => self.punct(),
        }
    }

    fn word(&mut self) -> Result<TokenKind, LexError> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_alphanumeric() || c == b'_')
            .unwrap_or(false)
        {
            self.bump();
        }
        let text = std::str::from_utf8(&self.src[start..self.i]).unwrap();
        let upper = text.to_ascii_uppercase();
        // Typed literal: TYPE#value (e.g. REAL#1.5, INT#-3, 16#FF handled
        // in number()).
        if self.peek() == Some(b'#') {
            self.bump();
            let lit_start = self.i;
            if self.peek() == Some(b'-') || self.peek() == Some(b'+') {
                self.bump();
            }
            while self
                .peek()
                .map(|c| c.is_ascii_alphanumeric() || c == b'.' || c == b'_')
                .unwrap_or(false)
            {
                self.bump();
            }
            let lit = std::str::from_utf8(&self.src[lit_start..self.i])
                .unwrap()
                .to_string();
            if lit.is_empty() {
                return Err(self.err("empty typed literal"));
            }
            return Ok(TokenKind::Typed(upper, lit));
        }
        if let Some(kw) = KEYWORDS.iter().find(|k| **k == upper) {
            return Ok(TokenKind::Kw(kw));
        }
        Ok(TokenKind::Ident(text.to_string()))
    }

    fn number(&mut self) -> Result<TokenKind, LexError> {
        let start = self.i;
        while self.peek().map(|c| c.is_ascii_digit() || c == b'_').unwrap_or(false)
        {
            self.bump();
        }
        // Based literal: 16#FF, 2#1010_1010, 8#777
        if self.peek() == Some(b'#') {
            let base_txt = std::str::from_utf8(&self.src[start..self.i]).unwrap();
            let base: u32 = base_txt
                .replace('_', "")
                .parse()
                .map_err(|_| self.err(format!("bad numeric base {base_txt:?}")))?;
            if ![2, 8, 16].contains(&base) {
                return Err(self.err(format!("unsupported base {base}")));
            }
            self.bump(); // '#'
            let dstart = self.i;
            while self
                .peek()
                .map(|c| c.is_ascii_alphanumeric() || c == b'_')
                .unwrap_or(false)
            {
                self.bump();
            }
            let digits = std::str::from_utf8(&self.src[dstart..self.i])
                .unwrap()
                .replace('_', "");
            let v = i64::from_str_radix(&digits, base)
                .map_err(|_| self.err(format!("bad base-{base} literal")))?;
            return Ok(TokenKind::Int(v));
        }
        // Real part? Careful: `1..2` is Int(1) Range Int(2).
        let mut is_real = false;
        if self.peek() == Some(b'.')
            && self.peek2().map(|c| c.is_ascii_digit()).unwrap_or(false)
        {
            is_real = true;
            self.bump();
            while self.peek().map(|c| c.is_ascii_digit() || c == b'_').unwrap_or(false)
            {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            let save = (self.i, self.line, self.col);
            self.bump();
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.bump();
            }
            if self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
                is_real = true;
                while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
                    self.bump();
                }
            } else {
                (self.i, self.line, self.col) = save;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.i])
            .unwrap()
            .replace('_', "");
        if is_real {
            text.parse::<f64>()
                .map(TokenKind::Real)
                .map_err(|_| self.err(format!("bad real literal {text:?}")))
        } else {
            text.parse::<i64>()
                .map(TokenKind::Int)
                .map_err(|_| self.err(format!("bad integer literal {text:?}")))
        }
    }

    fn string(&mut self) -> Result<TokenKind, LexError> {
        self.bump(); // opening '
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'\'') => return Ok(TokenKind::Str(s)),
                Some(b'$') => match self.bump() {
                    Some(b'\'') => s.push('\''),
                    Some(b'$') => s.push('$'),
                    Some(b'N') | Some(b'n') => s.push('\n'),
                    Some(b'T') | Some(b't') => s.push('\t'),
                    Some(b'R') | Some(b'r') => s.push('\r'),
                    _ => return Err(self.err("bad $ escape in string")),
                },
                Some(c) => s.push(c as char),
            }
        }
    }

    fn punct(&mut self) -> Result<TokenKind, LexError> {
        let c = self.bump().unwrap();
        let two = |lx: &mut Self, next: u8, yes: TokenKind, no: TokenKind| {
            if lx.peek() == Some(next) {
                lx.bump();
                yes
            } else {
                no
            }
        };
        Ok(match c {
            b':' => two(self, b'=', TokenKind::Assign, TokenKind::Colon),
            b'=' => two(self, b'>', TokenKind::Arrow, TokenKind::Eq),
            b'<' => {
                if self.peek() == Some(b'=') {
                    self.bump();
                    TokenKind::Le
                } else if self.peek() == Some(b'>') {
                    self.bump();
                    TokenKind::Neq
                } else {
                    TokenKind::Lt
                }
            }
            b'>' => two(self, b'=', TokenKind::Ge, TokenKind::Gt),
            b'.' => two(self, b'.', TokenKind::Range, TokenKind::Dot),
            b'*' => two(self, b'*', TokenKind::Power, TokenKind::Star),
            b'+' => TokenKind::Plus,
            b'-' => TokenKind::Minus,
            b'/' => TokenKind::Slash,
            b'(' => TokenKind::LParen,
            b')' => TokenKind::RParen,
            b'[' => TokenKind::LBracket,
            b']' => TokenKind::RBracket,
            b',' => TokenKind::Comma,
            b';' => TokenKind::Semi,
            b'^' => TokenKind::Caret,
            b'#' => TokenKind::Hash,
            other => {
                return Err(self.err(format!(
                    "unexpected character {:?}",
                    other as char
                )))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src).unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn keywords_case_insensitive() {
        assert_eq!(kinds("if If iF IF"), vec![TokenKind::Kw("IF"); 4]);
    }

    #[test]
    fn idents_keep_spelling() {
        assert_eq!(
            kinds("myVar"),
            vec![TokenKind::Ident("myVar".to_string())]
        );
    }

    #[test]
    fn numbers() {
        assert_eq!(
            kinds("42 1.5 1.0E-3 16#FF 2#1010 1_000"),
            vec![
                TokenKind::Int(42),
                TokenKind::Real(1.5),
                TokenKind::Real(1.0e-3),
                TokenKind::Int(255),
                TokenKind::Int(10),
                TokenKind::Int(1000),
            ]
        );
    }

    #[test]
    fn range_vs_real() {
        assert_eq!(
            kinds("0..10"),
            vec![TokenKind::Int(0), TokenKind::Range, TokenKind::Int(10)]
        );
        assert_eq!(
            kinds("ARRAY[0..L1_size - 1]")[..3],
            [
                TokenKind::Kw("ARRAY"),
                TokenKind::LBracket,
                TokenKind::Int(0)
            ]
        );
    }

    #[test]
    fn typed_literals() {
        assert_eq!(
            kinds("REAL#1.5 INT#-3"),
            vec![
                TokenKind::Typed("REAL".into(), "1.5".into()),
                TokenKind::Typed("INT".into(), "-3".into()),
            ]
        );
    }

    #[test]
    fn strings_and_escapes() {
        assert_eq!(
            kinds("'abc' 'a$'b' '$$'"),
            vec![
                TokenKind::Str("abc".into()),
                TokenKind::Str("a'b".into()),
                TokenKind::Str("$".into()),
            ]
        );
    }

    #[test]
    fn comments() {
        assert_eq!(
            kinds("1 (* c (* nested *) *) 2 // line\n3"),
            vec![TokenKind::Int(1), TokenKind::Int(2), TokenKind::Int(3)]
        );
    }

    #[test]
    fn operators() {
        assert_eq!(
            kinds(":= => = <> <= >= < > ^ .."),
            vec![
                TokenKind::Assign,
                TokenKind::Arrow,
                TokenKind::Eq,
                TokenKind::Neq,
                TokenKind::Le,
                TokenKind::Ge,
                TokenKind::Lt,
                TokenKind::Gt,
                TokenKind::Caret,
                TokenKind::Range,
            ]
        );
    }

    #[test]
    fn positions_tracked() {
        let toks = lex("a\n  b").unwrap();
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
    }

    #[test]
    fn errors_have_positions() {
        let e = lex("a ?").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(e.message.contains("unexpected"));
    }

    #[test]
    fn unterminated_comment_errors() {
        assert!(lex("(* oops").is_err());
        assert!(lex("'oops").is_err());
    }
}
