//! Bytecode disassembler with a parseable, round-trippable listing
//! format.
//!
//! Every [`Op`] renders as one line — `Mnemonic key=value ...` — and
//! [`parse_line`] recovers the same [`GenericOp`] from that text, so
//! the property tests can assert `parse(render(op)) == generic(op)`
//! over the whole compiled corpus (fused and plain). Exactness rules:
//! floats print as their IEEE bit patterns (`0x3f800000`), strings are
//! single-quoted with `\\`/`\'`/`\n` escapes, and list-valued fields
//! (call args, CASE ranges) use `,`/`|` separators so no value ever
//! contains a bare space.

use std::fmt::Write as _;

use super::bytecode::{Code, Konst, Op};

/// An op reduced to its mnemonic and stringly-typed fields — the
/// common form both the renderer and the parser speak.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenericOp {
    /// Variant name, e.g. `LoadPtr`.
    pub name: String,
    /// `(key, raw value)` pairs in declaration order. Values are
    /// unescaped; quoting happens at render time.
    pub fields: Vec<(String, String)>,
}

fn f32_bits(v: f32) -> String {
    format!("0x{:08x}", v.to_bits())
}

fn f64_bits(v: f64) -> String {
    format!("0x{:016x}", v.to_bits())
}

fn dbg(x: impl std::fmt::Debug) -> String {
    format!("{x:?}")
}

fn reg_list(rs: &[u16]) -> String {
    let items: Vec<String> = rs.iter().map(|r| r.to_string()).collect();
    format!("[{}]", items.join(","))
}

fn range_list(rs: &[(i64, i64)]) -> String {
    let items: Vec<String> =
        rs.iter().map(|(lo, hi)| format!("{lo}..{hi}")).collect();
    items.join("|")
}

/// Reduce an op to its generic (mnemonic + fields) form.
pub fn op_to_generic(op: &Op) -> GenericOp {
    macro_rules! g {
        ($name:expr $(, $k:expr => $v:expr)* $(,)?) => {
            GenericOp {
                name: ($name).to_string(),
                fields: vec![$((($k).to_string(), $v)),*],
            }
        };
    }
    match op {
        Op::ConstBool { dst, v } => {
            g!("ConstBool", "dst" => dst.to_string(), "v" => dbg(v))
        }
        Op::ConstInt { dst, v } => {
            g!("ConstInt", "dst" => dst.to_string(), "v" => v.to_string())
        }
        Op::ConstF32 { dst, v } => {
            g!("ConstF32", "dst" => dst.to_string(), "v" => f32_bits(*v))
        }
        Op::ConstF64 { dst, v } => {
            g!("ConstF64", "dst" => dst.to_string(), "v" => f64_bits(*v))
        }
        Op::ConstStr { dst, v } => {
            g!("ConstStr", "dst" => dst.to_string(), "v" => v.to_string())
        }
        Op::ConstNull { dst } => g!("ConstNull", "dst" => dst.to_string()),
        Op::Mov { dst, src } => {
            g!("Mov", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::LoadLocal { dst, slot } => {
            g!("LoadLocal", "dst" => dst.to_string(), "slot" => slot.to_string())
        }
        Op::LoadGlobal { dst, g } => {
            g!("LoadGlobal", "dst" => dst.to_string(), "g" => g.to_string())
        }
        Op::LoadSelf { dst, f } => {
            g!("LoadSelf", "dst" => dst.to_string(), "f" => f.to_string())
        }
        Op::LoadField { dst, base, f } => g!("LoadField",
            "dst" => dst.to_string(), "base" => base.to_string(),
            "f" => f.to_string()),
        Op::LoadFbField { dst, base, f } => g!("LoadFbField",
            "dst" => dst.to_string(), "base" => base.to_string(),
            "f" => f.to_string()),
        Op::LoadIdx { dst, base, idx, len, kind, line } => g!("LoadIdx",
            "dst" => dst.to_string(), "base" => base.to_string(),
            "idx" => idx.to_string(), "len" => len.to_string(),
            "kind" => dbg(kind), "line" => line.to_string()),
        Op::LoadPtr { dst, p, off, kind, line } => g!("LoadPtr",
            "dst" => dst.to_string(), "p" => p.to_string(),
            "off" => off.to_string(), "kind" => dbg(kind),
            "line" => line.to_string()),
        Op::AdrLocal { dst, slot, kind } => g!("AdrLocal",
            "dst" => dst.to_string(), "slot" => slot.to_string(),
            "kind" => dbg(kind)),
        Op::AdrGlobal { dst, g, kind } => g!("AdrGlobal",
            "dst" => dst.to_string(), "g" => g.to_string(),
            "kind" => dbg(kind)),
        Op::AdrSelf { dst, f, kind } => g!("AdrSelf",
            "dst" => dst.to_string(), "f" => f.to_string(),
            "kind" => dbg(kind)),
        Op::AdrField { dst, base, f, kind } => g!("AdrField",
            "dst" => dst.to_string(), "base" => base.to_string(),
            "f" => f.to_string(), "kind" => dbg(kind)),
        Op::AdrFbField { dst, base, f, kind } => g!("AdrFbField",
            "dst" => dst.to_string(), "base" => base.to_string(),
            "f" => f.to_string(), "kind" => dbg(kind)),
        Op::AdrIdx { dst, base, idx, len, kind, line } => g!("AdrIdx",
            "dst" => dst.to_string(), "base" => base.to_string(),
            "idx" => idx.to_string(), "len" => len.to_string(),
            "kind" => dbg(kind), "line" => line.to_string()),
        Op::AdrPtr { dst, p, off, kind, line } => g!("AdrPtr",
            "dst" => dst.to_string(), "p" => p.to_string(),
            "off" => off.to_string(), "kind" => dbg(kind),
            "line" => line.to_string()),
        Op::NegF32 { dst, src } => {
            g!("NegF32", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::NegF64 { dst, src } => {
            g!("NegF64", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::NegInt { dst, src } => {
            g!("NegInt", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::NotBool { dst, src } => {
            g!("NotBool", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::ArithF32 { op, dst, a, b, line } => g!("ArithF32",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string(),
            "line" => line.to_string()),
        Op::ArithF64 { op, dst, a, b, line } => g!("ArithF64",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string(),
            "line" => line.to_string()),
        Op::ArithInt { op, dst, a, b, line } => g!("ArithInt",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string(),
            "line" => line.to_string()),
        Op::CmpF32 { op, dst, a, b } => g!("CmpF32",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string()),
        Op::CmpF64 { op, dst, a, b } => g!("CmpF64",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string()),
        Op::CmpInt { op, dst, a, b } => g!("CmpInt",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string()),
        Op::CmpBool { op, dst, a, b } => g!("CmpBool",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string()),
        Op::BoolB { op, dst, a, b } => g!("BoolB",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string()),
        Op::IntB { op, dst, a, b } => g!("IntB",
            "op" => dbg(op), "dst" => dst.to_string(),
            "a" => a.to_string(), "b" => b.to_string()),
        Op::IntToF32 { dst, src } => {
            g!("IntToF32", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::IntToF64 { dst, src } => {
            g!("IntToF64", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::F32ToF64 { dst, src } => {
            g!("F32ToF64", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::F64ToF32 { dst, src } => {
            g!("F64ToF32", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::F32ToInt { dst, src, ty } => g!("F32ToInt",
            "dst" => dst.to_string(), "src" => src.to_string(),
            "ty" => dbg(ty)),
        Op::F64ToInt { dst, src, ty } => g!("F64ToInt",
            "dst" => dst.to_string(), "src" => src.to_string(),
            "ty" => dbg(ty)),
        Op::IntNarrow { dst, src, ty } => g!("IntNarrow",
            "dst" => dst.to_string(), "src" => src.to_string(),
            "ty" => dbg(ty)),
        Op::BoolToInt { dst, src } => {
            g!("BoolToInt", "dst" => dst.to_string(), "src" => src.to_string())
        }
        Op::CallFn { dst, fid, args } => g!("CallFn",
            "dst" => dst.to_string(), "fid" => fid.to_string(),
            "args" => reg_list(args)),
        Op::CallMethod { dst, fb, midx, self_r, args } => g!("CallMethod",
            "dst" => dst.to_string(), "fb" => fb.to_string(),
            "midx" => midx.to_string(), "self_r" => self_r.to_string(),
            "args" => reg_list(args)),
        Op::CallIface { dst, iface, mid, self_r, args, line } => {
            g!("CallIface",
                "dst" => dst.to_string(), "iface" => iface.to_string(),
                "mid" => mid.to_string(), "self_r" => self_r.to_string(),
                "args" => reg_list(args), "line" => line.to_string())
        }
        Op::CheckFb { r, line } => g!("CheckFb",
            "r" => r.to_string(), "line" => line.to_string()),
        Op::InvokeFbBody { fb_r, fb_id, line } => g!("InvokeFbBody",
            "fb_r" => fb_r.to_string(), "fb_id" => fb_id.to_string(),
            "line" => line.to_string()),
        Op::StoreFbInput { fb_r, fidx, src, copy } => g!("StoreFbInput",
            "fb_r" => fb_r.to_string(), "fidx" => fidx.to_string(),
            "src" => src.to_string(), "copy" => dbg(copy)),
        Op::LoadFbOutput { dst, fb_r, fidx } => g!("LoadFbOutput",
            "dst" => dst.to_string(), "fb_r" => fb_r.to_string(),
            "fidx" => fidx.to_string()),
        Op::StructNew { dst, sid } => g!("StructNew",
            "dst" => dst.to_string(), "sid" => sid.to_string()),
        Op::StructSet { s, fidx, src } => g!("StructSet",
            "s" => s.to_string(), "fidx" => fidx.to_string(),
            "src" => src.to_string()),
        Op::Intrinsic { dst, b, kind, args } => g!("Intrinsic",
            "dst" => dst.to_string(), "b" => dbg(b),
            "kind" => dbg(kind), "args" => reg_list(args)),
        Op::FileIo { dst, b, args, line } => g!("FileIo",
            "dst" => dst.to_string(), "b" => dbg(b),
            "args" => reg_list(args), "line" => line.to_string()),
        Op::StoreLocal { src, slot, copy } => g!("StoreLocal",
            "src" => src.to_string(), "slot" => slot.to_string(),
            "copy" => dbg(copy)),
        Op::StoreGlobal { src, g, copy } => g!("StoreGlobal",
            "src" => src.to_string(), "g" => g.to_string(),
            "copy" => dbg(copy)),
        Op::StoreSelf { src, f, copy } => g!("StoreSelf",
            "src" => src.to_string(), "f" => f.to_string(),
            "copy" => dbg(copy)),
        Op::StoreField { src, base, f, copy } => g!("StoreField",
            "src" => src.to_string(), "base" => base.to_string(),
            "f" => f.to_string(), "copy" => dbg(copy)),
        Op::StoreFbField { src, base, f, copy } => g!("StoreFbField",
            "src" => src.to_string(), "base" => base.to_string(),
            "f" => f.to_string(), "copy" => dbg(copy)),
        Op::StoreIdx { src, base, idx, len, kind, line } => g!("StoreIdx",
            "src" => src.to_string(), "base" => base.to_string(),
            "idx" => idx.to_string(), "len" => len.to_string(),
            "kind" => dbg(kind), "line" => line.to_string()),
        Op::StorePtr { src, p, off, kind, line } => g!("StorePtr",
            "src" => src.to_string(), "p" => p.to_string(),
            "off" => off.to_string(), "kind" => dbg(kind),
            "line" => line.to_string()),
        Op::Jump { t } => g!("Jump", "t" => t.to_string()),
        Op::JumpIfFalse { c, t } => g!("JumpIfFalse",
            "c" => c.to_string(), "t" => t.to_string()),
        Op::BumpBranch => g!("BumpBranch"),
        Op::CaseJump { src, ranges, t } => g!("CaseJump",
            "src" => src.to_string(), "ranges" => range_list(ranges),
            "t" => t.to_string()),
        Op::ForCheck { i, to, step, exit } => g!("ForCheck",
            "i" => i.to_string(), "to" => to.to_string(),
            "step" => step.to_string(), "exit" => exit.to_string()),
        Op::ForIncr { i, step } => g!("ForIncr",
            "i" => i.to_string(), "step" => step.to_string()),
        Op::ForStepCheck { step } => {
            g!("ForStepCheck", "step" => step.to_string())
        }
        Op::Ret => g!("Ret"),
        Op::FusedForHead { i, to, step, var, exit } => g!("FusedForHead",
            "i" => i.to_string(), "to" => to.to_string(),
            "step" => step.to_string(), "var" => var.to_string(),
            "exit" => exit.to_string()),
        Op::FusedForIncrJump { i, step, t } => g!("FusedForIncrJump",
            "i" => i.to_string(), "step" => step.to_string(),
            "t" => t.to_string()),
        Op::FusedDotStep { s, pw, px, i, l1, l2 } => g!("FusedDotStep",
            "s" => s.to_string(), "pw" => pw.to_string(),
            "px" => px.to_string(), "i" => i.to_string(),
            "l1" => l1.to_string(), "l2" => l2.to_string()),
        Op::FusedMacStep { s, a, p, i, line } => g!("FusedMacStep",
            "s" => s.to_string(), "a" => a.to_string(),
            "p" => p.to_string(), "i" => i.to_string(),
            "line" => line.to_string()),
        Op::FusedMacLoad { dst, p, a, b, b_self, c, line } => {
            g!("FusedMacLoad",
                "dst" => dst.to_string(), "p" => p.to_string(),
                "a" => a.to_string(), "b" => b.to_string(),
                "b_self" => b_self.to_string(), "c" => c.to_string(),
                "line" => line.to_string())
        }
        Op::FusedIfCmpF32Br { slot, k, op, t } => g!("FusedIfCmpF32Br",
            "slot" => slot.to_string(), "k" => f32_bits(*k),
            "op" => dbg(op), "t" => t.to_string()),
        Op::ConstPool { dst, idx } => g!("ConstPool",
            "dst" => dst.to_string(), "idx" => idx.to_string()),
    }
}

fn needs_quoting(v: &str) -> bool {
    v.is_empty()
        || v.chars()
            .any(|c| c.is_whitespace() || c == '\'' || c == '\\')
}

fn quote(v: &str) -> String {
    let mut q = String::from("'");
    for c in v.chars() {
        match c {
            '\\' => q.push_str("\\\\"),
            '\'' => q.push_str("\\'"),
            '\n' => q.push_str("\\n"),
            c => q.push(c),
        }
    }
    q.push('\'');
    q
}

/// Render a generic op as one listing line.
pub fn render(op: &GenericOp) -> String {
    let mut out = op.name.clone();
    for (k, v) in &op.fields {
        out.push(' ');
        out.push_str(k);
        out.push('=');
        if needs_quoting(v) {
            out.push_str(&quote(v));
        } else {
            out.push_str(v);
        }
    }
    out
}

/// Parse one listing line back into its generic form — the exact
/// inverse of [`render`].
pub fn parse_line(line: &str) -> Result<GenericOp, String> {
    let mut chars = line.trim().chars().peekable();
    let mut name = String::new();
    while let Some(&c) = chars.peek() {
        if c.is_whitespace() {
            break;
        }
        name.push(c);
        chars.next();
    }
    if name.is_empty() {
        return Err("empty line".into());
    }
    let mut fields = Vec::new();
    loop {
        while matches!(chars.peek(), Some(c) if c.is_whitespace()) {
            chars.next();
        }
        if chars.peek().is_none() {
            break;
        }
        let mut key = String::new();
        while let Some(&c) = chars.peek() {
            if c == '=' {
                break;
            }
            if c.is_whitespace() {
                return Err(format!("key `{key}` without value"));
            }
            key.push(c);
            chars.next();
        }
        if chars.next() != Some('=') {
            return Err(format!("key `{key}` without `=`"));
        }
        let mut val = String::new();
        if chars.peek() == Some(&'\'') {
            chars.next();
            loop {
                match chars.next() {
                    Some('\\') => match chars.next() {
                        Some('\\') => val.push('\\'),
                        Some('\'') => val.push('\''),
                        Some('n') => val.push('\n'),
                        other => return Err(format!("bad escape {other:?}")),
                    },
                    Some('\'') => break,
                    Some(c) => val.push(c),
                    None => return Err("unterminated string".into()),
                }
            }
        } else {
            while let Some(&c) = chars.peek() {
                if c.is_whitespace() {
                    break;
                }
                val.push(c);
                chars.next();
            }
        }
        fields.push((key, val));
    }
    Ok(GenericOp { name, fields })
}

fn render_konst(k: &Konst) -> String {
    match k {
        Konst::Int(v) => format!("int {v}"),
        Konst::F32(v) => format!("f32 {}", f32_bits(*v)),
        Konst::F64(v) => format!("f64 {}", f64_bits(*v)),
        Konst::Str(s) => format!("str {}", quote(s)),
    }
}

/// Disassemble one compiled body: a `; code` header, the constant
/// pool, then one line per op.
pub fn disasm_code(code: &Code) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "; code {} regs={} ops={}",
        code.name,
        code.n_regs,
        code.ops.len()
    );
    for (i, k) in code.pool.iter().enumerate() {
        let _ = writeln!(out, "k{i}: {}", render_konst(k));
    }
    for (pc, op) in code.ops.iter().enumerate() {
        let _ = writeln!(out, "{pc}: {}", render(&op_to_generic(op)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::st::bytecode::{compile_unit, CopyMode};
    use std::sync::Arc;

    #[test]
    fn ops_round_trip_over_a_compiled_program() {
        let unit = crate::st::compile(
            "FUNCTION DOT : REAL\n\
             VAR_INPUT pa : POINTER TO REAL; pb : POINTER TO REAL; n : DINT; END_VAR\n\
             VAR s : REAL; i : DINT; END_VAR\n\
             FOR i := 0 TO n - 1 DO s := s + pa[i] * pb[i]; END_FOR\n\
             DOT := s;\n\
             END_FUNCTION\n\
             PROGRAM p VAR a, b : ARRAY[0..7] OF REAL; r : REAL; x : DINT; END_VAR\n\
             CASE x OF 0..4: r := 1.0; 7: r := 2.0; ELSE r := 0.5; END_CASE\n\
             r := r + DOT(ADR(a), ADR(b), 8);\n\
             END_PROGRAM",
        )
        .expect("compile");
        let cu = compile_unit(&unit);
        let mut seen = 0;
        for code in cu.all_codes() {
            for op in &code.ops {
                let g = op_to_generic(op);
                let line = render(&g);
                let back = parse_line(&line)
                    .unwrap_or_else(|e| panic!("parse `{line}`: {e}"));
                assert_eq!(back, g, "round-trip failed for `{line}`");
                seen += 1;
            }
        }
        assert!(seen > 30, "corpus too small ({seen} ops)");
    }

    #[test]
    fn hostile_string_constants_round_trip() {
        let op = Op::ConstStr {
            dst: 3,
            v: Arc::from("a b\\c'd\ne"),
        };
        let g = op_to_generic(&op);
        let line = render(&g);
        assert_eq!(parse_line(&line).unwrap(), g);
        // Store-mode enums and empty arg lists render unambiguously.
        let g2 = op_to_generic(&Op::StoreLocal {
            src: 1,
            slot: 0,
            copy: CopyMode::Auto,
        });
        assert_eq!(parse_line(&render(&g2)).unwrap(), g2);
    }

    #[test]
    fn disasm_code_lists_header_pool_and_every_op() {
        let unit = crate::st::compile(
            "PROGRAM p VAR x : REAL; END_VAR x := 1.5 + 1.5; END_PROGRAM",
        )
        .expect("compile");
        let cu = compile_unit(&unit);
        let code = &cu.programs[0];
        let text = disasm_code(code);
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].starts_with("; code p "));
        assert_eq!(lines.len(), 1 + code.pool.len() + code.ops.len());
        // Every op line parses back.
        for line in &lines[1 + code.pool.len()..] {
            let body = line.split_once(": ").unwrap().1;
            parse_line(body).unwrap();
        }
    }
}
