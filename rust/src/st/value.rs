//! Runtime values for the ST interpreter.
//!
//! Type tags are erased at runtime — [`super::lower`] guarantees all
//! operations are applied to matching representations. Integer types of
//! every IEC width share `i64` storage; width semantics (wrapping,
//! SIZEOF) are applied by explicit IR conversion nodes.
//!
//! Arrays use `Rc<RefCell<…>>` handles: **assignment deep-copies**
//! (ST value semantics, metered) while `VAR_IN_OUT` parameters and
//! POINTER values share the handle (ST reference semantics).

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// A runtime value.
#[derive(Debug, Clone)]
pub enum Value {
    Bool(bool),
    /// All integer widths (SINT..ULINT, BYTE..LWORD).
    Int(i64),
    /// REAL (IEC 32-bit float).
    Real(f32),
    /// LREAL (IEC 64-bit float).
    LReal(f64),
    /// Strings are immutable in the supported subset, so the handle is
    /// `Arc` — it lives inside the (shared, `Send + Sync`) compiled
    /// [`super::ir::Unit`] as well as in runtime state.
    Str(Arc<str>),
    ArrF32(Rc<RefCell<Vec<f32>>>),
    ArrF64(Rc<RefCell<Vec<f64>>>),
    ArrInt(Rc<RefCell<Vec<i64>>>),
    /// Arrays of interface/FB references (e.g. `ARRAY OF ILayer`).
    ArrRef(Rc<RefCell<Vec<Value>>>),
    /// Struct value: ordered field storage.
    Struct(Rc<RefCell<Vec<Value>>>),
    /// Handle to a function-block instance in the interpreter arena.
    FbRef(usize),
    /// POINTER TO REAL (+element offset) — created by ADR().
    PtrF32(Rc<RefCell<Vec<f32>>>, usize),
    PtrF64(Rc<RefCell<Vec<f64>>>, usize),
    PtrInt(Rc<RefCell<Vec<i64>>>, usize),
    /// Uninitialized interface/pointer value.
    Null,
}

impl Value {
    /// Deep copy with ST value semantics: arrays and structs are cloned
    /// element-wise; pointers and FB references copy the handle (they
    /// *are* references in ST).
    pub fn deep_clone(&self) -> Value {
        match self {
            Value::ArrF32(a) => {
                Value::ArrF32(Rc::new(RefCell::new(a.borrow().clone())))
            }
            Value::ArrF64(a) => {
                Value::ArrF64(Rc::new(RefCell::new(a.borrow().clone())))
            }
            Value::ArrInt(a) => {
                Value::ArrInt(Rc::new(RefCell::new(a.borrow().clone())))
            }
            Value::ArrRef(a) => Value::ArrRef(Rc::new(RefCell::new(
                a.borrow().iter().map(Value::deep_clone).collect(),
            ))),
            Value::Struct(s) => Value::Struct(Rc::new(RefCell::new(
                s.borrow().iter().map(Value::deep_clone).collect(),
            ))),
            other => other.clone(),
        }
    }

    /// True for values with ST *value semantics* on assignment and
    /// call-by-value (arrays and structs — deep-copied and metered);
    /// false for scalars and reference-like values (pointers, FB
    /// references). The single source of truth for every copy-or-move
    /// decision in both execution tiers.
    #[inline]
    pub fn is_aggregate(&self) -> bool {
        matches!(
            self,
            Value::ArrF32(_)
                | Value::ArrF64(_)
                | Value::ArrInt(_)
                | Value::ArrRef(_)
                | Value::Struct(_)
        )
    }

    /// Byte size of the payload (used to meter VAR_INPUT copies).
    pub fn byte_size(&self) -> u64 {
        match self {
            Value::Bool(_) => 1,
            Value::Int(_) => 8,
            Value::Real(_) => 4,
            Value::LReal(_) => 8,
            Value::Str(s) => s.len() as u64,
            Value::ArrF32(a) => 4 * a.borrow().len() as u64,
            Value::ArrF64(a) => 8 * a.borrow().len() as u64,
            Value::ArrInt(a) => 8 * a.borrow().len() as u64,
            Value::ArrRef(a) => 8 * a.borrow().len() as u64,
            Value::Struct(s) => {
                s.borrow().iter().map(Value::byte_size).sum()
            }
            Value::FbRef(_)
            | Value::PtrF32(..)
            | Value::PtrF64(..)
            | Value::PtrInt(..) => 8,
            Value::Null => 8,
        }
    }

    /// Structural, bit-exact equality: floats compare by bit pattern
    /// (NaN == NaN, 0.0 != -0.0), aggregates compare element-wise, and
    /// pointers compare (offset, pointed-to contents). Used by the
    /// interpreter-vs-VM differential harness, where "the same program
    /// state" must mean the same bits, not approximately equal floats.
    pub fn bits_eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Real(a), Value::Real(b)) => a.to_bits() == b.to_bits(),
            (Value::LReal(a), Value::LReal(b)) => a.to_bits() == b.to_bits(),
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::ArrF32(a), Value::ArrF32(b)) => {
                let (a, b) = (a.borrow(), b.borrow());
                a.len() == b.len()
                    && a.iter().zip(b.iter()).all(|(x, y)| {
                        x.to_bits() == y.to_bits()
                    })
            }
            (Value::ArrF64(a), Value::ArrF64(b)) => {
                let (a, b) = (a.borrow(), b.borrow());
                a.len() == b.len()
                    && a.iter().zip(b.iter()).all(|(x, y)| {
                        x.to_bits() == y.to_bits()
                    })
            }
            (Value::ArrInt(a), Value::ArrInt(b)) => *a.borrow() == *b.borrow(),
            (Value::ArrRef(a), Value::ArrRef(b))
            | (Value::Struct(a), Value::Struct(b)) => {
                let (a, b) = (a.borrow(), b.borrow());
                a.len() == b.len()
                    && a.iter().zip(b.iter()).all(|(x, y)| x.bits_eq(y))
            }
            (Value::FbRef(a), Value::FbRef(b)) => a == b,
            (Value::PtrF32(a, ao), Value::PtrF32(b, bo)) => {
                ao == bo
                    && a.borrow().len() == b.borrow().len()
                    && a.borrow()
                        .iter()
                        .zip(b.borrow().iter())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            }
            (Value::PtrF64(a, ao), Value::PtrF64(b, bo)) => {
                ao == bo
                    && a.borrow().len() == b.borrow().len()
                    && a.borrow()
                        .iter()
                        .zip(b.borrow().iter())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            }
            (Value::PtrInt(a, ao), Value::PtrInt(b, bo)) => {
                ao == bo && *a.borrow() == *b.borrow()
            }
            (Value::Null, Value::Null) => true,
            _ => false,
        }
    }

    // ------------------------------------------------- typed accessors
    // (sema guarantees these never fail on checked programs; the
    // panics indicate an interpreter bug, not a user error)
    #[inline]
    pub fn bool(&self) -> bool {
        match self {
            Value::Bool(b) => *b,
            other => panic!("expected BOOL, got {other:?}"),
        }
    }

    #[inline]
    pub fn int(&self) -> i64 {
        match self {
            Value::Int(v) => *v,
            other => panic!("expected INT, got {other:?}"),
        }
    }

    #[inline]
    pub fn real(&self) -> f32 {
        match self {
            Value::Real(v) => *v,
            other => panic!("expected REAL, got {other:?}"),
        }
    }

    #[inline]
    pub fn lreal(&self) -> f64 {
        match self {
            Value::LReal(v) => *v,
            other => panic!("expected LREAL, got {other:?}"),
        }
    }

    #[inline]
    pub fn arr_f32(&self) -> &Rc<RefCell<Vec<f32>>> {
        match self {
            Value::ArrF32(a) => a,
            other => panic!("expected ARRAY OF REAL, got {other:?}"),
        }
    }

    #[inline]
    pub fn arr_int(&self) -> &Rc<RefCell<Vec<i64>>> {
        match self {
            Value::ArrInt(a) => a,
            other => panic!("expected integer array, got {other:?}"),
        }
    }
}

/// A `Send + Sync` initial-value template for a declaration.
///
/// [`Value`] handles aggregates through `Rc<RefCell<…>>`, which pins a
/// compiled unit to one thread. Initializers never alias (every
/// frame/instance creation materializes a fresh copy), so the compiled
/// [`super::ir::Unit`] stores this plain-data mirror instead and both
/// execution tiers call [`Init::to_value`] where they previously
/// deep-cloned a template `Value`. This is what makes a compiled unit
/// shareable across threads (`Arc<Unit>` behind the ST backend).
#[derive(Debug, Clone, PartialEq)]
pub enum Init {
    Bool(bool),
    Int(i64),
    Real(f32),
    LReal(f64),
    Str(Arc<str>),
    ArrF32(Vec<f32>),
    ArrF64(Vec<f64>),
    ArrInt(Vec<i64>),
    ArrRef(Vec<Init>),
    Struct(Vec<Init>),
    Null,
}

impl Init {
    /// Materialize a fresh runtime value (the moral equivalent of
    /// `template.deep_clone()` on the old `Value` templates: every call
    /// yields detached storage).
    pub fn to_value(&self) -> Value {
        match self {
            Init::Bool(b) => Value::Bool(*b),
            Init::Int(v) => Value::Int(*v),
            Init::Real(v) => Value::Real(*v),
            Init::LReal(v) => Value::LReal(*v),
            Init::Str(s) => Value::Str(s.clone()),
            Init::ArrF32(v) => {
                Value::ArrF32(Rc::new(RefCell::new(v.clone())))
            }
            Init::ArrF64(v) => {
                Value::ArrF64(Rc::new(RefCell::new(v.clone())))
            }
            Init::ArrInt(v) => {
                Value::ArrInt(Rc::new(RefCell::new(v.clone())))
            }
            Init::ArrRef(v) => Value::ArrRef(Rc::new(RefCell::new(
                v.iter().map(Init::to_value).collect(),
            ))),
            Init::Struct(v) => Value::Struct(Rc::new(RefCell::new(
                v.iter().map(Init::to_value).collect(),
            ))),
            Init::Null => Value::Null,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_to_value_detaches_storage() {
        let init = Init::ArrF32(vec![1.0, 2.0]);
        let a = init.to_value();
        let b = init.to_value();
        if let (Value::ArrF32(ra), Value::ArrF32(rb)) = (&a, &b) {
            ra.borrow_mut()[0] = 9.0;
            assert_eq!(rb.borrow()[0], 1.0, "instances must not alias");
        } else {
            unreachable!()
        }
    }

    #[test]
    fn deep_clone_detaches_arrays() {
        let a = Value::ArrF32(Rc::new(RefCell::new(vec![1.0, 2.0])));
        let b = a.deep_clone();
        if let (Value::ArrF32(ra), Value::ArrF32(rb)) = (&a, &b) {
            ra.borrow_mut()[0] = 9.0;
            assert_eq!(rb.borrow()[0], 1.0);
        } else {
            unreachable!()
        }
    }

    #[test]
    fn deep_clone_shares_pointers() {
        let backing = Rc::new(RefCell::new(vec![1.0f32]));
        let p = Value::PtrF32(backing.clone(), 0);
        let q = p.deep_clone();
        backing.borrow_mut()[0] = 5.0;
        if let Value::PtrF32(rb, _) = q {
            assert_eq!(rb.borrow()[0], 5.0);
        } else {
            unreachable!()
        }
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(Value::Real(0.0).byte_size(), 4);
        let a = Value::ArrF32(Rc::new(RefCell::new(vec![0.0; 10])));
        assert_eq!(a.byte_size(), 40);
        let s = Value::Struct(Rc::new(RefCell::new(vec![
            Value::Real(0.0),
            Value::Int(0),
        ])));
        assert_eq!(s.byte_size(), 12);
    }
}
