//! Pure-math builtin implementations shared by the interpreter.
//!
//! Kept separate so the native engine can reuse the exact IEC semantics
//! (e.g. REAL_TO_INT round-half-away-from-zero) when cross-validating.

use super::ir::IntTy;

/// IEC REAL->ANY_INT conversion: round to nearest, half away from zero
/// (what Codesys implements), then wrap to the target width.
#[inline]
pub fn real_to_int(v: f64, ty: IntTy) -> i64 {
    let r = if v >= 0.0 { (v + 0.5).floor() } else { (v - 0.5).ceil() };
    ty.wrap(r as i64)
}

/// TRUNC: toward zero.
#[inline]
pub fn trunc_to_int(v: f64) -> i64 {
    v.trunc() as i64
}

/// FLOOR: toward negative infinity.
#[inline]
pub fn floor_to_int(v: f64) -> i64 {
    v.floor() as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_to_int_rounds_half_away() {
        assert_eq!(real_to_int(2.5, IntTy::Dint), 3);
        assert_eq!(real_to_int(-2.5, IntTy::Dint), -3);
        assert_eq!(real_to_int(2.4, IntTy::Dint), 2);
        assert_eq!(real_to_int(-2.4, IntTy::Dint), -2);
    }

    #[test]
    fn real_to_int_wraps_width() {
        assert_eq!(real_to_int(200.0, IntTy::Sint), IntTy::Sint.wrap(200));
    }

    #[test]
    fn trunc_and_floor() {
        assert_eq!(trunc_to_int(2.9), 2);
        assert_eq!(trunc_to_int(-2.9), -2);
        assert_eq!(floor_to_int(-2.1), -3);
    }
}
