//! Pure-math builtin implementations shared by the interpreter.
//!
//! Kept separate so the native engine can reuse the exact IEC semantics
//! (e.g. REAL_TO_INT round-half-away-from-zero) when cross-validating,
//! and so the two execution tiers (tree-walking [`super::interp::Interp`]
//! and the bytecode [`super::vm::Vm`]) share one implementation of the
//! intrinsic and file-I/O operations — meter-for-meter.

use std::path::Path;

use super::cost::Meter;
use super::interp::{rerr, RuntimeError};
use super::ir::{Builtin, IntTy, NumKind};
use super::value::Value;

/// IEC REAL->ANY_INT conversion: round to nearest, half away from zero
/// (what Codesys implements), then wrap to the target width.
#[inline]
pub fn real_to_int(v: f64, ty: IntTy) -> i64 {
    let r = if v >= 0.0 { (v + 0.5).floor() } else { (v - 0.5).ceil() };
    ty.wrap(r as i64)
}

/// TRUNC: toward zero.
#[inline]
pub fn trunc_to_int(v: f64) -> i64 {
    v.trunc() as i64
}

/// FLOOR: toward negative infinity.
#[inline]
pub fn floor_to_int(v: f64) -> i64 {
    v.floor() as i64
}

/// Execute a pure (non-I/O) intrinsic over already-evaluated argument
/// values, metering exactly what the tree-walker meters. Shared by
/// `Interp::intrinsic` and the VM's `Intrinsic` opcode so the two tiers
/// cannot drift.
///
/// `BinArr`/`ArrBin` are not pure — route them to [`exec_file_io`].
pub(crate) fn eval_intrinsic(
    meter: &mut Meter,
    b: Builtin,
    kind: NumKind,
    vals: &[Value],
) -> Value {
    let as_f64 = |v: &Value| match kind {
        NumKind::F32 => v.real() as f64,
        NumKind::F64 => v.lreal(),
        NumKind::Int => v.int() as f64,
    };
    let wrap = |x: f64| match kind {
        NumKind::F32 => Value::Real(x as f32),
        NumKind::F64 => Value::LReal(x),
        NumKind::Int => Value::Int(x as i64),
    };
    match b {
        Builtin::Abs => {
            meter.int_ops += 1;
            match kind {
                NumKind::Int => Value::Int(vals[0].int().abs()),
                _ => wrap(as_f64(&vals[0]).abs()),
            }
        }
        Builtin::Sqrt => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).sqrt())
        }
        Builtin::Exp => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).exp())
        }
        Builtin::Ln => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).ln())
        }
        Builtin::Log => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).log10())
        }
        Builtin::Sin => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).sin())
        }
        Builtin::Cos => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).cos())
        }
        Builtin::Tan => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).tan())
        }
        Builtin::Atan => {
            meter.fp_trans += 1;
            wrap(as_f64(&vals[0]).atan())
        }
        Builtin::Min => {
            meter.cmp += 1;
            match kind {
                NumKind::Int => Value::Int(vals[0].int().min(vals[1].int())),
                _ => wrap(as_f64(&vals[0]).min(as_f64(&vals[1]))),
            }
        }
        Builtin::Max => {
            meter.cmp += 1;
            match kind {
                NumKind::Int => Value::Int(vals[0].int().max(vals[1].int())),
                _ => wrap(as_f64(&vals[0]).max(as_f64(&vals[1]))),
            }
        }
        Builtin::Limit => {
            meter.cmp += 2;
            match kind {
                NumKind::Int => Value::Int(
                    vals[1].int().clamp(vals[0].int(), vals[2].int()),
                ),
                _ => wrap(
                    as_f64(&vals[1]).clamp(as_f64(&vals[0]), as_f64(&vals[2])),
                ),
            }
        }
        Builtin::Trunc => {
            meter.converts += 1;
            Value::Int(trunc_to_int(as_f64(&vals[0])))
        }
        Builtin::Floor => {
            meter.converts += 1;
            Value::Int(floor_to_int(as_f64(&vals[0])))
        }
        Builtin::BinArr | Builtin::ArrBin => {
            unreachable!("file I/O routed through exec_file_io")
        }
    }
}

/// BINARR / ARRBIN over already-evaluated operands: the framework's
/// binary file I/O. `bytes` is the requested byte count, `ptr` the
/// destination (BINARR) or source (ARRBIN) pointer, `elem_bytes` the
/// element width for integer arrays. Shared by both execution tiers.
pub(crate) fn exec_file_io(
    meter: &mut Meter,
    io_dir: &Path,
    b: Builtin,
    fname: &str,
    bytes: i64,
    ptr: &Value,
    elem_bytes: usize,
    line: u32,
) -> Result<Value, RuntimeError> {
    if bytes < 0 {
        return Err(rerr(line, "negative byte count"));
    }
    let bytes = bytes as usize;
    let path = io_dir.join(fname);
    meter.io_calls += 1;
    meter.io_bytes += bytes as u64;
    let n = bytes / elem_bytes;

    match (b, ptr) {
        (Builtin::BinArr, Value::PtrF32(a, off)) => {
            let data = std::fs::read(&path).map_err(|e| {
                rerr(line, format!("BINARR {}: {e}", path.display()))
            })?;
            if data.len() < bytes {
                return Err(rerr(line, "BINARR: file smaller than requested"));
            }
            let mut arr = a.borrow_mut();
            if off + n > arr.len() {
                return Err(rerr(line, "BINARR: destination overflow"));
            }
            for (i, c) in data[..bytes].chunks_exact(4).enumerate() {
                arr[off + i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            Ok(Value::Bool(true))
        }
        (Builtin::BinArr, Value::PtrInt(a, off)) => {
            let data = std::fs::read(&path).map_err(|e| {
                rerr(line, format!("BINARR {}: {e}", path.display()))
            })?;
            if data.len() < bytes {
                return Err(rerr(line, "BINARR: file smaller than requested"));
            }
            let mut arr = a.borrow_mut();
            if off + n > arr.len() {
                return Err(rerr(line, "BINARR: destination overflow"));
            }
            for i in 0..n {
                let chunk = &data[i * elem_bytes..(i + 1) * elem_bytes];
                arr[off + i] = match elem_bytes {
                    1 => chunk[0] as i8 as i64,
                    2 => i16::from_le_bytes([chunk[0], chunk[1]]) as i64,
                    4 => i32::from_le_bytes([
                        chunk[0], chunk[1], chunk[2], chunk[3],
                    ]) as i64,
                    8 => i64::from_le_bytes(chunk.try_into().unwrap()),
                    _ => return Err(rerr(line, "bad element width")),
                };
            }
            Ok(Value::Bool(true))
        }
        (Builtin::ArrBin, Value::PtrF32(a, off)) => {
            let arr = a.borrow();
            if off + n > arr.len() {
                return Err(rerr(line, "ARRBIN: source overflow"));
            }
            let mut out = Vec::with_capacity(bytes);
            for i in 0..n {
                out.extend_from_slice(&arr[off + i].to_le_bytes());
            }
            std::fs::write(&path, out).map_err(|e| {
                rerr(line, format!("ARRBIN {}: {e}", path.display()))
            })?;
            Ok(Value::Bool(true))
        }
        (Builtin::ArrBin, Value::PtrInt(a, off)) => {
            let arr = a.borrow();
            if off + n > arr.len() {
                return Err(rerr(line, "ARRBIN: source overflow"));
            }
            let mut out = Vec::with_capacity(bytes);
            for i in 0..n {
                let v = arr[off + i];
                match elem_bytes {
                    1 => out.push(v as u8),
                    2 => out.extend_from_slice(&(v as i16).to_le_bytes()),
                    4 => out.extend_from_slice(&(v as i32).to_le_bytes()),
                    8 => out.extend_from_slice(&v.to_le_bytes()),
                    _ => return Err(rerr(line, "bad element width")),
                }
            }
            std::fs::write(&path, out).map_err(|e| {
                rerr(line, format!("ARRBIN {}: {e}", path.display()))
            })?;
            Ok(Value::Bool(true))
        }
        (_, Value::Null) => Err(rerr(line, "null pointer in file I/O")),
        _ => Err(rerr(line, "unsupported pointer kind in file I/O")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_to_int_rounds_half_away() {
        assert_eq!(real_to_int(2.5, IntTy::Dint), 3);
        assert_eq!(real_to_int(-2.5, IntTy::Dint), -3);
        assert_eq!(real_to_int(2.4, IntTy::Dint), 2);
        assert_eq!(real_to_int(-2.4, IntTy::Dint), -2);
    }

    #[test]
    fn real_to_int_wraps_width() {
        assert_eq!(real_to_int(200.0, IntTy::Sint), IntTy::Sint.wrap(200));
    }

    #[test]
    fn trunc_and_floor() {
        assert_eq!(trunc_to_int(2.9), 2);
        assert_eq!(trunc_to_int(-2.9), -2);
        assert_eq!(floor_to_int(-2.1), -3);
    }
}
