//! Shared runtime state + host API of the ST execution tiers.
//!
//! [`Host`] owns everything both tiers ([`super::Interp`],
//! [`super::Vm`]) load at instantiation time — globals, the FB-instance
//! arena, program-instance handles, the cost [`Meter`], the file-I/O
//! base dir — together with the by-name accessors the embedding host
//! uses (`program_instance`, `instance_field`, `global`, …). The tiers
//! embed one `Host` and `Deref` to it, so name resolution has a single
//! implementation and cannot drift between tiers (it used to be
//! duplicated in `interp.rs` and `vm.rs`).
//!
//! [`HostImage`] is a `Send + Sync` snapshot of a `Host`: runtime
//! values use `Rc<RefCell<…>>` handles and are pinned to one thread,
//! but a snapshot flattens them into plain buffers (preserving aliasing
//! — two fields sharing one array, or a `POINTER` into a global, come
//! back sharing storage after [`Host::from_image`]). This is what lets
//! one immutable ST backend mint independent per-request sessions on
//! any thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use super::cost::Meter;
use super::interp::{rerr, RuntimeError};
use super::ir::{Ty, Unit};
use super::value::Value;

/// One live FB (or program) instance.
#[derive(Debug, Clone)]
pub struct FbInstance {
    /// FB type id, or `usize::MAX` for program instances.
    pub fb_id: usize,
    pub fields: Vec<Value>,
}

/// The load-time state + host API shared by both execution tiers.
pub struct Host {
    pub unit: Arc<Unit>,
    pub globals: Vec<Value>,
    pub instances: Vec<FbInstance>,
    /// Arena index of each program's instance (parallel to
    /// `unit.programs`).
    pub program_instances: Vec<usize>,
    pub meter: Meter,
    /// Base directory for BINARR/ARRBIN file access.
    pub io_dir: PathBuf,
}

impl Host {
    /// Instantiate a compiled unit: allocate globals, program
    /// instances, and every FB instance they declare. Allocation order
    /// (globals first, then per-program fields, nested FB fields
    /// allocated while their declaring field is instantiated) fixes
    /// the `FbRef` arena indices — both tiers and [`HostImage`] rely
    /// on it being deterministic.
    pub fn new(unit: Arc<Unit>) -> Host {
        let mut host = Host {
            unit: unit.clone(),
            globals: Vec::new(),
            instances: Vec::new(),
            program_instances: Vec::new(),
            meter: Meter::new(),
            io_dir: PathBuf::from("."),
        };
        for g in &unit.globals {
            let v = host.instantiate_value(&g.ty, &g.init);
            host.globals.push(v);
        }
        for p in &unit.programs {
            let fields: Vec<Value> = p
                .fields
                .iter()
                .map(|f| host.instantiate_value(&f.ty, &f.init))
                .collect();
            let idx = host.instances.len();
            host.instances.push(FbInstance { fb_id: usize::MAX, fields });
            host.program_instances.push(idx);
        }
        host
    }

    /// Create a runtime value; FB-typed declarations allocate an arena
    /// instance (recursively for the FB's own fields — which sema
    /// guarantees contain no further FBs).
    fn instantiate_value(
        &mut self,
        ty: &Ty,
        init: &super::value::Init,
    ) -> Value {
        if let Ty::Fb(fb_id) = ty {
            let fb = &self.unit.clone().fbs[*fb_id];
            let fields: Vec<Value> =
                fb.fields.iter().map(|f| f.init.to_value()).collect();
            let idx = self.instances.len();
            self.instances.push(FbInstance { fb_id: *fb_id, fields });
            return Value::FbRef(idx);
        }
        init.to_value()
    }

    // ------------------------------------------------------- host API
    pub fn program_instance(&self, name: &str) -> Option<usize> {
        let pid = self.unit.find_program(name)?;
        Some(self.program_instances[pid])
    }

    /// The unit's compiled §2.7 task model, when it declares a
    /// CONFIGURATION block.
    pub fn task_model(&self) -> Option<&super::tasks::TaskModel> {
        self.unit.tasks.as_ref()
    }

    /// Read a field of an arena instance by name (program VARs included).
    pub fn instance_field(&self, inst: usize, field: &str) -> Option<Value> {
        let fi = self.field_index(inst, field)?;
        Some(self.instances[inst].fields[fi].clone())
    }

    pub fn set_instance_field(
        &mut self,
        inst: usize,
        field: &str,
        value: Value,
    ) -> Result<(), RuntimeError> {
        let fi = self
            .field_index(inst, field)
            .ok_or_else(|| rerr(0, format!("no field {field}")))?;
        self.instances[inst].fields[fi] = value;
        Ok(())
    }

    fn field_index(&self, inst: usize, field: &str) -> Option<usize> {
        let i = &self.instances[inst];
        let defs = if i.fb_id == usize::MAX {
            let pid = self
                .program_instances
                .iter()
                .position(|&x| x == inst)?;
            &self.unit.programs[pid].fields
        } else {
            &self.unit.fbs[i.fb_id].fields
        };
        defs.iter().position(|f| f.name.eq_ignore_ascii_case(field))
    }

    pub fn global(&self, name: &str) -> Option<Value> {
        self.unit.find_global(name).map(|g| self.globals[g].clone())
    }

    pub fn set_global(&mut self, name: &str, value: Value) -> bool {
        match self.unit.find_global(name) {
            Some(g) => {
                self.globals[g] = value;
                true
            }
            None => false,
        }
    }

    // ------------------------------------------------------- snapshot
    /// Snapshot the full runtime state into a `Send + Sync` image.
    pub fn image(&self) -> HostImage {
        let mut enc = Encoder { map: HashMap::new(), bufs: Vec::new() };
        let globals: Vec<ImgValue> =
            self.globals.iter().map(|v| enc.value(v)).collect();
        let instances: Vec<(usize, Vec<ImgValue>)> = self
            .instances
            .iter()
            .map(|i| {
                (i.fb_id, i.fields.iter().map(|v| enc.value(v)).collect())
            })
            .collect();
        HostImage {
            unit: self.unit.clone(),
            globals,
            instances,
            program_instances: self.program_instances.clone(),
            meter: self.meter.clone(),
            io_dir: self.io_dir.clone(),
            bufs: enc.bufs,
        }
    }

    /// Rebuild a live `Host` from an image. Aliasing among the image's
    /// values (shared arrays, pointers into them) is restored exactly;
    /// floats come back bit-identical.
    pub fn from_image(img: &HostImage) -> Host {
        let mut dec =
            Decoder { built: vec![None; img.bufs.len()], bufs: &img.bufs };
        let globals: Vec<Value> =
            img.globals.iter().map(|v| dec.value(v)).collect();
        let instances: Vec<FbInstance> = img
            .instances
            .iter()
            .map(|(fb_id, fields)| FbInstance {
                fb_id: *fb_id,
                fields: fields.iter().map(|v| dec.value(v)).collect(),
            })
            .collect();
        Host {
            unit: img.unit.clone(),
            globals,
            instances,
            program_instances: img.program_instances.clone(),
            meter: img.meter.clone(),
            io_dir: img.io_dir.clone(),
        }
    }
}

/// A `Send + Sync` snapshot of a [`Host`] (compiled unit + flattened
/// runtime state). Cheap to restore: one pass over the value graph,
/// one buffer clone per distinct array/struct.
#[derive(Debug, Clone)]
pub struct HostImage {
    unit: Arc<Unit>,
    globals: Vec<ImgValue>,
    instances: Vec<(usize, Vec<ImgValue>)>,
    program_instances: Vec<usize>,
    meter: Meter,
    io_dir: PathBuf,
    bufs: Vec<ImgBuf>,
}

impl HostImage {
    pub fn unit(&self) -> &Arc<Unit> {
        &self.unit
    }

    pub fn io_dir(&self) -> &PathBuf {
        &self.io_dir
    }
}

/// Flattened value: aggregates refer to [`ImgBuf`]s by index, so
/// aliasing survives the round trip.
#[derive(Debug, Clone)]
enum ImgValue {
    Bool(bool),
    Int(i64),
    Real(f32),
    LReal(f64),
    Str(Arc<str>),
    ArrF32(usize),
    ArrF64(usize),
    ArrInt(usize),
    ArrRef(usize),
    Struct(usize),
    FbRef(usize),
    PtrF32(usize, usize),
    PtrF64(usize, usize),
    PtrInt(usize, usize),
    Null,
}

/// One distinct heap buffer of the snapshotted state.
#[derive(Debug, Clone)]
enum ImgBuf {
    F32(Vec<f32>),
    F64(Vec<f64>),
    Int(Vec<i64>),
    Vals(Vec<ImgValue>),
}

struct Encoder {
    /// `Rc` allocation address -> buffer id (the aliasing map).
    map: HashMap<usize, usize>,
    bufs: Vec<ImgBuf>,
}

impl Encoder {
    fn value(&mut self, v: &Value) -> ImgValue {
        match v {
            Value::Bool(b) => ImgValue::Bool(*b),
            Value::Int(v) => ImgValue::Int(*v),
            Value::Real(v) => ImgValue::Real(*v),
            Value::LReal(v) => ImgValue::LReal(*v),
            Value::Str(s) => ImgValue::Str(s.clone()),
            Value::ArrF32(a) => ImgValue::ArrF32(self.buf_f32(a)),
            Value::ArrF64(a) => ImgValue::ArrF64(self.buf_f64(a)),
            Value::ArrInt(a) => ImgValue::ArrInt(self.buf_int(a)),
            Value::ArrRef(a) => ImgValue::ArrRef(self.buf_vals(a)),
            Value::Struct(s) => ImgValue::Struct(self.buf_vals(s)),
            Value::FbRef(h) => ImgValue::FbRef(*h),
            Value::PtrF32(a, o) => ImgValue::PtrF32(self.buf_f32(a), *o),
            Value::PtrF64(a, o) => ImgValue::PtrF64(self.buf_f64(a), *o),
            Value::PtrInt(a, o) => ImgValue::PtrInt(self.buf_int(a), *o),
            Value::Null => ImgValue::Null,
        }
    }

    fn buf_f32(&mut self, a: &Rc<RefCell<Vec<f32>>>) -> usize {
        let key = Rc::as_ptr(a) as usize;
        if let Some(&id) = self.map.get(&key) {
            return id;
        }
        let id = self.bufs.len();
        self.map.insert(key, id);
        self.bufs.push(ImgBuf::F32(a.borrow().clone()));
        id
    }

    fn buf_f64(&mut self, a: &Rc<RefCell<Vec<f64>>>) -> usize {
        let key = Rc::as_ptr(a) as usize;
        if let Some(&id) = self.map.get(&key) {
            return id;
        }
        let id = self.bufs.len();
        self.map.insert(key, id);
        self.bufs.push(ImgBuf::F64(a.borrow().clone()));
        id
    }

    fn buf_int(&mut self, a: &Rc<RefCell<Vec<i64>>>) -> usize {
        let key = Rc::as_ptr(a) as usize;
        if let Some(&id) = self.map.get(&key) {
            return id;
        }
        let id = self.bufs.len();
        self.map.insert(key, id);
        self.bufs.push(ImgBuf::Int(a.borrow().clone()));
        id
    }

    fn buf_vals(&mut self, a: &Rc<RefCell<Vec<Value>>>) -> usize {
        let key = Rc::as_ptr(a) as usize;
        if let Some(&id) = self.map.get(&key) {
            return id;
        }
        // Reserve the slot before recursing so a (hypothetical) cyclic
        // graph cannot re-enter and double-allocate the buffer.
        let id = self.bufs.len();
        self.map.insert(key, id);
        self.bufs.push(ImgBuf::Vals(Vec::new()));
        let vals: Vec<ImgValue> =
            a.borrow().iter().map(|v| self.value(v)).collect();
        self.bufs[id] = ImgBuf::Vals(vals);
        id
    }
}

/// A restored buffer handle (shared among every value that aliased the
/// original).
#[derive(Clone)]
enum BuiltBuf {
    F32(Rc<RefCell<Vec<f32>>>),
    F64(Rc<RefCell<Vec<f64>>>),
    Int(Rc<RefCell<Vec<i64>>>),
    Vals(Rc<RefCell<Vec<Value>>>),
}

struct Decoder<'a> {
    built: Vec<Option<BuiltBuf>>,
    bufs: &'a [ImgBuf],
}

impl Decoder<'_> {
    fn value(&mut self, v: &ImgValue) -> Value {
        match v {
            ImgValue::Bool(b) => Value::Bool(*b),
            ImgValue::Int(v) => Value::Int(*v),
            ImgValue::Real(v) => Value::Real(*v),
            ImgValue::LReal(v) => Value::LReal(*v),
            ImgValue::Str(s) => Value::Str(s.clone()),
            ImgValue::ArrF32(id) => Value::ArrF32(self.f32_buf(*id)),
            ImgValue::ArrF64(id) => Value::ArrF64(self.f64_buf(*id)),
            ImgValue::ArrInt(id) => Value::ArrInt(self.int_buf(*id)),
            ImgValue::ArrRef(id) => Value::ArrRef(self.vals_buf(*id)),
            ImgValue::Struct(id) => Value::Struct(self.vals_buf(*id)),
            ImgValue::FbRef(h) => Value::FbRef(*h),
            ImgValue::PtrF32(id, o) => Value::PtrF32(self.f32_buf(*id), *o),
            ImgValue::PtrF64(id, o) => Value::PtrF64(self.f64_buf(*id), *o),
            ImgValue::PtrInt(id, o) => Value::PtrInt(self.int_buf(*id), *o),
            ImgValue::Null => Value::Null,
        }
    }

    fn buf(&mut self, id: usize) -> BuiltBuf {
        if let Some(b) = &self.built[id] {
            return b.clone();
        }
        let built = match &self.bufs[id] {
            ImgBuf::F32(v) => {
                BuiltBuf::F32(Rc::new(RefCell::new(v.clone())))
            }
            ImgBuf::F64(v) => {
                BuiltBuf::F64(Rc::new(RefCell::new(v.clone())))
            }
            ImgBuf::Int(v) => {
                BuiltBuf::Int(Rc::new(RefCell::new(v.clone())))
            }
            ImgBuf::Vals(vs) => {
                // Publish the handle before recursing (cycle guard,
                // mirroring the encoder).
                let rc = Rc::new(RefCell::new(Vec::new()));
                self.built[id] = Some(BuiltBuf::Vals(rc.clone()));
                let vals: Vec<Value> =
                    vs.iter().map(|v| self.value(v)).collect();
                *rc.borrow_mut() = vals;
                return BuiltBuf::Vals(rc);
            }
        };
        self.built[id] = Some(built.clone());
        built
    }

    fn f32_buf(&mut self, id: usize) -> Rc<RefCell<Vec<f32>>> {
        match self.buf(id) {
            BuiltBuf::F32(rc) => rc,
            _ => unreachable!("image buffer {id} is not f32"),
        }
    }

    fn f64_buf(&mut self, id: usize) -> Rc<RefCell<Vec<f64>>> {
        match self.buf(id) {
            BuiltBuf::F64(rc) => rc,
            _ => unreachable!("image buffer {id} is not f64"),
        }
    }

    fn int_buf(&mut self, id: usize) -> Rc<RefCell<Vec<i64>>> {
        match self.buf(id) {
            BuiltBuf::Int(rc) => rc,
            _ => unreachable!("image buffer {id} is not int"),
        }
    }

    fn vals_buf(&mut self, id: usize) -> Rc<RefCell<Vec<Value>>> {
        match self.buf(id) {
            BuiltBuf::Vals(rc) => rc,
            _ => unreachable!("image buffer {id} is not a value vec"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(vals: &[f32]) -> Value {
        Value::ArrF32(Rc::new(RefCell::new(vals.to_vec())))
    }

    /// Snapshot/restore must preserve aliasing: a pointer into an
    /// array and a second handle to the same array keep sharing
    /// storage after the round trip.
    #[test]
    fn image_round_trip_preserves_aliasing() {
        let mut host = Host::new(Arc::new(Unit::default()));
        let shared = arr(&[1.0, 2.0, 3.0]);
        let ptr = match &shared {
            Value::ArrF32(a) => Value::PtrF32(a.clone(), 1),
            _ => unreachable!(),
        };
        host.globals = vec![shared, ptr, arr(&[9.0])];
        host.meter.loads = 42;

        let img = host.image();
        let restored = Host::from_image(&img);
        assert_eq!(restored.meter.loads, 42);
        let (a, p, b) = (
            restored.globals[0].clone(),
            restored.globals[1].clone(),
            restored.globals[2].clone(),
        );
        // Write through the pointer; the array handle must see it.
        match (&a, &p) {
            (Value::ArrF32(arr), Value::PtrF32(parr, off)) => {
                assert!(Rc::ptr_eq(arr, parr), "aliasing lost");
                assert_eq!(*off, 1);
                parr.borrow_mut()[1] = 7.5;
                assert_eq!(arr.borrow()[1], 7.5);
            }
            other => panic!("unexpected restored values: {other:?}"),
        }
        // The unrelated array is detached storage.
        match (&a, &b) {
            (Value::ArrF32(x), Value::ArrF32(y)) => {
                assert!(!Rc::ptr_eq(x, y));
            }
            _ => unreachable!(),
        }
    }

    /// Restoring twice yields independent states (the per-session
    /// guarantee behind the ST backend).
    #[test]
    fn restored_hosts_are_independent() {
        let mut host = Host::new(Arc::new(Unit::default()));
        host.globals = vec![arr(&[1.0, 2.0])];
        let img = host.image();
        let h1 = Host::from_image(&img);
        let h2 = Host::from_image(&img);
        match (&h1.globals[0], &h2.globals[0]) {
            (Value::ArrF32(a), Value::ArrF32(b)) => {
                a.borrow_mut()[0] = 100.0;
                assert_eq!(b.borrow()[0], 1.0, "sessions must not share");
            }
            _ => unreachable!(),
        }
    }

    /// Nested aggregates (structs holding pointers) round-trip with
    /// aliasing intact — the shape the ICSML `Memory` structs produce.
    #[test]
    fn struct_with_pointer_round_trips() {
        let backing = Rc::new(RefCell::new(vec![1.0f32, 2.0]));
        let st = Value::Struct(Rc::new(RefCell::new(vec![
            Value::PtrF32(backing.clone(), 0),
            Value::Int(2),
        ])));
        let mut host = Host::new(Arc::new(Unit::default()));
        host.globals = vec![Value::ArrF32(backing), st];
        let img = host.image();
        let r = Host::from_image(&img);
        match (&r.globals[0], &r.globals[1]) {
            (Value::ArrF32(arr), Value::Struct(s)) => {
                match &s.borrow()[0] {
                    Value::PtrF32(p, 0) => {
                        assert!(Rc::ptr_eq(arr, p), "struct ptr aliasing");
                    }
                    other => panic!("bad struct field: {other:?}"),
                }
            }
            _ => unreachable!(),
        }
    }
}
